"""Layer-2: Marvel's combine compute graphs (jax, calling L1 kernels).

These are the functions AOT-lowered to HLO text and executed by the Rust
coordinator's `runtime` module inside every map task. Shapes are fixed at
lowering time; the manifest (aot.py) records them so the Rust side can
build matching literals.

Partition/bucket scheme (must match rust/src/mapreduce/partition.rs):
  hashes are non-negative int32 (Rust masks the sign bit);
  bucket = h & (B - 1)          -- low bits
  part   = (h >> 10) & (R - 1)  -- bits above the bucket bits (B = 1024)
A combine output (R, B) ships at most R*B aggregates per batch instead of
N raw tokens — the kernel-level analog of the paper's "keep intermediate
data near compute" I/O reduction.
"""

import jax
import jax.numpy as jnp

from .kernels import grep_match, histogram, segsum

# Canonical lowering constants — mirrored in artifacts/manifest.json and
# rust/src/runtime/manifest.rs. B must stay 1024 while the partition shift
# below is 10.
TOKENS_PER_BATCH = 8192   # N
SMALL_BATCH = 1024        # N for the low-latency artifact variant
WORD_WIDTH = 16           # W
BUCKETS = 1024            # B (per partition)
PARTS = 32                # R (max reducers)
SEGMENTS = 1024           # S (aggregation query groups)
_PART_SHIFT = 10          # log2(BUCKETS)


def _flat_ids(hashes, parts: int, buckets: int):
    bucket = hashes & (buckets - 1)
    part = (hashes >> _PART_SHIFT) & (parts - 1)
    return part * buckets + bucket


def wordcount_combine(hashes, mask):
    """(N,) int32 hashes + (N,) f32 mask -> (R, B) f32 partitioned counts."""
    flat = _flat_ids(hashes, PARTS, BUCKETS)
    counts = histogram(flat, mask, bins=PARTS * BUCKETS)
    return (counts.reshape(PARTS, BUCKETS),)


def grep_combine(tokens, hashes, mask, pattern):
    """Match tokens vs pattern, then partitioned counts of the matches.

    tokens: (N, W) int32; hashes: (N,) int32; mask: (N,) f32;
    pattern: (W,) int32 with wildcard sentinels. Returns ((R, B) counts,
    (1,) total-match count).
    """
    m = grep_match(tokens, pattern) * mask
    flat = _flat_ids(hashes, PARTS, BUCKETS)
    counts = histogram(flat, m, bins=PARTS * BUCKETS)
    return counts.reshape(PARTS, BUCKETS), jnp.sum(m).reshape(1)


def agg_combine(seg_ids, values, mask):
    """GROUP-BY combine: (S,) sums and (S,) counts per group."""
    sums, cnts = segsum(seg_ids, values, mask, segments=SEGMENTS)
    return sums, cnts


# --- CPU-specialized variants -----------------------------------------
#
# The Pallas kernels above are tiled for the TPU MXU; under
# ``interpret=True`` on CPU-PJRT the grid machinery costs ~40 ms per
# batch (measured; EXPERIMENTS.md §Perf). These variants lower the SAME
# math through XLA scatter-add (segment_sum), which the CPU backend
# executes in microseconds. aot.py ships both; the Rust runtime picks
# the ``*_cpu`` artifact on CPU-PJRT and the Pallas one is kept as the
# TPU-shaped reference (validated against ref.py either way).

def _segment_sum(weights, ids, bins):
    return jax.ops.segment_sum(weights, ids, num_segments=bins)


def wordcount_combine_cpu(hashes, mask):
    flat = _flat_ids(hashes, PARTS, BUCKETS)
    counts = _segment_sum(mask, flat, PARTS * BUCKETS)
    return (counts.reshape(PARTS, BUCKETS),)


def grep_combine_cpu(tokens, hashes, mask, pattern):
    pat = pattern.reshape(1, -1)
    rest = jnp.cumsum((pat == -2).astype(jnp.int32), axis=1) > 0
    ok = (tokens == pat) | (pat == -1) | rest
    m = jnp.all(ok, axis=1).astype(jnp.float32) * mask
    flat = _flat_ids(hashes, PARTS, BUCKETS)
    counts = _segment_sum(m, flat, PARTS * BUCKETS)
    return counts.reshape(PARTS, BUCKETS), jnp.sum(m).reshape(1)


def agg_combine_cpu(seg_ids, values, mask):
    valid = (seg_ids >= 0) & (seg_ids < SEGMENTS)
    m = jnp.where(valid, mask, 0.0)
    ids = jnp.clip(seg_ids, 0, SEGMENTS - 1)
    sums = _segment_sum(values * m, ids, SEGMENTS)
    cnts = _segment_sum(m, ids, SEGMENTS)
    return sums, cnts
