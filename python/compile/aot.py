"""AOT lowering: jax -> StableHLO -> XlaComputation -> HLO *text*.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. Lowered with return_tuple=True; the Rust side unwraps with
`to_tuple1()`/`to_tuple()`.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def entries():
    """(name, fn, arg specs, metadata) for every artifact we ship."""
    i32, f32 = jnp.int32, jnp.float32
    n, ns = model.TOKENS_PER_BATCH, model.SMALL_BATCH
    w, b, r, s = (model.WORD_WIDTH, model.BUCKETS, model.PARTS,
                  model.SEGMENTS)
    return [
        ("wordcount_combine", model.wordcount_combine,
         [_spec((n,), i32), _spec((n,), f32)],
         {"n": n, "parts": r, "buckets": b,
          "outputs": [[r, b]]}),
        ("wordcount_combine_small", model.wordcount_combine,
         [_spec((ns,), i32), _spec((ns,), f32)],
         {"n": ns, "parts": r, "buckets": b,
          "outputs": [[r, b]]}),
        ("grep_combine", model.grep_combine,
         [_spec((n, w), i32), _spec((n,), i32), _spec((n,), f32),
          _spec((w,), i32)],
         {"n": n, "w": w, "parts": r, "buckets": b,
          "outputs": [[r, b], [1]]}),
        ("agg_combine", model.agg_combine,
         [_spec((ns,), i32), _spec((ns,), f32), _spec((ns,), f32)],
         {"n": ns, "segments": s, "outputs": [[s], [s]]}),
        # CPU-specialized lowering of the same math (scatter-add instead
        # of the TPU-tiled Pallas grid) — see model.py.
        ("wordcount_combine_cpu", model.wordcount_combine_cpu,
         [_spec((n,), i32), _spec((n,), f32)],
         {"n": n, "parts": r, "buckets": b, "outputs": [[r, b]]}),
        ("grep_combine_cpu", model.grep_combine_cpu,
         [_spec((n, w), i32), _spec((n,), i32), _spec((n,), f32),
          _spec((w,), i32)],
         {"n": n, "w": w, "parts": r, "buckets": b,
          "outputs": [[r, b], [1]]}),
        ("agg_combine_cpu", model.agg_combine_cpu,
         [_spec((ns,), i32), _spec((ns,), f32), _spec((ns,), f32)],
         {"n": ns, "segments": s, "outputs": [[s], [s]]}),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "format": "hlo-text/return-tuple",
        "constants": {
            "tokens_per_batch": model.TOKENS_PER_BATCH,
            "small_batch": model.SMALL_BATCH,
            "word_width": model.WORD_WIDTH,
            "buckets": model.BUCKETS,
            "parts": model.PARTS,
            "segments": model.SEGMENTS,
            "part_shift": 10,
        },
        "artifacts": {},
    }
    for name, fn, specs, meta in entries():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["file"] = fname
        meta["sha256"] = hashlib.sha256(text.encode()).hexdigest()
        meta["params"] = [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
        ]
        manifest["artifacts"][name] = meta
        print(f"wrote {fname}: {len(text)} chars")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest.json with {len(manifest['artifacts'])} entries")


if __name__ == "__main__":
    main()
