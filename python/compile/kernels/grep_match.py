"""Fixed-width token pattern-match Pallas kernel (the Grep mapper core).

Tokens are padded/truncated to W int32 "bytes" (0 = padding). The pattern
is a (W,) int32 vector where ``-1`` is a single-position wildcard and
``-2`` means "match anything from here on" (prefix match). The kernel
emits a 0/1 f32 mask per token; the combiner multiplies it into the
histogram weights so only matching words are counted/shuffled.

Vectorization: the (TN, W) tile is compared element-wise against the
broadcast pattern and reduced along W — pure VPU work, tiled over the
token axis by BlockSpec.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 512

WILD_ONE = -1   # match any single byte
WILD_REST = -2  # match the remainder of the token


def _grep_kernel(toks_ref, pat_ref, o_ref):
    toks = toks_ref[...]  # (TN, W) int32
    pat = pat_ref[...]  # (1, W) int32
    rest = jnp.cumsum((pat == WILD_REST).astype(jnp.int32), axis=1) > 0
    ok = (toks == pat) | (pat == WILD_ONE) | rest
    o_ref[...] = jnp.all(ok, axis=1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_n",))
def grep_match(tokens, pattern, *, tile_n: int = TILE_N):
    """Match every padded token against the wildcard pattern.

    Args:
      tokens: (N, W) int32 padded token bytes (0-padded).
      pattern: (W,) int32 pattern with WILD_ONE / WILD_REST sentinels.
    Returns:
      (N,) float32 in {0.0, 1.0}.
    """
    n, w = tokens.shape
    tile_n = min(tile_n, n)
    if n % tile_n != 0:
        raise ValueError(f"n={n} not divisible by tile_n={tile_n}")
    return pl.pallas_call(
        _grep_kernel,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(tokens, pattern.reshape(1, w))
