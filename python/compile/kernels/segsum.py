"""Segmented-sum Pallas kernel (aggregation-query combiner).

Sums ``values`` into ``segments`` buckets — the SQL ``GROUP BY`` combine
step of the paper's Aggregation Query workload. Identical tiling strategy
to the histogram kernel (one-hot contraction over segment tiles), but the
contraction weight is ``mask * value`` and we emit the count alongside the
sum so downstream AVG-type reducers need no second pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 512
TILE_S = 256


def _segsum_kernel(seg_ref, val_ref, mask_ref, sum_ref, cnt_ref, *,
                   tile_s: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    seg = seg_ref[...]  # (TN,) int32
    val = val_ref[...]  # (TN,) f32
    mask = mask_ref[...]  # (TN,) f32
    base = pl.program_id(0) * tile_s
    segs = base + jax.lax.broadcasted_iota(jnp.int32, (tile_s,), 0)
    onehot = (seg[:, None] == segs[None, :]).astype(jnp.float32)
    sum_ref[...] += (val * mask) @ onehot
    cnt_ref[...] += mask @ onehot


@functools.partial(jax.jit, static_argnames=("segments", "tile_n", "tile_s"))
def segsum(seg_ids, values, mask, *, segments: int, tile_n: int = TILE_N,
           tile_s: int = TILE_S):
    """Masked segmented sum + count.

    Args:
      seg_ids: (N,) int32 segment ids; out-of-range contributes nothing.
      values: (N,) float32.
      mask: (N,) float32 validity mask.
      segments: number of segments S.
    Returns:
      (sums, counts): each (segments,) float32.
    """
    n = seg_ids.shape[0]
    tile_n = min(tile_n, n)
    tile_s = min(tile_s, segments)
    if n % tile_n != 0 or segments % tile_s != 0:
        raise ValueError(f"n={n} segments={segments} not divisible by tiles")
    grid = (segments // tile_s, n // tile_n)
    tok = pl.BlockSpec((tile_n,), lambda i, j: (j,))
    out = pl.BlockSpec((tile_s,), lambda i, j: (i,))
    return pl.pallas_call(
        functools.partial(_segsum_kernel, tile_s=tile_s),
        grid=grid,
        in_specs=[tok, tok, tok],
        out_specs=[out, out],
        out_shape=[
            jax.ShapeDtypeStruct((segments,), jnp.float32),
            jax.ShapeDtypeStruct((segments,), jnp.float32),
        ],
        interpret=True,
    )(seg_ids, values, mask)
