"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Kept deliberately naive (segment_sum / direct compare) so a bug in the
tiled kernels cannot be mirrored here.
"""

import jax.numpy as jnp
import jax.ops

from .grep_match import WILD_ONE, WILD_REST


def histogram_ref(ids, weights, *, bins: int):
    """Sum of weights per bucket, out-of-range ids dropped."""
    valid = (ids >= 0) & (ids < bins)
    w = jnp.where(valid, weights, 0.0)
    ids = jnp.clip(ids, 0, bins - 1)
    return jax.ops.segment_sum(w, ids, num_segments=bins)


def grep_match_ref(tokens, pattern):
    """0/1 match mask for padded tokens vs wildcard pattern."""
    pat = pattern.reshape(1, -1)
    rest = jnp.cumsum((pat == WILD_REST).astype(jnp.int32), axis=1) > 0
    ok = (tokens == pat) | (pat == WILD_ONE) | rest
    return jnp.all(ok, axis=1).astype(jnp.float32)


def segsum_ref(seg_ids, values, mask, *, segments: int):
    """(sums, counts) per segment, out-of-range ids dropped."""
    valid = (seg_ids >= 0) & (seg_ids < segments)
    m = jnp.where(valid, mask, 0.0)
    ids = jnp.clip(seg_ids, 0, segments - 1)
    sums = jax.ops.segment_sum(values * m, ids, num_segments=segments)
    cnts = jax.ops.segment_sum(m, ids, num_segments=segments)
    return sums, cnts


def wordcount_combine_ref(hashes, mask, *, parts: int, buckets: int):
    """(R, B) partitioned counts; see model.wordcount_combine."""
    bucket = hashes & (buckets - 1)
    part = (hashes >> 10) & (parts - 1)
    flat = part * buckets + bucket
    return histogram_ref(flat, mask, bins=parts * buckets).reshape(
        parts, buckets)


def grep_combine_ref(tokens, hashes, mask, pattern, *, parts: int,
                     buckets: int):
    """(R, B) partitioned counts of pattern-matching tokens."""
    m = grep_match_ref(tokens, pattern) * mask
    return wordcount_combine_ref(hashes, m, parts=parts, buckets=buckets)
