"""Layer-1 Pallas kernels for Marvel's map-side combine hot-spot.

Each kernel has a pure-jnp oracle in `ref.py`; pytest sweeps shapes and
asserts allclose. Kernels are lowered with ``interpret=True`` — the CPU
PJRT plugin cannot execute Mosaic custom-calls, so interpret mode is the
correctness (and AOT) path; real-TPU performance is estimated analytically
in DESIGN.md §Perf.
"""

from .histogram import histogram
from .grep_match import grep_match
from .segsum import segsum

__all__ = ["histogram", "grep_match", "segsum"]
