"""Masked bucket-count (histogram) Pallas kernel.

The MapReduce map-side combiner reduces ``N`` hashed tokens into ``B``
bucket counts before anything is shipped over the (simulated) network —
the I/O-reduction insight of the paper applied to the compute layer.

TPU adaptation (DESIGN.md §Hardware-Adaptation): a scatter-add histogram
is hostile to the MXU, so the kernel is restructured as a tiled one-hot
contraction: for each (token-tile × bucket-tile) grid cell we materialize
a (TN, TB) one-hot compare in VMEM and contract it against the weight
vector — a (1×TN)·(TN×TB) matmul shape. BlockSpec expresses the HBM↔VMEM
schedule over both axes; the bucket axis is the output block, the token
axis is the accumulation (fastest-varying) grid axis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. TN * TB * 4 B = 512 KiB of one-hot per grid cell —
# comfortably double-bufferable in a 16 MiB VMEM budget.
TILE_N = 512
TILE_B = 256


def _hist_kernel(ids_ref, w_ref, o_ref, *, tile_b: int):
    """One (bucket-tile i, token-tile j) grid cell."""
    j = pl.program_id(1)  # token axis — accumulation axis (fastest)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]  # (TN,) int32
    w = w_ref[...]  # (TN,) f32
    base = pl.program_id(0) * tile_b
    buckets = base + jax.lax.broadcasted_iota(jnp.int32, (tile_b,), 0)
    # (TN, TB) one-hot; contraction against w is MXU-shaped.
    onehot = (ids[:, None] == buckets[None, :]).astype(jnp.float32)
    o_ref[...] += w @ onehot


@functools.partial(jax.jit, static_argnames=("bins", "tile_n", "tile_b"))
def histogram(ids, weights, *, bins: int, tile_n: int = TILE_N,
              tile_b: int = TILE_B):
    """Masked histogram: sum of ``weights`` per bucket id.

    Args:
      ids: (N,) int32 bucket ids; entries outside [0, bins) contribute 0.
      weights: (N,) float32 per-token weight (use the validity mask, or
        mask * value for weighted counts).
      bins: number of buckets B.
    Returns:
      (bins,) float32 counts.
    """
    n = ids.shape[0]
    tile_n = min(tile_n, n)
    tile_b = min(tile_b, bins)
    if n % tile_n != 0 or bins % tile_b != 0:
        raise ValueError(f"n={n} bins={bins} not divisible by tiles "
                         f"({tile_n},{tile_b})")
    grid = (bins // tile_b, n // tile_n)
    return pl.pallas_call(
        functools.partial(_hist_kernel, tile_b=tile_b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n,), lambda i, j: (j,)),
            pl.BlockSpec((tile_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_b,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((bins,), jnp.float32),
        interpret=True,
    )(ids, weights)
