"""AOT pipeline checks: lowering produces loadable HLO text + manifest."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from compile import aot, model


def test_entries_cover_all_models():
    names = {e[0] for e in aot.entries()}
    assert {"wordcount_combine", "grep_combine", "agg_combine"} <= names


def test_hlo_text_lowering():
    import jax
    name, fn, specs, meta = aot.entries()[1]  # small variant: fast
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "HloModule" in text
    assert "ROOT" in text


def test_full_aot_run(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text/return-tuple"
    for name, meta in manifest["artifacts"].items():
        p = out / meta["file"]
        assert p.exists(), name
        text = p.read_text()
        assert "HloModule" in text
        import hashlib
        assert hashlib.sha256(text.encode()).hexdigest() == meta["sha256"]
