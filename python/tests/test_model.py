"""Model-level (L2) checks: partition scheme, shapes, oracle agreement."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _rng(seed):
    return np.random.default_rng(seed)


class TestWordcountCombine:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, seed):
        r = _rng(seed)
        n = model.TOKENS_PER_BATCH
        h = r.integers(0, 2**31 - 1, n).astype(np.int32)
        mask = (r.random(n) > 0.1).astype(np.float32)
        (got,) = model.wordcount_combine(jnp.asarray(h), jnp.asarray(mask))
        want = ref.wordcount_combine_ref(
            jnp.asarray(h), jnp.asarray(mask),
            parts=model.PARTS, buckets=model.BUCKETS)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        assert got.shape == (model.PARTS, model.BUCKETS)

    def test_total_mass(self):
        n = model.TOKENS_PER_BATCH
        h = np.arange(n, dtype=np.int32)
        mask = np.ones(n, np.float32)
        (got,) = model.wordcount_combine(jnp.asarray(h), jnp.asarray(mask))
        assert float(got.sum()) == pytest.approx(float(n))

    def test_same_hash_same_cell(self):
        n = model.TOKENS_PER_BATCH
        h = np.full(n, 123456789, np.int32)
        mask = np.ones(n, np.float32)
        (got,) = model.wordcount_combine(jnp.asarray(h), jnp.asarray(mask))
        got = np.asarray(got)
        assert (got > 0).sum() == 1
        assert float(got.max()) == pytest.approx(float(n))


class TestGrepCombine:
    def test_counts_only_matches(self):
        n, w = model.TOKENS_PER_BATCH, model.WORD_WIDTH
        r = _rng(5)
        toks = np.zeros((n, w), np.int32)
        toks[: n // 2, 0] = 42  # half start with byte 42
        h = r.integers(0, 2**31 - 1, n).astype(np.int32)
        mask = np.ones(n, np.float32)
        pat = np.full(w, -2, np.int32)
        pat[0] = 42
        counts, total = model.grep_combine(
            jnp.asarray(toks), jnp.asarray(h), jnp.asarray(mask),
            jnp.asarray(pat))
        assert float(total[0]) == pytest.approx(n / 2)
        assert float(counts.sum()) == pytest.approx(n / 2)


class TestAggCombine:
    def test_group_by_average(self):
        n = model.SMALL_BATCH
        r = _rng(9)
        ids = r.integers(0, model.SEGMENTS, n).astype(np.int32)
        vals = r.random(n).astype(np.float32)
        mask = np.ones(n, np.float32)
        sums, cnts = model.agg_combine(
            jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(mask))
        assert float(cnts.sum()) == pytest.approx(float(n))
        np.testing.assert_allclose(float(sums.sum()), vals.sum(), rtol=1e-4)
