"""Kernel-vs-oracle correctness: hypothesis sweeps shapes/values.

This is the CORE correctness signal for Layer 1 — everything the Rust
runtime executes flows through these kernels.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import grep_match, histogram, segsum
from compile.kernels.grep_match import WILD_ONE, WILD_REST
from compile.kernels import ref

SHAPES = st.sampled_from([(64, 32), (128, 64), (512, 256), (1024, 128)])


def _rng(seed):
    return np.random.default_rng(seed)


class TestHistogram:
    @settings(max_examples=20, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        n, bins = shape
        r = _rng(seed)
        ids = r.integers(0, bins, n).astype(np.int32)
        w = r.random(n).astype(np.float32)
        got = histogram(jnp.asarray(ids), jnp.asarray(w), bins=bins)
        want = ref.histogram_ref(jnp.asarray(ids), jnp.asarray(w), bins=bins)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_mass_conservation(self):
        r = _rng(7)
        ids = r.integers(0, 256, 2048).astype(np.int32)
        w = np.ones(2048, np.float32)
        got = histogram(jnp.asarray(ids), jnp.asarray(w), bins=256)
        assert float(got.sum()) == pytest.approx(2048.0)

    def test_masked_tokens_do_not_count(self):
        ids = np.zeros(512, np.int32)
        w = np.zeros(512, np.float32)
        w[:100] = 1.0
        got = histogram(jnp.asarray(ids), jnp.asarray(w), bins=64)
        assert float(got[0]) == pytest.approx(100.0)
        assert float(got[1:].sum()) == 0.0

    def test_out_of_range_dropped(self):
        ids = np.full(512, 9999, np.int32)
        w = np.ones(512, np.float32)
        got = histogram(jnp.asarray(ids), jnp.asarray(w), bins=64)
        assert float(got.sum()) == 0.0

    def test_non_divisible_tile_raises(self):
        with pytest.raises(ValueError):
            histogram(jnp.zeros(100, jnp.int32), jnp.zeros(100), bins=64,
                      tile_n=64)

    @pytest.mark.parametrize("tile_n,tile_b", [(64, 32), (128, 128),
                                               (256, 64)])
    def test_tile_invariance(self, tile_n, tile_b):
        r = _rng(3)
        ids = r.integers(0, 128, 512).astype(np.int32)
        w = r.random(512).astype(np.float32)
        a = histogram(jnp.asarray(ids), jnp.asarray(w), bins=128,
                      tile_n=tile_n, tile_b=tile_b)
        b = ref.histogram_ref(jnp.asarray(ids), jnp.asarray(w), bins=128)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


class TestGrepMatch:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           n=st.sampled_from([64, 256, 512]),
           w=st.sampled_from([8, 16]))
    def test_matches_ref(self, seed, n, w):
        r = _rng(seed)
        toks = r.integers(0, 4, (n, w)).astype(np.int32)  # small alphabet
        pat = r.integers(-2, 4, w).astype(np.int32)
        got = grep_match(jnp.asarray(toks), jnp.asarray(pat))
        want = ref.grep_match_ref(jnp.asarray(toks), jnp.asarray(pat))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_exact_match(self):
        toks = np.zeros((64, 8), np.int32)
        toks[0] = [104, 101, 108, 108, 111, 0, 0, 0]  # "hello"
        pat = np.array([104, 101, 108, 108, 111, 0, 0, 0], np.int32)
        got = np.asarray(grep_match(jnp.asarray(toks), jnp.asarray(pat)))
        assert got[0] == 1.0
        assert got[1:].sum() == 0.0  # all-zero tokens match? pattern != 0s

    def test_wildcard_one(self):
        toks = np.array([[1, 2, 3, 4]] * 64, np.int32)
        pat = np.array([1, WILD_ONE, 3, 4], np.int32)
        got = np.asarray(grep_match(jnp.asarray(toks), jnp.asarray(pat)))
        assert got.sum() == 64.0

    def test_wildcard_rest_prefix(self):
        toks = np.zeros((64, 8), np.int32)
        toks[:, 0] = 7
        toks[0, 1] = 9
        pat = np.array([7, WILD_REST, 0, 0, 0, 0, 0, 0], np.int32)
        got = np.asarray(grep_match(jnp.asarray(toks), jnp.asarray(pat)))
        assert got.sum() == 64.0  # prefix 7 matches regardless of tail

    def test_no_match(self):
        toks = np.ones((64, 8), np.int32)
        pat = np.full(8, 2, np.int32)
        got = np.asarray(grep_match(jnp.asarray(toks), jnp.asarray(pat)))
        assert got.sum() == 0.0


class TestSegsum:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           shape=st.sampled_from([(64, 32), (512, 256), (1024, 64)]))
    def test_matches_ref(self, seed, shape):
        n, s = shape
        r = _rng(seed)
        ids = r.integers(0, s, n).astype(np.int32)
        vals = r.normal(size=n).astype(np.float32)
        mask = (r.random(n) > 0.3).astype(np.float32)
        got_s, got_c = segsum(jnp.asarray(ids), jnp.asarray(vals),
                              jnp.asarray(mask), segments=s)
        want_s, want_c = ref.segsum_ref(jnp.asarray(ids), jnp.asarray(vals),
                                        jnp.asarray(mask), segments=s)
        np.testing.assert_allclose(got_s, want_s, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got_c, want_c, rtol=1e-5, atol=1e-5)

    def test_counts_equal_mask_sum(self):
        r = _rng(11)
        ids = r.integers(0, 64, 512).astype(np.int32)
        vals = r.random(512).astype(np.float32)
        mask = np.ones(512, np.float32)
        _, cnt = segsum(jnp.asarray(ids), jnp.asarray(vals),
                        jnp.asarray(mask), segments=64)
        assert float(cnt.sum()) == pytest.approx(512.0)
