//! SQL-on-serverless scenario: the Scan / Aggregation / Join queries
//! whose dataset behaviour motivates the paper (Table 1). Runs each
//! query on Marvel and prints the phase dataset sizes alongside the
//! intermediate-expansion factors.

use marvel::coordinator::{ClusterSpec, Marvel};
use marvel::mapreduce::{SystemConfig, Workload};
use marvel::util::bytes::{self, MIB};
use marvel::util::table::Table;
use marvel::workloads::{AggregationQuery, JoinQuery, ScanQuery};

fn main() -> Result<(), String> {
    let mut m = Marvel::new(ClusterSpec::default(), 11)?;
    let input = 16 * MIB;
    let agg = AggregationQuery::new(&m.rt);
    let scan = ScanQuery { categories: 1024, selectivity: 0.5 };
    let join = JoinQuery::new();
    let workloads: Vec<(&dyn Workload, &str)> = vec![
        (&scan, "Scan Query"),
        (&agg, "Aggregation Query"),
        (&join, "Join Query"),
    ];

    for cfg in [SystemConfig::corral_lambda(), SystemConfig::marvel_igfs()] {
        let mut t = Table::new(
            &format!("Query dataset sizes on {} ({} input)", cfg.name,
                     bytes::human(input)),
            &["query", "input", "intermediate", "output", "expansion",
              "job time"],
        );
        for (wl, label) in &workloads {
            let r = m.run(&cfg, *wl, input);
            assert!(r.ok(), "{label}: {:?}", r.failed);
            t.row(&[
                label.to_string(),
                bytes::human(r.input_bytes),
                bytes::human(r.intermediate_bytes),
                bytes::human(r.output_bytes),
                format!("{:.2}x",
                        r.intermediate_bytes as f64 / r.input_bytes as f64),
                format!("{}", r.job_time),
            ]);
        }
        t.print();
        println!();
    }
    println!("paper Table 1 shapes: scan ≈1.1–1.4x, aggregation ≈1.2–1.7x,");
    println!("join ≈3.7–4x (all pre-combiner); Marvel's kernel combiner");
    println!("collapses scan/aggregation intermediates to near-constant.");
    Ok(())
}
