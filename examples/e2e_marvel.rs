//! End-to-end driver (see ARCHITECTURE.md): a real small workload through
//! every layer of the stack, on all three system configurations.
//!
//! Pipeline proven here: zipf corpus generation (real bytes) → HDFS
//! block placement on the PMEM device model → OpenWhisk/Lambda action
//! scheduling → tokenize + hash in Rust → AOT PJRT combine kernels
//! (python-free hot path) → shuffle via S3 / PMEM-HDFS / IGFS → reduce
//! → output store. Reports the paper's headline metric (job-time
//! reduction vs the Lambda baseline) plus correctness cross-checks.
//! Results recorded in EXPERIMENTS.md §E2E.

use marvel::coordinator::{reduction, ClusterSpec, Marvel};
use marvel::mapreduce::SystemConfig;
use marvel::metrics::tags;
use marvel::util::bytes::{self, MIB};
use marvel::util::table::{fmt_pct, Table};
use marvel::workloads::WordCount;

fn main() -> Result<(), String> {
    let input = 24 * MIB; // real data plane (below materialize cap)
    let mut m = Marvel::new(ClusterSpec::default(), 42)?;
    assert!(
        m.rt.is_pjrt() || std::env::var("ALLOW_ORACLE").is_ok(),
        "run `make artifacts` first: the E2E driver must exercise PJRT"
    );
    println!("runtime: {}", if m.rt.is_pjrt() { "PJRT" } else { "oracle" });

    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let configs = [
        SystemConfig::corral_lambda(),
        SystemConfig::marvel_hdfs(),
        SystemConfig::marvel_igfs(),
    ];
    let results = m.compare(&configs, &wc, input);

    let mut t = Table::new(
        &format!("E2E WordCount, {} real input", bytes::human(input)),
        &["system", "job time", "map", "reduce", "intermediate",
          "shuffle Gbps", "combine batches"],
    );
    for r in &results {
        assert!(r.ok(), "{} failed: {:?}", r.config, r.failed);
        t.row(&[
            r.config.clone(),
            format!("{}", r.job_time),
            format!("{}", r.map.duration),
            format!("{}", r.reduce.duration),
            bytes::human(r.intermediate_bytes),
            format!("{:.2}", r.io.gbps_over_makespan(&[
                tags::INTERMEDIATE_WRITE, tags::INTERMEDIATE_READ])),
            r.rt_batches.to_string(),
        ]);
    }
    t.print();

    // Correctness: all three systems must count the same tokens.
    // (Outputs differ in representation — raw wordcount vs bucket
    // aggregates — but the map phase token counts are comparable.)
    let lambda = &results[0];
    let igfs = &results[2];
    assert_eq!(lambda.input_bytes, igfs.input_bytes);
    assert!(igfs.rt_batches > 0, "PJRT combine must run on the hot path");

    // Headline: paper reports up to 86.6 % reduction vs Lambda.
    let red_hdfs = reduction(lambda, &results[1]);
    let red_igfs = reduction(lambda, igfs);
    println!("\nreduction vs lambda-s3: marvel-hdfs {}  marvel-igfs {}",
             fmt_pct(red_hdfs), fmt_pct(red_igfs));
    println!("paper reports: up to 86.6 % at the largest common input");
    assert!(red_igfs > 0.3,
            "Marvel-IGFS should beat Lambda substantially, got {red_igfs}");
    println!("\nE2E OK — all layers composed (real data, PJRT hot path).");
    Ok(())
}
