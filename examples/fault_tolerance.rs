//! Fault-tolerance scenario (paper §4.3 future work, built here):
//! stateful functions checkpoint progress to the IGFS state store and
//! resume after container failures; stateless functions restart from
//! zero. Quantifies recomputed work under injected failures.

use marvel::coordinator::recovery::{run_with_failures, RecoveryConfig};
use marvel::igfs::StateStore;
use marvel::util::bytes::{self, MIB};
use marvel::util::rng::Rng;
use marvel::util::table::Table;

fn main() {
    let split = 128 * MIB;
    let cfg = RecoveryConfig {
        interval_bytes: 16 * MIB,
        max_attempts: 5,
        ..Default::default()
    };
    let mut rng = Rng::new(99);

    let mut t = Table::new(
        "Recovery under injected failures (128 MiB split, 16 MiB ckpt)",
        &["failures", "mode", "attempts", "work done", "recomputed",
          "overhead"],
    );
    for n_failures in [0usize, 1, 2, 3] {
        let failures: Vec<u64> = (0..n_failures)
            .map(|_| rng.range(MIB, split))
            .collect();
        for stateful in [true, false] {
            let mut store = StateStore::new();
            let r = run_with_failures(
                &mut store, &cfg, "job", 0, split, &failures, stateful, &[],
            );
            assert!(r.recovered, "must recover within attempt budget");
            t.row(&[
                format!("{n_failures}"),
                if stateful { "stateful (Marvel)" } else { "stateless" }
                    .to_string(),
                r.attempts.to_string(),
                bytes::human(r.bytes_processed),
                bytes::human(r.bytes_recomputed),
                format!("{:.1} %",
                        100.0 * (r.bytes_processed - split) as f64
                            / split as f64),
            ]);
        }
    }
    t.print();
    println!("\nstateful recovery bounds recomputation to one checkpoint");
    println!("interval per failure; stateless recomputes the whole split.");

    // The same policy, live: a FailurePlan armed on the real execution
    // path. Containers crash mid-split, release their slots through
    // the fair queue, and retries resume from IGFS checkpoints — the
    // job's output bytes are identical to a failure-free run.
    use marvel::coordinator::{ClusterSpec, Marvel};
    use marvel::mapreduce::SystemConfig;
    use marvel::workloads::WordCount;

    let mut sys = SystemConfig::marvel_igfs();
    sys.failures.crash_prob = 0.6;
    sys.failures.seed = 7;
    sys.recovery.interval_bytes = 256 * 1024;
    let mut m = Marvel::new(ClusterSpec::default(), 42).expect("client");
    let wc = WordCount::new(4000, 1.07, &m.rt);
    let r = m.run(&sys, &wc, 4 * MIB);
    assert!(r.ok(), "{:?}", r.failed);
    println!(
        "\nlive injection: {} tasks ran as {} attempts, {} recomputed, \
         {} checkpoints ({} overhead), job time {}",
        r.map.tasks + r.reduce.tasks,
        r.task_attempts,
        bytes::human(r.recomputed_bytes),
        r.checkpoints,
        r.checkpoint_overhead,
        r.job_time,
    );
}
