//! Grep analytics scenario: log-scanning with patterns of different
//! selectivity — the workload class the paper's §4.2.1 Figure 5
//! evaluates. Shows how intermediate volume (and thus the benefit of
//! in-memory shuffle) tracks pattern selectivity.

use marvel::coordinator::{ClusterSpec, Marvel};
use marvel::mapreduce::SystemConfig;
use marvel::util::bytes::{self, MIB};
use marvel::util::table::Table;
use marvel::workloads::{Corpus, Grep};

fn main() -> Result<(), String> {
    let mut m = Marvel::new(ClusterSpec::default(), 7)?;
    let corpus = Corpus::new(10_000, 1.07);
    let input = 16 * MIB;

    let mut t = Table::new(
        "Grep: pattern selectivity vs shuffle volume (marvel-igfs)",
        &["pattern rank", "match rate", "intermediate", "matches", "job time"],
    );
    for rank in [0usize, 5, 50, 500] {
        let prefix = corpus.prefix_of_rank(rank, 2);
        let grep = Grep::new(10_000, 1.07, &prefix, &m.rt);
        let r = m.run(&SystemConfig::marvel_igfs(), &grep, input);
        assert!(r.ok(), "{:?}", r.failed);
        t.row(&[
            format!("{} ({:?})", rank, String::from_utf8_lossy(&prefix)),
            format!("{:.3}", grep.match_prob()),
            bytes::human(r.intermediate_bytes),
            r.reduce.bytes_in.to_string(),
            format!("{}", r.job_time),
        ]);
    }
    t.print();

    // Cross-system comparison at one pattern (Figure 5's shape).
    let prefix = corpus.prefix_of_rank(5, 2);
    let grep = Grep::new(10_000, 1.07, &prefix, &m.rt);
    let mut t = Table::new(
        "Grep across systems",
        &["system", "job time", "intermediate"],
    );
    for cfg in [
        SystemConfig::corral_lambda(),
        SystemConfig::marvel_hdfs(),
        SystemConfig::marvel_igfs(),
    ] {
        let r = m.run(&cfg, &grep, input);
        assert!(r.ok(), "{}: {:?}", cfg.name, r.failed);
        t.row(&[
            r.config.clone(),
            format!("{}", r.job_time),
            bytes::human(r.intermediate_bytes),
        ]);
    }
    t.print();
    Ok(())
}
