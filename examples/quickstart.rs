//! Quickstart: run one WordCount job on the Marvel-IGFS stack.
//!
//! ```bash
//! make artifacts          # once: AOT-compile the combine kernels
//! cargo run --release --example quickstart
//! ```

use marvel::coordinator::{ClusterSpec, Marvel};
use marvel::mapreduce::SystemConfig;
use marvel::util::bytes::MIB;
use marvel::workloads::WordCount;

fn main() -> Result<(), String> {
    // 1. A client against the paper's testbed shape (1 node, 32 slots,
    //    700 GB PMEM). Loads artifacts/ if `make artifacts` has run.
    let mut marvel = Marvel::new(ClusterSpec::default(), 42)?;
    println!(
        "runtime: {}",
        if marvel.rt.is_pjrt() { "PJRT (AOT artifacts)" } else { "oracle" }
    );

    // 2. A workload: WordCount over a 10k-word zipfian corpus.
    let wc = WordCount::new(10_000, 1.07, &marvel.rt);

    // 3. Run 8 MiB of real text through the full stack: HDFS-on-PMEM
    //    input, OpenWhisk actions, PJRT combine, IGFS shuffle.
    let result = marvel.run(&SystemConfig::marvel_igfs(), &wc, 8 * MIB);

    marvel::cli::print_job_result(&result);
    assert!(result.ok(), "job failed: {:?}", result.failed);
    println!(
        "counted {} tokens into {} bytes of output in {} (simulated)",
        result.map.tasks,
        result.output_bytes,
        result.job_time
    );
    Ok(())
}
