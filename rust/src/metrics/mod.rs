//! Metrics: flow tags, counters, and report assembly for the bench
//! harness (tables/figures) and EXPERIMENTS.md. See `ARCHITECTURE.md`
//! (Observability) for how tags attribute shared-cluster traffic.

pub mod tags {
    //! Flow tags — label every simulated transfer so throughput can be
    //! attributed per phase (Figure 6 needs I/O throughput by backend).
    //!
    //! The low [`TENANT_SHIFT`] bits carry the *phase* (the constants
    //! below); the high bits carry the *tenant class* a multi-tenant
    //! co-run stamps on its traffic ([`scoped`]). Single-job runs use
    //! tenant 0, for which `scoped(base, 0) == base` — the legacy tag
    //! values are unchanged.
    pub const INPUT_READ: u32 = 1;
    pub const INTERMEDIATE_WRITE: u32 = 2;
    pub const INTERMEDIATE_READ: u32 = 3;
    pub const OUTPUT_WRITE: u32 = 4;
    pub const S3_REQUEST: u32 = 5;
    pub const STATE_OP: u32 = 6;
    pub const REPLICATION: u32 = 7;
    pub const FIO: u32 = 8;

    /// Bits reserved for the phase; tenant class lives above them.
    pub const TENANT_SHIFT: u32 = 8;

    /// Stamp a phase tag with a tenant class.
    pub fn scoped(base: u32, tenant: u32) -> u32 {
        debug_assert!(base < (1 << TENANT_SHIFT));
        base | (tenant << TENANT_SHIFT)
    }

    /// The phase constant of a (possibly tenant-scoped) tag.
    pub fn base_of(tag: u32) -> u32 {
        tag & ((1 << TENANT_SHIFT) - 1)
    }

    /// The tenant class of a tag (0 = unscoped / single job).
    pub fn tenant_of(tag: u32) -> u32 {
        tag >> TENANT_SHIFT
    }

    pub fn name(tag: u32) -> &'static str {
        match base_of(tag) {
            INPUT_READ => "input_read",
            INTERMEDIATE_WRITE => "intermediate_write",
            INTERMEDIATE_READ => "intermediate_read",
            OUTPUT_WRITE => "output_write",
            S3_REQUEST => "s3_request",
            STATE_OP => "state_op",
            REPLICATION => "replication",
            FIO => "fio",
            _ => "other",
        }
    }
}

use std::collections::BTreeMap;

use crate::sim::{FlowLog, SimNs};

/// Aggregated I/O accounting from an engine run.
#[derive(Clone, Debug, Default)]
pub struct IoSummary {
    /// tag → (bytes, busy-span seconds).
    pub per_tag: BTreeMap<u32, (f64, f64)>,
    pub total_bytes: f64,
    pub makespan: SimNs,
}

impl IoSummary {
    pub fn from_flow_log(log: &[FlowLog], makespan: SimNs) -> IoSummary {
        let mut per_tag: BTreeMap<u32, (f64, f64)> = BTreeMap::new();
        let mut total = 0.0;
        // Busy span per tag = union of [start, end) intervals.
        let mut intervals: BTreeMap<u32, Vec<(u64, u64)>> = BTreeMap::new();
        for f in log {
            total += f.bytes;
            per_tag.entry(f.tag).or_default().0 += f.bytes;
            intervals
                .entry(f.tag)
                .or_default()
                .push((f.start.as_nanos(), f.end.as_nanos()));
        }
        for (tag, mut iv) in intervals {
            iv.sort_unstable();
            let mut busy = 0u64;
            let mut cur: Option<(u64, u64)> = None;
            for (s, e) in iv {
                match cur {
                    None => cur = Some((s, e)),
                    Some((cs, ce)) => {
                        if s <= ce {
                            cur = Some((cs, ce.max(e)));
                        } else {
                            busy += ce - cs;
                            cur = Some((s, e));
                        }
                    }
                }
            }
            if let Some((cs, ce)) = cur {
                busy += ce - cs;
            }
            per_tag.get_mut(&tag).unwrap().1 = busy as f64 / 1e9;
        }
        IoSummary { per_tag, total_bytes: total, makespan }
    }

    /// Summarize only one tenant's flows out of a shared co-run log,
    /// normalizing tags back to their phase constants so `bytes_for`
    /// and friends answer with the usual keys. Tenant 0 selects
    /// unscoped (single-job) traffic — for a solo run over its own
    /// flow-log slice this is identical to [`IoSummary::from_flow_log`].
    pub fn for_tenant(
        log: &[FlowLog],
        tenant: u32,
        makespan: SimNs,
    ) -> IoSummary {
        let scoped: Vec<FlowLog> = log
            .iter()
            .filter(|f| tags::tenant_of(f.tag) == tenant)
            .map(|f| FlowLog { tag: tags::base_of(f.tag), ..f.clone() })
            .collect();
        IoSummary::from_flow_log(&scoped, makespan)
    }

    pub fn bytes_for(&self, tag: u32) -> f64 {
        self.per_tag.get(&tag).map(|v| v.0).unwrap_or(0.0)
    }

    /// Mean throughput of a tag over its busy span, in Gbit/s
    /// (the unit of the paper's Figure 6).
    pub fn gbps_for(&self, tag: u32) -> f64 {
        match self.per_tag.get(&tag) {
            Some(&(bytes, busy)) if busy > 0.0 => bytes * 8.0 / busy / 1e9,
            _ => 0.0,
        }
    }

    /// Aggregate throughput of several tags over the union busy span.
    pub fn gbps_over_makespan(&self, tag_list: &[u32]) -> f64 {
        let bytes: f64 = tag_list.iter().map(|t| self.bytes_for(*t)).sum();
        let secs = self.makespan.as_secs_f64();
        if secs > 0.0 {
            bytes * 8.0 / secs / 1e9
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fl(tag: u32, bytes: f64, s: u64, e: u64) -> FlowLog {
        FlowLog {
            tag,
            bytes,
            start: SimNs::from_nanos(s),
            end: SimNs::from_nanos(e),
        }
    }

    #[test]
    fn per_tag_bytes() {
        let log = vec![fl(1, 100.0, 0, 10), fl(1, 50.0, 10, 20),
                       fl(2, 30.0, 0, 5)];
        let s = IoSummary::from_flow_log(&log, SimNs::from_nanos(20));
        assert_eq!(s.bytes_for(1), 150.0);
        assert_eq!(s.bytes_for(2), 30.0);
        assert_eq!(s.total_bytes, 180.0);
    }

    #[test]
    fn busy_span_merges_overlaps() {
        // Two overlapping flows: [0,10) and [5,15) → busy 15 ns.
        let log = vec![fl(1, 1e9, 0, 10), fl(1, 1e9, 5, 15)];
        let s = IoSummary::from_flow_log(&log, SimNs::from_nanos(15));
        let (_, busy) = s.per_tag[&1];
        assert!((busy - 15e-9).abs() < 1e-15);
    }

    #[test]
    fn gbps_math() {
        // 1.25e9 bytes over 1 s busy = 10 Gbps.
        let log = vec![fl(1, 1.25e9, 0, 1_000_000_000)];
        let s = IoSummary::from_flow_log(&log, SimNs::from_secs_f64(1.0));
        assert!((s.gbps_for(1) - 10.0).abs() < 1e-9);
        assert!((s.gbps_over_makespan(&[1]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn tag_names() {
        assert_eq!(tags::name(tags::INPUT_READ), "input_read");
        assert_eq!(tags::name(0xff), "other");
    }

    #[test]
    fn scoped_tags_roundtrip_and_zero_is_identity() {
        let t = tags::scoped(tags::OUTPUT_WRITE, 3);
        assert_eq!(tags::base_of(t), tags::OUTPUT_WRITE);
        assert_eq!(tags::tenant_of(t), 3);
        assert_eq!(tags::name(t), "output_write");
        assert_eq!(tags::scoped(tags::INPUT_READ, 0), tags::INPUT_READ);
        assert_eq!(tags::tenant_of(tags::INPUT_READ), 0);
    }

    #[test]
    fn for_tenant_filters_and_normalizes() {
        let log = vec![
            fl(tags::scoped(tags::INPUT_READ, 1), 100.0, 0, 10),
            fl(tags::scoped(tags::INPUT_READ, 2), 40.0, 0, 10),
            fl(tags::scoped(tags::OUTPUT_WRITE, 1), 7.0, 10, 20),
            fl(tags::INPUT_READ, 5.0, 0, 10), // unscoped
        ];
        let t1 = IoSummary::for_tenant(&log, 1, SimNs::from_nanos(20));
        assert_eq!(t1.bytes_for(tags::INPUT_READ), 100.0);
        assert_eq!(t1.bytes_for(tags::OUTPUT_WRITE), 7.0);
        assert_eq!(t1.total_bytes, 107.0);
        let t0 = IoSummary::for_tenant(&log, 0, SimNs::from_nanos(20));
        assert_eq!(t0.total_bytes, 5.0);
    }
}
