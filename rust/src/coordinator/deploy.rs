//! Deployment: build a fully-wired cluster (engine, topology, stores,
//! FaaS platform, YARN) from a [`ClusterSpec`] — the paper's "automated
//! end-to-end deployment" contribution (§3.2 Ease of deployment).

use crate::faas::{ContainerConfig, Controller, Lambda, LambdaConfig};
use crate::hdfs::Hdfs;
use crate::igfs::Igfs;
use crate::mapreduce::{Cluster, Stores, SystemConfig};
use crate::net::TopologyBuilder;
use crate::objstore::{ObjStoreConfig, ObjectStore};
use crate::sim::Engine;
use crate::util::bytes::GIB;
use crate::yarn::{NodeCapacity, ResourceManager};

/// Physical shape of the deployment (defaults = the paper's testbed:
/// one server, 32 CPUs, 360 GB DRAM, 700 GB PMEM AppDirect).
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub nodes: usize,
    pub slots_per_node: usize,
    pub nic_gbps: f64,
    pub pmem_capacity: u64,
    pub ssd_capacity: u64,
    pub dram_capacity: u64,
    pub wan_gbps: f64,
    pub lambda: LambdaConfig,
    pub containers: ContainerConfig,
    pub objstore: ObjStoreConfig,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            nodes: 1,
            slots_per_node: 32,
            nic_gbps: 10.0,
            pmem_capacity: 700 * GIB,
            ssd_capacity: 960 * GIB,
            dram_capacity: 360 * GIB,
            wan_gbps: 12.5,
            lambda: LambdaConfig::default(),
            containers: ContainerConfig::default(),
            objstore: ObjStoreConfig::default(),
        }
    }
}

impl ClusterSpec {
    pub fn with_nodes(nodes: usize) -> ClusterSpec {
        ClusterSpec { nodes, ..Default::default() }
    }

    /// Deploy a cluster for one job run under `cfg`.
    pub fn deploy(&self, cfg: &SystemConfig) -> Cluster {
        let mut engine = Engine::new();
        let topo = TopologyBuilder {
            nodes: self.nodes,
            slots_per_node: self.slots_per_node,
            nic_gbps: self.nic_gbps,
            pmem_capacity: self.pmem_capacity,
            ssd_capacity: self.ssd_capacity,
            dram_capacity: self.dram_capacity,
            wan_gbps: self.wan_gbps,
            wan_rtt: self.objstore.request_rtt,
            with_hdd: true,
            // Heterogeneous node speeds: a pure function of the
            // profile's seed, so the same config always deploys the
            // same straggler set (time plane only; bytes never move).
            node_speeds: cfg.stragglers.speeds(self.nodes),
        }
        .build(&mut engine);
        // Link fault windows: a pure function of the plan's seed and
        // the topology's link order, installed once per deploy. Inert
        // plans install nothing — the flow simulator is bit-for-bit
        // the legacy uniform one.
        cfg.netfaults.install(&topo, &mut engine);
        let stores = Stores::new(
            Hdfs::new(&topo, cfg.hdfs_role, cfg.replication),
            Igfs::new(&topo, cfg.igfs_capacity.max(1)),
            ObjectStore::new(&mut engine, &self.objstore),
        );
        let controller = Controller::new(
            &mut engine,
            &vec![self.slots_per_node; self.nodes],
            self.containers.clone(),
        );
        let lambda = Lambda::new(&mut engine, self.lambda.clone());
        let mut rm = ResourceManager::new(
            (0..self.nodes)
                .map(|i| NodeCapacity {
                    node: crate::net::NodeId(i),
                    vcores: self.slots_per_node as u32,
                    memory_mb: 64 * 1024,
                })
                .collect(),
        );
        // Pluggable placement: the strategy steers only which node each
        // container lands on; StragglerAware additionally sees the same
        // speed table the topology was built from.
        rm.scheduler.placement = cfg.placement;
        rm.scheduler.node_speeds = cfg.stragglers.speeds(self.nodes);
        Cluster { engine, topo, stores, controller, lambda, rm, tenant: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deploys_default_testbed() {
        let c = ClusterSpec::default().deploy(&SystemConfig::marvel_igfs());
        assert_eq!(c.topo.n_nodes(), 1);
        assert_eq!(c.rm.total_vcores(), 32);
        assert_eq!(c.controller.n_invokers(), 1);
    }

    #[test]
    fn multi_node_deploys() {
        let c = ClusterSpec::with_nodes(4)
            .deploy(&SystemConfig::marvel_hdfs());
        assert_eq!(c.topo.n_nodes(), 4);
        assert_eq!(c.stores.hdfs.datanodes.len(), 4);
        assert_eq!(c.stores.igfs.caches.len(), 4);
    }

    #[test]
    fn straggler_profile_reaches_the_topology() {
        use crate::net::{NodeId, StragglerProfile};
        let mut cfg = SystemConfig::marvel_igfs();
        cfg.stragglers = StragglerProfile { seed: 5, prob: 1.0, slowdown: 4.0 };
        let c = ClusterSpec::with_nodes(3).deploy(&cfg);
        for i in 0..3 {
            assert!((c.topo.speed_of(NodeId(i)) - 0.25).abs() < 1e-12);
        }
        // Disabled profile: uniform cluster, bit-for-bit legacy speeds.
        let c = ClusterSpec::with_nodes(3)
            .deploy(&SystemConfig::marvel_igfs());
        for i in 0..3 {
            assert_eq!(c.topo.speed_of(NodeId(i)), 1.0);
        }
    }

    #[test]
    fn netfault_plan_reaches_the_flow_sim() {
        use crate::net::NetFaultPlan;
        let mut cfg = SystemConfig::marvel_igfs();
        cfg.netfaults = NetFaultPlan { prob: 1.0, ..NetFaultPlan::default() };
        let c = ClusterSpec::with_nodes(2).deploy(&cfg);
        // prob=1: every NIC pair + both WAN pipes carry a window.
        assert_eq!(c.engine.flows.capacity_windows().len(), 2 * 2 + 2);
        // Disabled plan: no windows, legacy flow sim.
        let c = ClusterSpec::with_nodes(2)
            .deploy(&SystemConfig::marvel_igfs());
        assert!(c.engine.flows.capacity_windows().is_empty());
    }

    #[test]
    fn placement_strategy_reaches_the_scheduler() {
        use crate::net::StragglerProfile;
        use crate::yarn::PlacementStrategy;
        let mut cfg = SystemConfig::marvel_igfs();
        cfg.placement = PlacementStrategy::CacheAffinity;
        cfg.stragglers = StragglerProfile { seed: 5, prob: 1.0, slowdown: 4.0 };
        let c = ClusterSpec::with_nodes(3).deploy(&cfg);
        assert_eq!(c.rm.scheduler.placement, PlacementStrategy::CacheAffinity);
        assert_eq!(c.rm.scheduler.node_speeds, vec![0.25; 3]);
        // Default config: FairOrder, uniform speeds — legacy placement.
        // (Guard the env knob: the CI determinism matrix sweeps
        // MARVEL_PLACEMENT across the whole suite.)
        let c = ClusterSpec::with_nodes(3)
            .deploy(&SystemConfig::marvel_igfs());
        if std::env::var("MARVEL_PLACEMENT").is_err() {
            assert_eq!(
                c.rm.scheduler.placement,
                PlacementStrategy::FairOrder
            );
        }
        assert_eq!(c.rm.scheduler.node_speeds, vec![1.0; 3]);
    }

    #[test]
    fn hdfs_role_follows_config() {
        use crate::net::DeviceRole;
        let c = ClusterSpec::default()
            .deploy(&SystemConfig::onprem(DeviceRole::Ssd, false));
        assert_eq!(c.stores.hdfs.role, DeviceRole::Ssd);
    }
}
