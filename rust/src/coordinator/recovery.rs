//! Checkpoint-based fault tolerance — the paper's §4.3 first
//! future-work item, built on the IGFS state store: map tasks
//! checkpoint (progress, partial aggregate) as they consume their
//! split; on container failure the retry restores the checkpoint and
//! recomputes only the tail.

use crate::igfs::StateStore;
use crate::sim::SimNs;

/// Recovery policy for a job.
#[derive(Clone, Debug)]
pub struct RecoveryConfig {
    /// Checkpoint every `interval_bytes` of consumed split.
    pub interval_bytes: u64,
    /// Max re-execution attempts per task.
    pub max_attempts: u32,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig { interval_bytes: 16 * 1024 * 1024, max_attempts: 3 }
    }
}

/// Outcome of simulating one task with failure injection.
#[derive(Clone, Debug)]
pub struct TaskRecovery {
    pub attempts: u32,
    /// Bytes processed in total, including recomputed tail work.
    pub bytes_processed: u64,
    /// Bytes that had to be recomputed after failures.
    pub bytes_recomputed: u64,
    pub recovered: bool,
}

/// Simulate a map task of `split_bytes` that fails at the given
/// progress points (bytes consumed at failure). With checkpointing,
/// each retry resumes from the last checkpoint; without, it restarts
/// from zero (the stateless baseline, where the paper notes "any
/// function failure results in loss of computation, state and data").
pub fn run_with_failures(
    store: &mut StateStore,
    cfg: &RecoveryConfig,
    job: &str,
    task: u32,
    split_bytes: u64,
    failures_at: &[u64],
    stateful: bool,
) -> TaskRecovery {
    let mut attempts = 0u32;
    let mut processed = 0u64;
    let mut recomputed = 0u64;
    let mut fail_iter = failures_at.iter().copied();
    loop {
        attempts += 1;
        if attempts > cfg.max_attempts {
            return TaskRecovery {
                attempts: attempts - 1,
                bytes_processed: processed,
                bytes_recomputed: recomputed,
                recovered: false,
            };
        }
        // Resume point.
        let start = if stateful {
            store.restore(job, task).map(|s| s.progress).unwrap_or(0)
        } else {
            0
        };
        recomputed += start.min(split_bytes).saturating_sub(0).min(0); // no-op, clarity
        let fail_at = fail_iter.next();
        let mut pos = start;
        loop {
            let next_ckpt = (pos / cfg.interval_bytes + 1)
                * cfg.interval_bytes;
            let target = next_ckpt.min(split_bytes);
            if let Some(f) = fail_at {
                if f > pos && f <= target {
                    // Crash mid-interval: work up to f is lost beyond
                    // the last checkpoint.
                    processed += f - pos;
                    recomputed += if stateful {
                        f - pos.min(f)
                    } else {
                        f
                    };
                    break;
                }
            }
            processed += target - pos;
            pos = target;
            if stateful {
                store
                    .checkpoint(job, task, attempts, pos, vec![])
                    .expect("checkpoint rejected");
            }
            if pos >= split_bytes {
                return TaskRecovery {
                    attempts,
                    bytes_processed: processed,
                    bytes_recomputed: recomputed,
                    recovered: true,
                };
            }
        }
    }
}

/// Estimated wall-time overhead of checkpointing a split (state writes
/// to IGFS at DRAM speed + metadata round-trips).
pub fn checkpoint_overhead(
    split_bytes: u64,
    cfg: &RecoveryConfig,
    per_checkpoint: SimNs,
) -> SimNs {
    let n = split_bytes / cfg.interval_bytes.max(1);
    SimNs::from_nanos(per_checkpoint.as_nanos() * n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> RecoveryConfig {
        RecoveryConfig { interval_bytes: 10, max_attempts: 5 }
    }

    #[test]
    fn no_failures_single_attempt() {
        let mut s = StateStore::new();
        let r = run_with_failures(&mut s, &cfg(), "j", 0, 100, &[], true);
        assert!(r.recovered);
        assert_eq!(r.attempts, 1);
        assert_eq!(r.bytes_processed, 100);
        assert_eq!(r.bytes_recomputed, 0);
    }

    #[test]
    fn stateful_resumes_from_checkpoint() {
        let mut s = StateStore::new();
        // Fail at byte 35: checkpoints at 10, 20, 30; retry resumes @30.
        let r = run_with_failures(&mut s, &cfg(), "j", 0, 100, &[35], true);
        assert!(r.recovered);
        assert_eq!(r.attempts, 2);
        // 35 (first attempt) + 70 (resume from 30) = 105.
        assert_eq!(r.bytes_processed, 105);
        assert_eq!(r.bytes_recomputed, 5);
    }

    #[test]
    fn stateless_restarts_from_zero() {
        let mut s = StateStore::new();
        let r = run_with_failures(&mut s, &cfg(), "j", 0, 100, &[35], false);
        assert!(r.recovered);
        assert_eq!(r.attempts, 2);
        // 35 lost entirely + full 100 again.
        assert_eq!(r.bytes_processed, 135);
        assert_eq!(r.bytes_recomputed, 35);
    }

    #[test]
    fn gives_up_after_max_attempts() {
        let mut s = StateStore::new();
        let fails = vec![5u64; 10];
        let r = run_with_failures(&mut s, &cfg(), "j", 0, 100, &fails, true);
        assert!(!r.recovered);
        assert_eq!(r.attempts, 5);
    }

    #[test]
    fn stateful_beats_stateless_on_work() {
        let mut s1 = StateStore::new();
        let mut s2 = StateStore::new();
        let fails = [55, 83];
        let st = run_with_failures(&mut s1, &cfg(), "j", 0, 100, &fails, true);
        let sl =
            run_with_failures(&mut s2, &cfg(), "j", 1, 100, &fails, false);
        assert!(st.bytes_processed < sl.bytes_processed,
                "stateful {} vs stateless {}", st.bytes_processed,
                sl.bytes_processed);
    }

    #[test]
    fn overhead_scales_with_checkpoints() {
        let o = checkpoint_overhead(100, &cfg(), SimNs::from_micros(50));
        assert_eq!(o, SimNs::from_micros(500));
    }
}
