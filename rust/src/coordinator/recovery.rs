//! Checkpoint-based fault tolerance — the paper's §4.3 first
//! future-work item, built on the IGFS state store: map/reduce tasks
//! checkpoint (progress, partial aggregate) as they consume their
//! split; on container failure the retry restores the checkpoint and
//! recomputes only the tail, while the stateless baseline restarts
//! from zero ("any function failure results in loss of computation,
//! state and data").
//!
//! This module is the *policy layer* shared by the live execution path:
//! `mapreduce::driver::plan_stage` samples fault events from a
//! [`FailurePlan`], runs [`run_with_failures`] against the cluster's
//! real [`StateStore`], and compiles the returned attempt
//! [`AttemptSeg`]s into DES proc stages (slot re-acquisition through
//! the fair queue, input-span replays, checkpoint delays, crash
//! events). See `ARCHITECTURE.md` (Fault tolerance).

use crate::igfs::StateStore;
use crate::sim::SimNs;
use crate::util::hash::fnv1a64;
use crate::util::rng::Rng;

/// Recovery policy for a job.
#[derive(Clone, Debug)]
pub struct RecoveryConfig {
    /// Checkpoint every `interval_bytes` of consumed split.
    pub interval_bytes: u64,
    /// Max re-execution attempts per task.
    pub max_attempts: u32,
    /// Stateful (checkpoint/resume) vs stateless (restart-from-zero)
    /// recovery — the fig8 comparison axis.
    pub stateful: bool,
    /// Virtual-time cost of writing one checkpoint (state write to
    /// IGFS at DRAM speed + metadata round-trip). Charged only while a
    /// failure plan is armed, so failure-free runs keep their legacy
    /// timings.
    pub per_checkpoint: SimNs,
    /// Base of the capped exponential backoff a retry waits before
    /// re-acquiring its slot: attempt *n*'s retry sleeps
    /// `base × 2^(n-1)`, capped at [`RecoveryConfig::backoff_cap`].
    /// ZERO (the default) disables backoff entirely — retries requeue
    /// immediately, exactly the pre-backoff schedule, so existing
    /// pinned recovery timings do not move.
    pub backoff_base: SimNs,
    /// Upper bound on any single backoff delay.
    pub backoff_cap: SimNs,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            interval_bytes: 16 * 1024 * 1024,
            max_attempts: 3,
            stateful: true,
            per_checkpoint: SimNs::from_micros(50),
            backoff_base: SimNs::ZERO,
            backoff_cap: SimNs::from_secs_f64(2.0),
        }
    }
}

impl RecoveryConfig {
    /// Backoff slept before the retry that *follows* failed attempt
    /// `n` (1-based): `base × 2^(n-1)`, saturating, capped. ZERO base
    /// → ZERO always.
    pub fn backoff_for(&self, n: u32) -> SimNs {
        if self.backoff_base == SimNs::ZERO || n == 0 {
            return SimNs::ZERO;
        }
        let shift = (n - 1).min(20);
        SimNs::from_nanos(
            self.backoff_base.as_nanos().saturating_mul(1u64 << shift),
        )
        .min(self.backoff_cap)
    }
}

/// Deterministic, seed-driven fault injection: which containers crash
/// (and where in their split) and which DataNodes are lost. Disabled by
/// default (`crash_prob == 0`, no DataNodes); the whole live path is
/// byte-for-byte the legacy one while disabled.
///
/// Determinism contract: fault events derive only from
/// `(seed, job, task kind, task index, work size)` — never from worker
/// counts, admission order, or co-tenants — so with any plan a job's
/// *outputs* stay byte-identical to its failure-free run; only virtual
/// times and attempt counts move.
#[derive(Clone, Debug, PartialEq)]
pub struct FailurePlan {
    /// Seed driving all fault sampling (independent of the data seed;
    /// CI sweeps it via `MARVEL_FAILURE_SEED`).
    pub seed: u64,
    /// Per-attempt probability that a task's container crashes.
    pub crash_prob: f64,
    /// Cap on injected crashes per task. Keep it below the recovery
    /// policy's `max_attempts` to guarantee completion; at or above it
    /// a fully-unlucky task exhausts its budget and the job errors.
    pub max_failures_per_task: u32,
    /// DataNode ids killed at plan time: their block replicas are lost
    /// and reads fall back to surviving replicas (sole-replica blocks
    /// surface as job errors, never as wrong answers).
    pub lose_datanodes: Vec<usize>,
}

impl Default for FailurePlan {
    fn default() -> Self {
        FailurePlan {
            seed: 42,
            crash_prob: 0.0,
            max_failures_per_task: 2,
            lose_datanodes: Vec::new(),
        }
    }
}

impl FailurePlan {
    /// An inert plan (the default for every `SystemConfig` preset).
    pub fn disabled() -> FailurePlan {
        FailurePlan::default()
    }

    /// Whether this plan injects anything at all.
    pub fn enabled(&self) -> bool {
        self.crash_prob > 0.0 || !self.lose_datanodes.is_empty()
    }

    /// Parse a comma-separated DataNode id list (`"0, 2"`) — the one
    /// parser behind both the `--lose-datanodes` CLI flag and the
    /// TOML `[failures] lose_datanodes` key, so the two surfaces
    /// cannot drift.
    pub fn parse_datanode_list(s: &str) -> Result<Vec<usize>, String> {
        s.split(',')
            .map(|p| p.trim())
            .filter(|p| !p.is_empty())
            .map(|p| {
                p.parse::<usize>()
                    .map_err(|_| format!("bad DataNode id {p:?}"))
            })
            .collect()
    }

    /// Sample the crash offsets for one task: element *k* is the
    /// absolute progress offset (bytes of the split consumed) at which
    /// attempt *k+1*'s container dies. Pure function of
    /// `(seed, job, kind, task, work_bytes)`.
    pub fn failures_for(
        &self,
        job: &str,
        kind: &str,
        task: u64,
        work_bytes: u64,
    ) -> Vec<u64> {
        if self.crash_prob <= 0.0 || work_bytes == 0 {
            return Vec::new();
        }
        let h = fnv1a64(job.as_bytes())
            ^ fnv1a64(kind.as_bytes()).rotate_left(31);
        let mut rng = Rng::new(
            self.seed ^ h ^ task.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let mut out = Vec::new();
        for _ in 0..self.max_failures_per_task {
            if !rng.chance(self.crash_prob) {
                break;
            }
            out.push(rng.below(work_bytes + 1));
        }
        out
    }
}

/// One attempt of a task under failure injection: the progress span it
/// covered, whether it crashed, and the checkpoints it wrote. The
/// driver compiles each segment into a separate container invocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttemptSeg {
    /// Resume offset the attempt started from (0, or the last
    /// checkpoint when stateful).
    pub start: u64,
    /// Progress reached: the crash offset, or the split end.
    pub end: u64,
    pub crashed: bool,
    /// Checkpoints written during this attempt (stateful only).
    pub checkpoints: u32,
}

/// Outcome of simulating one task with failure injection.
#[derive(Clone, Debug)]
pub struct TaskRecovery {
    pub attempts: u32,
    /// Bytes processed in total, including recomputed tail work.
    pub bytes_processed: u64,
    /// Bytes that had to be recomputed after failures.
    pub bytes_recomputed: u64,
    pub recovered: bool,
    /// Per-attempt spans, in execution order.
    pub segments: Vec<AttemptSeg>,
}

impl TaskRecovery {
    /// Total checkpoints written across all attempts.
    pub fn checkpoints(&self) -> u64 {
        self.segments.iter().map(|s| s.checkpoints as u64).sum()
    }
}

/// Simulate a map/reduce task of `split_bytes` that fails at the given
/// progress points (bytes consumed at failure; point *k* kills attempt
/// *k+1*). With checkpointing, each retry resumes from the last
/// checkpoint; without, it restarts from zero. A failure point at or
/// below the attempt's resume offset is a startup crash: the attempt
/// dies before making progress (it is *not* silently consumed).
/// Checkpoints are written into `store` under `(job, task)` with
/// `partial` as the opaque partial-aggregate payload; any pre-existing
/// record under that key is dropped first (it would be a leftover from
/// an earlier execution of a reused task name, not a checkpoint of
/// this one).
#[allow(clippy::too_many_arguments)] // policy knobs, mirrored by the driver
pub fn run_with_failures(
    store: &mut StateStore,
    cfg: &RecoveryConfig,
    job: &str,
    task: u32,
    split_bytes: u64,
    failures_at: &[u64],
    stateful: bool,
    partial: &[u8],
) -> TaskRecovery {
    store.remove(job, task);
    let interval = cfg.interval_bytes.max(1);
    let max_attempts = cfg.max_attempts.max(1);
    let mut attempts = 0u32;
    let mut processed = 0u64;
    let mut recomputed = 0u64;
    let mut segments: Vec<AttemptSeg> = Vec::new();
    let mut fail_iter = failures_at.iter().copied();
    loop {
        attempts += 1;
        if attempts > max_attempts {
            return TaskRecovery {
                attempts: attempts - 1,
                bytes_processed: processed,
                bytes_recomputed: recomputed,
                recovered: false,
                segments,
            };
        }
        // Resume point.
        let start = if stateful {
            store.restore(job, task).map(|s| s.progress).unwrap_or(0)
        } else {
            0
        };
        let fail_at = fail_iter.next();
        if let Some(f) = fail_at {
            if f <= start {
                // Startup crash: the container dies at or before the
                // resume offset, so this attempt does zero work.
                segments.push(AttemptSeg {
                    start,
                    end: start,
                    crashed: true,
                    checkpoints: 0,
                });
                continue;
            }
        }
        let mut pos = start;
        let mut ckpts = 0u32;
        loop {
            let next_ckpt = (pos / interval + 1) * interval;
            let target = next_ckpt.min(split_bytes);
            if let Some(f) = fail_at {
                if f > pos && f <= target {
                    // Crash mid-interval (or exactly at the boundary,
                    // pre-empting that boundary's checkpoint): work
                    // past the last checkpoint is lost — the whole
                    // attempt, if stateless.
                    processed += f - pos;
                    recomputed += if stateful { f - pos } else { f };
                    segments.push(AttemptSeg {
                        start,
                        end: f,
                        crashed: true,
                        checkpoints: ckpts,
                    });
                    break;
                }
            }
            processed += target - pos;
            pos = target;
            if stateful && pos > start {
                store
                    .checkpoint(job, task, attempts, pos, partial.to_vec())
                    .expect("checkpoint rejected");
                ckpts += 1;
            }
            if pos >= split_bytes {
                segments.push(AttemptSeg {
                    start,
                    end: pos,
                    crashed: false,
                    checkpoints: ckpts,
                });
                return TaskRecovery {
                    attempts,
                    bytes_processed: processed,
                    bytes_recomputed: recomputed,
                    recovered: true,
                    segments,
                };
            }
        }
    }
}

/// Estimated wall-time overhead of checkpointing a split (state writes
/// to IGFS at DRAM speed + metadata round-trips).
pub fn checkpoint_overhead(
    split_bytes: u64,
    cfg: &RecoveryConfig,
    per_checkpoint: SimNs,
) -> SimNs {
    let n = split_bytes / cfg.interval_bytes.max(1);
    SimNs::from_nanos(per_checkpoint.as_nanos() * n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> RecoveryConfig {
        RecoveryConfig {
            interval_bytes: 10,
            max_attempts: 5,
            ..Default::default()
        }
    }

    fn run(
        s: &mut StateStore,
        split: u64,
        fails: &[u64],
        stateful: bool,
    ) -> TaskRecovery {
        run_with_failures(s, &cfg(), "j", 0, split, fails, stateful, &[])
    }

    #[test]
    fn no_failures_single_attempt() {
        let mut s = StateStore::new();
        let r = run(&mut s, 100, &[], true);
        assert!(r.recovered);
        assert_eq!(r.attempts, 1);
        assert_eq!(r.bytes_processed, 100);
        assert_eq!(r.bytes_recomputed, 0);
        assert_eq!(r.segments.len(), 1);
        assert_eq!(r.segments[0], AttemptSeg {
            start: 0,
            end: 100,
            crashed: false,
            checkpoints: 10,
        });
    }

    #[test]
    fn stateful_resumes_from_checkpoint() {
        let mut s = StateStore::new();
        // Fail at byte 35: checkpoints at 10, 20, 30; retry resumes @30.
        let r = run(&mut s, 100, &[35], true);
        assert!(r.recovered);
        assert_eq!(r.attempts, 2);
        // 35 (first attempt) + 70 (resume from 30) = 105.
        assert_eq!(r.bytes_processed, 105);
        assert_eq!(r.bytes_recomputed, 5);
        assert_eq!(r.segments[0], AttemptSeg {
            start: 0,
            end: 35,
            crashed: true,
            checkpoints: 3,
        });
        assert_eq!(r.segments[1].start, 30);
    }

    #[test]
    fn stateless_restarts_from_zero() {
        let mut s = StateStore::new();
        let r = run(&mut s, 100, &[35], false);
        assert!(r.recovered);
        assert_eq!(r.attempts, 2);
        // 35 lost entirely + full 100 again.
        assert_eq!(r.bytes_processed, 135);
        assert_eq!(r.bytes_recomputed, 35);
        assert_eq!(r.segments[1], AttemptSeg {
            start: 0,
            end: 100,
            crashed: false,
            checkpoints: 0,
        });
    }

    #[test]
    fn gives_up_after_max_attempts() {
        let mut s = StateStore::new();
        let fails = vec![5u64; 10];
        let r = run(&mut s, 100, &fails, true);
        assert!(!r.recovered);
        assert_eq!(r.attempts, 5);
        assert_eq!(r.segments.len(), 5);
        assert!(r.segments.iter().all(|seg| seg.crashed));
    }

    #[test]
    fn stateful_beats_stateless_on_work() {
        let mut s1 = StateStore::new();
        let mut s2 = StateStore::new();
        let fails = [55, 83];
        let st = run(&mut s1, 100, &fails, true);
        let sl = run(&mut s2, 100, &fails, false);
        assert!(st.bytes_processed < sl.bytes_processed,
                "stateful {} vs stateless {}", st.bytes_processed,
                sl.bytes_processed);
    }

    #[test]
    fn failure_at_byte_zero_crashes_the_attempt() {
        // Regression: a failure point at (or below) the resume offset
        // used to be silently consumed — the attempt ran to completion
        // and the scheduled crash never happened.
        for stateful in [true, false] {
            let mut s = StateStore::new();
            let r = run(&mut s, 100, &[0], stateful);
            assert!(r.recovered, "stateful={stateful}");
            assert_eq!(r.attempts, 2, "stateful={stateful}");
            assert_eq!(r.segments[0], AttemptSeg {
                start: 0,
                end: 0,
                crashed: true,
                checkpoints: 0,
            });
            assert_eq!(r.bytes_processed, 100);
            assert_eq!(r.bytes_recomputed, 0);
        }
    }

    #[test]
    fn failure_below_resume_offset_crashes_the_retry() {
        // Attempt 1 crashes at 15 (checkpoint at 10). Attempt 2's
        // scheduled failure is at byte 8 — at/below its resume offset
        // of 10 — and must crash it immediately, not vanish.
        let mut s = StateStore::new();
        let r = run(&mut s, 100, &[15, 8], true);
        assert!(r.recovered);
        assert_eq!(r.attempts, 3);
        assert_eq!(r.segments[1], AttemptSeg {
            start: 10,
            end: 10,
            crashed: true,
            checkpoints: 0,
        });
        assert_eq!(r.segments[2].start, 10);
        // 15 + 0 + 90 processed; 5 recomputed (15 → last ckpt 10).
        assert_eq!(r.bytes_processed, 105);
        assert_eq!(r.bytes_recomputed, 5);
    }

    #[test]
    fn failure_at_exact_checkpoint_boundary() {
        // Crash at byte 30 — exactly where the third checkpoint would
        // be written. The crash pre-empts that checkpoint: the retry
        // resumes from 20, not 30.
        let mut s = StateStore::new();
        let r = run(&mut s, 100, &[30], true);
        assert!(r.recovered);
        assert_eq!(r.attempts, 2);
        assert_eq!(r.segments[0], AttemptSeg {
            start: 0,
            end: 30,
            crashed: true,
            checkpoints: 2,
        });
        assert_eq!(r.segments[1].start, 20);
        assert_eq!(r.bytes_processed, 30 + 80);
        assert_eq!(r.bytes_recomputed, 10);
    }

    #[test]
    fn interval_larger_than_split_degenerates_to_stateless() {
        // With interval_bytes > split_bytes no mid-split checkpoint
        // exists: a stateful crash loses exactly as much as a
        // stateless one.
        let big = RecoveryConfig {
            interval_bytes: 1000,
            max_attempts: 5,
            ..Default::default()
        };
        let mut s1 = StateStore::new();
        let st = run_with_failures(&mut s1, &big, "j", 0, 100, &[60], true,
                                   &[]);
        let mut s2 = StateStore::new();
        let sl = run_with_failures(&mut s2, &big, "j", 0, 100, &[60], false,
                                   &[]);
        assert!(st.recovered && sl.recovered);
        assert_eq!(st.bytes_recomputed, 60);
        assert_eq!(st.bytes_processed, sl.bytes_processed);
        // The successful attempt still checkpoints its completion...
        assert_eq!(st.segments[1].checkpoints, 1);
        // ...and never mid-split.
        assert_eq!(st.segments[0].checkpoints, 0);
    }

    #[test]
    fn stale_state_from_a_previous_execution_is_dropped() {
        // A reused (job, task) key must not resume from a phantom
        // checkpoint of an earlier run.
        let mut s = StateStore::new();
        run(&mut s, 100, &[], true); // leaves progress=100 behind
        let r = run(&mut s, 100, &[35], true);
        assert_eq!(r.segments[0].start, 0, "fresh execution starts at 0");
        assert_eq!(r.attempts, 2);
    }

    #[test]
    fn empty_split_succeeds_without_checkpoints() {
        let mut s = StateStore::new();
        let r = run(&mut s, 0, &[], true);
        assert!(r.recovered);
        assert_eq!(r.attempts, 1);
        assert_eq!(r.bytes_processed, 0);
        assert_eq!(r.checkpoints(), 0);
    }

    #[test]
    fn partial_payload_lands_in_the_store() {
        let mut s = StateStore::new();
        run_with_failures(&mut s, &cfg(), "j", 3, 25, &[], true, &[7, 7]);
        let ts = s.peek("j", 3).expect("final checkpoint kept");
        assert_eq!(ts.partial, vec![7, 7]);
        assert_eq!(ts.progress, 25);
    }

    #[test]
    fn plan_sampling_is_deterministic_and_bounded() {
        let plan = FailurePlan {
            seed: 7,
            crash_prob: 1.0,
            max_failures_per_task: 3,
            lose_datanodes: vec![],
        };
        let a = plan.failures_for("job", "map", 4, 1000);
        let b = plan.failures_for("job", "map", 4, 1000);
        assert_eq!(a, b, "same coordinates, same schedule");
        assert_eq!(a.len(), 3, "prob 1.0 fills the cap");
        assert!(a.iter().all(|&f| f <= 1000));
        // Distinct coordinates draw distinct streams.
        assert_ne!(plan.failures_for("job", "red", 4, 1000), a);
        assert_ne!(plan.failures_for("job", "map", 5, 1000), a);
        // Disabled and zero-work tasks sample nothing.
        assert!(FailurePlan::disabled()
            .failures_for("job", "map", 0, 1000)
            .is_empty());
        assert!(!FailurePlan::disabled().enabled());
        assert!(plan.failures_for("job", "map", 0, 0).is_empty());
        assert!(plan.enabled());
    }

    #[test]
    fn datanode_list_parses() {
        assert_eq!(FailurePlan::parse_datanode_list("0, 2").unwrap(),
                   vec![0, 2]);
        assert_eq!(FailurePlan::parse_datanode_list("").unwrap(),
                   Vec::<usize>::new());
        assert!(FailurePlan::parse_datanode_list("zero").is_err());
    }

    #[test]
    fn backoff_grows_exponentially_and_caps() {
        let rc = RecoveryConfig {
            backoff_base: SimNs::from_millis(100),
            backoff_cap: SimNs::from_millis(450),
            ..Default::default()
        };
        assert_eq!(rc.backoff_for(0), SimNs::ZERO);
        assert_eq!(rc.backoff_for(1), SimNs::from_millis(100));
        assert_eq!(rc.backoff_for(2), SimNs::from_millis(200));
        assert_eq!(rc.backoff_for(3), SimNs::from_millis(400));
        assert_eq!(rc.backoff_for(4), SimNs::from_millis(450), "capped");
        assert_eq!(rc.backoff_for(63), SimNs::from_millis(450), "no overflow");
        // Default: backoff disabled — legacy retry schedule exactly.
        let off = RecoveryConfig::default();
        assert_eq!(off.backoff_for(5), SimNs::ZERO);
    }

    #[test]
    fn overhead_scales_with_checkpoints() {
        let o = checkpoint_overhead(100, &cfg(), SimNs::from_micros(50));
        assert_eq!(o, SimNs::from_micros(500));
    }
}
