//! Marvel client — the user-facing entry point (Figure 3, step 1):
//! deploy, stage input, run, collect results. One call per
//! (system-config, workload, input-size) cell of the evaluation grid.

use crate::mapreduce::{
    run_job, run_stage, stage_input, stage_named_input, Cluster, JobResult,
    StageInput, SystemConfig,
};
use crate::mapreduce::Workload;
use crate::runtime::{default_artifacts_dir, RtEngine};

use super::deploy::ClusterSpec;

/// The user-facing client: deploy, stage, run, collect (Figure 3,
/// step 1).
pub struct Marvel {
    pub spec: ClusterSpec,
    pub rt: RtEngine,
    pub seed: u64,
}

impl Marvel {
    /// Create a client, loading AOT artifacts when present (PJRT mode)
    /// or falling back to the Rust oracle.
    pub fn new(spec: ClusterSpec, seed: u64) -> Result<Marvel, String> {
        let dir = default_artifacts_dir();
        let rt = RtEngine::load(dir.as_deref())?;
        Ok(Marvel { spec, rt, seed })
    }

    /// Run a workload with `bytes` of input under a system config on a
    /// fresh deployment. Returns the full job report.
    pub fn run(
        &mut self,
        cfg: &SystemConfig,
        wl: &dyn Workload,
        bytes: u64,
    ) -> JobResult {
        let mut cluster = self.spec.deploy(cfg);
        let input =
            match stage_input(&mut cluster, cfg, wl, bytes, self.seed) {
                Ok(p) => p,
                Err(e) => {
                    return JobResult::failed(wl.name(), &cfg.name, bytes, e)
                }
            };
        run_job(&mut cluster, cfg, wl, &input, &mut self.rt, self.seed)
    }

    /// Run a workload on an *existing* deployment instead of a fresh
    /// one: warm container pools, cache contents, YARN queues, and the
    /// virtual clock all carry across calls — so a second job on the
    /// same cluster pays zero cold starts for containers the first job
    /// already warmed. `job` must be unique per call on one cluster
    /// (it namespaces the input path and every shuffle/output key).
    pub fn run_shared(
        &mut self,
        cluster: &mut Cluster,
        cfg: &SystemConfig,
        wl: &dyn Workload,
        bytes: u64,
        job: &str,
    ) -> JobResult {
        let path = format!("{job}/input");
        let input = match stage_named_input(
            cluster, cfg, wl, bytes, self.seed, &path,
        ) {
            Ok(p) => p,
            Err(e) => return JobResult::failed(job, &cfg.name, bytes, e),
        };
        match run_stage(
            cluster,
            cfg,
            wl,
            job,
            StageInput::Path(input),
            &mut self.rt,
            self.seed,
        ) {
            Ok(r) => r,
            Err(e) => JobResult::failed(job, &cfg.name, bytes, e),
        }
    }

    /// Convenience: run the same workload/size across several configs
    /// (one Figure 4/5 x-axis point).
    pub fn compare(
        &mut self,
        configs: &[SystemConfig],
        wl: &dyn Workload,
        bytes: u64,
    ) -> Vec<JobResult> {
        configs.iter().map(|c| self.run(c, wl, bytes)).collect()
    }
}

/// Relative reduction of `b` vs `a` job time: (a - b) / a.
pub fn reduction(a: &JobResult, b: &JobResult) -> f64 {
    let (ta, tb) = (a.job_time.as_secs_f64(), b.job_time.as_secs_f64());
    if ta <= 0.0 {
        return 0.0;
    }
    (ta - tb) / ta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MIB;
    use crate::workloads::WordCount;

    #[test]
    fn small_real_wordcount_all_configs() {
        let mut m = Marvel::new(ClusterSpec::default(), 42).unwrap();
        let wc = WordCount::new(2000, 1.07, &m.rt);
        let configs = [
            SystemConfig::corral_lambda(),
            SystemConfig::marvel_hdfs(),
            SystemConfig::marvel_igfs(),
        ];
        let results = m.compare(&configs, &wc, 4 * MIB);
        for r in &results {
            assert!(r.ok(), "{}: {:?}", r.config, r.failed);
            assert_eq!(r.input_bytes, 4 * MIB);
            assert!(r.job_time.as_secs_f64() > 0.0);
            assert!(r.intermediate_bytes > 0);
            assert!(r.output_bytes > 0);
        }
        // The paper's ordering: Lambda+S3 slowest, IGFS fastest.
        assert!(results[0].job_time > results[1].job_time,
                "lambda {} vs hdfs {}", results[0].job_time,
                results[1].job_time);
        assert!(results[1].job_time >= results[2].job_time,
                "hdfs {} vs igfs {}", results[1].job_time,
                results[2].job_time);
    }

    #[test]
    fn lambda_fails_past_transfer_limit() {
        let mut m = Marvel::new(ClusterSpec::default(), 42).unwrap();
        let wc = WordCount::new(2000, 1.07, &m.rt);
        let r = m.run(&SystemConfig::corral_lambda(), &wc,
                      16_000_000_000);
        assert!(!r.ok(), "16 GB should exceed the 15 GB quota");
        let r = m.run(&SystemConfig::marvel_igfs(), &wc, 16_000_000_000);
        assert!(r.ok(), "Marvel must survive 16 GB: {:?}", r.failed);
    }

    #[test]
    fn determinism_same_seed_same_times() {
        let run = || {
            let mut m = Marvel::new(ClusterSpec::default(), 7).unwrap();
            let wc = WordCount::new(1000, 1.07, &m.rt);
            m.run(&SystemConfig::marvel_igfs(), &wc, 2 * MIB).job_time
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn reduction_math() {
        let mut a = JobResult::failed("x", "a", 0, "".into());
        a.failed = None;
        a.job_time = crate::sim::SimNs::from_secs_f64(10.0);
        let mut b = a.clone();
        b.job_time = crate::sim::SimNs::from_secs_f64(2.0);
        assert!((reduction(&a, &b) - 0.8).abs() < 1e-9);
    }
}
