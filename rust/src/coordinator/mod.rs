//! Marvel coordinator: deployment automation, the client API tying the
//! Figure 3 workflow together, and checkpoint-based recovery (§4.3).
//!
//! See `ARCHITECTURE.md` for how deployment composes the layers.

pub mod deploy;
pub mod marvel;
pub mod recovery;

pub use deploy::ClusterSpec;
pub use marvel::{reduction, Marvel};
pub use recovery::{
    run_with_failures, AttemptSeg, FailurePlan, RecoveryConfig, TaskRecovery,
};
