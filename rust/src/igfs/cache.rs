//! Per-node in-memory cache with capacity enforcement and LRU eviction.
//!
//! Evicted entries are demoted to a *backing tier* rather than dropped:
//! this is the paper's §4.3 future-work design ("Ignite as a distributed
//! database on top of PMEM — intermediate data persisted while available
//! in DRAM") — a get may therefore hit DRAM (fast) or the backing tier
//! (PMEM-speed), and the ablation bench sweeps the DRAM capacity.

use std::collections::HashMap;

use crate::storage::Payload;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Which cache tier served (or would serve) an entry.
pub enum Tier {
    Dram,
    Backing,
}

#[derive(Clone, Debug, Default)]
/// Hit/miss/eviction counters for one cache node (or a cluster-wide
/// aggregate; deltas attribute activity to a job or tenant).
pub struct CacheStats {
    pub hits_dram: u64,
    pub hits_backing: u64,
    pub misses: u64,
    pub evictions: u64,
    pub bytes_evicted: u64,
    /// Reads that the cache could not serve (node blacked out or entry
    /// dropped) and a lower storage tier (HDFS/S3) served instead —
    /// degraded-mode I/O, not an error.
    pub degraded_reads: u64,
}

impl CacheStats {
    /// Accumulate another counter set (per-tenant aggregation across a
    /// co-run's jobs).
    pub fn add(&mut self, other: &CacheStats) {
        self.hits_dram += other.hits_dram;
        self.hits_backing += other.hits_backing;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.bytes_evicted += other.bytes_evicted;
        self.degraded_reads += other.degraded_reads;
    }

    /// Counters accumulated since `base` was captured (per-job / per-
    /// pipeline-stage attribution over a shared cluster's caches).
    pub fn delta_since(&self, base: &CacheStats) -> CacheStats {
        CacheStats {
            hits_dram: self.hits_dram.saturating_sub(base.hits_dram),
            hits_backing: self.hits_backing.saturating_sub(base.hits_backing),
            misses: self.misses.saturating_sub(base.misses),
            evictions: self.evictions.saturating_sub(base.evictions),
            bytes_evicted: self
                .bytes_evicted
                .saturating_sub(base.bytes_evicted),
            degraded_reads: self
                .degraded_reads
                .saturating_sub(base.degraded_reads),
        }
    }
}

#[derive(Debug)]
/// One node's share of the distributed cache: a DRAM-capacity LRU
/// over a PMEM-speed backing tier.
pub struct CacheNode {
    capacity: u64,
    used: u64,
    entries: HashMap<String, (Payload, u64)>, // value, lru stamp
    backing: HashMap<String, Payload>,
    clock: u64,
    pub stats: CacheStats,
}

impl CacheNode {
    pub fn new(capacity: u64) -> CacheNode {
        CacheNode {
            capacity,
            used: 0,
            entries: HashMap::new(),
            backing: HashMap::new(),
            clock: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn used(&self) -> u64 {
        self.used
    }
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Insert a value; evicts LRU entries to the backing tier until the
    /// new value fits. Values larger than the whole cache go straight to
    /// backing.
    pub fn put(&mut self, key: &str, value: Payload) {
        let len = value.len();
        if let Some((old, _)) = self.entries.remove(key) {
            self.used -= old.len();
        }
        self.backing.remove(key);
        if len > self.capacity {
            self.stats.evictions += 1;
            self.stats.bytes_evicted += len;
            self.backing.insert(key.to_string(), value);
            return;
        }
        while self.used + len > self.capacity {
            self.evict_one();
        }
        let stamp = self.tick();
        self.used += len;
        self.entries.insert(key.to_string(), (value, stamp));
    }

    fn evict_one(&mut self) {
        let victim = self
            .entries
            .iter()
            .min_by_key(|(k, (_, stamp))| (*stamp, (*k).clone()))
            .map(|(k, _)| k.clone());
        if let Some(k) = victim {
            let (v, _) = self.entries.remove(&k).unwrap();
            self.used -= v.len();
            self.stats.evictions += 1;
            self.stats.bytes_evicted += v.len();
            self.backing.insert(k, v);
        } else {
            panic!("evict_one on empty cache (value larger than capacity?)");
        }
    }

    /// Fetch; returns which tier served it (time plane differs).
    ///
    /// A backing-tier hit *promotes* the entry back into DRAM (with
    /// normal LRU eviction to make room) — a hot key that was evicted
    /// once must not stay PMEM-priced forever. The returned tier is
    /// the tier that *served* this request (the promotion benefits the
    /// next one), and `hits_backing` counts accordingly. Known
    /// tradeoff of promote-always: a working set just over DRAM
    /// capacity ping-pongs (each promotion demotes the other key), so
    /// such sets pay backing price on every access — the PMEM tier
    /// keeps that a constant-factor cost, not a miss.
    pub fn get(&mut self, key: &str) -> Option<(Payload, Tier)> {
        if let Some((v, stamp)) = self.entries.get_mut(key) {
            *stamp = self.clock + 1;
            self.clock += 1;
            self.stats.hits_dram += 1;
            return Some((v.clone(), Tier::Dram));
        }
        if let Some(v) = self.backing.remove(key) {
            self.stats.hits_backing += 1;
            let len = v.len();
            if len > self.capacity {
                // Too big for DRAM ever: stays on the backing tier.
                self.backing.insert(key.to_string(), v.clone());
                return Some((v, Tier::Backing));
            }
            while self.used + len > self.capacity {
                self.evict_one();
            }
            let stamp = self.tick();
            self.used += len;
            self.entries.insert(key.to_string(), (v.clone(), stamp));
            return Some((v, Tier::Backing));
        }
        self.stats.misses += 1;
        None
    }

    /// Non-mutating probe: the stored value's length in either tier.
    /// No hit/miss accounting — planners use this to size work without
    /// disturbing the stats a later `get` will record.
    pub fn len_of(&self, key: &str) -> Option<u64> {
        self.entries
            .get(key)
            .map(|(v, _)| v.len())
            .or_else(|| self.backing.get(key).map(|v| v.len()))
    }

    pub fn contains(&self, key: &str) -> bool {
        self.len_of(key).is_some()
    }

    pub fn remove(&mut self, key: &str) -> bool {
        let mut found = false;
        if let Some((v, _)) = self.entries.remove(key) {
            self.used -= v.len();
            found = true;
        }
        found |= self.backing.remove(key).is_some();
        found
    }

    /// Blackout: drop everything in both tiers (DRAM and PMEM backing
    /// both live on the failed node). Returns bytes dropped. Stats
    /// survive — the node's history is still real even if its data
    /// isn't.
    pub fn clear(&mut self) -> u64 {
        let dram: u64 = self.entries.values().map(|(v, _)| v.len()).sum();
        let back: u64 = self.backing.values().map(|v| v.len()).sum();
        self.entries.clear();
        self.backing.clear();
        self.used = 0;
        dram + back
    }

    pub fn keys(&self) -> Vec<String> {
        let mut ks: Vec<String> = self
            .entries
            .keys()
            .chain(self.backing.keys())
            .cloned()
            .collect();
        ks.sort();
        ks.dedup();
        ks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_dram() {
        let mut c = CacheNode::new(100);
        c.put("a", Payload::real(vec![1; 10]));
        let (v, tier) = c.get("a").unwrap();
        assert_eq!(v.len(), 10);
        assert_eq!(tier, Tier::Dram);
        assert_eq!(c.used(), 10);
    }

    #[test]
    fn lru_eviction_to_backing() {
        let mut c = CacheNode::new(100);
        c.put("a", Payload::synthetic(60));
        c.put("b", Payload::synthetic(30));
        c.get("a"); // a is now more recent than b
        c.put("c", Payload::synthetic(40)); // evicts b (LRU)
        // b is served from backing — and promoted back into DRAM,
        // which demotes a (now the LRU entry) to make room.
        assert_eq!(c.get("b").unwrap().1, Tier::Backing);
        assert_eq!(c.get("b").unwrap().1, Tier::Dram);
        assert_eq!(c.get("a").unwrap().1, Tier::Backing);
        assert_eq!(c.stats.evictions, 3); // b, then a, then c (a returns)
        assert!(c.used() <= c.capacity());
    }

    #[test]
    fn backing_hit_promotes_to_dram() {
        // Regression: a hot key evicted once used to stay PMEM-priced
        // forever — `get` never moved a backing hit back into DRAM.
        let mut c = CacheNode::new(100);
        c.put("hot", Payload::synthetic(80));
        c.put("filler", Payload::synthetic(80)); // demotes hot
        assert_eq!(c.get("hot").unwrap().1, Tier::Backing);
        assert_eq!(c.stats.hits_backing, 1, "serving tier counted");
        // Promoted: every later hit is DRAM-priced again.
        assert_eq!(c.get("hot").unwrap().1, Tier::Dram);
        assert_eq!(c.get("hot").unwrap().1, Tier::Dram);
        assert_eq!(c.stats.hits_backing, 1);
        assert_eq!(c.stats.hits_dram, 2);
        // Capacity invariant held throughout: filler was demoted.
        assert!(c.used() <= c.capacity());
        assert_eq!(c.get("filler").unwrap().1, Tier::Backing);
    }

    #[test]
    fn oversized_value_goes_to_backing() {
        let mut c = CacheNode::new(10);
        c.put("huge", Payload::synthetic(1000));
        assert_eq!(c.get("huge").unwrap().1, Tier::Backing);
        assert_eq!(c.used(), 0);
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut c = CacheNode::new(100);
        for i in 0..50 {
            c.put(&format!("k{i}"), Payload::synthetic(17));
            assert!(c.used() <= 100, "used {} > cap", c.used());
        }
    }

    #[test]
    fn overwrite_replaces() {
        let mut c = CacheNode::new(100);
        c.put("a", Payload::synthetic(50));
        c.put("a", Payload::synthetic(20));
        assert_eq!(c.used(), 20);
        assert_eq!(c.get("a").unwrap().0.len(), 20);
    }

    #[test]
    fn miss_counted() {
        let mut c = CacheNode::new(10);
        assert!(c.get("nope").is_none());
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn len_of_probes_both_tiers_without_stats() {
        let mut c = CacheNode::new(100);
        c.put("a", Payload::synthetic(30));
        c.put("big", Payload::synthetic(500)); // straight to backing
        assert_eq!(c.len_of("a"), Some(30));
        assert_eq!(c.len_of("big"), Some(500));
        assert_eq!(c.len_of("nope"), None);
        assert!(c.contains("a") && !c.contains("nope"));
        // The probe recorded neither hits nor misses.
        assert_eq!(c.stats.hits_dram + c.stats.hits_backing, 0);
        assert_eq!(c.stats.misses, 0);
    }

    #[test]
    fn stats_delta_since() {
        let mut c = CacheNode::new(100);
        c.put("a", Payload::synthetic(10));
        c.get("a");
        let base = c.stats.clone();
        c.get("a");
        c.get("missing");
        let d = c.stats.delta_since(&base);
        assert_eq!(d.hits_dram, 1);
        assert_eq!(d.misses, 1);
    }

    #[test]
    fn remove_both_tiers() {
        let mut c = CacheNode::new(10);
        c.put("a", Payload::synthetic(5));
        c.put("big", Payload::synthetic(100));
        assert!(c.remove("a"));
        assert!(c.remove("big"));
        assert!(!c.remove("a"));
        assert_eq!(c.used(), 0);
    }
}
