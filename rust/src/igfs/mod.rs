//! IGFS analog — Apache Ignite's role in Marvel: a distributed
//! in-memory cache for intermediate MapReduce data plus the function
//! state store enabling stateful serverless execution.
//!
//! Keys are rendezvous-hashed to owner nodes; values live in the
//! owner's DRAM-capacity cache with LRU demotion to a PMEM backing tier
//! (the paper's §4.3 future-work design, used by the ablation bench).
//!
//! See `ARCHITECTURE.md` (Layer 4) for the tiering + tenancy model.

pub mod cache;
pub mod partition;
pub mod state;

use std::collections::HashMap;

use crate::net::{DeviceRole, NodeId, Topology};
use crate::sim::Stage;
use crate::storage::{Access, Dir, Payload};

pub use cache::{CacheNode, CacheStats, Tier};
pub use partition::PartitionMap;
pub use state::{StateStore, TaskState};

/// The distributed in-memory cache: rendezvous-partitioned
/// [`CacheNode`]s plus the function state store.
pub struct Igfs {
    pub partitions: PartitionMap,
    pub caches: HashMap<NodeId, CacheNode>,
    pub state: StateStore,
    /// Backing tier device role for evicted entries (Pmem in Marvel).
    pub backing_role: DeviceRole,
}

impl Igfs {
    /// `capacity_per_node` is the DRAM budget Ignite gets on each node.
    pub fn new(topo: &Topology, capacity_per_node: u64) -> Igfs {
        let members: Vec<NodeId> =
            (0..topo.n_nodes()).map(NodeId).collect();
        let caches = members
            .iter()
            .map(|n| (*n, CacheNode::new(capacity_per_node)))
            .collect();
        Igfs {
            partitions: PartitionMap::new(members),
            caches,
            state: StateStore::new(),
            backing_role: DeviceRole::Pmem,
        }
    }

    pub fn owner(&self, key: &str) -> NodeId {
        self.partitions.owner(key)
    }

    /// Store a value from `from` node; returns time-plane stages:
    /// LAN hop to the owner (if remote) + a DRAM write on the owner.
    pub fn put(
        &mut self,
        topo: &Topology,
        from: NodeId,
        key: &str,
        value: Payload,
        tag: u32,
    ) -> Vec<Stage> {
        let owner = self.owner(key);
        let bytes = value.len();
        self.caches.get_mut(&owner).unwrap().put(key, value);
        let dram = topo
            .device_of(owner, DeviceRole::Dram)
            .map(|d| topo.device(d))
            .expect("owner lacks DRAM device");
        let mut path = topo.lan_path(from, owner);
        path.push(dram.channel(Dir::Write));
        vec![
            Stage::Delay(dram.latency(Access::Seq, Dir::Write)),
            Stage::Flow { bytes: bytes as f64, path, tag, timeout: None },
        ]
    }

    /// Fetch a value to `to` node. Returns (value, stages). The stage
    /// cost depends on the tier that served the hit: DRAM read vs the
    /// PMEM backing tier (paper §4.3).
    pub fn get(
        &mut self,
        topo: &Topology,
        to: NodeId,
        key: &str,
        tag: u32,
    ) -> Option<(Payload, Vec<Stage>)> {
        self.get_tiered(topo, to, key, tag).map(|(v, st, _)| (v, st))
    }

    /// `get` with the serving tier exposed — pipeline stage handoff
    /// accounting distinguishes a DRAM hit from a PMEM backing hit.
    pub fn get_tiered(
        &mut self,
        topo: &Topology,
        to: NodeId,
        key: &str,
        tag: u32,
    ) -> Option<(Payload, Vec<Stage>, Tier)> {
        let owner = self.owner(key);
        let (value, tier) = self.caches.get_mut(&owner)?.get(key)?;
        let role = match tier {
            Tier::Dram => DeviceRole::Dram,
            Tier::Backing => self.backing_role,
        };
        let dev = topo
            .device_of(owner, role)
            .map(|d| topo.device(d))
            .expect("owner lacks tier device");
        let mut path = vec![dev.channel(Dir::Read)];
        path.extend(topo.lan_path(owner, to));
        let stages = vec![
            Stage::Delay(dev.latency(Access::Rand, Dir::Read)),
            Stage::Flow {
                bytes: dev.effective_bytes(value.len(), Access::Seq, Dir::Read),
                path,
                tag,
                timeout: None,
            },
        ];
        Some((value, stages, tier))
    }

    /// Non-mutating length probe across tiers (no hit/miss accounting).
    pub fn len_of(&self, key: &str) -> Option<u64> {
        self.caches.get(&self.owner(key))?.len_of(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.len_of(key).is_some()
    }

    pub fn remove(&mut self, key: &str) -> bool {
        let owner = self.owner(key);
        self.caches.get_mut(&owner).map_or(false, |c| c.remove(key))
    }

    pub fn total_used(&self) -> u64 {
        self.caches.values().map(|c| c.used()).sum()
    }

    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for c in self.caches.values() {
            s.add(&c.stats);
        }
        s
    }

    /// Cache-node blackout: drop the node's DRAM *and* PMEM contents
    /// and remove it from the rendezvous partition map so later puts
    /// land on live nodes. Idempotent — failing a node twice (or a
    /// node that was never a member) drops nothing the second time.
    /// Returns bytes dropped, or `Err` when the blackout would empty
    /// the partition map (losing the whole cache tier is cluster
    /// teardown, not degradation).
    pub fn fail_cache_node(&mut self, node: NodeId) -> Result<u64, String> {
        let was_member = self.partitions.remove(node)?;
        if !was_member {
            return Ok(0);
        }
        Ok(self.caches.get_mut(&node).map_or(0, |c| c.clear()))
    }

    /// Record a degraded read: the cache tier could not serve `key`
    /// (blackout victim) and a lower tier (HDFS/S3) did. Attributed to
    /// the key's *current* owner so per-job stat deltas see it.
    pub fn note_degraded(&mut self, key: &str) {
        let owner = self.owner(key);
        if let Some(c) = self.caches.get_mut(&owner) {
            c.stats.degraded_reads += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::TopologyBuilder;
    use crate::sim::Engine;
    use crate::util::bytes::GIB;

    fn setup(nodes: usize, cap: u64) -> (Engine, Topology, Igfs) {
        let mut e = Engine::new();
        let t = TopologyBuilder { nodes, ..Default::default() }.build(&mut e);
        let g = Igfs::new(&t, cap);
        (e, t, g)
    }

    #[test]
    fn put_get_roundtrip_any_node() {
        let (mut e, t, mut g) = setup(3, GIB);
        let st = g.put(&t, NodeId(0), "k1", Payload::real(vec![5; 100]), 0);
        e.spawn("p", st);
        let (v, st) = g.get(&t, NodeId(2), "k1", 0).unwrap();
        e.spawn("g", st);
        e.run().unwrap();
        assert_eq!(v.len(), 100);
    }

    #[test]
    fn get_tiered_reports_serving_tier_and_len_probe_is_silent() {
        let (_, t, mut g) = setup(1, 100);
        g.put(&t, NodeId(0), "a", Payload::synthetic(80), 0);
        g.put(&t, NodeId(0), "b", Payload::synthetic(80), 0); // demotes a
        assert_eq!(g.len_of("a"), Some(80));
        assert_eq!(g.len_of("b"), Some(80));
        assert_eq!(g.len_of("zzz"), None);
        assert!(g.contains("a") && !g.contains("zzz"));
        // len_of probes recorded nothing.
        let s = g.stats();
        assert_eq!(s.hits_dram + s.hits_backing + s.misses, 0);
        let (_, _, tier) = g.get_tiered(&t, NodeId(0), "a", 0).unwrap();
        assert_eq!(tier, Tier::Backing);
        // The backing hit promoted a into DRAM, demoting b — two 80 B
        // values ping-pong through a 100 B cache, so each get serves
        // from backing and promotes for the next round.
        let (_, _, tier) = g.get_tiered(&t, NodeId(0), "b", 0).unwrap();
        assert_eq!(tier, Tier::Backing);
        let (_, _, tier) = g.get_tiered(&t, NodeId(0), "a", 0).unwrap();
        assert_eq!(tier, Tier::Backing);
        assert_eq!(g.stats().hits_dram, 0);
        assert_eq!(g.stats().hits_backing, 3);
    }

    #[test]
    fn miss_returns_none() {
        let (_, t, mut g) = setup(2, GIB);
        assert!(g.get(&t, NodeId(0), "absent", 0).is_none());
    }

    #[test]
    fn keys_distribute() {
        let (_, t, mut g) = setup(4, GIB);
        for i in 0..400 {
            g.put(&t, NodeId(0), &format!("k{i}"), Payload::synthetic(10), 0);
        }
        let occupied = g.caches.values().filter(|c| c.used() > 0).count();
        assert_eq!(occupied, 4, "all caches should hold keys");
        assert_eq!(g.total_used(), 4000);
    }

    #[test]
    fn eviction_spills_to_backing_with_pmem_cost() {
        let (mut e, t, mut g) = setup(1, 100);
        g.put(&t, NodeId(0), "a", Payload::synthetic(80), 0);
        g.put(&t, NodeId(0), "b", Payload::synthetic(80), 0); // evicts a
        let (_, st) = g.get(&t, NodeId(0), "a", 0).unwrap();
        // Backing-tier read pays PMEM random-read latency (600ns),
        // DRAM would pay 100ns.
        if let Stage::Delay(d) = &st[0] {
            assert_eq!(d.as_nanos(), 600);
        } else {
            panic!("expected delay first");
        }
        e.spawn("g", st);
        e.run().unwrap();
        assert_eq!(g.stats().hits_backing, 1);
    }

    #[test]
    fn fail_cache_node_is_idempotent_and_reroutes_new_keys() {
        let (_, t, mut g) = setup(3, GIB);
        // Spread keys so the victim certainly owns some.
        for i in 0..60 {
            g.put(&t, NodeId(0), &format!("k{i}"), Payload::synthetic(10), 0);
        }
        let victim = NodeId(1);
        let before = g.total_used();
        let dropped = g.fail_cache_node(victim).unwrap();
        assert!(dropped > 0, "victim owned nothing?");
        assert_eq!(g.total_used(), before - dropped);
        // Idempotent: a second blackout drops nothing more.
        assert_eq!(g.fail_cache_node(victim).unwrap(), 0);
        assert_eq!(g.total_used(), before - dropped);
        // New puts land only on live nodes.
        for i in 0..60 {
            let key = format!("post/{i}");
            assert_ne!(g.owner(&key), victim);
            g.put(&t, NodeId(0), &key, Payload::synthetic(10), 0);
        }
        assert_eq!(g.caches[&victim].used(), 0);
        // A victim-owned key now misses (callers degrade to HDFS/S3
        // and note_degraded attributes it to the live owner).
        g.note_degraded("k0");
        assert_eq!(g.stats().degraded_reads, 1);
        // Failing every remaining node is refused, not a panic.
        g.fail_cache_node(NodeId(0)).unwrap();
        let err = g.fail_cache_node(NodeId(2)).unwrap_err();
        assert!(err.contains("last partition-map member"), "{err}");
    }

    #[test]
    fn local_put_faster_than_remote() {
        // put from the owner node vs from another node: remote pays NIC.
        let (_, t, mut g) = setup(2, GIB);
        let key = "some-key";
        let owner = g.owner(key);
        let other = NodeId((owner.0 + 1) % 2);
        let run = |from: NodeId, g: &mut Igfs| {
            let mut e = Engine::new();
            let t2 = TopologyBuilder { nodes: 2, ..Default::default() }
                .build(&mut e);
            // NB: fresh engine, same resource layout as `t`.
            let st = g.put(&t2, from, key, Payload::synthetic(1_250_000_000), 0);
            e.spawn("p", st);
            e.run().unwrap().as_secs_f64()
        };
        let local = run(owner, &mut g);
        let remote = run(other, &mut g);
        let _ = &t;
        // Remote bound by 10 Gb/s NIC (1 s/1.25 GB); local by DRAM bw.
        assert!(remote > 10.0 * local, "local={local} remote={remote}");
    }
}
