//! Rendezvous (highest-random-weight) hashing: maps cache keys to owner
//! nodes with minimal disruption when membership changes — the role
//! Ignite's partition map plays in the paper's deployment.

use crate::net::NodeId;
use crate::util::hash::{fnv1a64, mix64};

#[derive(Clone, Debug)]
/// Rendezvous (highest-random-weight) key → owner-node mapping;
/// stable under membership changes.
pub struct PartitionMap {
    members: Vec<NodeId>,
}

impl PartitionMap {
    pub fn new(members: Vec<NodeId>) -> PartitionMap {
        assert!(!members.is_empty(), "partition map needs members");
        PartitionMap { members }
    }

    pub fn members(&self) -> &[NodeId] {
        &self.members
    }

    /// Owner of a key: the member maximizing mix64(hash(key) ^ node).
    pub fn owner(&self, key: &str) -> NodeId {
        let kh = fnv1a64(key.as_bytes());
        *self
            .members
            .iter()
            .max_by_key(|n| (mix64(kh ^ (n.0 as u64 + 1)), n.0))
            .unwrap()
    }

    /// Owner plus `replicas - 1` backups (distinct members, HRW order).
    pub fn owners(&self, key: &str, replicas: usize) -> Vec<NodeId> {
        let kh = fnv1a64(key.as_bytes());
        let mut scored: Vec<(u64, NodeId)> = self
            .members
            .iter()
            .map(|n| (mix64(kh ^ (n.0 as u64 + 1)), *n))
            .collect();
        scored.sort_by(|a, b| b.cmp(a));
        scored
            .into_iter()
            .take(replicas.max(1).min(self.members.len()))
            .map(|(_, n)| n)
            .collect()
    }

    /// Remove a member (cache-node loss / rebalance). Refuses to drop
    /// the *last* member: rendezvous hashing over zero nodes has no
    /// owner for any key, and `owner`/`owners` would panic on the next
    /// lookup — losing the whole cache tier is cluster teardown, not a
    /// rebalance, and must surface as an error the caller can report
    /// instead of a latent panic (reachable via an all-nodes
    /// `lose_datanodes` failure plan). Returns whether the node was a
    /// member.
    pub fn remove(&mut self, node: NodeId) -> Result<bool, String> {
        if self.members == [node] {
            return Err(format!(
                "cannot remove {node:?}: it is the last partition-map \
                 member — every key would be ownerless"
            ));
        }
        let before = self.members.len();
        self.members.retain(|n| *n != node);
        Ok(self.members.len() < before)
    }

    pub fn add(&mut self, node: NodeId) {
        if !self.members.contains(&node) {
            self.members.push(node);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map(n: usize) -> PartitionMap {
        PartitionMap::new((0..n).map(NodeId).collect())
    }

    #[test]
    fn owner_is_deterministic() {
        let m = map(5);
        for k in ["a", "b", "part/0/7", "x/y/z"] {
            assert_eq!(m.owner(k), m.owner(k));
        }
    }

    #[test]
    fn keys_spread_over_members() {
        let m = map(4);
        let mut counts = [0usize; 4];
        for i in 0..4000 {
            counts[m.owner(&format!("key-{i}")).0] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "skewed: {counts:?}");
        }
    }

    #[test]
    fn membership_change_moves_few_keys() {
        let before = map(5);
        let mut after = before.clone();
        assert_eq!(after.remove(NodeId(4)), Ok(true));
        let mut moved = 0;
        for i in 0..1000 {
            let k = format!("key-{i}");
            if before.owner(&k) != after.owner(&k) {
                moved += 1;
            }
        }
        // Only keys owned by the removed node (≈1/5) should move.
        assert!(moved < 300, "moved {moved}");
    }

    #[test]
    fn removing_the_last_member_is_refused() {
        // Regression: `remove` could empty `members`, after which
        // `owner()` panicked on `.unwrap()` — reachable through an
        // all-nodes `lose_datanodes` plan. The last member now stays
        // and the caller gets an error to report.
        let mut m = map(2);
        assert_eq!(m.remove(NodeId(0)), Ok(true));
        assert_eq!(m.remove(NodeId(0)), Ok(false), "already gone");
        let err = m.remove(NodeId(1)).unwrap_err();
        assert!(err.contains("last partition-map member"), "{err}");
        // The map is still total: every key has an owner, no panic.
        assert_eq!(m.members(), &[NodeId(1)]);
        for k in ["a", "b", "x/y/z"] {
            assert_eq!(m.owner(k), NodeId(1));
            assert_eq!(m.owners(k, 3), vec![NodeId(1)]);
        }
        // Removing a non-member of a singleton map is a no-op, not an
        // error (the guard is about emptying, not about membership).
        assert_eq!(m.remove(NodeId(9)), Ok(false));
    }

    #[test]
    fn owners_distinct_and_capped() {
        let m = map(3);
        let o = m.owners("k", 5);
        assert_eq!(o.len(), 3);
        let mut d = o.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 3);
        assert_eq!(o[0], m.owner("k"));
    }
}
