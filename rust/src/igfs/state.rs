//! Function state store — what makes Marvel's functions *stateful*.
//!
//! Each running function owns a state record (progress counters, offsets
//! of consumed splits, partial aggregates) keyed by (job, task). On
//! failure the re-executed function resumes from the last checkpoint
//! instead of recomputing — exercised by `coordinator::recovery` and the
//! fault-tolerance example.

use std::collections::HashMap;

#[derive(Clone, Debug, PartialEq)]
/// One function's checkpointed execution state.
pub struct TaskState {
    pub job: String,
    pub task: u32,
    /// Monotonic progress marker (e.g. bytes of the split consumed).
    pub progress: u64,
    /// Serialized partial aggregate (opaque to the store).
    pub partial: Vec<u8>,
    /// Attempt that wrote this state.
    pub attempt: u32,
    pub epoch: u64,
}

#[derive(Clone, Debug, Default)]
/// Cluster-wide (job, task) → [`TaskState`] map with zombie-attempt
/// fencing — the paper's stateful-function substrate.
pub struct StateStore {
    entries: HashMap<(String, u32), TaskState>,
    epoch: u64,
    pub checkpoints: u64,
    pub restores: u64,
}

impl StateStore {
    pub fn new() -> StateStore {
        StateStore::default()
    }

    /// Persist a checkpoint. Rejects stale attempts (an old zombie
    /// container must not clobber the retry's progress).
    pub fn checkpoint(
        &mut self,
        job: &str,
        task: u32,
        attempt: u32,
        progress: u64,
        partial: Vec<u8>,
    ) -> Result<(), String> {
        let key = (job.to_string(), task);
        if let Some(prev) = self.entries.get(&key) {
            if attempt < prev.attempt {
                return Err(format!(
                    "stale attempt {attempt} < {}",
                    prev.attempt
                ));
            }
            if attempt == prev.attempt && progress < prev.progress {
                return Err(format!(
                    "progress went backwards: {progress} < {}",
                    prev.progress
                ));
            }
        }
        self.epoch += 1;
        self.checkpoints += 1;
        self.entries.insert(
            key,
            TaskState {
                job: job.to_string(),
                task,
                progress,
                partial,
                attempt,
                epoch: self.epoch,
            },
        );
        Ok(())
    }

    /// Non-mutating read: no restore accounting. Pipeline resume uses
    /// this to *validate* a checkpoint (outputs still resolvable?)
    /// before deciding to consume it via [`StateStore::restore`].
    pub fn peek(&self, job: &str, task: u32) -> Option<&TaskState> {
        self.entries.get(&(job.to_string(), task))
    }

    /// Restore the latest checkpoint for a task, if any.
    pub fn restore(&mut self, job: &str, task: u32) -> Option<TaskState> {
        let v = self.entries.get(&(job.to_string(), task)).cloned();
        if v.is_some() {
            self.restores += 1;
        }
        v
    }

    /// Drop one task's state record — a fresh execution of a reused
    /// task name must not resume from a phantom checkpoint, and a
    /// long-lived server clears records once a task's retries are
    /// resolved. Returns whether anything was removed.
    pub fn remove(&mut self, job: &str, task: u32) -> bool {
        self.entries.remove(&(job.to_string(), task)).is_some()
    }

    /// Drop all state for a completed job.
    pub fn clear_job(&mut self, job: &str) -> usize {
        let before = self.entries.len();
        self.entries.retain(|(j, _), _| j != job);
        before - self.entries.len()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_restore_roundtrip() {
        let mut s = StateStore::new();
        s.checkpoint("job1", 3, 0, 1024, vec![7, 7]).unwrap();
        let st = s.restore("job1", 3).unwrap();
        assert_eq!(st.progress, 1024);
        assert_eq!(st.partial, vec![7, 7]);
        assert!(s.restore("job1", 4).is_none());
    }

    #[test]
    fn peek_does_not_count_as_restore() {
        let mut s = StateStore::new();
        s.checkpoint("j", 0, 0, 5, vec![1]).unwrap();
        assert_eq!(s.peek("j", 0).unwrap().progress, 5);
        assert!(s.peek("j", 1).is_none());
        assert_eq!(s.restores, 0);
        s.restore("j", 0).unwrap();
        assert_eq!(s.restores, 1);
    }

    #[test]
    fn stale_attempt_rejected() {
        let mut s = StateStore::new();
        s.checkpoint("j", 0, 2, 10, vec![]).unwrap();
        assert!(s.checkpoint("j", 0, 1, 99, vec![]).is_err());
        // Newer attempt may restart from 0.
        s.checkpoint("j", 0, 3, 0, vec![]).unwrap();
        assert_eq!(s.restore("j", 0).unwrap().attempt, 3);
    }

    #[test]
    fn progress_monotonic_within_attempt() {
        let mut s = StateStore::new();
        s.checkpoint("j", 0, 1, 100, vec![]).unwrap();
        assert!(s.checkpoint("j", 0, 1, 50, vec![]).is_err());
        s.checkpoint("j", 0, 1, 150, vec![]).unwrap();
    }

    #[test]
    fn remove_is_task_scoped() {
        let mut s = StateStore::new();
        s.checkpoint("j", 0, 0, 1, vec![]).unwrap();
        s.checkpoint("j", 1, 0, 2, vec![]).unwrap();
        assert!(s.remove("j", 0));
        assert!(!s.remove("j", 0));
        assert!(s.restore("j", 0).is_none());
        assert_eq!(s.restore("j", 1).unwrap().progress, 2);
        // A removed key accepts a fresh attempt-0 checkpoint again.
        s.checkpoint("j", 0, 0, 1, vec![]).unwrap();
    }

    #[test]
    fn clear_job_scoped() {
        let mut s = StateStore::new();
        s.checkpoint("a", 0, 0, 1, vec![]).unwrap();
        s.checkpoint("a", 1, 0, 1, vec![]).unwrap();
        s.checkpoint("b", 0, 0, 1, vec![]).unwrap();
        assert_eq!(s.clear_job("a"), 2);
        assert_eq!(s.len(), 1);
        assert!(s.restore("b", 0).is_some());
    }
}
