//! Remote object store — the S3 analog backing the Lambda/Corral
//! baseline (and the "+S3 durability" Marvel variants of Figure 1).
//!
//! Mechanisms modeled (all cited by the paper as the baseline's
//! bottlenecks): per-request round-trip latency, a shared WAN pipe,
//! request-rate throttling per prefix (AWS's published 5 500 GET /
//! 3 500 PUT per second — requests beyond the rate queue, the fluid
//! analog of 503-retry loops), and account-level transfer quotas that
//! fail the job outright (Corral's observed 15 GB failure).
//!
//! See `ARCHITECTURE.md` (Layer 1).

use std::collections::BTreeMap;

use crate::net::{NodeId, Topology};
use crate::sim::{Engine, ResourceId, SimNs, Stage};
use crate::storage::Payload;

/// AWS-published default request rates per prefix.
pub const DEFAULT_GET_RPS: f64 = 5_500.0;
/// AWS's published per-prefix PUT rate limit (requests/second).
pub const DEFAULT_PUT_RPS: f64 = 3_500.0;

#[derive(Clone, Debug)]
/// Remote object store shape: WAN RTT, request rates, quotas.
pub struct ObjStoreConfig {
    pub get_rps: f64,
    pub put_rps: f64,
    /// Per-request round trip (on top of WAN bandwidth time).
    pub request_rtt: SimNs,
    /// Internal frontend bandwidth cap (bytes/sec) across all clients.
    pub frontend_gbps: f64,
    /// Per-connection throughput cap (bytes/sec): a single S3 GET/PUT
    /// stream sustains ~35 MB/s in practice — the mechanism that
    /// throttles Corral's per-function transfers.
    pub stream_bps: f64,
}

impl Default for ObjStoreConfig {
    fn default() -> Self {
        ObjStoreConfig {
            get_rps: DEFAULT_GET_RPS,
            put_rps: DEFAULT_PUT_RPS,
            request_rtt: SimNs::from_millis(20),
            frontend_gbps: 25.0,
            stream_bps: 35e6,
        }
    }
}

/// Data-plane + time-plane handle for the object store.
pub struct ObjectStore {
    objects: BTreeMap<String, Payload>,
    get_rate: ResourceId,
    put_rate: ResourceId,
    frontend_in: ResourceId,
    frontend_out: ResourceId,
    rtt: SimNs,
    stream_bps: f64,
    pub stats: ObjStats,
}

#[derive(Clone, Debug, Default)]
/// Request/byte counters for the object store.
pub struct ObjStats {
    pub gets: u64,
    pub puts: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

impl ObjectStore {
    pub fn new(engine: &mut Engine, cfg: &ObjStoreConfig) -> ObjectStore {
        let bps = cfg.frontend_gbps * 1e9 / 8.0;
        ObjectStore {
            objects: BTreeMap::new(),
            get_rate: engine.add_resource("s3.get_rate", cfg.get_rps),
            put_rate: engine.add_resource("s3.put_rate", cfg.put_rps),
            frontend_in: engine.add_resource("s3.frontend.in", bps),
            frontend_out: engine.add_resource("s3.frontend.out", bps),
            rtt: cfg.request_rtt,
            stream_bps: cfg.stream_bps,
            stats: ObjStats::default(),
        }
    }

    // ---- data plane -------------------------------------------------

    pub fn put(&mut self, key: &str, value: Payload) {
        self.stats.puts += 1;
        self.stats.bytes_in += value.len();
        self.objects.insert(key.to_string(), value);
    }

    pub fn get(&mut self, key: &str) -> Option<Payload> {
        let v = self.objects.get(key).cloned();
        if let Some(p) = &v {
            self.stats.gets += 1;
            self.stats.bytes_out += p.len();
        }
        v
    }

    /// Stat-free length probe: the stored object's size, if present.
    /// Unlike [`ObjectStore::get`], this records neither a GET nor any
    /// byte traffic — planners (`mapreduce::Stores::locate`) size work
    /// without disturbing the stats a later data-plane `get` will
    /// record. Mirrors `igfs::CacheNode::len_of`.
    pub fn len_of(&self, key: &str) -> Option<u64> {
        self.objects.get(key).map(|p| p.len())
    }

    pub fn delete(&mut self, key: &str) -> bool {
        self.objects.remove(key).is_some()
    }

    pub fn list(&self, prefix: &str) -> Vec<String> {
        self.objects
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect()
    }

    pub fn total_bytes(&self) -> u64 {
        self.objects.values().map(|p| p.len()).sum()
    }

    pub fn len(&self) -> usize {
        self.objects.len()
    }

    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }

    // ---- time plane -------------------------------------------------

    /// Stages for one GET of `bytes` flowing down to `node`. Each
    /// request gets a private stream resource capping its rate at
    /// `stream_bps` on top of the shared WAN/frontend fair shares.
    pub fn get_stages(&self, engine: &mut Engine, topo: &Topology,
                      node: NodeId, bytes: u64, tag: u32) -> Vec<Stage> {
        let stream = engine.add_resource("s3.stream", self.stream_bps);
        let mut path = vec![stream, self.frontend_out];
        path.extend(topo.wan_get_path(node));
        vec![
            Stage::Delay(self.rtt),
            // One token through the GET rate limiter (queues under load).
            Stage::Flow { bytes: 1.0, path: vec![self.get_rate], tag, timeout: None },
            Stage::Flow { bytes: bytes as f64, path, tag, timeout: None },
        ]
    }

    /// Stages for one PUT of `bytes` flowing up from `node`.
    pub fn put_stages(&self, engine: &mut Engine, topo: &Topology,
                      node: NodeId, bytes: u64, tag: u32) -> Vec<Stage> {
        let stream = engine.add_resource("s3.stream", self.stream_bps);
        let mut path = vec![stream, self.frontend_in];
        path.extend(topo.wan_put_path(node));
        vec![
            Stage::Delay(self.rtt),
            Stage::Flow { bytes: 1.0, path: vec![self.put_rate], tag, timeout: None },
            Stage::Flow { bytes: bytes as f64, path, tag, timeout: None },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::TopologyBuilder;

    fn setup() -> (Engine, Topology, ObjectStore) {
        let mut e = Engine::new();
        let t = TopologyBuilder::default().build(&mut e);
        let s = ObjectStore::new(&mut e, &ObjStoreConfig::default());
        (e, t, s)
    }

    #[test]
    fn data_plane_roundtrip() {
        let (_, _, mut s) = setup();
        s.put("a/1", Payload::real(vec![1, 2, 3]));
        s.put("a/2", Payload::synthetic(10));
        s.put("b/1", Payload::real(vec![9]));
        assert_eq!(s.get("a/1").unwrap().len(), 3);
        assert_eq!(s.list("a/").len(), 2);
        assert_eq!(s.total_bytes(), 14);
        assert!(s.delete("b/1"));
        assert!(!s.delete("b/1"));
        assert_eq!(s.stats.gets, 1);
        assert_eq!(s.stats.puts, 3);
    }

    #[test]
    fn single_get_is_stream_capped() {
        let (mut e, t, s) = setup();
        // One 350 MB GET: stream cap 35 MB/s dominates the shared WAN
        // → ≈ 10 s + 20 ms RTT.
        let st = s.get_stages(&mut e, &t, NodeId(0), 350_000_000, 0);
        e.spawn("get", st);
        let end = e.run().unwrap().as_secs_f64();
        assert!((end - 10.02).abs() < 0.05, "{end}");
    }

    #[test]
    fn parallel_gets_fill_the_wan() {
        let (mut e, t, s) = setup();
        // 8 × 500 MB in parallel: each stream capped at 35 MB/s
        // (aggregate 280 MB/s < WAN) -> ~14.3 s, far better than the
        // ~114 s eight serial transfers would take.
        for i in 0..8u32 {
            let st = s.get_stages(&mut e, &t, NodeId(0), 500_000_000, i);
            e.spawn(&format!("g{i}"), st);
        }
        let end = e.run().unwrap().as_secs_f64();
        assert!(end > 13.0 && end < 16.0, "{end}");
    }

    #[test]
    fn request_rate_throttles_small_ops() {
        let (mut e, t, s) = setup();
        // 11 000 tiny GETs at 5 500/s ≈ 2 s even though bytes ≈ 0.
        for i in 0..11_000u32 {
            let st = s.get_stages(&mut e, &t, NodeId(0), 1, i);
            e.spawn(&format!("g{i}"), st);
        }
        let end = e.run().unwrap().as_secs_f64();
        assert!(end > 1.8 && end < 2.5, "{end}");
    }

    #[test]
    fn puts_and_gets_use_separate_limiters() {
        let (mut e, t, s) = setup();
        for i in 0..3_500u32 {
            let stp = s.put_stages(&mut e, &t, NodeId(0), 1, i);
            e.spawn(&format!("p{i}"), stp);
            let stg = s.get_stages(&mut e, &t, NodeId(0), 1, i);
            e.spawn(&format!("g{i}"), stg);
        }
        // If they shared one limiter this would take ≈ 7000/4500 s more.
        let end = e.run().unwrap().as_secs_f64();
        assert!(end < 1.6, "{end}");
    }
}
