//! Deterministic discrete-event simulation: the virtual time axis that
//! replaces the paper's physical testbed (see ARCHITECTURE.md, Layer 0).

pub mod clock;
pub mod engine;
pub mod flow;
pub(crate) mod wheel;

pub use clock::SimNs;
pub use engine::{
    BarrierId, CrashEvent, Engine, FlowLog, PoolId, ProcId, ProcState, Stage,
};
pub use flow::{FlowId, FlowSim, ResourceId};
