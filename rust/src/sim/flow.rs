//! Flow-level bandwidth simulation with max–min fair sharing.
//!
//! A *flow* moves `bytes` through a *path* of resources (device read
//! channel → source NIC → destination NIC → device write channel, say).
//! Each resource has a capacity in bytes/sec (or ops/sec for IOPS-class
//! resources). Whenever the active-flow set changes, rates are
//! recomputed by progressive filling: repeatedly find the most
//! constrained resource, freeze the fair share of every unfrozen flow
//! through it, remove its capacity, repeat. This is the classic fluid
//! model used by flow-level datacenter simulators.
//!
//! Degraded-mode I/O: a resource's capacity can vary over virtual time
//! through [`CapacityWindow`]s — a fault window `[t0, t1)` scales the
//! nominal capacity by a factor (0 = full blackout). Shared flows
//! re-rate deterministically at window edges because
//! [`FlowSim::time_to_next_completion`] never lets the engine step
//! across an edge, and [`FlowSim::remove`] lets the engine reap a
//! timed-out flow so a blackout victim does not leak link capacity.

use std::collections::HashMap;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
/// Index of a bandwidth resource (link/channel) in the flow sim.
pub struct ResourceId(pub usize);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
/// Index of an active flow.
pub struct FlowId(pub u64);

#[derive(Clone, Debug)]
/// One capacity-limited bandwidth resource.
pub struct Resource {
    pub name: String,
    pub capacity: f64, // bytes/sec (or ops/sec)
}

#[derive(Clone, Debug)]
struct Flow {
    remaining: f64,
    path: Vec<ResourceId>,
    rate: f64,
    tag: u32,
    total: f64,
}

/// Record of a finished flow, for throughput accounting.
#[derive(Clone, Debug)]
pub struct FlowRecord {
    pub id: FlowId,
    pub bytes: f64,
    pub tag: u32,
}

/// A time-varying capacity fault: over virtual seconds `[t0, t1)`,
/// `resource` serves at `factor` × its nominal capacity. `factor == 0`
/// is a full blackout — flows through the resource starve until the
/// window closes (or their owner reaps them on a deadline).
/// Overlapping windows on one resource take the *worst* (minimum)
/// factor: concurrent faults do not partially cancel each other.
#[derive(Clone, Debug)]
pub struct CapacityWindow {
    pub resource: ResourceId,
    pub t0: f64,
    pub t1: f64,
    pub factor: f64,
}

#[derive(Default)]
/// Max–min fair-share fluid flow simulator.
pub struct FlowSim {
    resources: Vec<Resource>,
    flows: HashMap<FlowId, Flow>,
    next_id: u64,
    dirty: bool,
    /// Scheduled capacity faults, consulted at the current clock.
    windows: Vec<CapacityWindow>,
    /// Virtual seconds elapsed, advanced in lockstep with the engine
    /// via [`FlowSim::advance`] — what decides which windows are open.
    now: f64,
}

const EPS: f64 = 1e-6;

impl FlowSim {
    pub fn new() -> Self {
        FlowSim::default()
    }

    pub fn add_resource(&mut self, name: &str, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0, "resource {name} needs capacity > 0");
        self.resources.push(Resource { name: name.to_string(), capacity });
        ResourceId(self.resources.len() - 1)
    }

    pub fn resource(&self, id: ResourceId) -> &Resource {
        &self.resources[id.0]
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Schedule a capacity fault window. Windows may be added at any
    /// time before or during a run; rates re-derive at the next event.
    pub fn add_capacity_window(
        &mut self,
        resource: ResourceId,
        t0: f64,
        t1: f64,
        factor: f64,
    ) {
        assert!(resource.0 < self.resources.len(), "unknown {resource:?}");
        assert!(t1 > t0, "empty fault window [{t0}, {t1})");
        assert!(
            (0.0..=1.0).contains(&factor),
            "fault factor {factor} outside [0, 1]"
        );
        self.windows.push(CapacityWindow { resource, t0, t1, factor });
        self.dirty = true;
    }

    /// Scheduled fault windows (inspection/reporting hook).
    pub fn capacity_windows(&self) -> &[CapacityWindow] {
        &self.windows
    }

    /// Effective capacity of resource `i` at the current clock: the
    /// nominal capacity scaled by the worst open fault window. The
    /// half-ns slack keeps the integer-ns engine clock (which lands on
    /// window edges via `from_secs_f64_ceil`) on the correct side of
    /// each edge despite f64 accumulation.
    fn effective_capacity(&self, i: usize) -> f64 {
        let mut factor = 1.0f64;
        for w in &self.windows {
            if w.resource.0 == i
                && self.now >= w.t0 - 0.5e-9
                && self.now < w.t1 - 0.5e-9
            {
                factor = factor.min(w.factor);
            }
        }
        self.resources[i].capacity * factor
    }

    /// Seconds until the next window edge strictly ahead of the clock,
    /// if any. The engine must re-rate there: a flow's constant-rate
    /// extrapolation is only valid between edges.
    fn time_to_next_edge(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        for w in &self.windows {
            for e in [w.t0, w.t1] {
                let dt = e - self.now;
                if dt > 1e-9 {
                    t = t.min(dt);
                }
            }
        }
        t.is_finite().then_some(t)
    }

    /// Reap an active flow (deadline enforcement): its claim on every
    /// path resource is released and survivors re-rate at the next
    /// event. Returns false if the flow already completed.
    pub fn remove(&mut self, id: FlowId) -> bool {
        let removed = self.flows.remove(&id).is_some();
        if removed {
            self.dirty = true;
        }
        removed
    }

    /// Total bytes, path, and tag of an active flow — what a retry
    /// must re-issue after reaping it. None once completed/removed.
    pub fn spec_of(&self, id: FlowId) -> Option<(f64, Vec<ResourceId>, u32)> {
        self.flows
            .get(&id)
            .map(|f| (f.total, f.path.clone(), f.tag))
    }

    /// Start a flow of `bytes` through `path`. Zero-byte flows are legal
    /// and complete at the next event boundary.
    pub fn start(&mut self, bytes: f64, path: Vec<ResourceId>, tag: u32) -> FlowId {
        assert!(!path.is_empty(), "flow needs a non-empty path");
        for r in &path {
            assert!(r.0 < self.resources.len(), "unknown resource {r:?}");
        }
        let id = FlowId(self.next_id);
        self.next_id += 1;
        self.flows.insert(
            id,
            Flow { remaining: bytes.max(0.0), path, rate: 0.0, tag, total: bytes.max(0.0) },
        );
        self.dirty = true;
        id
    }

    /// Recompute max–min fair rates (progressive filling).
    fn recompute(&mut self) {
        if !self.dirty {
            return;
        }
        self.dirty = false;
        let mut residual: Vec<f64> = (0..self.resources.len())
            .map(|i| self.effective_capacity(i))
            .collect();
        let mut unfrozen: Vec<FlowId> = self.flows.keys().copied().collect();
        unfrozen.sort_unstable(); // determinism
        for f in self.flows.values_mut() {
            f.rate = 0.0;
        }
        while !unfrozen.is_empty() {
            // Count unfrozen flows per resource.
            let mut counts = vec![0usize; self.resources.len()];
            for id in &unfrozen {
                for r in &self.flows[id].path {
                    counts[r.0] += 1;
                }
            }
            // Bottleneck = resource minimizing residual / count.
            let mut best: Option<(f64, usize)> = None;
            for (i, &c) in counts.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                let share = residual[i] / c as f64;
                if best.map_or(true, |(s, _)| share < s - EPS) {
                    best = Some((share, i));
                }
            }
            let Some((share, bottleneck)) = best else { break };
            // Freeze every unfrozen flow through the bottleneck at `share`.
            let mut still = Vec::with_capacity(unfrozen.len());
            for id in unfrozen {
                let through = self.flows[&id].path.contains(&ResourceId(bottleneck));
                if through {
                    let f = self.flows.get_mut(&id).unwrap();
                    f.rate = share;
                    for r in f.path.clone() {
                        residual[r.0] = (residual[r.0] - share).max(0.0);
                    }
                } else {
                    still.push(id);
                }
            }
            residual[bottleneck] = 0.0;
            unfrozen = still;
        }
    }

    /// Seconds until the next flow event: a completion at current
    /// rates, or a capacity-window edge where rates change. The engine
    /// must not step further than this in one advance — a blacked-out
    /// flow's zero rate is only valid until its window closes.
    pub fn time_to_next_completion(&mut self) -> Option<f64> {
        if self.flows.is_empty() {
            return None;
        }
        self.recompute();
        let mut t = f64::INFINITY;
        for f in self.flows.values() {
            if f.remaining <= EPS {
                return Some(0.0);
            }
            if f.rate > 0.0 {
                t = t.min(f.remaining / f.rate);
            }
        }
        if let Some(edge) = self.time_to_next_edge() {
            t = t.min(edge);
        }
        if t.is_finite() {
            Some(t)
        } else {
            // All active flows fully starved with no window edge ahead
            // — impossible while every resource has positive capacity
            // and fault windows are finite; the engine treats it as a
            // deadlock unless a flow deadline is pending.
            None
        }
    }

    /// Advance all flows by `dt` seconds; return flows that completed.
    pub fn advance(&mut self, dt: f64) -> Vec<FlowRecord> {
        self.recompute();
        let was = self.now;
        self.now += dt;
        // Rates derive from the clock: crossing (or landing on) any
        // window edge invalidates them for the next interval.
        if self
            .windows
            .iter()
            .any(|w| [w.t0, w.t1].iter().any(|e| *e > was - 0.5e-9
                && *e <= self.now + 0.5e-9))
        {
            self.dirty = true;
        }
        let mut done = Vec::new();
        for (id, f) in self.flows.iter_mut() {
            f.remaining -= f.rate * dt;
            // Complete when less than one ns of service remains — the
            // engine's event clock cannot resolve anything finer.
            if f.remaining <= EPS + f.rate * 1e-9 {
                done.push(FlowRecord { id: *id, bytes: f.total, tag: f.tag });
            }
        }
        done.sort_by_key(|r| r.id); // determinism
        for r in &done {
            self.flows.remove(&r.id);
        }
        if !done.is_empty() {
            self.dirty = true;
        }
        done
    }

    /// Current rate of a flow (test hook).
    pub fn rate_of(&mut self, id: FlowId) -> Option<f64> {
        self.recompute();
        self.flows.get(&id).map(|f| f.rate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_gets_full_capacity() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        let f = s.start(1000.0, vec![r], 0);
        assert!((s.rate_of(f).unwrap() - 100.0).abs() < 1e-9);
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn equal_share_two_flows() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        let a = s.start(1000.0, vec![r], 0);
        let b = s.start(1000.0, vec![r], 0);
        assert!((s.rate_of(a).unwrap() - 50.0).abs() < 1e-9);
        assert!((s.rate_of(b).unwrap() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn max_min_reallocates_leftover() {
        // Flow A through narrow (10) + wide (100); flow B through wide only.
        // A bottlenecked at 10; B gets the remaining 90.
        let mut s = FlowSim::new();
        let narrow = s.add_resource("narrow", 10.0);
        let wide = s.add_resource("wide", 100.0);
        let a = s.start(1e6, vec![narrow, wide], 0);
        let b = s.start(1e6, vec![wide], 0);
        assert!((s.rate_of(a).unwrap() - 10.0).abs() < 1e-6);
        assert!((s.rate_of(b).unwrap() - 90.0).abs() < 1e-6);
    }

    #[test]
    fn completion_frees_bandwidth() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        let _a = s.start(100.0, vec![r], 1); // 2s at 50
        let b = s.start(1000.0, vec![r], 2);
        let t1 = s.time_to_next_completion().unwrap(); // a finishes at 2s
        assert!((t1 - 2.0).abs() < 1e-9);
        let done = s.advance(t1);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tag, 1);
        // b now alone: rate 100, remaining 900 → 9s
        assert!((s.rate_of(b).unwrap() - 100.0).abs() < 1e-9);
        let t2 = s.time_to_next_completion().unwrap();
        assert!((t2 - 9.0).abs() < 1e-6);
    }

    #[test]
    fn zero_byte_flow_completes_immediately() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        s.start(0.0, vec![r], 7);
        let t = s.time_to_next_completion().unwrap();
        assert_eq!(t, 0.0);
        let done = s.advance(0.0);
        assert_eq!(done.len(), 1);
    }

    #[test]
    fn conservation_total_rate_le_capacity() {
        let mut s = FlowSim::new();
        let r1 = s.add_resource("a", 37.0);
        let r2 = s.add_resource("b", 53.0);
        let mut ids = Vec::new();
        for i in 0..10 {
            let path = match i % 3 {
                0 => vec![r1],
                1 => vec![r2],
                _ => vec![r1, r2],
            };
            ids.push(s.start(1e9, path, 0));
        }
        let mut through_r1 = 0.0;
        let mut through_r2 = 0.0;
        for (i, id) in ids.iter().enumerate() {
            let rate = s.rate_of(*id).unwrap();
            if i % 3 == 0 || i % 3 == 2 {
                through_r1 += rate;
            }
            if i % 3 == 1 || i % 3 == 2 {
                through_r2 += rate;
            }
        }
        assert!(through_r1 <= 37.0 + 1e-6, "r1 oversubscribed {through_r1}");
        assert!(through_r2 <= 53.0 + 1e-6, "r2 oversubscribed {through_r2}");
    }

    #[test]
    fn slowdown_window_stretches_the_transfer() {
        // 1000 B over 100 B/s, but [2, 6) serves at 1/4 capacity:
        // 2 s × 100 + 4 s × 25 = 300 B by t=6, then 700/100 = 7 s more.
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        s.add_capacity_window(r, 2.0, 6.0, 0.25);
        let f = s.start(1000.0, vec![r], 0);
        assert!((s.rate_of(f).unwrap() - 100.0).abs() < 1e-9);
        // First event is the window opening, not a completion.
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 2.0).abs() < 1e-9, "edge at 2s, got {t}");
        assert!(s.advance(t).is_empty());
        assert!((s.rate_of(f).unwrap() - 25.0).abs() < 1e-9);
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 4.0).abs() < 1e-9, "next edge at 6s, got {t}");
        assert!(s.advance(t).is_empty());
        assert!((s.rate_of(f).unwrap() - 100.0).abs() < 1e-9);
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 7.0).abs() < 1e-6, "remaining 700 B, got {t}");
        assert_eq!(s.advance(t).len(), 1);
    }

    #[test]
    fn blackout_starves_then_resumes_at_the_edge() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        s.add_capacity_window(r, 0.0, 3.0, 0.0);
        let f = s.start(100.0, vec![r], 0);
        assert_eq!(s.rate_of(f).unwrap(), 0.0, "blacked out");
        // A starved flow must not report None while an edge is ahead.
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 3.0).abs() < 1e-9, "wait for the window edge");
        assert!(s.advance(t).is_empty());
        assert!((s.rate_of(f).unwrap() - 100.0).abs() < 1e-9);
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 1.0).abs() < 1e-6);
    }

    #[test]
    fn overlapping_windows_take_the_worst_factor() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        s.add_capacity_window(r, 0.0, 10.0, 0.5);
        s.add_capacity_window(r, 0.0, 4.0, 0.0);
        let f = s.start(1000.0, vec![r], 0);
        assert_eq!(s.rate_of(f).unwrap(), 0.0, "blackout wins");
    }

    #[test]
    fn removed_flow_returns_its_share_to_survivors() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        let a = s.start(1000.0, vec![r], 0);
        let b = s.start(1000.0, vec![r], 1);
        assert!((s.rate_of(a).unwrap() - 50.0).abs() < 1e-9);
        assert!(s.spec_of(a).is_some());
        assert!(s.remove(a), "active flow reaped");
        assert!(!s.remove(a), "double-reap is a no-op");
        assert!(s.spec_of(a).is_none());
        assert!((s.rate_of(b).unwrap() - 100.0).abs() < 1e-9);
        assert_eq!(s.active_flows(), 1);
    }
}
