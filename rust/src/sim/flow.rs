//! Flow-level bandwidth simulation with max–min fair sharing.
//!
//! A *flow* moves `bytes` through a *path* of resources (device read
//! channel → source NIC → destination NIC → device write channel, say).
//! Each resource has a capacity in bytes/sec (or ops/sec for IOPS-class
//! resources). Whenever the active-flow set changes, rates are
//! recomputed by progressive filling: repeatedly find the most
//! constrained resource, freeze the fair share of every unfrozen flow
//! through it, remove its capacity, repeat. This is the classic fluid
//! model used by flow-level datacenter simulators.
//!
//! **Incremental re-rating.** Progressive filling is defined and
//! executed *per connected component* of the flow↔resource bipartite
//! graph: a change (flow added/removed/completed, window edge crossed)
//! marks its path resources dirty, and the next recompute refills only
//! the components reachable from dirty resources. Components that
//! share no resource cannot influence each other's shares, so an
//! untouched component's rates are bit-for-bit what a full refill
//! would produce — the invariant the differential suite
//! (`rust/tests/engine_equiv.rs`) pins. [`FlowSim::set_full_rerate`]
//! retains the naive mark-everything-dirty behavior as the reference
//! core for that suite.
//!
//! Paths are interned into a shared arena ([`PathId`]): the engine's
//! compiled stage programs and flow-retry re-issues reference a span,
//! not a cloned `Vec<ResourceId>` per transfer.
//!
//! Degraded-mode I/O: a resource's capacity can vary over virtual time
//! through [`CapacityWindow`]s — a fault window `[t0, t1)` scales the
//! nominal capacity by a factor (0 = full blackout). Shared flows
//! re-rate deterministically at window edges because
//! [`FlowSim::time_to_next_completion`] never lets the engine step
//! across an edge, and [`FlowSim::remove`] lets the engine reap a
//! timed-out flow so a blackout victim does not leak link capacity.
//! Window edges are kept pre-sorted by time with monotone cursors, so
//! per-advance edge checks no longer scan every scheduled window.

use std::collections::{HashMap, HashSet};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
/// Index of a bandwidth resource (link/channel) in the flow sim.
pub struct ResourceId(pub usize);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
/// Index of an active flow.
pub struct FlowId(pub u64);

/// Index of an interned resource path in the flow sim's path arena.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct PathId(pub(crate) u32);

#[derive(Clone, Debug)]
/// One capacity-limited bandwidth resource.
pub struct Resource {
    pub name: String,
    pub capacity: f64, // bytes/sec (or ops/sec)
}

#[derive(Clone, Debug)]
struct Flow {
    remaining: f64,
    path: PathId,
    rate: f64,
    tag: u32,
    total: f64,
}

/// Record of a finished flow, for throughput accounting.
#[derive(Clone, Debug)]
pub struct FlowRecord {
    pub id: FlowId,
    pub bytes: f64,
    pub tag: u32,
}

/// A time-varying capacity fault: over virtual seconds `[t0, t1)`,
/// `resource` serves at `factor` × its nominal capacity. `factor == 0`
/// is a full blackout — flows through the resource starve until the
/// window closes (or their owner reaps them on a deadline).
/// Overlapping windows on one resource take the *worst* (minimum)
/// factor: concurrent faults do not partially cancel each other.
#[derive(Clone, Debug)]
pub struct CapacityWindow {
    pub resource: ResourceId,
    pub t0: f64,
    pub t1: f64,
    pub factor: f64,
}

#[derive(Default)]
/// Max–min fair-share fluid flow simulator.
pub struct FlowSim {
    resources: Vec<Resource>,
    flows: HashMap<FlowId, Flow>,
    next_id: u64,
    /// Active flows through each resource (one entry per path
    /// occurrence) — the adjacency the component walk follows.
    res_flows: Vec<Vec<FlowId>>,
    /// Resources whose component must re-rate at the next recompute.
    dirty_res: Vec<usize>,
    dirty_mark: Vec<bool>,
    /// Reference mode: treat every change as dirtying all resources
    /// (the pre-overhaul behavior, kept for differential testing).
    full_rerate: bool,
    /// Scheduled capacity faults, consulted at the current clock.
    windows: Vec<CapacityWindow>,
    /// Per-resource `(t0, t1, factor)` views of `windows`, in insertion
    /// order so overlapping-window MIN-folding is order-stable.
    res_windows: Vec<Vec<(f64, f64, f64)>>,
    /// Every window edge `(time, resource)`, sorted by time.
    edges: Vec<(f64, usize)>,
    /// Monotone cursor: edges before it are `<= now + 1e-9` (behind the
    /// clock for `time_to_next_edge` purposes).
    edge_next: usize,
    /// Monotone cursor: edges before it are `<= now - 0.5e-9` (already
    /// crossed as far as `advance`'s re-rate marking is concerned).
    edge_cross: usize,
    /// Virtual seconds elapsed, advanced in lockstep with the engine
    /// via [`FlowSim::advance`] — what decides which windows are open.
    now: f64,
    // Path arena: spans into `path_data`, deduped via `path_lookup`.
    path_data: Vec<ResourceId>,
    path_spans: Vec<(u32, u32)>,
    path_lookup: HashMap<Vec<ResourceId>, u32>,
    // Recompute scratch (reused across calls; contents transient).
    visit_res: Vec<u32>,
    visit_stamp: u32,
    seen_flows: HashSet<FlowId>,
    residual: Vec<f64>,
    counts: Vec<usize>,
}

const EPS: f64 = 1e-6;

impl FlowSim {
    pub fn new() -> Self {
        FlowSim::default()
    }

    pub fn add_resource(&mut self, name: &str, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0, "resource {name} needs capacity > 0");
        self.resources.push(Resource { name: name.to_string(), capacity });
        self.res_flows.push(Vec::new());
        self.res_windows.push(Vec::new());
        self.dirty_mark.push(false);
        self.visit_res.push(0);
        self.residual.push(0.0);
        self.counts.push(0);
        ResourceId(self.resources.len() - 1)
    }

    pub fn resource(&self, id: ResourceId) -> &Resource {
        &self.resources[id.0]
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Differential-testing hook: when enabled, every recompute refills
    /// every component (the naive pre-overhaul behavior). Rates must be
    /// bit-identical either way — `rust/tests/engine_equiv.rs` replays
    /// whole runs against an engine with this reference core.
    pub fn set_full_rerate(&mut self, on: bool) {
        self.full_rerate = on;
    }

    fn mark_dirty(&mut self, r: usize) {
        if !self.dirty_mark[r] {
            self.dirty_mark[r] = true;
            self.dirty_res.push(r);
        }
    }

    /// Schedule a capacity fault window. Windows may be added at any
    /// time before or during a run; rates re-derive at the next event.
    pub fn add_capacity_window(
        &mut self,
        resource: ResourceId,
        t0: f64,
        t1: f64,
        factor: f64,
    ) {
        assert!(resource.0 < self.resources.len(), "unknown {resource:?}");
        assert!(t1 > t0, "empty fault window [{t0}, {t1})");
        assert!(
            (0.0..=1.0).contains(&factor),
            "fault factor {factor} outside [0, 1]"
        );
        self.windows.push(CapacityWindow { resource, t0, t1, factor });
        self.res_windows[resource.0].push((t0, t1, factor));
        for e in [t0, t1] {
            let pos = self.edges.partition_point(|&(t, _)| t < e);
            self.edges.insert(pos, (e, resource.0));
            // A mid-run insertion may land behind a cursor; pull the
            // cursor back and let the lazy skip re-derive it.
            self.edge_next = self.edge_next.min(pos);
            self.edge_cross = self.edge_cross.min(pos);
        }
        self.mark_dirty(resource.0);
    }

    /// Scheduled fault windows (inspection/reporting hook).
    pub fn capacity_windows(&self) -> &[CapacityWindow] {
        &self.windows
    }

    /// Effective capacity of resource `i` at the current clock: the
    /// nominal capacity scaled by the worst open fault window. The
    /// half-ns slack keeps the integer-ns engine clock (which lands on
    /// window edges via `from_secs_f64_ceil`) on the correct side of
    /// each edge despite f64 accumulation.
    fn effective_capacity(&self, i: usize) -> f64 {
        let mut factor = 1.0f64;
        for &(t0, t1, f) in &self.res_windows[i] {
            if self.now >= t0 - 0.5e-9 && self.now < t1 - 0.5e-9 {
                factor = factor.min(f);
            }
        }
        self.resources[i].capacity * factor
    }

    /// Seconds until the next window edge strictly ahead of the clock,
    /// if any. The engine must re-rate there: a flow's constant-rate
    /// extrapolation is only valid between edges. The sorted edge array
    /// plus the monotone `edge_next` cursor make this O(1) amortized
    /// instead of a scan over every scheduled window.
    fn time_to_next_edge(&mut self) -> Option<f64> {
        while self.edge_next < self.edges.len()
            && self.edges[self.edge_next].0 - self.now <= 1e-9
        {
            self.edge_next += 1;
        }
        (self.edge_next < self.edges.len())
            .then(|| self.edges[self.edge_next].0 - self.now)
    }

    /// Reap an active flow (deadline enforcement): its claim on every
    /// path resource is released and survivors re-rate at the next
    /// event. Returns false if the flow already completed.
    pub fn remove(&mut self, id: FlowId) -> bool {
        match self.flows.remove(&id) {
            Some(f) => {
                self.unlink(id, f.path);
                true
            }
            None => false,
        }
    }

    /// Drop one adjacency entry per path occurrence and mark the path's
    /// resources for re-rating.
    fn unlink(&mut self, id: FlowId, path: PathId) {
        let (start, len) = self.path_spans[path.0 as usize];
        for k in start..start + len {
            let r = self.path_data[k as usize].0;
            let fs = &mut self.res_flows[r];
            let pos = fs.iter().position(|&f| f == id).expect("adjacency out of sync");
            fs.swap_remove(pos);
            if !self.dirty_mark[r] {
                self.dirty_mark[r] = true;
                self.dirty_res.push(r);
            }
        }
    }

    /// Total bytes, path, and tag of an active flow — what a retry
    /// must re-issue after reaping it. None once completed/removed.
    pub fn spec_of(&self, id: FlowId) -> Option<(f64, Vec<ResourceId>, u32)> {
        self.flows.get(&id).map(|f| {
            let (start, len) = self.path_spans[f.path.0 as usize];
            let path = self.path_data[start as usize..(start + len) as usize].to_vec();
            (f.total, path, f.tag)
        })
    }

    /// Arena-backed variant of [`FlowSim::spec_of`] — the engine's
    /// flow-retry path re-issues from the interned span, no clone.
    pub(crate) fn spec_ids(&self, id: FlowId) -> Option<(f64, PathId, u32)> {
        self.flows.get(&id).map(|f| (f.total, f.path, f.tag))
    }

    /// Intern a resource path, deduping identical sequences. The
    /// engine's stage compiler calls this once per distinct path; every
    /// transfer over the same route shares one span.
    pub(crate) fn intern_path(&mut self, path: &[ResourceId]) -> PathId {
        assert!(!path.is_empty(), "flow needs a non-empty path");
        for r in path {
            assert!(r.0 < self.resources.len(), "unknown resource {r:?}");
        }
        if let Some(&id) = self.path_lookup.get(path) {
            return PathId(id);
        }
        let start = self.path_data.len() as u32;
        self.path_data.extend_from_slice(path);
        self.path_spans.push((start, path.len() as u32));
        let id = (self.path_spans.len() - 1) as u32;
        self.path_lookup.insert(path.to_vec(), id);
        PathId(id)
    }

    /// Start a flow of `bytes` through `path`. Zero-byte flows are legal
    /// and complete at the next event boundary.
    pub fn start(&mut self, bytes: f64, path: Vec<ResourceId>, tag: u32) -> FlowId {
        let pid = self.intern_path(&path);
        self.start_interned(bytes, pid, tag)
    }

    /// Start a flow over an already-interned path.
    pub(crate) fn start_interned(&mut self, bytes: f64, path: PathId, tag: u32) -> FlowId {
        let id = FlowId(self.next_id);
        self.next_id += 1;
        self.flows.insert(
            id,
            Flow { remaining: bytes.max(0.0), path, rate: 0.0, tag, total: bytes.max(0.0) },
        );
        let (start, len) = self.path_spans[path.0 as usize];
        for k in start..start + len {
            let r = self.path_data[k as usize].0;
            self.res_flows[r].push(id);
            if !self.dirty_mark[r] {
                self.dirty_mark[r] = true;
                self.dirty_res.push(r);
            }
        }
        id
    }

    /// Recompute max–min fair rates over every component touched by a
    /// dirty resource (all components in reference mode).
    fn recompute(&mut self) {
        if self.dirty_res.is_empty() {
            return;
        }
        if self.full_rerate {
            for r in 0..self.resources.len() {
                if !self.dirty_mark[r] {
                    self.dirty_mark[r] = true;
                    self.dirty_res.push(r);
                }
            }
        }
        let mut seeds = std::mem::take(&mut self.dirty_res);
        seeds.sort_unstable(); // component visit order is id-ordered
        for &r in &seeds {
            self.dirty_mark[r] = false;
        }
        self.visit_stamp += 1;
        let stamp = self.visit_stamp;
        self.seen_flows.clear();
        let mut stack: Vec<usize> = Vec::new();
        let mut comp_res: Vec<usize> = Vec::new();
        let mut comp_flows: Vec<FlowId> = Vec::new();
        for &seed in &seeds {
            if self.visit_res[seed] == stamp {
                continue;
            }
            self.visit_res[seed] = stamp;
            stack.push(seed);
            comp_res.clear();
            comp_flows.clear();
            while let Some(r) = stack.pop() {
                comp_res.push(r);
                for i in 0..self.res_flows[r].len() {
                    let fid = self.res_flows[r][i];
                    if !self.seen_flows.insert(fid) {
                        continue;
                    }
                    comp_flows.push(fid);
                    let (start, len) = self.path_spans[self.flows[&fid].path.0 as usize];
                    for k in start..start + len {
                        let r2 = self.path_data[k as usize].0;
                        if self.visit_res[r2] != stamp {
                            self.visit_res[r2] = stamp;
                            stack.push(r2);
                        }
                    }
                }
            }
            if !comp_flows.is_empty() {
                comp_res.sort_unstable();
                let unfrozen = std::mem::take(&mut comp_flows);
                comp_flows = self.fill_component(&comp_res, unfrozen);
                comp_flows.clear();
            }
        }
        self.dirty_res = seeds;
        self.dirty_res.clear();
    }

    /// Progressive filling restricted to one connected component. The
    /// arithmetic (share = residual/count, path-order subtraction,
    /// first-index EPS bottleneck tie-break) is exactly the classic
    /// global fill's — a component's shares never depend on any other
    /// component, so the restriction is value-preserving. Returns the
    /// (emptied) work vec so the caller can reuse its allocation.
    fn fill_component(&mut self, comp_res: &[usize], mut unfrozen: Vec<FlowId>) -> Vec<FlowId> {
        unfrozen.sort_unstable(); // determinism
        for id in &unfrozen {
            self.flows.get_mut(id).unwrap().rate = 0.0;
        }
        for &i in comp_res {
            self.residual[i] = self.effective_capacity(i);
        }
        while !unfrozen.is_empty() {
            // Count unfrozen flows per resource.
            for &i in comp_res {
                self.counts[i] = 0;
            }
            for id in &unfrozen {
                let (start, len) = self.path_spans[self.flows[id].path.0 as usize];
                for k in start..start + len {
                    self.counts[self.path_data[k as usize].0] += 1;
                }
            }
            // Bottleneck = resource minimizing residual / count.
            let mut best: Option<(f64, usize)> = None;
            for &i in comp_res {
                let c = self.counts[i];
                if c == 0 {
                    continue;
                }
                let share = self.residual[i] / c as f64;
                if best.map_or(true, |(s, _)| share < s - EPS) {
                    best = Some((share, i));
                }
            }
            let Some((share, bottleneck)) = best else { break };
            // Freeze every unfrozen flow through the bottleneck at `share`.
            let mut still = Vec::with_capacity(unfrozen.len());
            for id in unfrozen {
                let (start, len) = self.path_spans[self.flows[&id].path.0 as usize];
                let through = self.path_data[start as usize..(start + len) as usize]
                    .iter()
                    .any(|r| r.0 == bottleneck);
                if through {
                    self.flows.get_mut(&id).unwrap().rate = share;
                    for k in start..start + len {
                        let r = self.path_data[k as usize].0;
                        self.residual[r] = (self.residual[r] - share).max(0.0);
                    }
                } else {
                    still.push(id);
                }
            }
            self.residual[bottleneck] = 0.0;
            unfrozen = still;
        }
        unfrozen
    }

    /// Seconds until the next flow event: a completion at current
    /// rates, or a capacity-window edge where rates change. The engine
    /// must not step further than this in one advance — a blacked-out
    /// flow's zero rate is only valid until its window closes.
    pub fn time_to_next_completion(&mut self) -> Option<f64> {
        if self.flows.is_empty() {
            return None;
        }
        self.recompute();
        let mut t = f64::INFINITY;
        for f in self.flows.values() {
            if f.remaining <= EPS {
                return Some(0.0);
            }
            if f.rate > 0.0 {
                t = t.min(f.remaining / f.rate);
            }
        }
        if let Some(edge) = self.time_to_next_edge() {
            t = t.min(edge);
        }
        if t.is_finite() {
            Some(t)
        } else {
            // All active flows fully starved with no window edge ahead
            // — impossible while every resource has positive capacity
            // and fault windows are finite; the engine treats it as a
            // deadlock unless a flow deadline is pending.
            None
        }
    }

    /// Advance all flows by `dt` seconds; return flows that completed.
    pub fn advance(&mut self, dt: f64) -> Vec<FlowRecord> {
        self.recompute();
        let was = self.now;
        self.now += dt;
        // Rates derive from the clock: crossing (or landing on) any
        // window edge invalidates them for the next interval. Only the
        // crossed edges' resources (their components) re-rate.
        while self.edge_cross < self.edges.len()
            && self.edges[self.edge_cross].0 <= was - 0.5e-9
        {
            self.edge_cross += 1;
        }
        let mut k = self.edge_cross;
        while k < self.edges.len() && self.edges[k].0 <= self.now + 0.5e-9 {
            let r = self.edges[k].1;
            self.mark_dirty(r);
            k += 1;
        }
        let mut done = Vec::new();
        for (id, f) in self.flows.iter_mut() {
            f.remaining -= f.rate * dt;
            // Complete when less than one ns of service remains — the
            // engine's event clock cannot resolve anything finer.
            if f.remaining <= EPS + f.rate * 1e-9 {
                done.push(FlowRecord { id: *id, bytes: f.total, tag: f.tag });
            }
        }
        done.sort_by_key(|r| r.id); // determinism
        for r in &done {
            if let Some(f) = self.flows.remove(&r.id) {
                self.unlink(r.id, f.path);
            }
        }
        done
    }

    /// Current rate of a flow (test hook).
    pub fn rate_of(&mut self, id: FlowId) -> Option<f64> {
        self.recompute();
        self.flows.get(&id).map(|f| f.rate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_gets_full_capacity() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        let f = s.start(1000.0, vec![r], 0);
        assert!((s.rate_of(f).unwrap() - 100.0).abs() < 1e-9);
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn equal_share_two_flows() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        let a = s.start(1000.0, vec![r], 0);
        let b = s.start(1000.0, vec![r], 0);
        assert!((s.rate_of(a).unwrap() - 50.0).abs() < 1e-9);
        assert!((s.rate_of(b).unwrap() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn max_min_reallocates_leftover() {
        // Flow A through narrow (10) + wide (100); flow B through wide only.
        // A bottlenecked at 10; B gets the remaining 90.
        let mut s = FlowSim::new();
        let narrow = s.add_resource("narrow", 10.0);
        let wide = s.add_resource("wide", 100.0);
        let a = s.start(1e6, vec![narrow, wide], 0);
        let b = s.start(1e6, vec![wide], 0);
        assert!((s.rate_of(a).unwrap() - 10.0).abs() < 1e-6);
        assert!((s.rate_of(b).unwrap() - 90.0).abs() < 1e-6);
    }

    #[test]
    fn completion_frees_bandwidth() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        let _a = s.start(100.0, vec![r], 1); // 2s at 50
        let b = s.start(1000.0, vec![r], 2);
        let t1 = s.time_to_next_completion().unwrap(); // a finishes at 2s
        assert!((t1 - 2.0).abs() < 1e-9);
        let done = s.advance(t1);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tag, 1);
        // b now alone: rate 100, remaining 900 → 9s
        assert!((s.rate_of(b).unwrap() - 100.0).abs() < 1e-9);
        let t2 = s.time_to_next_completion().unwrap();
        assert!((t2 - 9.0).abs() < 1e-6);
    }

    #[test]
    fn zero_byte_flow_completes_immediately() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        s.start(0.0, vec![r], 7);
        let t = s.time_to_next_completion().unwrap();
        assert_eq!(t, 0.0);
        let done = s.advance(0.0);
        assert_eq!(done.len(), 1);
    }

    #[test]
    fn conservation_total_rate_le_capacity() {
        let mut s = FlowSim::new();
        let r1 = s.add_resource("a", 37.0);
        let r2 = s.add_resource("b", 53.0);
        let mut ids = Vec::new();
        for i in 0..10 {
            let path = match i % 3 {
                0 => vec![r1],
                1 => vec![r2],
                _ => vec![r1, r2],
            };
            ids.push(s.start(1e9, path, 0));
        }
        let mut through_r1 = 0.0;
        let mut through_r2 = 0.0;
        for (i, id) in ids.iter().enumerate() {
            let rate = s.rate_of(*id).unwrap();
            if i % 3 == 0 || i % 3 == 2 {
                through_r1 += rate;
            }
            if i % 3 == 1 || i % 3 == 2 {
                through_r2 += rate;
            }
        }
        assert!(through_r1 <= 37.0 + 1e-6, "r1 oversubscribed {through_r1}");
        assert!(through_r2 <= 53.0 + 1e-6, "r2 oversubscribed {through_r2}");
    }

    #[test]
    fn slowdown_window_stretches_the_transfer() {
        // 1000 B over 100 B/s, but [2, 6) serves at 1/4 capacity:
        // 2 s × 100 + 4 s × 25 = 300 B by t=6, then 700/100 = 7 s more.
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        s.add_capacity_window(r, 2.0, 6.0, 0.25);
        let f = s.start(1000.0, vec![r], 0);
        assert!((s.rate_of(f).unwrap() - 100.0).abs() < 1e-9);
        // First event is the window opening, not a completion.
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 2.0).abs() < 1e-9, "edge at 2s, got {t}");
        assert!(s.advance(t).is_empty());
        assert!((s.rate_of(f).unwrap() - 25.0).abs() < 1e-9);
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 4.0).abs() < 1e-9, "next edge at 6s, got {t}");
        assert!(s.advance(t).is_empty());
        assert!((s.rate_of(f).unwrap() - 100.0).abs() < 1e-9);
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 7.0).abs() < 1e-6, "remaining 700 B, got {t}");
        assert_eq!(s.advance(t).len(), 1);
    }

    #[test]
    fn blackout_starves_then_resumes_at_the_edge() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        s.add_capacity_window(r, 0.0, 3.0, 0.0);
        let f = s.start(100.0, vec![r], 0);
        assert_eq!(s.rate_of(f).unwrap(), 0.0, "blacked out");
        // A starved flow must not report None while an edge is ahead.
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 3.0).abs() < 1e-9, "wait for the window edge");
        assert!(s.advance(t).is_empty());
        assert!((s.rate_of(f).unwrap() - 100.0).abs() < 1e-9);
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 1.0).abs() < 1e-6);
    }

    #[test]
    fn overlapping_windows_take_the_worst_factor() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        s.add_capacity_window(r, 0.0, 10.0, 0.5);
        s.add_capacity_window(r, 0.0, 4.0, 0.0);
        let f = s.start(1000.0, vec![r], 0);
        assert_eq!(s.rate_of(f).unwrap(), 0.0, "blackout wins");
    }

    #[test]
    fn removed_flow_returns_its_share_to_survivors() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        let a = s.start(1000.0, vec![r], 0);
        let b = s.start(1000.0, vec![r], 1);
        assert!((s.rate_of(a).unwrap() - 50.0).abs() < 1e-9);
        assert!(s.spec_of(a).is_some());
        assert!(s.remove(a), "active flow reaped");
        assert!(!s.remove(a), "double-reap is a no-op");
        assert!(s.spec_of(a).is_none());
        assert!((s.rate_of(b).unwrap() - 100.0).abs() < 1e-9);
        assert_eq!(s.active_flows(), 1);
    }

    #[test]
    fn window_added_mid_run_lands_behind_the_edge_cursors() {
        // Regression for the sorted-edge cursor: a window scheduled
        // *after* the clock has advanced past where its edges sort must
        // still open/close correctly (netfault plans add windows during
        // setup, but the API allows mid-run insertion too).
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        s.add_capacity_window(r, 1.0, 2.0, 0.5);
        let f = s.start(10_000.0, vec![r], 0);
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 1.0).abs() < 1e-9, "edge at 1s, got {t}");
        assert!(s.advance(t).is_empty());
        assert!((s.rate_of(f).unwrap() - 50.0).abs() < 1e-9, "slowdown open");
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 1.0).abs() < 1e-9, "edge at 2s, got {t}");
        assert!(s.advance(t).is_empty());
        assert!((s.rate_of(f).unwrap() - 100.0).abs() < 1e-9, "back to full");
        // Both cursors have now walked past the 1s and 2s edges. Insert
        // a window whose t0 sorts *before* them: the cursors must be
        // pulled back so the still-open blackout and its closing edge
        // are seen.
        s.add_capacity_window(r, 0.5, 3.0, 0.0);
        assert_eq!(s.rate_of(f).unwrap(), 0.0, "blackout covers now=2s");
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 1.0).abs() < 1e-9, "blackout closes at 3s, got {t}");
        assert!(s.advance(t).is_empty());
        assert!((s.rate_of(f).unwrap() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn incremental_rerate_matches_full_recompute() {
        // Differential property: randomized starts/removes/advances on
        // disjoint-and-overlapping paths produce bit-identical rates
        // and event times under incremental component re-rating vs the
        // mark-everything reference.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xF10E5);
        for case in 0..60 {
            let mut inc = FlowSim::new();
            let mut full = FlowSim::new();
            full.set_full_rerate(true);
            let nres = rng.range(1, 6) as usize;
            let caps = [100.0, 250.0, 40.0, 1000.0, 12.5];
            let mut res = Vec::new();
            for i in 0..nres {
                let c = caps[i % caps.len()];
                res.push(inc.add_resource(&format!("r{i}"), c));
                full.add_resource(&format!("r{i}"), c);
            }
            if rng.chance(0.5) {
                let r = res[rng.below(nres as u64) as usize];
                let t0 = rng.below(5) as f64;
                let (t1, fac) = (t0 + 1.0 + rng.below(4) as f64, 0.25);
                inc.add_capacity_window(r, t0, t1, fac);
                full.add_capacity_window(r, t0, t1, fac);
            }
            let mut live: Vec<FlowId> = Vec::new();
            for _ in 0..40 {
                match rng.below(3) {
                    0 => {
                        let plen = 1 + rng.below(2.min(nres as u64)) as usize;
                        let mut path = Vec::new();
                        for _ in 0..plen {
                            path.push(res[rng.below(nres as u64) as usize]);
                        }
                        let bytes = 10.0 * (1 + rng.below(100)) as f64;
                        let a = inc.start(bytes, path.clone(), 0);
                        let b = full.start(bytes, path, 0);
                        assert_eq!(a, b, "id streams must match");
                        live.push(a);
                    }
                    1 if !live.is_empty() => {
                        let id = live.swap_remove(rng.below(live.len() as u64) as usize);
                        assert_eq!(inc.remove(id), full.remove(id));
                    }
                    _ => {
                        let ta = inc.time_to_next_completion();
                        let tb = full.time_to_next_completion();
                        assert_eq!(
                            ta.map(f64::to_bits),
                            tb.map(f64::to_bits),
                            "case {case}: next-event time diverged"
                        );
                        if let Some(dt) = ta {
                            let da: Vec<_> =
                                inc.advance(dt).iter().map(|r| r.id).collect();
                            let db: Vec<_> =
                                full.advance(dt).iter().map(|r| r.id).collect();
                            assert_eq!(da, db, "case {case}: completions diverged");
                            live.retain(|id| !da.contains(id));
                        }
                    }
                }
                for &id in &live {
                    let ra = inc.rate_of(id).map(f64::to_bits);
                    let rb = full.rate_of(id).map(f64::to_bits);
                    assert_eq!(ra, rb, "case {case}: rate of {id:?} diverged");
                }
            }
        }
    }
}
