//! Flow-level bandwidth simulation with max–min fair sharing.
//!
//! A *flow* moves `bytes` through a *path* of resources (device read
//! channel → source NIC → destination NIC → device write channel, say).
//! Each resource has a capacity in bytes/sec (or ops/sec for IOPS-class
//! resources). Whenever the active-flow set changes, rates are
//! recomputed by progressive filling: repeatedly find the most
//! constrained resource, freeze the fair share of every unfrozen flow
//! through it, remove its capacity, repeat. This is the classic fluid
//! model used by flow-level datacenter simulators.

use std::collections::HashMap;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
/// Index of a bandwidth resource (link/channel) in the flow sim.
pub struct ResourceId(pub usize);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
/// Index of an active flow.
pub struct FlowId(pub u64);

#[derive(Clone, Debug)]
/// One capacity-limited bandwidth resource.
pub struct Resource {
    pub name: String,
    pub capacity: f64, // bytes/sec (or ops/sec)
}

#[derive(Clone, Debug)]
struct Flow {
    remaining: f64,
    path: Vec<ResourceId>,
    rate: f64,
    tag: u32,
    total: f64,
}

/// Record of a finished flow, for throughput accounting.
#[derive(Clone, Debug)]
pub struct FlowRecord {
    pub id: FlowId,
    pub bytes: f64,
    pub tag: u32,
}

#[derive(Default)]
/// Max–min fair-share fluid flow simulator.
pub struct FlowSim {
    resources: Vec<Resource>,
    flows: HashMap<FlowId, Flow>,
    next_id: u64,
    dirty: bool,
}

const EPS: f64 = 1e-6;

impl FlowSim {
    pub fn new() -> Self {
        FlowSim::default()
    }

    pub fn add_resource(&mut self, name: &str, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0, "resource {name} needs capacity > 0");
        self.resources.push(Resource { name: name.to_string(), capacity });
        ResourceId(self.resources.len() - 1)
    }

    pub fn resource(&self, id: ResourceId) -> &Resource {
        &self.resources[id.0]
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Start a flow of `bytes` through `path`. Zero-byte flows are legal
    /// and complete at the next event boundary.
    pub fn start(&mut self, bytes: f64, path: Vec<ResourceId>, tag: u32) -> FlowId {
        assert!(!path.is_empty(), "flow needs a non-empty path");
        for r in &path {
            assert!(r.0 < self.resources.len(), "unknown resource {r:?}");
        }
        let id = FlowId(self.next_id);
        self.next_id += 1;
        self.flows.insert(
            id,
            Flow { remaining: bytes.max(0.0), path, rate: 0.0, tag, total: bytes.max(0.0) },
        );
        self.dirty = true;
        id
    }

    /// Recompute max–min fair rates (progressive filling).
    fn recompute(&mut self) {
        if !self.dirty {
            return;
        }
        self.dirty = false;
        let mut residual: Vec<f64> =
            self.resources.iter().map(|r| r.capacity).collect();
        let mut unfrozen: Vec<FlowId> = self.flows.keys().copied().collect();
        unfrozen.sort_unstable(); // determinism
        for f in self.flows.values_mut() {
            f.rate = 0.0;
        }
        while !unfrozen.is_empty() {
            // Count unfrozen flows per resource.
            let mut counts = vec![0usize; self.resources.len()];
            for id in &unfrozen {
                for r in &self.flows[id].path {
                    counts[r.0] += 1;
                }
            }
            // Bottleneck = resource minimizing residual / count.
            let mut best: Option<(f64, usize)> = None;
            for (i, &c) in counts.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                let share = residual[i] / c as f64;
                if best.map_or(true, |(s, _)| share < s - EPS) {
                    best = Some((share, i));
                }
            }
            let Some((share, bottleneck)) = best else { break };
            // Freeze every unfrozen flow through the bottleneck at `share`.
            let mut still = Vec::with_capacity(unfrozen.len());
            for id in unfrozen {
                let through = self.flows[&id].path.contains(&ResourceId(bottleneck));
                if through {
                    let f = self.flows.get_mut(&id).unwrap();
                    f.rate = share;
                    for r in f.path.clone() {
                        residual[r.0] = (residual[r.0] - share).max(0.0);
                    }
                } else {
                    still.push(id);
                }
            }
            residual[bottleneck] = 0.0;
            unfrozen = still;
        }
    }

    /// Seconds until the next flow completes, if any flow is active.
    pub fn time_to_next_completion(&mut self) -> Option<f64> {
        if self.flows.is_empty() {
            return None;
        }
        self.recompute();
        let mut t = f64::INFINITY;
        for f in self.flows.values() {
            if f.remaining <= EPS {
                return Some(0.0);
            }
            if f.rate > 0.0 {
                t = t.min(f.remaining / f.rate);
            }
        }
        if t.is_finite() {
            Some(t)
        } else {
            // All active flows fully starved — should be impossible while
            // every resource has positive capacity.
            None
        }
    }

    /// Advance all flows by `dt` seconds; return flows that completed.
    pub fn advance(&mut self, dt: f64) -> Vec<FlowRecord> {
        self.recompute();
        let mut done = Vec::new();
        for (id, f) in self.flows.iter_mut() {
            f.remaining -= f.rate * dt;
            // Complete when less than one ns of service remains — the
            // engine's event clock cannot resolve anything finer.
            if f.remaining <= EPS + f.rate * 1e-9 {
                done.push(FlowRecord { id: *id, bytes: f.total, tag: f.tag });
            }
        }
        done.sort_by_key(|r| r.id); // determinism
        for r in &done {
            self.flows.remove(&r.id);
        }
        if !done.is_empty() {
            self.dirty = true;
        }
        done
    }

    /// Current rate of a flow (test hook).
    pub fn rate_of(&mut self, id: FlowId) -> Option<f64> {
        self.recompute();
        self.flows.get(&id).map(|f| f.rate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_gets_full_capacity() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        let f = s.start(1000.0, vec![r], 0);
        assert!((s.rate_of(f).unwrap() - 100.0).abs() < 1e-9);
        let t = s.time_to_next_completion().unwrap();
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn equal_share_two_flows() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        let a = s.start(1000.0, vec![r], 0);
        let b = s.start(1000.0, vec![r], 0);
        assert!((s.rate_of(a).unwrap() - 50.0).abs() < 1e-9);
        assert!((s.rate_of(b).unwrap() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn max_min_reallocates_leftover() {
        // Flow A through narrow (10) + wide (100); flow B through wide only.
        // A bottlenecked at 10; B gets the remaining 90.
        let mut s = FlowSim::new();
        let narrow = s.add_resource("narrow", 10.0);
        let wide = s.add_resource("wide", 100.0);
        let a = s.start(1e6, vec![narrow, wide], 0);
        let b = s.start(1e6, vec![wide], 0);
        assert!((s.rate_of(a).unwrap() - 10.0).abs() < 1e-6);
        assert!((s.rate_of(b).unwrap() - 90.0).abs() < 1e-6);
    }

    #[test]
    fn completion_frees_bandwidth() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        let _a = s.start(100.0, vec![r], 1); // 2s at 50
        let b = s.start(1000.0, vec![r], 2);
        let t1 = s.time_to_next_completion().unwrap(); // a finishes at 2s
        assert!((t1 - 2.0).abs() < 1e-9);
        let done = s.advance(t1);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tag, 1);
        // b now alone: rate 100, remaining 900 → 9s
        assert!((s.rate_of(b).unwrap() - 100.0).abs() < 1e-9);
        let t2 = s.time_to_next_completion().unwrap();
        assert!((t2 - 9.0).abs() < 1e-6);
    }

    #[test]
    fn zero_byte_flow_completes_immediately() {
        let mut s = FlowSim::new();
        let r = s.add_resource("link", 100.0);
        s.start(0.0, vec![r], 7);
        let t = s.time_to_next_completion().unwrap();
        assert_eq!(t, 0.0);
        let done = s.advance(0.0);
        assert_eq!(done.len(), 1);
    }

    #[test]
    fn conservation_total_rate_le_capacity() {
        let mut s = FlowSim::new();
        let r1 = s.add_resource("a", 37.0);
        let r2 = s.add_resource("b", 53.0);
        let mut ids = Vec::new();
        for i in 0..10 {
            let path = match i % 3 {
                0 => vec![r1],
                1 => vec![r2],
                _ => vec![r1, r2],
            };
            ids.push(s.start(1e9, path, 0));
        }
        let mut through_r1 = 0.0;
        let mut through_r2 = 0.0;
        for (i, id) in ids.iter().enumerate() {
            let rate = s.rate_of(*id).unwrap();
            if i % 3 == 0 || i % 3 == 2 {
                through_r1 += rate;
            }
            if i % 3 == 1 || i % 3 == 2 {
                through_r2 += rate;
            }
        }
        assert!(through_r1 <= 37.0 + 1e-6, "r1 oversubscribed {through_r1}");
        assert!(through_r2 <= 53.0 + 1e-6, "r2 oversubscribed {through_r2}");
    }
}
