//! Virtual time. All simulated durations are integer nanoseconds — the
//! testbed's wall clock replaced by a deterministic axis.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point or span on the virtual time axis, in nanoseconds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SimNs(pub u64);

impl SimNs {
    pub const ZERO: SimNs = SimNs(0);

    pub fn from_secs_f64(s: f64) -> SimNs {
        debug_assert!(s >= 0.0, "negative duration {s}");
        SimNs((s * 1e9).round() as u64)
    }

    /// Round *up* to whole nanoseconds — used for flow completion times
    /// so the event loop always makes progress (a sub-ns residue would
    /// otherwise schedule a zero-length step forever).
    pub fn from_secs_f64_ceil(s: f64) -> SimNs {
        debug_assert!(s >= 0.0, "negative duration {s}");
        SimNs((s * 1e9).ceil() as u64)
    }
    pub fn from_millis(ms: u64) -> SimNs {
        SimNs(ms * 1_000_000)
    }
    pub fn from_micros(us: u64) -> SimNs {
        SimNs(us * 1_000)
    }
    pub fn from_nanos(ns: u64) -> SimNs {
        SimNs(ns)
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }
    pub fn as_nanos(self) -> u64 {
        self.0
    }

    pub fn saturating_sub(self, rhs: SimNs) -> SimNs {
        SimNs(self.0.saturating_sub(rhs.0))
    }

    /// Clamping addition — the engine uses this wherever a duration
    /// from outside (a flow deadline, a timer at `now + d`) could push
    /// the axis past `u64::MAX` ns (~584 years of virtual time): the
    /// sum pins to the end of the axis instead of wrapping back to 0,
    /// which would fire the event in the past.
    pub fn saturating_add(self, rhs: SimNs) -> SimNs {
        SimNs(self.0.saturating_add(rhs.0))
    }

    /// Stretch a duration by `1/speed` — the straggler node-speed
    /// scaling. The single definition shared by the engine's per-proc
    /// Delay stretching and the driver's overhead tallies, so reported
    /// virtual time can never drift from simulated virtual time.
    /// Identity at speed 1.0 and for degenerate factors, keeping
    /// healthy paths bit-exact.
    pub fn div_speed(self, speed: f64) -> SimNs {
        if !speed.is_finite() || speed <= 0.0 || speed == 1.0 {
            self
        } else {
            SimNs::from_secs_f64(self.as_secs_f64() / speed)
        }
    }
}

impl Add for SimNs {
    type Output = SimNs;
    fn add(self, rhs: SimNs) -> SimNs {
        SimNs(self.0 + rhs.0)
    }
}

impl AddAssign for SimNs {
    fn add_assign(&mut self, rhs: SimNs) {
        self.0 += rhs.0;
    }
}

impl Sub for SimNs {
    type Output = SimNs;
    fn sub(self, rhs: SimNs) -> SimNs {
        debug_assert!(self.0 >= rhs.0, "time went backwards");
        SimNs(self.0 - rhs.0)
    }
}

impl fmt::Display for SimNs {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.as_secs_f64();
        if s >= 1.0 {
            write!(f, "{s:.3}s")
        } else if self.0 >= 1_000_000 {
            write!(f, "{:.3}ms", self.0 as f64 / 1e6)
        } else if self.0 >= 1_000 {
            write!(f, "{:.3}µs", self.0 as f64 / 1e3)
        } else {
            write!(f, "{}ns", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(SimNs::from_secs_f64(1.5).0, 1_500_000_000);
        assert_eq!(SimNs::from_millis(3).0, 3_000_000);
        assert_eq!(SimNs::from_micros(7).0, 7_000);
        assert!((SimNs(2_500_000_000).as_secs_f64() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn arithmetic() {
        assert_eq!(SimNs(5) + SimNs(7), SimNs(12));
        assert_eq!(SimNs(7) - SimNs(5), SimNs(2));
        assert_eq!(SimNs(5).saturating_sub(SimNs(7)), SimNs(0));
        let mut t = SimNs(1);
        t += SimNs(2);
        assert_eq!(t, SimNs(3));
    }

    #[test]
    fn saturating_add_pins_to_the_end_of_the_axis() {
        assert_eq!(SimNs(5).saturating_add(SimNs(7)), SimNs(12));
        assert_eq!(
            SimNs(u64::MAX - 1).saturating_add(SimNs(100)),
            SimNs(u64::MAX),
            "overflow clamps instead of wrapping into the past"
        );
        assert_eq!(
            SimNs(u64::MAX).saturating_add(SimNs::ZERO),
            SimNs(u64::MAX)
        );
    }

    #[test]
    fn float_conversions_saturate_at_the_axis_end() {
        // Rust float→int `as` casts saturate, so absurd second counts
        // (including infinity from a divide-by-tiny) pin to u64::MAX
        // rather than producing small wrapped values.
        assert_eq!(SimNs::from_secs_f64(f64::MAX), SimNs(u64::MAX));
        assert_eq!(SimNs::from_secs_f64(f64::INFINITY), SimNs(u64::MAX));
        assert_eq!(SimNs::from_secs_f64_ceil(f64::MAX), SimNs(u64::MAX));
    }

    #[test]
    fn div_speed_overflow_edge_cases_stay_monotone() {
        // A near-max duration stretched by a tiny speed saturates.
        let huge = SimNs(u64::MAX / 2);
        assert_eq!(huge.div_speed(1e-12), SimNs(u64::MAX));
        // And a huge duration at exactly 1.0 stays bit-identical
        // (identity path, no float round-trip).
        assert_eq!(SimNs(u64::MAX).div_speed(1.0), SimNs(u64::MAX));
        assert_eq!(SimNs(u64::MAX - 3).div_speed(1.0), SimNs(u64::MAX - 3));
    }

    #[test]
    fn div_speed_stretches_and_is_identity_at_one() {
        let d = SimNs::from_millis(10);
        assert_eq!(d.div_speed(0.25), SimNs::from_millis(40));
        assert_eq!(d.div_speed(1.0), d);
        // Degenerate factors fall back to identity.
        assert_eq!(d.div_speed(0.0), d);
        assert_eq!(d.div_speed(f64::NAN), d);
        assert_eq!(d.div_speed(-2.0), d);
    }

    #[test]
    fn display_units() {
        assert_eq!(format!("{}", SimNs(500)), "500ns");
        assert_eq!(format!("{}", SimNs(1_500)), "1.500µs");
        assert_eq!(format!("{}", SimNs(2_000_000)), "2.000ms");
        assert_eq!(format!("{}", SimNs(3_000_000_000)), "3.000s");
    }
}
