//! Hierarchical timing wheel — the engine's event core.
//!
//! Replaces the old `BinaryHeap<Reverse<(SimNs, u64, ProcId)>>` timer
//! queue with an 8-level × 64-slot calendar keyed on absolute virtual
//! nanoseconds. Level 0 slots are 2^10 ns (≈1 µs) wide; each level up
//! widens slots by 64×, so the wheel covers 2^58 ns (≈9 virtual years)
//! before spilling into a small unordered overflow list. Every level
//! keeps a one-bit-per-slot occupancy word so `next_due` and `pop_due`
//! never walk empty slots.
//!
//! Semantics are *exactly* the heap's: timers pop in `(time, seq)`
//! order, where `seq` is the engine's monotone push counter — the FIFO
//! tiebreak the determinism contract leans on. `pop_due` drains every
//! slot whose span has been reached, emits the entries that are due,
//! lazily cascades the rest down to finer levels (each entry moves at
//! most `LEVELS` times over its lifetime), and sorts the due batch by
//! `(time, seq)` before handing it back.
//!
//! Two invariants make the bitmap scans sound:
//!
//! * **No wrap aliasing.** An entry is placed at the smallest level
//!   whose *remaining* span from the current floor covers it with one
//!   slot to spare (`delta ≤ span − slot_width`). A level therefore
//!   never holds two entries one full rotation apart, so "first
//!   occupied slot in rotation order from the floor" is the level
//!   minimum.
//! * **Monotone floor.** `pop_due(now)` advances the floor to `now`;
//!   pushes in the past are rejected (debug) / clamped (release), same
//!   as the engine's old `debug_assert` on timer ordering.
//!
//! [`TimerQueue`] wraps the wheel together with the retained naive
//! binary-heap reference core. `Engine::use_reference_core()` swaps the
//! reference in; the differential suite (`rust/tests/engine_equiv.rs`)
//! replays randomized programs through both and asserts identical
//! timestamps.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::clock::SimNs;

/// log2 of the level-0 slot width: 2^10 ns ≈ 1 µs.
const G_SHIFT: u32 = 10;
/// log2 of the slots-per-level fan-out (64 slots ↔ one u64 bitmap).
const SLOT_BITS: u32 = 6;
const SLOTS: usize = 1 << SLOT_BITS;
const LEVELS: usize = 8;

#[inline]
fn level_shift(level: usize) -> u32 {
    G_SHIFT + SLOT_BITS * level as u32
}

/// Slot width at `level`, in nanoseconds.
#[inline]
fn slot_width(level: usize) -> u64 {
    1u64 << level_shift(level)
}

/// Total span covered by `level` (64 slots), in nanoseconds.
#[inline]
fn level_span(level: usize) -> u64 {
    SLOTS as u64 << level_shift(level)
}

type Entry<T> = (u64, u64, T);

/// Hierarchical timing wheel over `(time, seq, payload)` entries.
#[derive(Debug)]
pub(crate) struct TimerWheel<T: Copy> {
    /// `LEVELS × SLOTS` buckets, row-major by level.
    slots: Vec<Vec<Entry<T>>>,
    /// One occupancy bit per slot, per level.
    occupied: [u64; LEVELS],
    /// Entries beyond the top level's span from `floor` (≈9 years out).
    overflow: Vec<Entry<T>>,
    /// Monotone pop watermark: no pending entry is earlier than this.
    floor: u64,
    len: usize,
    /// Cached earliest pending time; invalidated when entries pop.
    min_cache: Option<u64>,
}

impl<T: Copy> TimerWheel<T> {
    pub(crate) fn new() -> Self {
        TimerWheel {
            slots: (0..LEVELS * SLOTS).map(|_| Vec::new()).collect(),
            occupied: [0; LEVELS],
            overflow: Vec::new(),
            floor: 0,
            len: 0,
            min_cache: Some(u64::MAX),
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    pub(crate) fn push(&mut self, t: SimNs, seq: u64, payload: T) {
        let t = t.as_nanos();
        debug_assert!(t >= self.floor, "timer scheduled in the past");
        let t = t.max(self.floor);
        self.min_cache = match self.min_cache {
            Some(m) => Some(m.min(t)),
            None => None,
        };
        self.len += 1;
        let floor = self.floor;
        self.place(t, seq, payload, floor);
    }

    /// Bucket an entry relative to `floor` (the current watermark for
    /// fresh pushes, `now` for lazy cascades during a pop).
    fn place(&mut self, t: u64, seq: u64, payload: T, floor: u64) {
        let delta = t - floor;
        for level in 0..LEVELS {
            // One slot of slack below the full span keeps a level from
            // ever wrapping onto the floor's own slot (no aliasing).
            if delta <= level_span(level) - slot_width(level) {
                let slot = ((t >> level_shift(level)) & (SLOTS as u64 - 1)) as usize;
                self.slots[level * SLOTS + slot].push((t, seq, payload));
                self.occupied[level] |= 1u64 << slot;
                return;
            }
        }
        self.overflow.push((t, seq, payload));
    }

    /// Earliest pending `(time)` across all levels and the overflow.
    pub(crate) fn next_due(&mut self) -> Option<SimNs> {
        if self.len == 0 {
            return None;
        }
        let min = match self.min_cache {
            Some(m) => m,
            None => {
                let m = self.scan_min();
                self.min_cache = Some(m);
                m
            }
        };
        Some(SimNs(min))
    }

    fn scan_min(&self) -> u64 {
        let mut best = u64::MAX;
        for level in 0..LEVELS {
            let occ = self.occupied[level];
            if occ == 0 {
                continue;
            }
            let shift = level_shift(level);
            let fs = ((self.floor >> shift) & (SLOTS as u64 - 1)) as u32;
            // First occupied slot in rotation order from the floor's
            // slot holds this level's minimum (no-aliasing invariant).
            let dist = occ.rotate_right(fs).trailing_zeros();
            let slot = ((fs + dist) & (SLOTS as u32 - 1)) as usize;
            for &(t, _, _) in &self.slots[level * SLOTS + slot] {
                best = best.min(t);
            }
        }
        for &(t, _, _) in &self.overflow {
            best = best.min(t);
        }
        best
    }

    /// Pop every entry with `time <= now` into `out`, sorted by
    /// `(time, seq)`, advancing the floor to `now`. Entries sharing a
    /// reached slot but not yet due cascade down to finer levels.
    pub(crate) fn pop_due(&mut self, now: SimNs, out: &mut Vec<(SimNs, u64, T)>) {
        let now = now.as_nanos();
        let base = out.len();
        if self.len > 0 && self.min_cache.map_or(true, |m| m <= now) {
            for level in 0..LEVELS {
                let shift = level_shift(level);
                let width = slot_width(level);
                let fs = (self.floor >> shift) & (SLOTS as u64 - 1);
                let aligned = self.floor & !(width - 1);
                // Snapshot: lazily cascaded entries re-inserted below
                // must not be re-drained within this same pop.
                let mut occ = self.occupied[level];
                while occ != 0 {
                    let slot = occ.trailing_zeros() as u64;
                    occ &= occ - 1;
                    let dist = (slot + SLOTS as u64 - fs) & (SLOTS as u64 - 1);
                    if aligned + dist * width > now {
                        continue;
                    }
                    let drained =
                        std::mem::take(&mut self.slots[level * SLOTS + slot as usize]);
                    self.occupied[level] &= !(1u64 << slot);
                    for (t, seq, payload) in drained {
                        if t <= now {
                            self.len -= 1;
                            out.push((SimNs(t), seq, payload));
                        } else {
                            self.place(t, seq, payload, now);
                        }
                    }
                }
            }
            if !self.overflow.is_empty() {
                let mut i = 0;
                while i < self.overflow.len() {
                    let (t, seq, payload) = self.overflow[i];
                    if t <= now {
                        self.overflow.swap_remove(i);
                        self.len -= 1;
                        out.push((SimNs(t), seq, payload));
                    } else if t - now <= level_span(LEVELS - 1) - slot_width(LEVELS - 1) {
                        // Came within wheel coverage: migrate down.
                        self.overflow.swap_remove(i);
                        self.place(t, seq, payload, now);
                    } else {
                        i += 1;
                    }
                }
            }
        }
        if now > self.floor {
            self.floor = now;
        }
        if out.len() > base {
            self.min_cache = None;
            out[base..].sort_unstable_by_key(|&(t, seq, _)| (t, seq));
        }
    }
}

/// The engine's timer queue: the timing wheel by default, or the naive
/// binary-heap core retained as the differential-testing reference
/// (`Engine::use_reference_core`). Both pop in `(time, seq)` order.
#[derive(Debug)]
pub(crate) enum TimerQueue<T: Copy + Ord> {
    Wheel(TimerWheel<T>),
    Reference(BinaryHeap<Reverse<(u64, u64, T)>>),
}

impl<T: Copy + Ord> TimerQueue<T> {
    pub(crate) fn wheel() -> Self {
        TimerQueue::Wheel(TimerWheel::new())
    }

    pub(crate) fn reference() -> Self {
        TimerQueue::Reference(BinaryHeap::new())
    }

    pub(crate) fn len(&self) -> usize {
        match self {
            TimerQueue::Wheel(w) => w.len(),
            TimerQueue::Reference(h) => h.len(),
        }
    }

    pub(crate) fn push(&mut self, t: SimNs, seq: u64, payload: T) {
        match self {
            TimerQueue::Wheel(w) => w.push(t, seq, payload),
            TimerQueue::Reference(h) => h.push(Reverse((t.as_nanos(), seq, payload))),
        }
    }

    pub(crate) fn next_due(&mut self) -> Option<SimNs> {
        match self {
            TimerQueue::Wheel(w) => w.next_due(),
            TimerQueue::Reference(h) => h.peek().map(|Reverse((t, _, _))| SimNs(*t)),
        }
    }

    /// Append all entries due at or before `now` to `out` in
    /// `(time, seq)` order.
    pub(crate) fn pop_due(&mut self, now: SimNs, out: &mut Vec<(SimNs, u64, T)>) {
        match self {
            TimerQueue::Wheel(w) => w.pop_due(now, out),
            TimerQueue::Reference(h) => {
                while let Some(Reverse((t, _, _))) = h.peek() {
                    if *t > now.as_nanos() {
                        break;
                    }
                    let Reverse((t, seq, payload)) = h.pop().unwrap();
                    out.push((SimNs(t), seq, payload));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn drain_all(w: &mut TimerWheel<u32>) -> Vec<(u64, u64, u32)> {
        let mut out = Vec::new();
        while let Some(t) = w.next_due() {
            let mut batch = Vec::new();
            w.pop_due(t, &mut batch);
            assert!(!batch.is_empty(), "next_due pointed at an empty instant");
            out.extend(batch.into_iter().map(|(t, s, p)| (t.as_nanos(), s, p)));
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut w = TimerWheel::new();
        w.push(SimNs(500), 2, 0u32);
        w.push(SimNs(100), 1, 1);
        w.push(SimNs(500), 0, 2);
        w.push(SimNs(100), 3, 3);
        let got = drain_all(&mut w);
        assert_eq!(
            got,
            vec![(100, 1, 1), (100, 3, 3), (500, 0, 2), (500, 2, 0)]
        );
    }

    #[test]
    fn agrees_with_reference_on_random_schedules() {
        let mut rng = Rng::new(0x77ee11);
        for case in 0..200 {
            let mut wheel = TimerWheel::new();
            let mut reference: BinaryHeap<Reverse<(u64, u64, u32)>> = BinaryHeap::new();
            let mut now = 0u64;
            let mut seq = 0u64;
            // Mixed horizons: sub-slot, cross-slot, cross-level, and the
            // occasional far-future entry that lands in the overflow.
            for _ in 0..rng.range(1, 80) {
                let horizon = match rng.below(10) {
                    0..=4 => rng.below(2_000),                // within level 0
                    5..=6 => rng.below(1 << 20),              // level 1-2
                    7..=8 => rng.below(10_000_000_000),       // seconds
                    _ => 1 << 62,                             // overflow
                };
                let t = now + horizon;
                wheel.push(SimNs(t), seq, case as u32);
                reference.push(Reverse((t, seq, case as u32)));
                seq += 1;
                // Sometimes advance time partway and pop both sides.
                if rng.below(3) == 0 {
                    now += rng.below(5_000_000);
                    let mut got = Vec::new();
                    wheel.pop_due(SimNs(now), &mut got);
                    let mut want = Vec::new();
                    while let Some(Reverse((t, _, _))) = reference.peek() {
                        if *t > now {
                            break;
                        }
                        let Reverse(e) = reference.pop().unwrap();
                        want.push(e);
                    }
                    let got: Vec<_> =
                        got.into_iter().map(|(t, s, p)| (t.as_nanos(), s, p)).collect();
                    assert_eq!(got, want, "case {case} diverged at now={now}");
                }
            }
            // Drain the rest at the horizon end.
            let mut got = Vec::new();
            wheel.pop_due(SimNs(u64::MAX), &mut got);
            let mut want = Vec::new();
            while let Some(Reverse(e)) = reference.pop() {
                want.push(e);
            }
            let got: Vec<_> =
                got.into_iter().map(|(t, s, p)| (t.as_nanos(), s, p)).collect();
            assert_eq!(got, want, "case {case} final drain diverged");
            assert_eq!(wheel.len(), 0);
        }
    }

    #[test]
    fn next_due_tracks_minimum_across_cascades() {
        let mut w = TimerWheel::new();
        // A coarse-level entry plus a fine one far apart.
        w.push(SimNs(3_000_000_000), 0, 1u32); // 3s — high level
        w.push(SimNs(2_500), 1, 2); // 2.5µs — level 0/1
        assert_eq!(w.next_due(), Some(SimNs(2_500)));
        let mut out = Vec::new();
        w.pop_due(SimNs(2_500), &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(w.next_due(), Some(SimNs(3_000_000_000)));
        // Advancing partway cascades the 3s entry without losing it.
        out.clear();
        w.pop_due(SimNs(2_999_999_000), &mut out);
        assert!(out.is_empty());
        assert_eq!(w.next_due(), Some(SimNs(3_000_000_000)));
        out.clear();
        w.pop_due(SimNs(3_000_000_000), &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(w.len(), 0);
    }

    #[test]
    fn overflow_entries_survive_and_pop() {
        let mut w = TimerWheel::new();
        let far = 1u64 << 62; // beyond the 2^58 ns wheel coverage
        w.push(SimNs(far), 0, 7u32);
        w.push(SimNs(1_000), 1, 8);
        assert_eq!(w.next_due(), Some(SimNs(1_000)));
        let mut out = Vec::new();
        w.pop_due(SimNs(1_000), &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(w.next_due(), Some(SimNs(far)));
        // Popping at the far horizon yields the overflow entry.
        out.clear();
        w.pop_due(SimNs(far), &mut out);
        assert_eq!(out, vec![(SimNs(far), 0, 7)]);
        assert_eq!(w.len(), 0);
    }

    #[test]
    fn dense_equal_timestamps_keep_fifo_seq_order() {
        let mut w = TimerWheel::new();
        for seq in 0..1_000u64 {
            w.push(SimNs(42_000), seq, (seq % 7) as u32);
        }
        let got = drain_all(&mut w);
        for (i, &(t, seq, _)) in got.iter().enumerate() {
            assert_eq!(t, 42_000);
            assert_eq!(seq, i as u64);
        }
    }
}
