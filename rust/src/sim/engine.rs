//! Discrete-event engine: *procs* (simulated tasks) execute *stages*
//! against shared resources — slot pools (containers), fair-shared
//! bandwidth (flows), fixed latencies, and barriers (phase boundaries).
//!
//! The MapReduce driver compiles every map/reduce task into a proc; the
//! engine then yields deterministic completion times. This replaces the
//! authors' physical testbed as the time axis (`ARCHITECTURE.md`,
//! Layer 0 and the Two-plane execution model).
//!
//! **Hot-path layout** (ARCHITECTURE.md, Engine internals): timers live
//! in a hierarchical timing wheel (`sim::wheel`) with the exact
//! `(time, seq)` FIFO pop order of the old binary heap; stage programs
//! are compiled once into a shared per-engine op arena (`Vec<Op>`
//! slices — no per-proc `VecDeque<Stage>` clones, flow paths interned
//! in the flow sim's path arena); proc labels are interned into one
//! string arena with a lazily-merged sorted index so the
//! `*_with_prefix` queries binary-search instead of scanning every
//! proc and log line per finalized job. [`Engine::use_reference_core`]
//! swaps the naive heap + full-re-rate cores back in for differential
//! testing.
//!
//! Multi-tenancy: every proc carries a *class* (0 = unscoped; the
//! `mapreduce::JobServer` assigns one class per tenant). Slot pools
//! grant contended slots in weighted-fair order across classes
//! (`util::fairq::FairQueue`, weights set via
//! [`Engine::set_class_weight`]), so concurrent jobs' container waves
//! interleave deterministically in proportion to their shares while an
//! idle tenant's capacity backfills the busy ones.

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

use crate::util::fairq::FairQueue;

use super::clock::SimNs;
use super::flow::{FlowId, FlowSim, PathId, ResourceId};
use super::wheel::TimerQueue;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
/// Index of a proc (simulated task) in the engine.
pub struct ProcId(pub usize);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
/// Index of a slot pool (containers, vcores, concurrency tokens).
pub struct PoolId(pub usize);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
/// Index of a phase barrier.
pub struct BarrierId(pub usize);

/// One step in a proc's lifecycle.
#[derive(Clone, Debug)]
pub enum Stage {
    /// Wait for (then hold) one slot from a pool — a container, a Lambda
    /// concurrency token, a YARN vcore.
    Acquire(PoolId),
    /// Return a held slot.
    Release(PoolId),
    /// Fixed latency: cold start, per-request overhead, storage op latency.
    Delay(SimNs),
    /// Move `bytes` through `path` under max–min fair sharing. With a
    /// `timeout`, a transfer still in flight that long after it started
    /// fails the attempt like a crash: the engine reaps the flow from
    /// the fair-share set (no leaked link capacity) and either replays
    /// it after a capped-exponential backoff (when the proc carries a
    /// [`Engine::set_flow_retry`] policy — re-acquiring its slot
    /// through the fair queue) or fails the proc outright.
    Flow {
        bytes: f64,
        path: Vec<ResourceId>,
        tag: u32,
        timeout: Option<SimNs>,
    },
    /// Signal one arrival at a barrier.
    Arrive(BarrierId),
    /// Block until the barrier has received all its arrivals.
    Await(BarrierId),
    /// A non-fatal fault event: one container attempt of this proc
    /// died (injected failure). The event is timestamped into the
    /// engine's [`CrashEvent`] log and the proc *continues* with its
    /// next stage — which is the retry attempt the driver compiled
    /// behind it. Contrast [`Stage::Fail`], which terminates the proc.
    Crash(String),
    /// Abort this proc (quota exceeded, retry budget exhausted). The
    /// engine keeps running; the failure is recorded on the proc.
    Fail(String),
    /// Abort *another* proc if it has not yet completed: its remaining
    /// stages are dropped, every slot it holds goes back through the
    /// fair queue (the container returns warm), and it is marked
    /// [`ProcState::Cancelled`] at the current virtual time. The
    /// speculative-execution race compiles to this — original and
    /// backup each end with a `Cancel` of the other, so the first
    /// finisher wins and the loser is reaped. No-op on a proc that
    /// already finished, failed, or was cancelled.
    Cancel(ProcId),
}

/// A [`Stage`] compiled into the engine's shared op arena: `Copy`,
/// message strings and flow paths replaced by arena ids. Spawning
/// compiles a program once; procs execute `ops[prog.0..prog.1]` via a
/// program counter instead of popping an owned stage deque.
#[derive(Clone, Copy, Debug)]
enum Op {
    Acquire(PoolId),
    Release(PoolId),
    Delay(SimNs),
    Flow {
        bytes: f64,
        path: PathId,
        tag: u32,
        timeout: Option<SimNs>,
    },
    Arrive(BarrierId),
    Await(BarrierId),
    /// Index into the engine's message arena.
    Crash(u32),
    Fail(u32),
    Cancel(ProcId),
}

#[derive(Clone, Debug, PartialEq)]
/// Lifecycle state of a proc.
pub enum ProcState {
    Ready,
    Blocked,
    Finished,
    Failed(String),
    /// Reaped by a [`Stage::Cancel`] — the losing side of a
    /// speculative race. Terminal, like `Finished`, but countable so
    /// reports can census speculation outcomes.
    Cancelled,
}

/// Per-proc flow-deadline retry policy: capped exponential backoff
/// between replays, mirroring `RecoveryConfig`'s attempt machinery.
#[derive(Clone, Debug)]
struct FlowRetry {
    base: SimNs,
    cap: SimNs,
    max: u32,
    used: u32,
}

impl FlowRetry {
    /// Backoff before retry number `n` (1-based): `base × 2^(n-1)`,
    /// saturating, never above `cap`.
    fn backoff(&self, n: u32) -> SimNs {
        let shift = (n.saturating_sub(1)).min(20);
        let ns = self.base.as_nanos().saturating_mul(1u64 << shift);
        SimNs(ns).min(self.cap)
    }
}

#[derive(Debug)]
struct Proc {
    /// Ops injected at run time ahead of the compiled program — a
    /// blocked `Acquire` re-queuing itself, a flow-retry replay
    /// sequence. Almost always empty.
    prelude: VecDeque<Op>,
    /// Compiled program: `ops[prog.0..prog.1]` in the engine arena.
    prog: (u32, u32),
    /// Program counter within `prog`.
    pc: u32,
    state: ProcState,
    started: SimNs,
    finished: SimNs,
    /// `(offset, len)` span into the engine's label arena.
    label: (u32, u32),
    /// Fair-queueing class (tenant); 0 for unscoped procs.
    class: u32,
    /// Node speed factor (1.0 = healthy): every fixed-latency stage
    /// this proc executes is stretched by `1/speed` — the straggler
    /// model's compute half (the topology scales the device half).
    speed: f64,
    /// Pool whose slot was handed to this proc while it was blocked in
    /// `Acquire` (release-side direct grant) — consumed on wake.
    grant: Option<PoolId>,
    /// Slots currently held (acquired, not yet released) — what a
    /// `Cancel` must hand back so the loser's container returns warm.
    held: Vec<PoolId>,
    /// Flow-deadline retry policy; None fails the proc on first timeout.
    retry: Option<FlowRetry>,
    /// Per-proc tallies mirrored off `crash_log`/`timeout_log`, so the
    /// prefix censuses sum counters over an index range instead of
    /// re-scanning every log line.
    crashes: u32,
    timeouts: u32,
}

struct Pool {
    capacity: usize,
    in_use: usize,
    /// Blocked acquirers, drained in weighted-fair order by class.
    waiters: FairQueue<ProcId>,
}

struct Barrier {
    target: usize,
    arrived: usize,
    waiters: Vec<ProcId>,
    opened_at: Option<SimNs>,
}

/// Completed-flow accounting entry (throughput reporting, Figure 6).
#[derive(Clone, Debug)]
pub struct FlowLog {
    pub tag: u32,
    pub bytes: f64,
    pub start: SimNs,
    pub end: SimNs,
}

/// One injected container crash, timestamped on the virtual clock
/// (recorded by [`Stage::Crash`]; the proc lives on to retry).
#[derive(Clone, Debug)]
pub struct CrashEvent {
    pub at: SimNs,
    pub proc_label: String,
    pub what: String,
}

/// Lazily maintained sorted view of the label arena: proc indices
/// ordered by label bytes (ties by spawn order). Rebuilt by merging
/// the newly spawned suffix, so a finalize after `k` fresh spawns
/// costs `O(k log k + n)`, and each prefix query is a binary search.
#[derive(Default)]
struct LabelIndex {
    /// `procs.len()` the index was built at (labels are append-only).
    version: usize,
    order: Vec<u32>,
}

/// The discrete-event engine: procs, pools, barriers, flows, timers.
pub struct Engine {
    pub flows: FlowSim,
    procs: Vec<Proc>,
    /// Shared compiled-stage arena — every spawned program is a slice.
    ops: Vec<Op>,
    /// Crash/Fail message arena (referenced by `Op::Crash`/`Op::Fail`).
    msgs: Vec<String>,
    /// Non-contiguous program segments appended after later spawns
    /// (speculation race tails) — consulted when `pc` hits `prog.1`.
    extra_segs: HashMap<usize, VecDeque<(u32, u32)>>,
    /// Label arena: every proc label is a span into this one string.
    label_data: String,
    /// Sorted label view for the `*_with_prefix` queries. Interior
    /// mutability (rebuild under `&self`) without giving up `Sync`.
    label_index: Mutex<LabelIndex>,
    pools: Vec<Pool>,
    barriers: Vec<Barrier>,
    ready: VecDeque<ProcId>,
    timers: TimerQueue<ProcId>,
    timer_seq: u64,
    /// Scratch for draining due timers (reused across steps).
    due: Vec<(SimNs, u64, ProcId)>,
    /// Active transfers: flow, owning proc, start instant, deadline.
    flow_owner: Vec<(FlowId, ProcId, SimNs, Option<SimNs>)>,
    now: SimNs,
    pub flow_log: Vec<FlowLog>,
    /// Injected container crashes, in virtual-time order.
    pub crash_log: Vec<CrashEvent>,
    /// Flow-deadline expiries (reaped transfers), in virtual-time
    /// order — the degraded-network analog of `crash_log`.
    pub timeout_log: Vec<CrashEvent>,
    /// Per-class weights for contended slot grants (absent = 1).
    class_weights: HashMap<u32, u64>,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    pub fn new() -> Self {
        Engine {
            flows: FlowSim::new(),
            procs: Vec::new(),
            ops: Vec::new(),
            msgs: Vec::new(),
            extra_segs: HashMap::new(),
            label_data: String::new(),
            label_index: Mutex::new(LabelIndex::default()),
            pools: Vec::new(),
            barriers: Vec::new(),
            ready: VecDeque::new(),
            timers: TimerQueue::wheel(),
            timer_seq: 0,
            due: Vec::new(),
            flow_owner: Vec::new(),
            now: SimNs::ZERO,
            flow_log: Vec::new(),
            crash_log: Vec::new(),
            timeout_log: Vec::new(),
            class_weights: HashMap::new(),
        }
    }

    /// Swap in the naive reference cores — binary-heap timers and
    /// full-recompute flow re-rating — retained for differential
    /// testing (`rust/tests/engine_equiv.rs` replays randomized
    /// programs through both and pins identical timestamps). Call
    /// before spawning or running; queued wheel timers do not migrate.
    pub fn use_reference_core(&mut self) {
        debug_assert!(self.timers.len() == 0, "switch cores before running");
        self.timers = TimerQueue::reference();
        self.flows.set_full_rerate(true);
    }

    /// Arm a flow-deadline retry policy on `id`: up to `max` replays
    /// with capped exponential backoff (`base × 2^(n-1)`, ≤ `cap`)
    /// between them. Each replay releases the proc's held slot, backs
    /// off, and re-acquires through the weighted-fair queue — the same
    /// path a crashed attempt takes. Without a policy, the first
    /// expired deadline fails the proc.
    pub fn set_flow_retry(
        &mut self,
        id: ProcId,
        base: SimNs,
        cap: SimNs,
        max: u32,
    ) {
        self.procs[id.0].retry =
            Some(FlowRetry { base, cap, max, used: 0 });
    }

    /// Set the fair-share weight of a proc class (tenant). Contended
    /// slot grants across classes are proportional to these weights;
    /// unset classes weigh 1.
    pub fn set_class_weight(&mut self, class: u32, weight: u64) {
        self.class_weights.insert(class, weight.max(1));
    }

    pub fn now(&self) -> SimNs {
        self.now
    }

    pub fn add_resource(&mut self, name: &str, capacity: f64) -> ResourceId {
        self.flows.add_resource(name, capacity)
    }

    pub fn add_pool(&mut self, capacity: usize) -> PoolId {
        self.pools.push(Pool {
            capacity,
            in_use: 0,
            waiters: FairQueue::new(),
        });
        PoolId(self.pools.len() - 1)
    }

    pub fn add_barrier(&mut self, target: usize) -> BarrierId {
        self.barriers.push(Barrier {
            target,
            arrived: 0,
            waiters: Vec::new(),
            opened_at: if target == 0 { Some(SimNs::ZERO) } else { None },
        });
        BarrierId(self.barriers.len() - 1)
    }

    /// Compile a stage program into the shared op arena, returning its
    /// `[start, end)` span. Messages and flow paths are interned.
    fn compile(&mut self, stages: Vec<Stage>) -> (u32, u32) {
        let start = self.ops.len() as u32;
        for s in stages {
            let op = match s {
                Stage::Acquire(p) => Op::Acquire(p),
                Stage::Release(p) => Op::Release(p),
                Stage::Delay(d) => Op::Delay(d),
                Stage::Flow { bytes, path, tag, timeout } => Op::Flow {
                    bytes,
                    path: self.flows.intern_path(&path),
                    tag,
                    timeout,
                },
                Stage::Arrive(b) => Op::Arrive(b),
                Stage::Await(b) => Op::Await(b),
                Stage::Crash(m) => {
                    self.msgs.push(m);
                    Op::Crash(self.msgs.len() as u32 - 1)
                }
                Stage::Fail(m) => {
                    self.msgs.push(m);
                    Op::Fail(self.msgs.len() as u32 - 1)
                }
                Stage::Cancel(t) => Op::Cancel(t),
            };
            self.ops.push(op);
        }
        (start, self.ops.len() as u32)
    }

    pub fn spawn(&mut self, label: &str, stages: Vec<Stage>) -> ProcId {
        self.spawn_as(label, 0, stages)
    }

    /// Spawn a proc under a fair-queueing class (tenant). Class 0 is
    /// the unscoped default used by [`Engine::spawn`].
    pub fn spawn_as(
        &mut self,
        label: &str,
        class: u32,
        stages: Vec<Stage>,
    ) -> ProcId {
        self.spawn_scaled(label, class, 1.0, stages)
    }

    /// [`Engine::spawn_as`] with a node speed factor: every
    /// fixed-latency stage of this proc runs `1/speed` slower — how a
    /// straggler node's compute heterogeneity reaches the time plane
    /// (its devices are slowed by the topology's scaled channel
    /// capacities instead). Non-finite or non-positive speeds fall
    /// back to 1.0.
    pub fn spawn_scaled(
        &mut self,
        label: &str,
        class: u32,
        speed: f64,
        stages: Vec<Stage>,
    ) -> ProcId {
        let speed = if speed.is_finite() && speed > 0.0 { speed } else { 1.0 };
        let prog = self.compile(stages);
        let at = self.label_data.len() as u32;
        self.label_data.push_str(label);
        let id = ProcId(self.procs.len());
        self.procs.push(Proc {
            prelude: VecDeque::new(),
            prog,
            pc: prog.0,
            state: ProcState::Ready,
            started: self.now,
            finished: SimNs::ZERO,
            label: (at, label.len() as u32),
            class,
            speed,
            grant: None,
            held: Vec::new(),
            retry: None,
            crashes: 0,
            timeouts: 0,
        });
        self.ready.push_back(id);
        id
    }

    /// Append stages to an already-spawned proc. Plan-time composition
    /// only: the driver closes a speculative race by appending the
    /// original's `Cancel`-the-backup tail once the backup's [`ProcId`]
    /// exists. When the proc's program still ends the arena (nothing
    /// spawned in between) the span simply extends; otherwise the new
    /// segment chains behind it.
    pub fn append_stages(&mut self, id: ProcId, extra: Vec<Stage>) {
        let seg = self.compile(extra);
        if seg.0 == seg.1 {
            return;
        }
        let p = &mut self.procs[id.0];
        if p.prog.1 == seg.0 && !self.extra_segs.contains_key(&id.0) {
            p.prog.1 = seg.1;
        } else {
            self.extra_segs.entry(id.0).or_default().push_back(seg);
        }
    }

    pub fn state(&self, id: ProcId) -> &ProcState {
        &self.procs[id.0].state
    }

    pub fn finished_at(&self, id: ProcId) -> SimNs {
        self.procs[id.0].finished
    }

    pub fn started_at(&self, id: ProcId) -> SimNs {
        self.procs[id.0].started
    }

    pub fn label(&self, id: ProcId) -> &str {
        let (at, len) = self.procs[id.0].label;
        &self.label_data[at as usize..(at + len) as usize]
    }

    pub fn barrier_opened_at(&self, id: BarrierId) -> Option<SimNs> {
        self.barriers[id.0].opened_at
    }

    /// Total slot capacity of a pool — what admission control sizes its
    /// in-flight job budget against (the open-loop server defaults its
    /// token pool to the cluster's aggregate invoker slots).
    pub fn pool_capacity(&self, id: PoolId) -> usize {
        self.pools[id.0].capacity
    }

    /// Slots of `id` not currently held. Planning-time snapshot: during
    /// a run, waiters may be granted the instant a slot frees.
    pub fn pool_available(&self, id: PoolId) -> usize {
        let p = &self.pools[id.0];
        p.capacity.saturating_sub(p.in_use)
    }

    /// Run `f` over the label-sorted proc indices whose label starts
    /// with `prefix`, refreshing the index first if procs were spawned
    /// since the last query. The closure returns owned data so no
    /// borrow escapes the index lock.
    fn with_label_range<R>(
        &self,
        prefix: &str,
        f: impl FnOnce(&Engine, &[u32]) -> R,
    ) -> R {
        let mut idx = self.label_index.lock().unwrap();
        if idx.version != self.procs.len() {
            let by_label = |&i: &u32| self.label(ProcId(i as usize));
            let mut fresh: Vec<u32> =
                (idx.version as u32..self.procs.len() as u32).collect();
            fresh.sort_unstable_by(|a, b| {
                by_label(a).cmp(by_label(b)).then(a.cmp(b))
            });
            let old = std::mem::take(&mut idx.order);
            let mut merged = Vec::with_capacity(old.len() + fresh.len());
            let (mut i, mut j) = (0, 0);
            while i < old.len() && j < fresh.len() {
                let a = old[i];
                let b = fresh[j];
                if (by_label(&a), a) <= (by_label(&b), b) {
                    merged.push(a);
                    i += 1;
                } else {
                    merged.push(b);
                    j += 1;
                }
            }
            merged.extend_from_slice(&old[i..]);
            merged.extend_from_slice(&fresh[j..]);
            idx.order = merged;
            idx.version = self.procs.len();
        }
        let lo = idx
            .order
            .partition_point(|&i| self.label(ProcId(i as usize)) < prefix);
        let hi = lo
            + idx.order[lo..].partition_point(|&i| {
                self.label(ProcId(i as usize)).starts_with(prefix)
            });
        f(self, &idx.order[lo..hi])
    }

    /// First failure message among procs whose label starts with
    /// `prefix` — job-scoped failure probe. "First" is spawn order,
    /// the same proc the old full scan would have found, so job error
    /// messages are byte-stable across the index refactor.
    pub fn failure_with_prefix(&self, prefix: &str) -> Option<&str> {
        let first: Option<u32> = self.with_label_range(prefix, |e, range| {
            range
                .iter()
                .copied()
                .filter(|&i| {
                    matches!(e.procs[i as usize].state, ProcState::Failed(_))
                })
                .min()
        });
        first.map(|i| match &self.procs[i as usize].state {
            ProcState::Failed(m) => m.as_str(),
            _ => unreachable!("filtered to failed procs"),
        })
    }

    /// Injected crashes among procs whose label starts with `prefix` —
    /// the job-scoped companion of [`Engine::failure_with_prefix`] for
    /// non-fatal [`Stage::Crash`] events.
    pub fn crashes_with_prefix(&self, prefix: &str) -> usize {
        self.with_label_range(prefix, |e, range| {
            range
                .iter()
                .map(|&i| e.procs[i as usize].crashes as usize)
                .sum()
        })
    }

    /// Flow-deadline expiries among procs whose label starts with
    /// `prefix` — the per-job census of transfers reaped by a timeout
    /// (each retried or, with the budget spent, failed).
    pub fn timeouts_with_prefix(&self, prefix: &str) -> usize {
        self.with_label_range(prefix, |e, range| {
            range
                .iter()
                .map(|&i| e.procs[i as usize].timeouts as usize)
                .sum()
        })
    }

    /// Ids of procs that ended in `Failed`, with messages borrowed
    /// from the procs (no per-call clones).
    pub fn failures(&self) -> Vec<(ProcId, &str)> {
        self.procs
            .iter()
            .enumerate()
            .filter_map(|(i, p)| match &p.state {
                ProcState::Failed(m) => Some((ProcId(i), m.as_str())),
                _ => None,
            })
            .collect()
    }

    /// Labels of procs reaped by [`Stage::Cancel`] whose label starts
    /// with `prefix` — the per-job speculation-loser census, in spawn
    /// order.
    pub fn cancelled_with_prefix(&self, prefix: &str) -> Vec<&str> {
        let mut hits: Vec<u32> = self.with_label_range(prefix, |e, range| {
            range
                .iter()
                .copied()
                .filter(|&i| e.procs[i as usize].state == ProcState::Cancelled)
                .collect()
        });
        hits.sort_unstable();
        hits.into_iter()
            .map(|i| self.label(ProcId(i as usize)))
            .collect()
    }

    /// Next op for `id`: injected prelude first, then the compiled
    /// program, then any chained extra segments.
    fn next_op(&mut self, id: ProcId) -> Option<Op> {
        if let Some(op) = self.procs[id.0].prelude.pop_front() {
            return Some(op);
        }
        loop {
            let p = &mut self.procs[id.0];
            if p.pc < p.prog.1 {
                let op = self.ops[p.pc as usize];
                p.pc += 1;
                return Some(op);
            }
            let Some(q) = self.extra_segs.get_mut(&id.0) else {
                return None;
            };
            match q.pop_front() {
                Some(seg) => {
                    if q.is_empty() {
                        self.extra_segs.remove(&id.0);
                    }
                    let p = &mut self.procs[id.0];
                    p.prog = seg;
                    p.pc = seg.0;
                }
                None => {
                    self.extra_segs.remove(&id.0);
                    return None;
                }
            }
        }
    }

    fn wake(&mut self, id: ProcId) {
        // Only a blocked proc can wake: a cancelled proc's pending
        // timer or in-flight flow completion must not resurrect it.
        if self.procs[id.0].state == ProcState::Blocked {
            self.procs[id.0].state = ProcState::Ready;
            self.ready.push_back(id);
        }
    }

    /// Return one slot of `p`: hand it to the weighted-fair next *live*
    /// waiter (cancelled waiters are skipped — they take no slot), or
    /// free it. Shared by [`Stage::Release`] and [`Engine::cancel`].
    fn do_release(&mut self, p: PoolId) {
        loop {
            let weights = &self.class_weights;
            let pool = &mut self.pools[p.0];
            assert!(pool.in_use > 0, "release on empty pool");
            // Hand the slot to the weighted-fair next waiter without
            // letting it transit the free state (a ready proc could
            // otherwise steal it).
            let next = pool
                .waiters
                .pop(|c| weights.get(&c).copied().unwrap_or(1));
            match next {
                Some((_, w)) => {
                    // A waiter cancelled while queued is skipped; its
                    // class keeps the grant charge it was popped with
                    // (deterministic, and the distortion is one grant
                    // per cancelled waiter at most).
                    if self.procs[w.0].state == ProcState::Blocked {
                        self.procs[w.0].grant = Some(p);
                        self.wake(w);
                        return;
                    }
                }
                None => {
                    pool.in_use -= 1;
                    return;
                }
            }
        }
    }

    /// Abort `id` unless it already completed: drop its remaining
    /// stages, release every slot it holds (and any un-consumed direct
    /// grant), and mark it [`ProcState::Cancelled`] now. An in-flight
    /// flow of the cancelled proc drains harmlessly — its completion
    /// wakes nobody.
    fn cancel(&mut self, id: ProcId) {
        if !matches!(
            self.procs[id.0].state,
            ProcState::Ready | ProcState::Blocked
        ) {
            return;
        }
        let p = &mut self.procs[id.0];
        p.prelude.clear();
        p.pc = p.prog.1;
        p.state = ProcState::Cancelled;
        p.finished = self.now;
        let held = std::mem::take(&mut p.held);
        let grant = p.grant.take();
        self.extra_segs.remove(&id.0);
        for pool in held {
            self.do_release(pool);
        }
        if let Some(pool) = grant {
            self.do_release(pool);
        }
    }

    /// Execute stages of `id` until it blocks or finishes.
    fn step(&mut self, id: ProcId) {
        loop {
            let op = match self.next_op(id) {
                Some(op) => op,
                None => {
                    self.procs[id.0].state = ProcState::Finished;
                    self.procs[id.0].finished = self.now;
                    return;
                }
            };
            match op {
                Op::Acquire(p) => {
                    if self.procs[id.0].grant == Some(p) {
                        // A releaser handed this proc its slot directly
                        // (already counted in `in_use`).
                        self.procs[id.0].grant = None;
                        self.procs[id.0].held.push(p);
                    } else {
                        let class = self.procs[id.0].class;
                        let weights = &self.class_weights;
                        let pool = &mut self.pools[p.0];
                        // Grant immediately only when nobody is queued
                        // — otherwise newly-ready procs would jump the
                        // fair queue.
                        if pool.in_use < pool.capacity
                            && pool.waiters.is_empty()
                        {
                            pool.in_use += 1;
                            let w = weights.get(&class).copied().unwrap_or(1);
                            pool.waiters.charge(class, w);
                            self.procs[id.0].held.push(p);
                        } else {
                            pool.waiters.push(class, id);
                            // Re-queue the acquire: consumed on wake via
                            // the grant handshake above.
                            self.procs[id.0]
                                .prelude
                                .push_front(Op::Acquire(p));
                            self.procs[id.0].state = ProcState::Blocked;
                            return;
                        }
                    }
                }
                Op::Release(p) => {
                    let held = &mut self.procs[id.0].held;
                    if let Some(pos) = held.iter().rposition(|x| *x == p) {
                        held.swap_remove(pos);
                    }
                    self.do_release(p);
                }
                Op::Delay(d) => {
                    // Straggler scaling: a 0.25-speed node takes 4× as
                    // long for every fixed-latency stage it executes.
                    // Flows are not scaled here — the topology already
                    // scales a slow node's device channel capacities.
                    let d = d.div_speed(self.procs[id.0].speed);
                    self.timer_seq += 1;
                    self.timers
                        .push(self.now.saturating_add(d), self.timer_seq, id);
                    self.procs[id.0].state = ProcState::Blocked;
                    return;
                }
                Op::Flow { bytes, path, tag, timeout } => {
                    let fid = self.flows.start_interned(bytes, path, tag);
                    // A fresh deadline per attempt; retries re-arm it.
                    let deadline = timeout
                        .filter(|t| *t > SimNs::ZERO)
                        .map(|t| self.now.saturating_add(t));
                    self.flow_owner.push((fid, id, self.now, deadline));
                    self.procs[id.0].state = ProcState::Blocked;
                    return;
                }
                Op::Arrive(b) => {
                    let bar = &mut self.barriers[b.0];
                    bar.arrived += 1;
                    if bar.arrived >= bar.target && bar.opened_at.is_none() {
                        bar.opened_at = Some(self.now);
                        let ws = std::mem::take(&mut bar.waiters);
                        for w in ws {
                            self.wake(w);
                        }
                    }
                }
                Op::Await(b) => {
                    let bar = &mut self.barriers[b.0];
                    if bar.opened_at.is_none() {
                        bar.waiters.push(id);
                        self.procs[id.0].state = ProcState::Blocked;
                        return;
                    }
                }
                Op::Crash(m) => {
                    let proc_label = self.label(id).to_string();
                    self.crash_log.push(CrashEvent {
                        at: self.now,
                        proc_label,
                        what: self.msgs[m as usize].clone(),
                    });
                    self.procs[id.0].crashes += 1;
                }
                Op::Fail(m) => {
                    self.procs[id.0].state =
                        ProcState::Failed(self.msgs[m as usize].clone());
                    self.procs[id.0].finished = self.now;
                    return;
                }
                Op::Cancel(target) => {
                    self.cancel(target);
                    if self.procs[id.0].state == ProcState::Cancelled {
                        // Degenerate self-cancel: nothing further runs.
                        return;
                    }
                }
            }
        }
    }

    /// Run until every proc is finished/failed. Errors on deadlock.
    pub fn run(&mut self) -> Result<SimNs, String> {
        loop {
            while let Some(id) = self.ready.pop_front() {
                if self.procs[id.0].state == ProcState::Ready {
                    self.step(id);
                }
            }
            let live = self
                .procs
                .iter()
                .any(|p| matches!(p.state, ProcState::Ready | ProcState::Blocked));
            if !live {
                return Ok(self.now);
            }

            // Next event: earliest of timer pop, flow completion (or
            // capacity-window edge), and flow deadline.
            let t_timer = self.timers.next_due();
            // Ceil to whole ns: guarantees the step is non-zero so a
            // sub-ns residue cannot spin the loop (flows overshoot by at
            // most one ns of progress, which `advance` treats as done).
            let t_flow = self
                .flows
                .time_to_next_completion()
                .map(|dt| self.now + SimNs::from_secs_f64_ceil(dt));
            let t_dead = self
                .flow_owner
                .iter()
                .filter_map(|(_, _, _, d)| *d)
                .min();
            let next = match [t_timer, t_flow, t_dead]
                .into_iter()
                .flatten()
                .min()
            {
                Some(t) => t,
                None => {
                    let stuck: Vec<&str> = self
                        .procs
                        .iter()
                        .enumerate()
                        .filter(|(_, p)| p.state == ProcState::Blocked)
                        .map(|(i, _)| self.label(ProcId(i)))
                        .collect();
                    return Err(format!(
                        "deadlock at {} — blocked procs: {stuck:?}",
                        self.now
                    ));
                }
            };

            // Advance flows by the elapsed wall of virtual time.
            let dt = (next - self.now).as_secs_f64();
            let completed = self.flows.advance(dt);
            self.now = next;

            for rec in completed {
                let pos = self
                    .flow_owner
                    .iter()
                    .position(|(f, _, _, _)| *f == rec.id)
                    .expect("flow without owner");
                let (_, owner, started, _) =
                    self.flow_owner.swap_remove(pos);
                self.flow_log.push(FlowLog {
                    tag: rec.tag,
                    bytes: rec.bytes,
                    start: started,
                    end: self.now,
                });
                self.wake(owner);
            }
            // Fire due timers in (time, seq) order.
            let mut due = std::mem::take(&mut self.due);
            self.timers.pop_due(self.now, &mut due);
            for &(_, _, id) in &due {
                self.wake(id);
            }
            due.clear();
            self.due = due;
            self.expire_flow_deadlines();
        }
    }

    /// Reap every flow whose deadline has passed (completions at the
    /// same instant were already drained — a transfer finishing exactly
    /// on its deadline survives). The flow leaves the fair-share set so
    /// survivors re-rate; the owner retries under its backoff policy or
    /// fails. Deterministic: expiries are processed in flow-id order.
    fn expire_flow_deadlines(&mut self) {
        let mut expired: Vec<(FlowId, ProcId, SimNs, SimNs)> = self
            .flow_owner
            .iter()
            .filter_map(|(f, p, s, d)| {
                d.filter(|d| *d <= self.now).map(|d| (*f, *p, *s, d))
            })
            .collect();
        expired.sort_by_key(|(f, _, _, _)| *f);
        for (fid, owner, started, deadline) in expired {
            let pos = self
                .flow_owner
                .iter()
                .position(|(f, _, _, _)| *f == fid)
                .expect("expired flow without owner");
            self.flow_owner.swap_remove(pos);
            let spec = self.flows.spec_ids(fid);
            self.flows.remove(fid);
            if self.procs[owner.0].state != ProcState::Blocked {
                // Cancelled mid-flight: the reap already freed the
                // link capacity; nobody retries.
                continue;
            }
            let stalled = self.now.saturating_sub(started);
            let proc_label = self.label(owner).to_string();
            self.timeout_log.push(CrashEvent {
                at: self.now,
                proc_label,
                what: format!("flow stalled {stalled}, deadline hit"),
            });
            self.procs[owner.0].timeouts += 1;
            let budget = self.procs[owner.0].retry.clone();
            match (budget, spec) {
                (Some(r), Some((bytes, path, tag))) if r.used < r.max => {
                    let n = r.used + 1;
                    let backoff = r.backoff(n);
                    self.procs[owner.0].retry.as_mut().unwrap().used = n;
                    // Replay the whole transfer (progress restarts at
                    // the last durable point, which the flow volume
                    // already models) with a fresh deadline. The slot
                    // is surrendered during the backoff and re-won
                    // through the weighted-fair queue.
                    let timeout = deadline.saturating_sub(started);
                    let slot = self.procs[owner.0].held.last().copied();
                    let prelude = &mut self.procs[owner.0].prelude;
                    prelude.push_front(Op::Flow {
                        bytes,
                        path,
                        tag,
                        timeout: Some(timeout),
                    });
                    match slot {
                        Some(p) => {
                            prelude.push_front(Op::Acquire(p));
                            prelude.push_front(Op::Delay(backoff));
                            prelude.push_front(Op::Release(p));
                        }
                        None => prelude.push_front(Op::Delay(backoff)),
                    }
                    self.wake(owner);
                }
                _ => {
                    // Budget spent (or the flow vanished): fail like
                    // Stage::Fail, but hand every held slot back so a
                    // co-tenant can never deadlock on a leaked
                    // container.
                    let msg = format!(
                        "flow timeout: transfer stalled {stalled} and \
                         the retry budget is exhausted"
                    );
                    self.procs[owner.0].state = ProcState::Failed(msg);
                    self.procs[owner.0].finished = self.now;
                    let held =
                        std::mem::take(&mut self.procs[owner.0].held);
                    let grant = self.procs[owner.0].grant.take();
                    for p in held {
                        self.do_release(p);
                    }
                    if let Some(p) = grant {
                        self.do_release(p);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_sequence() {
        let mut e = Engine::new();
        let p = e.spawn("a", vec![
            Stage::Delay(SimNs::from_millis(5)),
            Stage::Delay(SimNs::from_millis(7)),
        ]);
        let end = e.run().unwrap();
        assert_eq!(end, SimNs::from_millis(12));
        assert_eq!(*e.state(p), ProcState::Finished);
    }

    #[test]
    fn pool_capacity_accessors() {
        let mut e = Engine::new();
        let pool = e.add_pool(3);
        assert_eq!(e.pool_capacity(pool), 3);
        assert_eq!(e.pool_available(pool), 3);
        e.spawn("holder", vec![Stage::Acquire(pool)]);
        e.run().unwrap();
        assert_eq!(e.pool_capacity(pool), 3, "capacity is static");
        assert_eq!(e.pool_available(pool), 2, "one slot held");
    }

    #[test]
    fn pool_serializes() {
        // 3 procs, pool of 1, each holds for 10ms → 30ms total.
        let mut e = Engine::new();
        let pool = e.add_pool(1);
        for i in 0..3 {
            e.spawn(&format!("p{i}"), vec![
                Stage::Acquire(pool),
                Stage::Delay(SimNs::from_millis(10)),
                Stage::Release(pool),
            ]);
        }
        assert_eq!(e.run().unwrap(), SimNs::from_millis(30));
    }

    #[test]
    fn pool_parallelizes() {
        let mut e = Engine::new();
        let pool = e.add_pool(3);
        for i in 0..3 {
            e.spawn(&format!("p{i}"), vec![
                Stage::Acquire(pool),
                Stage::Delay(SimNs::from_millis(10)),
                Stage::Release(pool),
            ]);
        }
        assert_eq!(e.run().unwrap(), SimNs::from_millis(10));
    }

    #[test]
    fn flows_share_bandwidth() {
        let mut e = Engine::new();
        let link = e.add_resource("link", 100.0);
        // Two 500-byte flows share a 100 B/s link → both end at 10s.
        for i in 0..2 {
            e.spawn(&format!("f{i}"), vec![Stage::Flow {
                bytes: 500.0,
                path: vec![link],
                tag: i,
                timeout: None,
            }]);
        }
        let end = e.run().unwrap();
        assert!((end.as_secs_f64() - 10.0).abs() < 1e-6);
        assert_eq!(e.flow_log.len(), 2);
    }

    #[test]
    fn barrier_gates_reducers() {
        let mut e = Engine::new();
        let maps_done = e.add_barrier(2);
        for i in 0..2 {
            e.spawn(&format!("map{i}"), vec![
                Stage::Delay(SimNs::from_millis(10 * (i + 1))),
                Stage::Arrive(maps_done),
            ]);
        }
        let red = e.spawn("reduce", vec![
            Stage::Await(maps_done),
            Stage::Delay(SimNs::from_millis(5)),
        ]);
        let end = e.run().unwrap();
        // reduce starts at 20ms (slowest map), ends at 25ms.
        assert_eq!(end, SimNs::from_millis(25));
        assert_eq!(e.finished_at(red), SimNs::from_millis(25));
        assert_eq!(
            e.barrier_opened_at(maps_done),
            Some(SimNs::from_millis(20))
        );
    }

    #[test]
    fn failure_recorded_others_continue() {
        let mut e = Engine::new();
        let f = e.spawn("bad", vec![Stage::Fail("quota".into())]);
        let g = e.spawn("good", vec![Stage::Delay(SimNs::from_millis(1))]);
        e.run().unwrap();
        assert!(matches!(e.state(f), ProcState::Failed(m) if m == "quota"));
        assert_eq!(*e.state(g), ProcState::Finished);
        assert_eq!(e.failures().len(), 1);
        assert_eq!(e.failures()[0], (f, "quota"));
    }

    #[test]
    fn crash_is_logged_and_proc_retries() {
        // A crashed attempt releases its slot through the fair queue
        // and the same proc carries on with its retry stages; the
        // crash is timestamped, the proc finishes normally.
        let mut e = Engine::new();
        let pool = e.add_pool(1);
        let p = e.spawn("task", vec![
            Stage::Acquire(pool),
            Stage::Delay(SimNs::from_millis(4)),
            Stage::Release(pool),
            Stage::Crash("attempt 1 died".into()),
            Stage::Acquire(pool),
            Stage::Delay(SimNs::from_millis(6)),
            Stage::Release(pool),
        ]);
        let other = e.spawn("other", vec![
            Stage::Acquire(pool),
            Stage::Delay(SimNs::from_millis(1)),
            Stage::Release(pool),
        ]);
        let end = e.run().unwrap();
        assert_eq!(*e.state(p), ProcState::Finished);
        assert_eq!(*e.state(other), ProcState::Finished);
        assert_eq!(e.crash_log.len(), 1);
        assert_eq!(e.crash_log[0].at, SimNs::from_millis(4));
        assert_eq!(e.crash_log[0].proc_label, "task");
        assert_eq!(e.crashes_with_prefix("task"), 1);
        assert_eq!(e.crashes_with_prefix("other"), 0);
        assert_eq!(e.failures().len(), 0, "a crash is not a failure");
        // The released slot served `other` between the attempts.
        assert_eq!(end, SimNs::from_millis(11));
    }

    #[test]
    fn deadlock_detected() {
        let mut e = Engine::new();
        let never = e.add_barrier(1); // nobody arrives
        e.spawn("stuck", vec![Stage::Await(never)]);
        assert!(e.run().is_err());
    }

    #[test]
    fn weighted_classes_share_a_pool_three_to_one() {
        // 8 procs per class × 10 ms on one slot. Class 1 (weight 3)
        // drains ~3× as fast as class 2 (weight 1): its last proc
        // finishes around 110 ms; class 2 occupies the full 160 ms.
        let mut e = Engine::new();
        e.set_class_weight(1, 3);
        e.set_class_weight(2, 1);
        let pool = e.add_pool(1);
        let mut ids = vec![];
        for class in [1u32, 2] {
            for i in 0..8 {
                ids.push((class, e.spawn_as(&format!("c{class}p{i}"), class, vec![
                    Stage::Acquire(pool),
                    Stage::Delay(SimNs::from_millis(10)),
                    Stage::Release(pool),
                ])));
            }
        }
        let end = e.run().unwrap();
        assert_eq!(end, SimNs::from_millis(160), "work conserved");
        let last = |c: u32| {
            ids.iter()
                .filter(|(cc, _)| *cc == c)
                .map(|(_, id)| e.finished_at(*id))
                .max()
                .unwrap()
        };
        let (l1, l2) = (last(1), last(2));
        assert_eq!(l2, SimNs::from_millis(160));
        assert!(l1 <= SimNs::from_millis(125),
                "weight-3 class should finish early, got {l1}");
    }

    #[test]
    fn idle_class_weight_costs_nothing() {
        // Weights for absent classes must not reserve capacity: a lone
        // class-0 stream through a weighted pool is still back-to-back.
        let mut e = Engine::new();
        e.set_class_weight(7, 1000);
        let pool = e.add_pool(1);
        for i in 0..3 {
            e.spawn(&format!("p{i}"), vec![
                Stage::Acquire(pool),
                Stage::Delay(SimNs::from_millis(10)),
                Stage::Release(pool),
            ]);
        }
        assert_eq!(e.run().unwrap(), SimNs::from_millis(30));
    }

    #[test]
    fn speed_factor_stretches_delays_only() {
        // A 0.25-speed straggler takes 4× as long per Delay; a flow is
        // untouched (device/NIC capacities carry that half).
        let mut e = Engine::new();
        let link = e.add_resource("l", 100.0);
        let slow = e.spawn_scaled("slow", 0, 0.25, vec![
            Stage::Delay(SimNs::from_millis(10)),
        ]);
        let flow = e.spawn_scaled("flow", 0, 0.25, vec![Stage::Flow {
            bytes: 100.0,
            path: vec![link],
            tag: 0,
            timeout: None,
        }]);
        e.run().unwrap();
        assert_eq!(e.finished_at(slow), SimNs::from_millis(40));
        assert!(
            (e.finished_at(flow).as_secs_f64() - 1.0).abs() < 1e-6,
            "flows are not proc-scaled"
        );
        // Degenerate speeds fall back to 1.0.
        let mut e = Engine::new();
        let p = e.spawn_scaled("z", 0, 0.0, vec![
            Stage::Delay(SimNs::from_millis(3)),
        ]);
        e.run().unwrap();
        assert_eq!(e.finished_at(p), SimNs::from_millis(3));
    }

    #[test]
    fn cancel_race_first_finisher_wins() {
        // The speculative-race compile shape: each racer ends with a
        // Cancel of the other; the first to finish reaps the loser.
        let mut e = Engine::new();
        let done = e.add_barrier(1);
        let orig = e.spawn("task", vec![
            Stage::Delay(SimNs::from_millis(40)),
        ]);
        let bak = e.spawn("task/bak", vec![
            Stage::Delay(SimNs::from_millis(5)),
            Stage::Cancel(orig),
            Stage::Arrive(done),
        ]);
        e.append_stages(orig, vec![Stage::Cancel(bak), Stage::Arrive(done)]);
        let end = e.run().unwrap();
        assert_eq!(end, SimNs::from_millis(5), "backup won the race");
        assert_eq!(*e.state(bak), ProcState::Finished);
        assert_eq!(*e.state(orig), ProcState::Cancelled);
        assert_eq!(e.finished_at(orig), SimNs::from_millis(5));
        assert_eq!(e.barrier_opened_at(done), Some(SimNs::from_millis(5)));
        assert_eq!(e.cancelled_with_prefix("task").len(), 1);
        assert_eq!(e.cancelled_with_prefix("task/bak").len(), 0);
        assert!(e.failures().is_empty(), "cancelled is not failed");
    }

    #[test]
    fn cancel_releases_held_slot_to_the_fair_queue() {
        // B holds the only slot; cancelling it mid-Delay frees the
        // slot for C immediately (the container went back).
        let mut e = Engine::new();
        let pool = e.add_pool(1);
        let b = e.spawn("b", vec![
            Stage::Acquire(pool),
            Stage::Delay(SimNs::from_millis(100)),
            Stage::Release(pool),
        ]);
        e.spawn("a", vec![
            Stage::Delay(SimNs::from_millis(1)),
            Stage::Cancel(b),
        ]);
        let c = e.spawn("c", vec![
            Stage::Acquire(pool),
            Stage::Delay(SimNs::from_millis(5)),
            Stage::Release(pool),
        ]);
        let end = e.run().unwrap();
        // C was queued behind B; B's cancel at 1 ms hands it the slot.
        assert_eq!(e.finished_at(c), SimNs::from_millis(6));
        assert_eq!(*e.state(b), ProcState::Cancelled);
        // B's stale 100 ms timer must not stretch the run.
        assert_eq!(end, SimNs::from_millis(6));
    }

    #[test]
    fn cancel_of_queued_waiter_is_skipped_on_release() {
        // B waits in the fair queue and is cancelled while queued: the
        // next release must skip it and serve C (no slot leak, no
        // zombie grant).
        let mut e = Engine::new();
        let pool = e.add_pool(1);
        let h = e.spawn("h", vec![
            Stage::Acquire(pool),
            Stage::Delay(SimNs::from_millis(10)),
            Stage::Release(pool),
        ]);
        let b = e.spawn("b", vec![
            Stage::Acquire(pool),
            Stage::Delay(SimNs::from_millis(50)),
            Stage::Release(pool),
        ]);
        let c = e.spawn("c", vec![
            Stage::Acquire(pool),
            Stage::Delay(SimNs::from_millis(5)),
            Stage::Release(pool),
        ]);
        e.spawn("a", vec![
            Stage::Delay(SimNs::from_millis(1)),
            Stage::Cancel(b),
        ]);
        let end = e.run().unwrap();
        assert_eq!(*e.state(b), ProcState::Cancelled);
        assert_eq!(*e.state(h), ProcState::Finished);
        assert_eq!(e.finished_at(c), SimNs::from_millis(15));
        assert_eq!(end, SimNs::from_millis(15));
    }

    #[test]
    fn cancel_of_completed_proc_is_a_noop() {
        let mut e = Engine::new();
        let fast = e.spawn("fast", vec![Stage::Delay(SimNs::from_millis(1))]);
        e.spawn("late", vec![
            Stage::Delay(SimNs::from_millis(5)),
            Stage::Cancel(fast),
        ]);
        e.run().unwrap();
        assert_eq!(*e.state(fast), ProcState::Finished);
        assert!(e.cancelled_with_prefix("").is_empty());
    }

    #[test]
    fn flow_timeout_without_policy_fails_the_proc() {
        // 1000 B over a blacked-out link with a 2 s deadline and no
        // retry policy: the proc fails at 2 s, the flow is reaped (no
        // leaked capacity — a second flow then runs at full rate once
        // the window lifts), and the stall is logged.
        let mut e = Engine::new();
        let link = e.add_resource("l", 100.0);
        e.flows.add_capacity_window(link, 0.0, 60.0, 0.0);
        let p = e.spawn("doomed", vec![Stage::Flow {
            bytes: 1000.0,
            path: vec![link],
            tag: 0,
            timeout: Some(SimNs::from_secs_f64(2.0)),
        }]);
        e.spawn("later", vec![
            Stage::Delay(SimNs::from_secs_f64(60.0)),
            Stage::Flow {
                bytes: 1000.0,
                path: vec![link],
                tag: 1,
                timeout: None,
            },
        ]);
        let end = e.run().unwrap();
        assert!(matches!(e.state(p), ProcState::Failed(m)
                         if m.contains("flow timeout")));
        assert_eq!(e.finished_at(p), SimNs::from_secs_f64(2.0));
        assert_eq!(e.timeouts_with_prefix("doomed"), 1);
        assert_eq!(e.timeouts_with_prefix("later"), 0);
        // later: starts at 60 s, 1000 B at full 100 B/s → 70 s.
        assert!((end.as_secs_f64() - 70.0).abs() < 1e-6, "{end}");
    }

    #[test]
    fn flow_timeout_retries_with_backoff_through_a_blackout() {
        // Link blacked out over [0, 3): the first attempt stalls and
        // times out at 1 s, backs off 0.5 s, retries at 1.5 s, times
        // out at 2.5 s, backs off 1 s (exponential), retries at 3.5 s
        // — after the window — and the 100 B transfer completes at
        // 4.5 s. The slot is released and re-acquired per retry.
        let mut e = Engine::new();
        let link = e.add_resource("l", 100.0);
        e.flows.add_capacity_window(link, 0.0, 3.0, 0.0);
        let pool = e.add_pool(1);
        let p = e.spawn("t", vec![
            Stage::Acquire(pool),
            Stage::Flow {
                bytes: 100.0,
                path: vec![link],
                tag: 7,
                timeout: Some(SimNs::from_secs_f64(1.0)),
            },
            Stage::Release(pool),
        ]);
        e.set_flow_retry(
            p,
            SimNs::from_millis(500),
            SimNs::from_secs_f64(8.0),
            5,
        );
        let end = e.run().unwrap();
        assert_eq!(*e.state(p), ProcState::Finished);
        assert_eq!(e.timeouts_with_prefix("t"), 2);
        assert!((end.as_secs_f64() - 4.5).abs() < 1e-6, "{end}");
        // Exactly one completed transfer in the log, full volume.
        assert_eq!(e.flow_log.len(), 1);
        assert!((e.flow_log[0].bytes - 100.0).abs() < 1e-9);
        // Backoff growth is capped.
        let r = FlowRetry {
            base: SimNs::from_millis(500),
            cap: SimNs::from_secs_f64(2.0),
            max: 10,
            used: 0,
        };
        assert_eq!(r.backoff(1), SimNs::from_millis(500));
        assert_eq!(r.backoff(2), SimNs::from_secs_f64(1.0));
        assert_eq!(r.backoff(3), SimNs::from_secs_f64(2.0));
        assert_eq!(r.backoff(9), SimNs::from_secs_f64(2.0), "capped");
    }

    #[test]
    fn backoff_saturates_instead_of_overflowing() {
        // A pathological base near u64::MAX must clamp, not wrap: the
        // shift is capped at 20 doublings and the multiply saturates,
        // so the result is always `cap`-bounded and finite.
        let r = FlowRetry {
            base: SimNs(u64::MAX / 2),
            cap: SimNs(u64::MAX),
            max: 64,
            used: 0,
        };
        assert_eq!(r.backoff(1), SimNs(u64::MAX / 2));
        assert_eq!(r.backoff(2), SimNs(u64::MAX), "saturated, not wrapped");
        assert_eq!(r.backoff(u32::MAX), SimNs(u64::MAX), "shift capped");
        let capped = FlowRetry {
            base: SimNs(u64::MAX / 2),
            cap: SimNs::from_secs_f64(30.0),
            max: 64,
            used: 0,
        };
        assert_eq!(capped.backoff(40), SimNs::from_secs_f64(30.0));
    }

    #[test]
    fn timed_out_flow_returns_capacity_to_survivors() {
        // Two flows share a link; one has a deadline it cannot make
        // (no retry policy). After it is reaped the survivor must run
        // at full capacity: 1000 B total, 2×50 B/s for 1 s, then
        // 950 B at 100 B/s → done at 10.5 s.
        let mut e = Engine::new();
        let link = e.add_resource("l", 100.0);
        e.spawn("dead", vec![Stage::Flow {
            bytes: 1e9,
            path: vec![link],
            tag: 0,
            timeout: Some(SimNs::from_secs_f64(1.0)),
        }]);
        let b = e.spawn("ok", vec![Stage::Flow {
            bytes: 1000.0,
            path: vec![link],
            tag: 1,
            timeout: None,
        }]);
        let end = e.run().unwrap();
        assert_eq!(*e.state(b), ProcState::Finished);
        assert!((end.as_secs_f64() - 10.5).abs() < 1e-6, "{end}");
        assert_eq!(e.failures().len(), 1);
    }

    #[test]
    fn determinism() {
        let build = || {
            let mut e = Engine::new();
            let link = e.add_resource("l", 50.0);
            let pool = e.add_pool(2);
            let bar = e.add_barrier(3);
            for i in 0..3u32 {
                e.spawn(&format!("t{i}"), vec![
                    Stage::Acquire(pool),
                    Stage::Flow { bytes: 100.0 * (i + 1) as f64, path: vec![link], tag: i, timeout: None },
                    Stage::Release(pool),
                    Stage::Arrive(bar),
                ]);
            }
            e.spawn("j", vec![Stage::Await(bar)]);
            e.run().unwrap()
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn prefix_queries_match_full_scans() {
        // The sorted label index must agree with what a naive scan
        // over every proc/log line reports, including the spawn-order
        // rule for failure_with_prefix and interleaved job prefixes.
        let mut e = Engine::new();
        for job in ["jobB", "jobA"] {
            for i in 0..3 {
                let stages = if i == 1 {
                    vec![
                        Stage::Crash(format!("{job} attempt died")),
                        Stage::Fail(format!("{job}/m{i} gave up")),
                    ]
                } else {
                    vec![Stage::Delay(SimNs::from_micros(i as u64 + 1))]
                };
                e.spawn(&format!("{job}/m{i}"), stages);
            }
        }
        e.run().unwrap();
        assert_eq!(
            e.failure_with_prefix("jobA/"),
            Some("jobA/m1 gave up"),
            "first failed proc in spawn order within the prefix"
        );
        assert_eq!(e.failure_with_prefix("jobB/"), Some("jobB/m1 gave up"));
        assert_eq!(e.failure_with_prefix("jobC/"), None);
        assert_eq!(e.crashes_with_prefix("jobA/"), 1);
        assert_eq!(e.crashes_with_prefix("job"), 2);
        assert_eq!(e.crashes_with_prefix(""), 2, "empty prefix = all");
        assert_eq!(e.timeouts_with_prefix("job"), 0);
        // Spawning after a query refreshes the index via suffix merge.
        let late = e.spawn("jobA/late", vec![Stage::Fail("late fail".into())]);
        e.run().unwrap();
        assert!(matches!(e.state(late), ProcState::Failed(_)));
        assert_eq!(e.failure_with_prefix("jobA/l"), Some("late fail"));
        assert_eq!(
            e.failure_with_prefix("jobA/"),
            Some("jobA/m1 gave up"),
            "earlier spawn still wins the prefix"
        );
    }
}
