//! Locality-aware container scheduler (capacity-scheduler shape, one
//! queue): grant node-local placements first, then fall back to any
//! node with headroom, tracking per-node commitments so waves never
//! over-commit vcores or memory.

use std::collections::HashMap;

use crate::net::NodeId;

use super::{ContainerRequest, NodeCapacity};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocalityLevel {
    NodeLocal,
    OffNode,
    /// Request queued: cluster had no headroom in this wave (the caller
    /// schedules it in a later wave; the DES slot pools serialize
    /// execution anyway).
    Queued,
}

#[derive(Clone, Debug)]
pub struct Allocation {
    pub request_idx: usize,
    pub node: NodeId,
    pub locality: LocalityLevel,
}

#[derive(Default)]
pub struct Scheduler {
    pub node_local: u64,
    pub off_node: u64,
    pub queued: u64,
}

impl Scheduler {
    pub fn new() -> Scheduler {
        Scheduler::default()
    }

    /// One allocation wave. Requests are served in order; each takes the
    /// best available placement. Requests that fit nowhere are marked
    /// `Queued` and assigned their preferred node (execution will wait
    /// on that node's slot pool).
    pub fn allocate(
        &mut self,
        nodes: &[NodeCapacity],
        requests: &[ContainerRequest],
    ) -> Vec<Allocation> {
        let mut free: HashMap<NodeId, (u32, u64)> = nodes
            .iter()
            .map(|n| (n.node, (n.vcores, n.memory_mb)))
            .collect();
        let mut out = Vec::with_capacity(requests.len());
        let node_ids: Vec<NodeId> = nodes.iter().map(|n| n.node).collect();
        let mut rr = 0usize;
        for (idx, req) in requests.iter().enumerate() {
            let fits = |f: &(u32, u64)| {
                f.0 >= req.vcores && f.1 >= req.memory_mb
            };
            // 1. node-local
            let mut placed = None;
            for pref in &req.locality {
                if let Some(f) = free.get_mut(pref) {
                    if fits(f) {
                        f.0 -= req.vcores;
                        f.1 -= req.memory_mb;
                        placed = Some((*pref, LocalityLevel::NodeLocal));
                        break;
                    }
                }
            }
            // 2. anywhere with headroom (round-robin start for balance)
            if placed.is_none() {
                for k in 0..node_ids.len() {
                    let cand = node_ids[(rr + k) % node_ids.len()];
                    let f = free.get_mut(&cand).unwrap();
                    if fits(f) {
                        f.0 -= req.vcores;
                        f.1 -= req.memory_mb;
                        placed = Some((cand, LocalityLevel::OffNode));
                        rr = (rr + k + 1) % node_ids.len();
                        break;
                    }
                }
            }
            // 3. queue on the preferred (or first) node
            let (node, locality) = placed.unwrap_or_else(|| {
                let node = req
                    .locality
                    .first()
                    .copied()
                    .unwrap_or(node_ids[idx % node_ids.len()]);
                (node, LocalityLevel::Queued)
            });
            match locality {
                LocalityLevel::NodeLocal => self.node_local += 1,
                LocalityLevel::OffNode => self.off_node += 1,
                LocalityLevel::Queued => self.queued += 1,
            }
            out.push(Allocation { request_idx: idx, node, locality });
        }
        out
    }

    /// Fraction of non-queued placements that were node-local.
    pub fn locality_ratio(&self) -> f64 {
        let placed = self.node_local + self.off_node;
        if placed == 0 {
            return 0.0;
        }
        self.node_local as f64 / placed as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize, vcores: u32) -> Vec<NodeCapacity> {
        (0..n)
            .map(|i| NodeCapacity {
                node: NodeId(i),
                vcores,
                memory_mb: 16 * 1024,
            })
            .collect()
    }

    fn req(locality: Vec<NodeId>) -> ContainerRequest {
        ContainerRequest { vcores: 1, memory_mb: 1024, locality }
    }

    #[test]
    fn local_preference_honored() {
        let mut s = Scheduler::new();
        let allocs = s.allocate(&nodes(3, 4), &[req(vec![NodeId(2)])]);
        assert_eq!(allocs[0].node, NodeId(2));
        assert_eq!(allocs[0].locality, LocalityLevel::NodeLocal);
    }

    #[test]
    fn falls_off_node_when_preferred_full() {
        let mut s = Scheduler::new();
        let ns = nodes(2, 1);
        let reqs = vec![req(vec![NodeId(0)]), req(vec![NodeId(0)])];
        let allocs = s.allocate(&ns, &reqs);
        assert_eq!(allocs[0].locality, LocalityLevel::NodeLocal);
        assert_eq!(allocs[1].locality, LocalityLevel::OffNode);
        assert_eq!(allocs[1].node, NodeId(1));
    }

    #[test]
    fn queues_when_cluster_full() {
        let mut s = Scheduler::new();
        let ns = nodes(1, 1);
        let reqs = vec![req(vec![NodeId(0)]), req(vec![NodeId(0)])];
        let allocs = s.allocate(&ns, &reqs);
        assert_eq!(allocs[1].locality, LocalityLevel::Queued);
        assert_eq!(s.queued, 1);
    }

    #[test]
    fn never_overcommits() {
        let mut s = Scheduler::new();
        let ns = nodes(3, 2);
        let reqs: Vec<_> = (0..20).map(|_| req(vec![])).collect();
        let allocs = s.allocate(&ns, &reqs);
        let mut used: HashMap<NodeId, u32> = HashMap::new();
        for a in &allocs {
            if a.locality != LocalityLevel::Queued {
                *used.entry(a.node).or_default() += 1;
            }
        }
        for (_, u) in used {
            assert!(u <= 2, "overcommitted: {u}");
        }
        assert_eq!(s.queued, 20 - 6);
    }

    #[test]
    fn locality_ratio_math() {
        let mut s = Scheduler::new();
        s.node_local = 3;
        s.off_node = 1;
        assert!((s.locality_ratio() - 0.75).abs() < 1e-9);
    }
}
