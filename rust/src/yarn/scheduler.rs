//! Locality-aware container scheduler (capacity-scheduler shape) with
//! weighted fair queues: one queue per tenant, each with a capacity
//! share. Placement grants node-local first, then any node with
//! headroom, tracking per-node commitments so waves never over-commit
//! vcores or memory; per-tenant grant/queue counters feed the
//! `mapreduce::JobServer` reports.
//!
//! Division of labor (see `ARCHITECTURE.md`, Multi-tenancy): this
//! scheduler owns the *placement plane* — which node each container
//! lands on and how much each tenant has been granted — while the
//! *time plane* enforcement of the same shares (who actually occupies
//! a vcore slot at each virtual instant, with preemption-free
//! backfill) happens in the DES slot pools, which drain waiters
//! through the identical `util::fairq::FairQueue` discipline under the
//! weights registered here.

use std::collections::HashMap;

use crate::net::NodeId;

use super::{ContainerRequest, NodeCapacity};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// How good a placement the scheduler found for a request.
pub enum LocalityLevel {
    NodeLocal,
    OffNode,
    /// Request queued: cluster had no headroom in this wave (the caller
    /// schedules it in a later wave; the DES slot pools serialize
    /// execution anyway).
    Queued,
}

#[derive(Clone, Debug)]
/// One granted (or queued) container placement.
pub struct Allocation {
    pub request_idx: usize,
    pub node: NodeId,
    pub locality: LocalityLevel,
}

/// One tenant's fair queue: its capacity share plus the placement
/// counters accumulated by every wave allocated under it.
#[derive(Clone, Debug)]
pub struct TenantQueue {
    pub name: String,
    /// Relative capacity share (weights, not percentages).
    pub share: u64,
    /// Containers placed (node-local + off-node).
    pub granted: u64,
    pub node_local: u64,
    pub off_node: u64,
    /// Requests that found no headroom in their wave.
    pub queued: u64,
}

impl TenantQueue {
    fn new(name: &str, share: u64) -> TenantQueue {
        TenantQueue {
            name: name.to_string(),
            share: share.max(1),
            granted: 0,
            node_local: 0,
            off_node: 0,
            queued: 0,
        }
    }
}

pub struct Scheduler {
    pub node_local: u64,
    pub off_node: u64,
    pub queued: u64,
    /// Weighted fair queues, one per tenant. Index = tenant id; id 0 is
    /// the always-present default queue single-job runs allocate under.
    pub queues: Vec<TenantQueue>,
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler {
    pub fn new() -> Scheduler {
        Scheduler {
            node_local: 0,
            off_node: 0,
            queued: 0,
            queues: vec![TenantQueue::new("default", 1)],
        }
    }

    /// Register (or re-weight) a tenant queue; returns its tenant id.
    /// Id 0 is the default queue and cannot be taken by a named tenant.
    pub fn register_tenant(&mut self, name: &str, share: u64) -> usize {
        // Queue 0 is reserved for unscoped runs; named tenants live
        // at indices ≥ 1 (index == engine class == flow-tag namespace).
        if let Some(i) =
            self.queues.iter().skip(1).position(|q| q.name == name)
        {
            self.queues[i + 1].share = share.max(1);
            return i + 1;
        }
        self.queues.push(TenantQueue::new(name, share));
        self.queues.len() - 1
    }

    /// Tenant id registered under `name`, if any. Skips the reserved
    /// default queue 0, mirroring `register_tenant` — a tenant that
    /// happens to be named "default" resolves to its own queue.
    pub fn tenant_id(&self, name: &str) -> Option<usize> {
        self.queues
            .iter()
            .skip(1)
            .position(|q| q.name == name)
            .map(|i| i + 1)
    }

    /// A tenant's registered share (1 for unknown tenants).
    pub fn share_of(&self, tenant: usize) -> u64 {
        self.queues.get(tenant).map_or(1, |q| q.share)
    }

    /// One allocation wave under the default queue (single-job path).
    pub fn allocate(
        &mut self,
        nodes: &[NodeCapacity],
        requests: &[ContainerRequest],
    ) -> Vec<Allocation> {
        self.allocate_for(0, nodes, requests)
    }

    /// One allocation wave for `tenant`'s queue. Requests are served in
    /// order; each takes the best available placement. Requests that
    /// fit nowhere are marked `Queued` and assigned their preferred
    /// node — execution then waits on that node's slot pool, where the
    /// engine's weighted fair queues interleave tenants' waves by the
    /// shares registered here (preemption-free backfill: an idle
    /// tenant's slots serve whoever is backlogged).
    pub fn allocate_for(
        &mut self,
        tenant: usize,
        nodes: &[NodeCapacity],
        requests: &[ContainerRequest],
    ) -> Vec<Allocation> {
        let mut free: HashMap<NodeId, (u32, u64)> = nodes
            .iter()
            .map(|n| (n.node, (n.vcores, n.memory_mb)))
            .collect();
        let mut out = Vec::with_capacity(requests.len());
        let node_ids: Vec<NodeId> = nodes.iter().map(|n| n.node).collect();
        let mut rr = 0usize;
        for (idx, req) in requests.iter().enumerate() {
            let fits = |f: &(u32, u64)| {
                f.0 >= req.vcores && f.1 >= req.memory_mb
            };
            // 1. node-local
            let mut placed = None;
            for pref in &req.locality {
                if let Some(f) = free.get_mut(pref) {
                    if fits(f) {
                        f.0 -= req.vcores;
                        f.1 -= req.memory_mb;
                        placed = Some((*pref, LocalityLevel::NodeLocal));
                        break;
                    }
                }
            }
            // 2. anywhere with headroom (round-robin start for balance)
            if placed.is_none() {
                for k in 0..node_ids.len() {
                    let cand = node_ids[(rr + k) % node_ids.len()];
                    let f = free.get_mut(&cand).unwrap();
                    if fits(f) {
                        f.0 -= req.vcores;
                        f.1 -= req.memory_mb;
                        placed = Some((cand, LocalityLevel::OffNode));
                        rr = (rr + k + 1) % node_ids.len();
                        break;
                    }
                }
            }
            // 3. queue on the preferred (or first) node
            let (node, locality) = placed.unwrap_or_else(|| {
                let node = req
                    .locality
                    .first()
                    .copied()
                    .unwrap_or(node_ids[idx % node_ids.len()]);
                (node, LocalityLevel::Queued)
            });
            let tq = self
                .queues
                .get_mut(tenant)
                .expect("unregistered tenant queue");
            match locality {
                LocalityLevel::NodeLocal => {
                    self.node_local += 1;
                    tq.node_local += 1;
                    tq.granted += 1;
                }
                LocalityLevel::OffNode => {
                    self.off_node += 1;
                    tq.off_node += 1;
                    tq.granted += 1;
                }
                LocalityLevel::Queued => {
                    self.queued += 1;
                    tq.queued += 1;
                }
            }
            out.push(Allocation { request_idx: idx, node, locality });
        }
        out
    }

    /// Fraction of non-queued placements that were node-local.
    pub fn locality_ratio(&self) -> f64 {
        let placed = self.node_local + self.off_node;
        if placed == 0 {
            return 0.0;
        }
        self.node_local as f64 / placed as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize, vcores: u32) -> Vec<NodeCapacity> {
        (0..n)
            .map(|i| NodeCapacity {
                node: NodeId(i),
                vcores,
                memory_mb: 16 * 1024,
            })
            .collect()
    }

    fn req(locality: Vec<NodeId>) -> ContainerRequest {
        ContainerRequest { vcores: 1, memory_mb: 1024, locality }
    }

    #[test]
    fn local_preference_honored() {
        let mut s = Scheduler::new();
        let allocs = s.allocate(&nodes(3, 4), &[req(vec![NodeId(2)])]);
        assert_eq!(allocs[0].node, NodeId(2));
        assert_eq!(allocs[0].locality, LocalityLevel::NodeLocal);
    }

    #[test]
    fn falls_off_node_when_preferred_full() {
        let mut s = Scheduler::new();
        let ns = nodes(2, 1);
        let reqs = vec![req(vec![NodeId(0)]), req(vec![NodeId(0)])];
        let allocs = s.allocate(&ns, &reqs);
        assert_eq!(allocs[0].locality, LocalityLevel::NodeLocal);
        assert_eq!(allocs[1].locality, LocalityLevel::OffNode);
        assert_eq!(allocs[1].node, NodeId(1));
    }

    #[test]
    fn queues_when_cluster_full() {
        let mut s = Scheduler::new();
        let ns = nodes(1, 1);
        let reqs = vec![req(vec![NodeId(0)]), req(vec![NodeId(0)])];
        let allocs = s.allocate(&ns, &reqs);
        assert_eq!(allocs[1].locality, LocalityLevel::Queued);
        assert_eq!(s.queued, 1);
    }

    #[test]
    fn never_overcommits() {
        let mut s = Scheduler::new();
        let ns = nodes(3, 2);
        let reqs: Vec<_> = (0..20).map(|_| req(vec![])).collect();
        let allocs = s.allocate(&ns, &reqs);
        let mut used: HashMap<NodeId, u32> = HashMap::new();
        for a in &allocs {
            if a.locality != LocalityLevel::Queued {
                *used.entry(a.node).or_default() += 1;
            }
        }
        for (_, u) in used {
            assert!(u <= 2, "overcommitted: {u}");
        }
        assert_eq!(s.queued, 20 - 6);
    }

    #[test]
    fn tenant_queues_track_shares_and_grants() {
        let mut s = Scheduler::new();
        let a = s.register_tenant("alice", 3);
        let b = s.register_tenant("bob", 1);
        assert_eq!((a, b), (1, 2));
        assert_eq!(s.register_tenant("alice", 3), a, "idempotent");
        assert_eq!(s.share_of(a), 3);
        assert_eq!(s.tenant_id("bob"), Some(b));
        assert_eq!(s.tenant_id("nobody"), None);
        let ns = nodes(1, 2);
        s.allocate_for(a, &ns, &[req(vec![NodeId(0)]), req(vec![])]);
        s.allocate_for(b, &ns, &[req(vec![]), req(vec![]), req(vec![])]);
        assert_eq!(s.queues[a].granted, 2);
        assert_eq!(s.queues[a].node_local, 1);
        // bob's wave found a full cluster drained by alice? No — waves
        // are independent capacity snapshots; 2 of bob's 3 fit.
        assert_eq!(s.queues[b].granted, 2);
        assert_eq!(s.queues[b].queued, 1);
        // Global counters aggregate the queues.
        assert_eq!(s.node_local + s.off_node, 4);
        assert_eq!(s.queued, 1);
    }

    #[test]
    fn default_queue_serves_unscoped_allocations() {
        let mut s = Scheduler::new();
        s.allocate(&nodes(2, 4), &[req(vec![]), req(vec![])]);
        assert_eq!(s.queues[0].granted, 2);
        assert_eq!(s.queues[0].name, "default");
    }

    #[test]
    fn locality_ratio_math() {
        let mut s = Scheduler::new();
        s.node_local = 3;
        s.off_node = 1;
        assert!((s.locality_ratio() - 0.75).abs() < 1e-9);
    }
}
