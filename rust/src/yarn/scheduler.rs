//! Locality-aware container scheduler (capacity-scheduler shape) with
//! weighted fair queues: one queue per tenant, each with a capacity
//! share. Placement grants node-local first, then any node with
//! headroom, tracking per-node commitments so waves never over-commit
//! vcores or memory; per-tenant grant/queue counters feed the
//! `mapreduce::JobServer` reports.
//!
//! Division of labor (see `ARCHITECTURE.md`, Multi-tenancy): this
//! scheduler owns the *placement plane* — which node each container
//! lands on and how much each tenant has been granted — while the
//! *time plane* enforcement of the same shares (who actually occupies
//! a vcore slot at each virtual instant, with preemption-free
//! backfill) happens in the DES slot pools, which drain waiters
//! through the identical `util::fairq::FairQueue` discipline under the
//! weights registered here.

use std::collections::HashMap;

use crate::net::NodeId;
use crate::util::rng::Rng;

use super::placement::{fastest_first, PlacementStrategy};
use super::{ContainerRequest, NodeCapacity};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// How good a placement the scheduler found for a request.
pub enum LocalityLevel {
    NodeLocal,
    OffNode,
    /// Request queued: cluster had no headroom in this wave (the caller
    /// schedules it in a later wave; the DES slot pools serialize
    /// execution anyway).
    Queued,
}

#[derive(Clone, Debug)]
/// One granted (or queued) container placement.
pub struct Allocation {
    pub request_idx: usize,
    pub node: NodeId,
    pub locality: LocalityLevel,
}

/// One tenant's fair queue: its capacity share plus the placement
/// counters accumulated by every wave allocated under it.
#[derive(Clone, Debug)]
pub struct TenantQueue {
    pub name: String,
    /// Relative capacity share (weights, not percentages).
    pub share: u64,
    /// Containers placed (node-local + off-node).
    pub granted: u64,
    pub node_local: u64,
    pub off_node: u64,
    /// Requests that found no headroom in their wave.
    pub queued: u64,
}

impl TenantQueue {
    fn new(name: &str, share: u64) -> TenantQueue {
        TenantQueue {
            name: name.to_string(),
            share: share.max(1),
            granted: 0,
            node_local: 0,
            off_node: 0,
            queued: 0,
        }
    }
}

pub struct Scheduler {
    pub node_local: u64,
    pub off_node: u64,
    pub queued: u64,
    /// Weighted fair queues, one per tenant. Index = tenant id; id 0 is
    /// the always-present default queue single-job runs allocate under.
    pub queues: Vec<TenantQueue>,
    /// Pluggable placement strategy (see `yarn::placement`). FairOrder
    /// — the default — keeps every legacy placement bit-for-bit.
    pub placement: PlacementStrategy,
    /// Node speed factors (index = node id), installed at deploy time
    /// from the straggler profile. Empty = uniform cluster. Consulted
    /// only by `PlacementStrategy::StragglerAware`.
    pub node_speeds: Vec<f64>,
    /// Persistent cursor for `PlacementStrategy::RoundRobin` — unlike
    /// FairOrder's per-wave spill cursor, it carries across waves so
    /// consecutive small waves keep rotating.
    rr_cursor: usize,
    /// Allocation-wave counter salting the `Random` strategy's per-wave
    /// RNG: a pure function of the call sequence, so identical runs
    /// draw identical placements.
    wave: u64,
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler {
    pub fn new() -> Scheduler {
        Scheduler {
            node_local: 0,
            off_node: 0,
            queued: 0,
            queues: vec![TenantQueue::new("default", 1)],
            placement: PlacementStrategy::default(),
            node_speeds: Vec::new(),
            rr_cursor: 0,
            wave: 0,
        }
    }

    /// Register (or re-weight) a tenant queue; returns its tenant id.
    /// Id 0 is the default queue and cannot be taken by a named tenant.
    pub fn register_tenant(&mut self, name: &str, share: u64) -> usize {
        // Queue 0 is reserved for unscoped runs; named tenants live
        // at indices ≥ 1 (index == engine class == flow-tag namespace).
        if let Some(i) =
            self.queues.iter().skip(1).position(|q| q.name == name)
        {
            self.queues[i + 1].share = share.max(1);
            return i + 1;
        }
        self.queues.push(TenantQueue::new(name, share));
        self.queues.len() - 1
    }

    /// Tenant id registered under `name`, if any. Skips the reserved
    /// default queue 0, mirroring `register_tenant` — a tenant that
    /// happens to be named "default" resolves to its own queue.
    pub fn tenant_id(&self, name: &str) -> Option<usize> {
        self.queues
            .iter()
            .skip(1)
            .position(|q| q.name == name)
            .map(|i| i + 1)
    }

    /// A tenant's registered share (1 for unknown tenants).
    pub fn share_of(&self, tenant: usize) -> u64 {
        self.queues.get(tenant).map_or(1, |q| q.share)
    }

    /// One allocation wave under the default queue (single-job path).
    pub fn allocate(
        &mut self,
        nodes: &[NodeCapacity],
        requests: &[ContainerRequest],
    ) -> Vec<Allocation> {
        self.allocate_for(0, nodes, requests)
    }

    /// One allocation wave for `tenant`'s queue. Requests are served in
    /// order; each takes the best available placement under the
    /// installed [`PlacementStrategy`] (FairOrder — the default — is
    /// the legacy algorithm bit-for-bit). Requests that fit nowhere are
    /// marked `Queued` and assigned their preferred node — execution
    /// then waits on that node's slot pool, where the engine's weighted
    /// fair queues interleave tenants' waves by the shares registered
    /// here (preemption-free backfill: an idle tenant's slots serve
    /// whoever is backlogged).
    ///
    /// Determinism: every strategy's choice is a pure function of the
    /// call sequence (request order, capacities, hints, seeds) — never
    /// of wall-clock, map iteration order, or data bytes — so placement
    /// moves only virtual time, and outputs stay byte-identical.
    pub fn allocate_for(
        &mut self,
        tenant: usize,
        nodes: &[NodeCapacity],
        requests: &[ContainerRequest],
    ) -> Vec<Allocation> {
        let mut free: HashMap<NodeId, (u32, u64)> = nodes
            .iter()
            .map(|n| (n.node, (n.vcores, n.memory_mb)))
            .collect();
        let mut out = Vec::with_capacity(requests.len());
        let node_ids: Vec<NodeId> = nodes.iter().map(|n| n.node).collect();
        // FairOrder's legacy spill cursor: resets every wave (pinned by
        // `fair_order_spill_cursor_resets_per_wave`).
        let mut rr = 0usize;
        self.wave = self.wave.wrapping_add(1);
        let mut rng = match self.placement {
            PlacementStrategy::Random { seed } => Some(Rng::new(
                seed ^ self.wave.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            )),
            _ => None,
        };
        for (idx, req) in requests.iter().enumerate() {
            let fits = |f: &(u32, u64)| {
                f.0 >= req.vcores && f.1 >= req.memory_mb
            };
            let hinted = |n: NodeId| req.locality.contains(&n);
            let mut placed = None;
            // A closure would borrow `free` twice; a macro keeps the
            // take-capacity step shared across the strategy arms.
            macro_rules! take {
                ($node:expr, $level:expr) => {{
                    let f = free.get_mut(&$node).unwrap();
                    f.0 -= req.vcores;
                    f.1 -= req.memory_mb;
                    placed = Some(($node, $level));
                }};
            }
            match self.placement {
                PlacementStrategy::FairOrder
                | PlacementStrategy::HdfsLocal
                | PlacementStrategy::CacheAffinity => {
                    // 1. node-local
                    for pref in &req.locality {
                        if free.get(pref).is_some_and(fits) {
                            take!(*pref, LocalityLevel::NodeLocal);
                            break;
                        }
                    }
                    // 2. anywhere with headroom (round-robin start for
                    // balance). Strict-affinity strategies skip the
                    // spill for hinted requests: they queue on the hint
                    // holder below and ride its slot pool instead.
                    let may_spill = !self.placement.strict_affinity()
                        || req.locality.is_empty();
                    if placed.is_none() && may_spill {
                        for k in 0..node_ids.len() {
                            let cand = node_ids[(rr + k) % node_ids.len()];
                            if fits(&free[&cand]) {
                                take!(cand, LocalityLevel::OffNode);
                                rr = (rr + k + 1) % node_ids.len();
                                break;
                            }
                        }
                    }
                }
                PlacementStrategy::Random { .. } => {
                    // Seeded scan start per request; hints only
                    // classify, never steer.
                    let r = rng.as_mut().expect("Random strategy has rng");
                    let start =
                        r.below(node_ids.len().max(1) as u64) as usize;
                    for k in 0..node_ids.len() {
                        let cand = node_ids[(start + k) % node_ids.len()];
                        if fits(&free[&cand]) {
                            let level = if hinted(cand) {
                                LocalityLevel::NodeLocal
                            } else {
                                LocalityLevel::OffNode
                            };
                            take!(cand, level);
                            break;
                        }
                    }
                }
                PlacementStrategy::RoundRobin => {
                    // Persistent cursor across waves.
                    for k in 0..node_ids.len() {
                        let cand = node_ids
                            [(self.rr_cursor + k) % node_ids.len()];
                        if fits(&free[&cand]) {
                            let level = if hinted(cand) {
                                LocalityLevel::NodeLocal
                            } else {
                                LocalityLevel::OffNode
                            };
                            take!(cand, level);
                            self.rr_cursor = (self.rr_cursor + k + 1)
                                % node_ids.len();
                            break;
                        }
                    }
                }
                PlacementStrategy::StragglerAware => {
                    // 1. a full-speed hint holder with headroom.
                    for pref in &req.locality {
                        let speed = self
                            .node_speeds
                            .get(pref.0)
                            .copied()
                            .unwrap_or(1.0);
                        if speed >= 1.0
                            && free.get(pref).is_some_and(fits)
                        {
                            take!(*pref, LocalityLevel::NodeLocal);
                            break;
                        }
                    }
                    // 2. anti-affinity spill: fastest node first.
                    if placed.is_none() {
                        for cand in
                            fastest_first(&node_ids, &self.node_speeds)
                        {
                            if fits(&free[&cand]) {
                                let level = if hinted(cand) {
                                    LocalityLevel::NodeLocal
                                } else {
                                    LocalityLevel::OffNode
                                };
                                take!(cand, level);
                                break;
                            }
                        }
                    }
                }
            }
            // 3. queue on the preferred (or first) node
            let (node, locality) = placed.unwrap_or_else(|| {
                let node = req
                    .locality
                    .first()
                    .copied()
                    .unwrap_or(node_ids[idx % node_ids.len()]);
                (node, LocalityLevel::Queued)
            });
            let tq = self
                .queues
                .get_mut(tenant)
                .expect("unregistered tenant queue");
            match locality {
                LocalityLevel::NodeLocal => {
                    self.node_local += 1;
                    tq.node_local += 1;
                    tq.granted += 1;
                }
                LocalityLevel::OffNode => {
                    self.off_node += 1;
                    tq.off_node += 1;
                    tq.granted += 1;
                }
                LocalityLevel::Queued => {
                    self.queued += 1;
                    tq.queued += 1;
                }
            }
            out.push(Allocation { request_idx: idx, node, locality });
        }
        out
    }

    /// Fraction of non-queued placements that were node-local. Queued
    /// requests are deliberately excluded from the denominator: a
    /// strict-affinity strategy that queues every hinted task on its
    /// holder would otherwise read as 0% local while achieving perfect
    /// locality (pinned by `queued_never_inflates_locality_ratio`).
    pub fn locality_ratio(&self) -> f64 {
        let placed = self.node_local + self.off_node;
        if placed == 0 {
            return 0.0;
        }
        self.node_local as f64 / placed as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize, vcores: u32) -> Vec<NodeCapacity> {
        (0..n)
            .map(|i| NodeCapacity {
                node: NodeId(i),
                vcores,
                memory_mb: 16 * 1024,
            })
            .collect()
    }

    fn req(locality: Vec<NodeId>) -> ContainerRequest {
        ContainerRequest { vcores: 1, memory_mb: 1024, locality }
    }

    #[test]
    fn local_preference_honored() {
        let mut s = Scheduler::new();
        let allocs = s.allocate(&nodes(3, 4), &[req(vec![NodeId(2)])]);
        assert_eq!(allocs[0].node, NodeId(2));
        assert_eq!(allocs[0].locality, LocalityLevel::NodeLocal);
    }

    #[test]
    fn falls_off_node_when_preferred_full() {
        let mut s = Scheduler::new();
        let ns = nodes(2, 1);
        let reqs = vec![req(vec![NodeId(0)]), req(vec![NodeId(0)])];
        let allocs = s.allocate(&ns, &reqs);
        assert_eq!(allocs[0].locality, LocalityLevel::NodeLocal);
        assert_eq!(allocs[1].locality, LocalityLevel::OffNode);
        assert_eq!(allocs[1].node, NodeId(1));
    }

    #[test]
    fn queues_when_cluster_full() {
        let mut s = Scheduler::new();
        let ns = nodes(1, 1);
        let reqs = vec![req(vec![NodeId(0)]), req(vec![NodeId(0)])];
        let allocs = s.allocate(&ns, &reqs);
        assert_eq!(allocs[1].locality, LocalityLevel::Queued);
        assert_eq!(s.queued, 1);
    }

    #[test]
    fn never_overcommits() {
        let mut s = Scheduler::new();
        let ns = nodes(3, 2);
        let reqs: Vec<_> = (0..20).map(|_| req(vec![])).collect();
        let allocs = s.allocate(&ns, &reqs);
        let mut used: HashMap<NodeId, u32> = HashMap::new();
        for a in &allocs {
            if a.locality != LocalityLevel::Queued {
                *used.entry(a.node).or_default() += 1;
            }
        }
        for (_, u) in used {
            assert!(u <= 2, "overcommitted: {u}");
        }
        assert_eq!(s.queued, 20 - 6);
    }

    #[test]
    fn tenant_queues_track_shares_and_grants() {
        let mut s = Scheduler::new();
        let a = s.register_tenant("alice", 3);
        let b = s.register_tenant("bob", 1);
        assert_eq!((a, b), (1, 2));
        assert_eq!(s.register_tenant("alice", 3), a, "idempotent");
        assert_eq!(s.share_of(a), 3);
        assert_eq!(s.tenant_id("bob"), Some(b));
        assert_eq!(s.tenant_id("nobody"), None);
        let ns = nodes(1, 2);
        s.allocate_for(a, &ns, &[req(vec![NodeId(0)]), req(vec![])]);
        s.allocate_for(b, &ns, &[req(vec![]), req(vec![]), req(vec![])]);
        assert_eq!(s.queues[a].granted, 2);
        assert_eq!(s.queues[a].node_local, 1);
        // bob's wave found a full cluster drained by alice? No — waves
        // are independent capacity snapshots; 2 of bob's 3 fit.
        assert_eq!(s.queues[b].granted, 2);
        assert_eq!(s.queues[b].queued, 1);
        // Global counters aggregate the queues.
        assert_eq!(s.node_local + s.off_node, 4);
        assert_eq!(s.queued, 1);
    }

    #[test]
    fn default_queue_serves_unscoped_allocations() {
        let mut s = Scheduler::new();
        s.allocate(&nodes(2, 4), &[req(vec![]), req(vec![])]);
        assert_eq!(s.queues[0].granted, 2);
        assert_eq!(s.queues[0].name, "default");
    }

    #[test]
    fn locality_ratio_math() {
        let mut s = Scheduler::new();
        s.node_local = 3;
        s.off_node = 1;
        assert!((s.locality_ratio() - 0.75).abs() < 1e-9);
    }

    // ---- test-bug sweep regressions (ISSUE 8 satellite) ----

    #[test]
    fn queued_never_inflates_locality_ratio() {
        // Audit finding: Queued allocations are excluded from the
        // ratio's denominator — a full cluster must not drag the
        // locality metric toward zero. Pin it.
        let mut s = Scheduler::new();
        let ns = nodes(1, 1);
        let reqs =
            vec![req(vec![NodeId(0)]), req(vec![NodeId(0)]), req(vec![])];
        s.allocate(&ns, &reqs);
        assert_eq!((s.node_local, s.off_node, s.queued), (1, 0, 2));
        assert!((s.locality_ratio() - 1.0).abs() < 1e-9, "queued inflated");
        // And an all-queued wave reads 0.0, not NaN.
        let mut s = Scheduler::new();
        s.allocate(&nodes(1, 0), &[req(vec![])]);
        assert_eq!(s.locality_ratio(), 0.0);
    }

    #[test]
    fn queued_fallback_rotation_is_deterministic() {
        // Audit finding: the unhinted Queued fallback rotates by
        // *request index* (`idx % nodes`), not by any persistent or
        // randomized cursor — two identical waves must queue on
        // identical nodes. Pin it.
        let waves = |s: &mut Scheduler| {
            let ns = nodes(3, 0); // no headroom anywhere
            let reqs: Vec<_> = (0..5).map(|_| req(vec![])).collect();
            s.allocate(&ns, &reqs)
                .iter()
                .map(|a| a.node)
                .collect::<Vec<_>>()
        };
        let mut s = Scheduler::new();
        let first = waves(&mut s);
        let second = waves(&mut s);
        assert_eq!(first, second);
        let expect: Vec<NodeId> =
            [0, 1, 2, 0, 1].iter().map(|&i| NodeId(i)).collect();
        assert_eq!(first, expect);
        // Hinted requests queue on their first hint, every wave.
        let a = s.allocate(&nodes(1, 0), &[req(vec![NodeId(0)])]);
        assert_eq!(a[0].node, NodeId(0));
        assert_eq!(a[0].locality, LocalityLevel::Queued);
    }

    #[test]
    fn fair_order_spill_cursor_resets_per_wave() {
        // The FairOrder spill cursor is per-wave (legacy, bit-for-bit):
        // two identical unhinted waves start their scan at node 0.
        let mut s = Scheduler::new();
        let ns = nodes(3, 4);
        let a = s.allocate(&ns, &[req(vec![])]);
        let b = s.allocate(&ns, &[req(vec![])]);
        assert_eq!(a[0].node, NodeId(0));
        assert_eq!(b[0].node, NodeId(0), "cursor leaked across waves");
    }

    // ---- placement strategies ----

    #[test]
    fn round_robin_cursor_persists_across_waves() {
        let mut s = Scheduler::new();
        s.placement = PlacementStrategy::RoundRobin;
        let ns = nodes(3, 4);
        let picks: Vec<NodeId> = (0..4)
            .map(|_| s.allocate(&ns, &[req(vec![])])[0].node)
            .collect();
        let expect: Vec<NodeId> =
            [0, 1, 2, 0].iter().map(|&i| NodeId(i)).collect();
        assert_eq!(picks, expect);
    }

    #[test]
    fn random_is_seed_deterministic_and_seed_sensitive() {
        let run = |seed| {
            let mut s = Scheduler::new();
            s.placement = PlacementStrategy::Random { seed };
            let ns = nodes(8, 4);
            let reqs: Vec<_> = (0..16).map(|_| req(vec![])).collect();
            s.allocate(&ns, &reqs)
                .iter()
                .map(|a| a.node)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7), "same seed, same placements");
        assert_ne!(run(7), run(8), "different seeds should diverge");
    }

    #[test]
    fn random_classifies_lucky_hits_as_local() {
        // Hints never steer Random, but a lucky landing still counts
        // as node-local so locality_ratio reads as the luck baseline.
        let mut s = Scheduler::new();
        s.placement = PlacementStrategy::Random { seed: 3 };
        let all: Vec<NodeId> = (0..2).map(NodeId).collect();
        s.allocate(&nodes(2, 4), &[req(all.clone()), req(all)]);
        assert_eq!(s.node_local, 2, "every node is a hint holder");
    }

    #[test]
    fn strict_affinity_queues_instead_of_spilling() {
        // HdfsLocal/CacheAffinity: a hinted request whose holders are
        // full queues on the first holder — never spills off-node.
        for strat in
            [PlacementStrategy::HdfsLocal, PlacementStrategy::CacheAffinity]
        {
            let mut s = Scheduler::new();
            s.placement = strat;
            let ns = nodes(3, 1);
            let reqs = vec![req(vec![NodeId(1)]), req(vec![NodeId(1)])];
            let allocs = s.allocate(&ns, &reqs);
            assert_eq!(allocs[0].locality, LocalityLevel::NodeLocal);
            assert_eq!(allocs[1].locality, LocalityLevel::Queued);
            assert_eq!(allocs[1].node, NodeId(1), "queued on the holder");
            assert_eq!(s.off_node, 0, "{}: spilled", strat.name());
            // Unhinted requests still spill like FairOrder.
            let a = s.allocate(&ns, &[req(vec![])]);
            assert_eq!(a[0].locality, LocalityLevel::OffNode);
        }
    }

    #[test]
    fn straggler_aware_avoids_slow_nodes() {
        let mut s = Scheduler::new();
        s.placement = PlacementStrategy::StragglerAware;
        s.node_speeds = vec![0.25, 1.0, 0.5];
        // Unhinted: fastest node (1) first, then 2, then the straggler.
        let ns = nodes(3, 1);
        let allocs =
            s.allocate(&ns, &[req(vec![]), req(vec![]), req(vec![])]);
        let picks: Vec<NodeId> = allocs.iter().map(|a| a.node).collect();
        let expect: Vec<NodeId> =
            [1, 2, 0].iter().map(|&i| NodeId(i)).collect();
        assert_eq!(picks, expect);
        // A hint on a straggler is anti-affined away (off-node, fast)…
        let a = s.allocate(&ns, &[req(vec![NodeId(0)])]);
        assert_eq!(a[0].node, NodeId(1));
        assert_eq!(a[0].locality, LocalityLevel::OffNode);
        // …but a full-speed hint holder is honored.
        let a = s.allocate(&ns, &[req(vec![NodeId(1)])]);
        assert_eq!(a[0].node, NodeId(1));
        assert_eq!(a[0].locality, LocalityLevel::NodeLocal);
    }

    #[test]
    fn strategies_never_overcommit() {
        for strat in [
            PlacementStrategy::FairOrder,
            PlacementStrategy::Random { seed: 11 },
            PlacementStrategy::RoundRobin,
            PlacementStrategy::HdfsLocal,
            PlacementStrategy::CacheAffinity,
            PlacementStrategy::StragglerAware,
        ] {
            let mut s = Scheduler::new();
            s.placement = strat;
            s.node_speeds = vec![1.0, 0.5, 1.0];
            let ns = nodes(3, 2);
            let reqs: Vec<_> =
                (0..20).map(|i| req(vec![NodeId(i % 3)])).collect();
            let allocs = s.allocate(&ns, &reqs);
            let mut used: HashMap<NodeId, u32> = HashMap::new();
            for a in &allocs {
                if a.locality != LocalityLevel::Queued {
                    *used.entry(a.node).or_default() += 1;
                }
            }
            for (_, u) in used {
                assert!(u <= 2, "{}: overcommitted {u}", strat.name());
            }
        }
    }
}
