//! Pluggable placement strategies for the YARN scheduler — *which node*
//! a container lands on, and nothing else. The strategy steers only the
//! placement plane: flow endpoints, tier pricing, and shuffle byte
//! accounting all follow the chosen node automatically, and the data
//! plane never consults it, so job outputs are byte-identical under
//! **any** strategy at any worker count (pinned by
//! `prop_placement_never_changes_output_bytes` in `rust/tests/props.rs`).
//!
//! Strategy semantics (see `Scheduler::allocate_for` for the code):
//!
//! - **FairOrder** — today's behavior, bit-for-bit: honor each request's
//!   locality hints first, spill anywhere with headroom on a per-wave
//!   round-robin cursor, queue on the preferred node when full.
//! - **Random(seed)** — seeded scan start per request, hints ignored for
//!   ordering. The locality-by-luck baseline the fig12 bench compares
//!   affinity strategies against.
//! - **RoundRobin** — rotate a *persistent* cursor across waves (the
//!   FairOrder cursor resets every wave), hints ignored for ordering.
//! - **HdfsLocal** — strict data locality: a request with hints (the
//!   block's replica set from the NameNode) never spills off-node; if no
//!   replica holder has headroom it queues on the first holder and waits
//!   for that node's slot pool instead.
//! - **CacheAffinity** — same strict-affinity placement, plus the driver
//!   enriches *reducer* requests with the nodes holding their partition's
//!   intermediate keys (via `Stores::locate`), so stage-k+1 tasks and
//!   reducers both land where stage k's DRAM/PMEM bytes already sit —
//!   the paper's PMEM story actually exploited rather than just priced.
//! - **StragglerAware** — anti-affinity with PR 5's speed profiles:
//!   prefer a full-speed hint holder, else the fastest node with
//!   headroom (speed descending, node id ascending).
use crate::net::NodeId;

/// Which placement strategy `Scheduler::allocate_for` runs. Defaults to
/// [`PlacementStrategy::FairOrder`] (the legacy behavior) everywhere;
/// wired to TOML `[placement]`, CLI `--placement`, and env
/// `MARVEL_PLACEMENT`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlacementStrategy {
    #[default]
    FairOrder,
    Random { seed: u64 },
    RoundRobin,
    HdfsLocal,
    CacheAffinity,
    StragglerAware,
}

impl PlacementStrategy {
    /// Parse a strategy name (the TOML/CLI/env spelling). `seed` feeds
    /// `Random` and is ignored by every other strategy.
    pub fn parse(name: &str, seed: u64) -> Result<PlacementStrategy, String> {
        match name.trim() {
            "fair" | "fair-order" => Ok(PlacementStrategy::FairOrder),
            "random" => Ok(PlacementStrategy::Random { seed }),
            "round-robin" => Ok(PlacementStrategy::RoundRobin),
            "hdfs-local" => Ok(PlacementStrategy::HdfsLocal),
            "cache-affinity" => Ok(PlacementStrategy::CacheAffinity),
            "straggler-aware" => Ok(PlacementStrategy::StragglerAware),
            other => Err(format!(
                "unknown placement strategy {other:?} (expected \
                 fair|random|round-robin|hdfs-local|cache-affinity|\
                 straggler-aware)"
            )),
        }
    }

    /// Canonical name (round-trips through [`PlacementStrategy::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            PlacementStrategy::FairOrder => "fair",
            PlacementStrategy::Random { .. } => "random",
            PlacementStrategy::RoundRobin => "round-robin",
            PlacementStrategy::HdfsLocal => "hdfs-local",
            PlacementStrategy::CacheAffinity => "cache-affinity",
            PlacementStrategy::StragglerAware => "straggler-aware",
        }
    }

    /// Strict-affinity strategies queue on a hint holder rather than
    /// spilling a hinted request off-node.
    pub fn strict_affinity(&self) -> bool {
        matches!(
            self,
            PlacementStrategy::HdfsLocal | PlacementStrategy::CacheAffinity
        )
    }

    /// Whether the driver should compute intermediate-key holder hints
    /// for reducer requests (only CacheAffinity consults them; every
    /// other strategy keeps the legacy empty hints bit-for-bit).
    pub fn wants_reduce_affinity(&self) -> bool {
        matches!(self, PlacementStrategy::CacheAffinity)
    }
}

/// Order `nodes` fastest-first (speed descending, node id ascending as
/// the deterministic tie-break — the same ordering `plan_backups` uses
/// to pick backup hosts). `speeds` is indexed by node id; missing
/// entries read as full speed.
pub(crate) fn fastest_first(nodes: &[NodeId], speeds: &[f64]) -> Vec<NodeId> {
    let speed =
        |n: &NodeId| speeds.get(n.0).copied().unwrap_or(1.0);
    let mut order = nodes.to_vec();
    order.sort_by(|a, b| {
        speed(b).total_cmp(&speed(a)).then(a.0.cmp(&b.0))
    });
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_strategy() {
        for name in [
            "fair",
            "random",
            "round-robin",
            "hdfs-local",
            "cache-affinity",
            "straggler-aware",
        ] {
            let s = PlacementStrategy::parse(name, 7).unwrap();
            assert_eq!(s.name(), name);
        }
        assert_eq!(
            PlacementStrategy::parse("random", 7).unwrap(),
            PlacementStrategy::Random { seed: 7 }
        );
        assert_eq!(
            PlacementStrategy::parse(" fair ", 0).unwrap(),
            PlacementStrategy::FairOrder
        );
        assert!(PlacementStrategy::parse("greedy", 0)
            .unwrap_err()
            .contains("unknown placement strategy"));
    }

    #[test]
    fn default_is_fair_order() {
        assert_eq!(PlacementStrategy::default(), PlacementStrategy::FairOrder);
        assert!(!PlacementStrategy::default().strict_affinity());
        assert!(!PlacementStrategy::default().wants_reduce_affinity());
    }

    #[test]
    fn strictness_and_reduce_affinity_classify() {
        assert!(PlacementStrategy::HdfsLocal.strict_affinity());
        assert!(PlacementStrategy::CacheAffinity.strict_affinity());
        assert!(!PlacementStrategy::RoundRobin.strict_affinity());
        assert!(PlacementStrategy::CacheAffinity.wants_reduce_affinity());
        assert!(!PlacementStrategy::HdfsLocal.wants_reduce_affinity());
    }

    #[test]
    fn fastest_first_orders_by_speed_then_id() {
        let nodes: Vec<NodeId> = (0..4).map(NodeId).collect();
        let speeds = vec![0.25, 1.0, 1.0, 0.5];
        let order = fastest_first(&nodes, &speeds);
        assert_eq!(order, vec![NodeId(1), NodeId(2), NodeId(3), NodeId(0)]);
        // No speed table: uniform cluster, id order.
        assert_eq!(fastest_first(&nodes, &[]), nodes);
    }
}
