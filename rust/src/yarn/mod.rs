//! YARN analog: ResourceManager + NodeManagers + a locality-aware
//! container scheduler with per-tenant weighted fair queues. The paper
//! uses YARN to "determine the appropriate number of Mappers/Reducers
//! per job" (§3.3) and to place them where OpenWhisk invokers run
//! (§3.5 steps 3–4, 8); the `mapreduce::JobServer` additionally
//! registers one queue per tenant so concurrent jobs share the cluster
//! by capacity shares. See `ARCHITECTURE.md` (Layer 3).

pub mod placement;
pub mod scheduler;

use crate::net::NodeId;

pub use placement::PlacementStrategy;
pub use scheduler::{Allocation, LocalityLevel, Scheduler, TenantQueue};

/// Per-node capacity advertised by a NodeManager.
#[derive(Clone, Debug)]
pub struct NodeCapacity {
    pub node: NodeId,
    pub vcores: u32,
    pub memory_mb: u64,
}

/// A container request from an application master.
#[derive(Clone, Debug)]
pub struct ContainerRequest {
    pub vcores: u32,
    pub memory_mb: u64,
    /// Nodes holding this task's input blocks, best first.
    pub locality: Vec<NodeId>,
}

/// ResourceManager: tracks cluster capacity, sizes jobs, and delegates
/// placement to the scheduler.
pub struct ResourceManager {
    pub nodes: Vec<NodeCapacity>,
    pub scheduler: Scheduler,
}

impl ResourceManager {
    pub fn new(nodes: Vec<NodeCapacity>) -> ResourceManager {
        ResourceManager { nodes, scheduler: Scheduler::new() }
    }

    pub fn total_vcores(&self) -> u32 {
        self.nodes.iter().map(|n| n.vcores).sum()
    }

    pub fn total_memory_mb(&self) -> u64 {
        self.nodes.iter().map(|n| n.memory_mb).sum()
    }

    /// The paper's YARN role: how many mappers/reducers a job gets.
    /// Mappers = one per input split (Hadoop semantics); reducers one
    /// per vcore (wordcount reduce is I/O-bound), capped by the
    /// artifact partition count R.
    pub fn size_job(&self, splits: usize, max_reducers: usize)
        -> (usize, usize)
    {
        let mappers = splits.max(1);
        let reducers =
            (self.total_vcores() as usize).max(1).min(max_reducers);
        (mappers, reducers)
    }

    /// Allocate containers for a wave of requests (default queue).
    pub fn allocate(&mut self, requests: &[ContainerRequest])
        -> Vec<Allocation>
    {
        self.scheduler.allocate(&self.nodes, requests)
    }

    /// Register (or re-weight) a tenant's fair queue; returns its id.
    pub fn register_tenant(&mut self, name: &str, share: u64) -> usize {
        self.scheduler.register_tenant(name, share)
    }

    /// Allocate a wave under a tenant's queue (per-tenant accounting;
    /// the DES slot pools enforce the shares in virtual time).
    pub fn allocate_for(
        &mut self,
        tenant: usize,
        requests: &[ContainerRequest],
    ) -> Vec<Allocation> {
        self.scheduler.allocate_for(tenant, &self.nodes, requests)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rm(nodes: usize, vcores: u32) -> ResourceManager {
        ResourceManager::new(
            (0..nodes)
                .map(|i| NodeCapacity {
                    node: NodeId(i),
                    vcores,
                    memory_mb: 64 * 1024,
                })
                .collect(),
        )
    }

    #[test]
    fn job_sizing_follows_splits_and_cores() {
        let rm = rm(4, 16);
        let (m, r) = rm.size_job(100, 32);
        assert_eq!(m, 100);
        assert_eq!(r, 32); // 64 vcores, capped at R=32
        let (m, r) = rm.size_job(3, 8);
        assert_eq!(m, 3);
        assert_eq!(r, 8); // reducers independent of mapper count
    }

    #[test]
    fn totals() {
        let rm = rm(3, 8);
        assert_eq!(rm.total_vcores(), 24);
        assert_eq!(rm.total_memory_mb(), 3 * 64 * 1024);
    }
}
