//! Streaming statistics and percentile summaries for metrics + benches.

/// Online mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Exact percentile summary over a retained sample set.
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Percentiles { xs: Vec::new(), sorted: true }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// q in [0, 1]; nearest-rank on the sorted samples.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        // Nearest-rank: smallest value with cumulative share >= q.
        let idx = ((self.xs.len() as f64 * q).ceil() as usize).max(1) - 1;
        self.xs[idx.min(self.xs.len() - 1)]
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }
    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_mean_var() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-9);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn quantiles() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert_eq!(p.p50(), 50.0);
        assert_eq!(p.quantile(0.0), 1.0);
        assert_eq!(p.quantile(1.0), 100.0);
        assert_eq!(p.p99(), 99.0);
    }

    #[test]
    fn empty_safe() {
        let mut p = Percentiles::new();
        assert_eq!(p.p50(), 0.0);
        let s = OnlineStats::new();
        assert_eq!(s.mean(), 0.0);
    }
}
