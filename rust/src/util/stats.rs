//! Streaming statistics and percentile summaries for metrics + benches.

/// Online mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Exact percentile summary over a retained sample set.
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Percentiles { xs: Vec::new(), sorted: true }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// q in [0, 1]; nearest-rank on the sorted samples.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        // Nearest-rank: smallest value with cumulative share >= q.
        let idx = ((self.xs.len() as f64 * q).ceil() as usize).max(1) - 1;
        self.xs[idx.min(self.xs.len() - 1)]
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }
    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }
    pub fn p999(&mut self) -> f64 {
        self.quantile(0.999)
    }
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    /// Freeze the tail summary the open-loop report carries per metric.
    pub fn summary(&mut self) -> PercentileSummary {
        PercentileSummary {
            n: self.len() as u64,
            mean: self.mean(),
            p50: self.p50(),
            p99: self.p99(),
            p999: self.p999(),
        }
    }
}

/// Immutable snapshot of a [`Percentiles`] set: sample count, mean and
/// the p50/p99/p999 tail — the unit of latency reporting in
/// `ServerResult::open_loop`. An empty set snapshots to all zeros.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PercentileSummary {
    /// Number of samples summarized.
    pub n: u64,
    /// Arithmetic mean of the samples.
    pub mean: f64,
    /// Median (nearest-rank).
    pub p50: f64,
    /// 99th percentile (nearest-rank).
    pub p99: f64,
    /// 99.9th percentile (nearest-rank) — on small N this degrades to
    /// the maximum sample, never an interpolated phantom.
    pub p999: f64,
}

/// Skew coefficient of a partition-bytes histogram: p99 / median
/// (nearest-rank), the number `JobResult::partition_skew` reports.
///
/// Edge semantics: an empty histogram, a single partition, and an
/// all-equal spread are all "no skew" — 1.0 — except the degenerate
/// all-zero histogram (median 0), which also reports 1.0 rather than
/// a division blow-up. A perfectly balanced shuffle therefore reads
/// exactly 1.0 and a viral-key shuffle reads ≫ 1.
pub fn skew_coefficient(partition_bytes: &[u64]) -> f64 {
    if partition_bytes.len() <= 1 {
        return 1.0;
    }
    let mut p = Percentiles::new();
    for &b in partition_bytes {
        p.push(b as f64);
    }
    let med = p.p50();
    if med <= 0.0 {
        return 1.0;
    }
    p.p99() / med
}

/// Gini coefficient of a partition-bytes histogram in [0, 1):
/// 0 = perfectly balanced, →1 = one partition carries everything.
/// Empty, single-partition, and all-zero histograms report 0.0.
pub fn gini(partition_bytes: &[u64]) -> f64 {
    let n = partition_bytes.len();
    if n <= 1 {
        return 0.0;
    }
    let total: u128 = partition_bytes.iter().map(|&b| b as u128).sum();
    if total == 0 {
        return 0.0;
    }
    let mut xs: Vec<u64> = partition_bytes.to_vec();
    xs.sort_unstable();
    // G = (2 Σ_i i·x_i) / (n Σ x) − (n + 1) / n, with i 1-based over
    // the ascending sort.
    let weighted: u128 = xs
        .iter()
        .enumerate()
        .map(|(i, &x)| (i as u128 + 1) * x as u128)
        .sum();
    (2.0 * weighted as f64) / (n as f64 * total as f64)
        - (n as f64 + 1.0) / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_mean_var() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-9);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn quantiles() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert_eq!(p.p50(), 50.0);
        assert_eq!(p.quantile(0.0), 1.0);
        assert_eq!(p.quantile(1.0), 100.0);
        assert_eq!(p.p99(), 99.0);
    }

    #[test]
    fn empty_safe() {
        let mut p = Percentiles::new();
        assert_eq!(p.p50(), 0.0);
        let s = OnlineStats::new();
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn empty_summary_is_all_zeros() {
        let mut p = Percentiles::new();
        let s = p.summary();
        assert_eq!(s, PercentileSummary::default());
        assert_eq!(s.n, 0);
        assert_eq!(s.p999, 0.0);
    }

    #[test]
    fn single_sample_dominates_every_quantile() {
        let mut p = Percentiles::new();
        p.push(42.0);
        assert_eq!(p.quantile(0.0), 42.0);
        assert_eq!(p.p50(), 42.0);
        assert_eq!(p.p99(), 42.0);
        assert_eq!(p.p999(), 42.0);
        let s = p.summary();
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 42.0);
    }

    #[test]
    fn p999_on_small_n_is_the_max_not_a_phantom() {
        // Nearest-rank with N < 1000: ceil(N * 0.999) == N, so p999 is
        // the largest observed sample — never interpolated past it.
        for n in [2usize, 10, 100, 999] {
            let mut p = Percentiles::new();
            for i in 1..=n {
                p.push(i as f64);
            }
            assert_eq!(p.p999(), n as f64, "N={n}");
            assert!(p.p99() <= p.p999(), "monotone tail at N={n}");
        }
        // At N=1000 the rank finally separates from the max.
        let mut p = Percentiles::new();
        for i in 1..=1000 {
            p.push(i as f64);
        }
        assert_eq!(p.p999(), 999.0);
        assert_eq!(p.quantile(1.0), 1000.0);
    }

    #[test]
    fn skew_coefficient_edge_cases() {
        // Empty, single-partition, all-equal, and all-zero histograms
        // all read "no skew".
        assert_eq!(skew_coefficient(&[]), 1.0);
        assert_eq!(skew_coefficient(&[123]), 1.0);
        assert_eq!(skew_coefficient(&[7, 7, 7, 7]), 1.0);
        assert_eq!(skew_coefficient(&[0, 0, 0]), 1.0);
    }

    #[test]
    fn skew_coefficient_flags_viral_key() {
        // 31 balanced partitions and one 100× whale: p99 picks the
        // whale (rank 32 of 32), median stays in the mass.
        let mut h = vec![10u64; 31];
        h.push(1000);
        let s = skew_coefficient(&h);
        assert!((s - 100.0).abs() < 1e-9, "got {s}");
        // Mild imbalance stays near 1.
        let mild = skew_coefficient(&[9, 10, 10, 11]);
        assert!(mild >= 1.0 && mild < 1.3, "got {mild}");
    }

    #[test]
    fn gini_bounds_and_edges() {
        assert_eq!(gini(&[]), 0.0);
        assert_eq!(gini(&[42]), 0.0);
        assert_eq!(gini(&[0, 0, 0, 0]), 0.0);
        assert!(gini(&[5, 5, 5, 5]).abs() < 1e-12);
        // One partition carries everything: G = (n−1)/n.
        let g = gini(&[0, 0, 0, 100]);
        assert!((g - 0.75).abs() < 1e-9, "got {g}");
        // Order-invariant.
        assert!((gini(&[1, 2, 3, 4]) - gini(&[4, 2, 1, 3])).abs() < 1e-12);
        // Known closed form for 1..=4: G = 0.25.
        assert!((gini(&[1, 2, 3, 4]) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn duplicate_heavy_samples() {
        // 990 copies of 1.0 and 10 copies of 100.0: the median and p99
        // sit in the duplicate mass, only the extreme tail escapes it.
        let mut p = Percentiles::new();
        for _ in 0..990 {
            p.push(1.0);
        }
        for _ in 0..10 {
            p.push(100.0);
        }
        assert_eq!(p.p50(), 1.0);
        assert_eq!(p.p99(), 1.0); // rank 990 is still inside the mass
        assert_eq!(p.p999(), 100.0);
        let s = p.summary();
        assert_eq!(s.n, 1000);
        assert!((s.mean - (990.0 + 1000.0) / 1000.0).abs() < 1e-9);
    }
}
