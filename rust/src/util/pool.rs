//! Fixed-size thread pool for the real data plane (tokenization, hashing,
//! corpus generation). The simulator itself is single-threaded and
//! deterministic; the pool only parallelizes *pure* per-chunk work.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Minimal fixed-size thread pool (offline rayon stand-in).
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(threads);
        for _ in 0..threads {
            let rx = Arc::clone(&rx);
            let inflight = Arc::clone(&inflight);
            workers.push(thread::spawn(move || loop {
                let job = rx.lock().unwrap().recv();
                match job {
                    Ok(job) => {
                        job();
                        inflight.fetch_sub(1, Ordering::SeqCst);
                    }
                    Err(_) => break,
                }
            }));
        }
        ThreadPool { tx: Some(tx), workers, inflight }
    }

    /// Number of logical CPUs (fallback 4).
    pub fn default_threads() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker hung up");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rrx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("worker died")).collect()
    }

    /// Block until all submitted jobs have finished.
    pub fn wait_idle(&self) {
        while self.inflight.load(Ordering::SeqCst) > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_waits() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                thread::sleep(std::time::Duration::from_millis(2));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
