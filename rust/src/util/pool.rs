//! Fixed-size thread pool for the real data plane (tokenization, hashing,
//! corpus generation). The simulator itself is single-threaded and
//! deterministic; the pool only parallelizes *pure* per-chunk work.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Minimal fixed-size thread pool (offline rayon stand-in).
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(threads);
        for _ in 0..threads {
            let rx = Arc::clone(&rx);
            let inflight = Arc::clone(&inflight);
            workers.push(thread::spawn(move || loop {
                let job = rx.lock().unwrap().recv();
                match job {
                    Ok(job) => {
                        job();
                        inflight.fetch_sub(1, Ordering::SeqCst);
                    }
                    Err(_) => break,
                }
            }));
        }
        ThreadPool { tx: Some(tx), workers, inflight }
    }

    /// Number of logical CPUs (fallback 4).
    pub fn default_threads() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker hung up");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rrx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("worker died")).collect()
    }

    /// Block until all submitted jobs have finished.
    pub fn wait_idle(&self) {
        while self.inflight.load(Ordering::SeqCst) > 0 {
            thread::yield_now();
        }
    }
}

/// Fan `f(i, state)` over `0..n` across `workers` *scoped* threads with
/// a self-claiming atomic index — the generic engine under the driver's
/// `pool_run`. Each worker owns a private state built by `init` (an
/// `RtEngine` oracle in the data plane); results land in per-item slots
/// and are returned in item order, with every worker's final state
/// alongside (stat absorption). Determinism: which worker claims which
/// item affects nothing but wall-clock, because items never share
/// mutable state and output order is by item, not by completion.
pub fn run_indexed<T, S, I, F>(
    workers: usize,
    n: usize,
    init: I,
    f: F,
) -> (Vec<T>, Vec<S>)
where
    T: Send,
    S: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut S) -> T + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 {
        let mut state = init();
        let out = (0..n).map(|i| f(i, &mut state)).collect();
        return (out, vec![state]);
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let states = Mutex::new(Vec::with_capacity(workers));
    thread::scope(|sc| {
        for _ in 0..workers {
            sc.spawn(|| {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = f(i, &mut state);
                    *slots[i].lock().unwrap() = Some(out);
                }
                states.lock().unwrap().push(state);
            });
        }
    });
    let out = slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("pool worker died"))
        .collect();
    (out, states.into_inner().unwrap())
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_waits() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                thread::sleep(std::time::Duration::from_millis(2));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn run_indexed_preserves_item_order() {
        let (out, states) =
            run_indexed(4, 100, || 0usize, |i, s: &mut usize| {
                *s += 1;
                i * 2
            });
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(states.len(), 4);
        assert_eq!(states.iter().sum::<usize>(), 100, "every item ran once");
    }

    #[test]
    fn run_indexed_serial_path_uses_one_state() {
        let (out, states) =
            run_indexed(1, 5, Vec::new, |i, s: &mut Vec<usize>| {
                s.push(i);
                i
            });
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        assert_eq!(states, vec![vec![0, 1, 2, 3, 4]], "in-order, one worker");
    }

    #[test]
    fn run_indexed_clamps_workers_to_items() {
        // More workers than items must not spawn idle-state havoc:
        // worker count clamps to n.
        let (out, states) = run_indexed(8, 2, || (), |i, _| i);
        assert_eq!(out, vec![0, 1]);
        assert_eq!(states.len(), 2);
    }
}
