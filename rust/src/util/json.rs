//! Minimal JSON parser + writer (no serde available offline).
//!
//! Parses `artifacts/manifest.json` and writes metrics reports. Supports
//! the full JSON value model; numbers are f64 (manifest values fit).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
/// A parsed JSON value (offline serde stand-in).
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len()
                        && (self.b[self.i] & 0xC0) == 0x80
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E'
                || c == b'+' || c == b'-'
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {txt:?}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"o":{"k":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo — ✓\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo — ✓"));
    }
}
