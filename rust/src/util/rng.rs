//! Deterministic PRNG + distributions (no external `rand` offline).
//!
//! SplitMix64 seeds Xoshiro256**; Zipf sampling backs the corpus
//! generator (word frequencies in natural-language corpora are zipfian,
//! which is what makes map-side combining effective).

/// SplitMix64 — used for seeding and cheap stateless mixing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = splitmix64(&mut sm);
        }
        Rng { s }
    }

    /// Derive an independent stream (for per-task determinism).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// true with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

/// Zipf(s, n) sampler via rejection-inversion (Hörmann).
///
/// Values are 0-based ranks in [0, n); rank 0 is the most frequent.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    c: f64,
}

impl Zipf {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n > 0, "zipf needs n > 0");
        assert!(s > 0.0 && (s - 1.0).abs() > 1e-9, "s>0, s!=1 supported");
        let h = |x: f64| -> f64 { (x.powf(1.0 - s) - 1.0) / (1.0 - s) };
        let h_x1 = h(1.5) - 1.0;
        let h_n = h(n as f64 + 0.5);
        let c = h_n - h_x1;
        Zipf { n, s, h_x1, h_n, c }
    }

    fn h_inv(&self, x: f64) -> f64 {
        (1.0 + x * (1.0 - self.s)).powf(1.0 / (1.0 - self.s))
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        loop {
            let u = self.h_n - rng.f64() * self.c;
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().max(1.0).min(self.n as f64);
            let h = |x: f64| -> f64 {
                (x.powf(1.0 - self.s) - 1.0) / (1.0 - self.s)
            };
            let hk = if k <= 1.5 { self.h_x1 + 1.0 } else { h(k + 0.5) };
            // Accept if u >= h(k + 0.5) - k^-s
            if u >= hk - k.powf(-self.s) {
                return k as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn zipf_rank_ordering() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Rng::new(5);
        let mut counts = vec![0u32; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut r) as usize] += 1;
        }
        // rank 0 clearly more frequent than rank 10 than rank 100
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[100]);
        // all samples in range (indexing above would have panicked)
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(11);
        let mean: f64 = (0..50_000).map(|_| r.exp(4.0)).sum::<f64>() / 50_000.0;
        assert!((mean - 4.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
