//! In-repo micro/bench harness (criterion is unavailable offline).
//!
//! `Bench::run` performs warm-up, then timed iterations, reporting
//! mean/p50/p99/min. Bench binaries (`benches/*.rs`, `harness = false`)
//! use this plus `util::table` to print the paper's tables/figures.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use super::json::Json;
use super::stats::Percentiles;

#[derive(Clone, Debug)]
/// Timing summary of one benchmarked closure.
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        if self.mean_ns <= 0.0 {
            return 0.0;
        }
        items_per_iter / (self.mean_ns / 1e9)
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("iters".into(), Json::Num(self.iters as f64));
        m.insert("mean_ns".into(), Json::Num(self.mean_ns));
        m.insert("p50_ns".into(), Json::Num(self.p50_ns));
        m.insert("p99_ns".into(), Json::Num(self.p99_ns));
        m.insert("min_ns".into(), Json::Num(self.min_ns));
        Json::Obj(m)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Warmup + timed-iteration micro-bench harness.
pub struct Bench {
    pub warmup_iters: u64,
    pub iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, iters: 10 }
    }
}

impl Bench {
    pub fn new(warmup_iters: u64, iters: u64) -> Self {
        Bench { warmup_iters, iters: iters.max(1) }
    }

    /// Time `f`, preventing dead-code elimination via the returned value.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut p = Percentiles::new();
        let mut min = f64::INFINITY;
        let mut total = 0.0;
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            let dt = t0.elapsed().as_nanos() as f64;
            p.push(dt);
            min = min.min(dt);
            total += dt;
        }
        BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_ns: total / self.iters as f64,
            p50_ns: p.p50(),
            p99_ns: p.p99(),
            min_ns: min,
        }
    }
}

/// Write a machine-readable bench report: per-result timing stats plus
/// free-form derived metrics (MB/s, tokens/s, speedups). Feeds the
/// repo's perf trajectory (`BENCH_*.json` files read by PERF.md).
pub fn write_report(
    path: &Path,
    results: &[&BenchResult],
    metrics: &[(&str, f64)],
) -> std::io::Result<()> {
    let mut res = BTreeMap::new();
    for r in results {
        res.insert(r.name.clone(), r.to_json());
    }
    let mut met = BTreeMap::new();
    for (k, v) in metrics {
        met.insert((*k).to_string(), Json::Num(*v));
    }
    let mut top = BTreeMap::new();
    top.insert("results".into(), Json::Obj(res));
    top.insert("metrics".into(), Json::Obj(met));
    std::fs::write(path, Json::Obj(top).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::new(1, 5);
        let r = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns);
        assert!(r.min_ns <= r.mean_ns);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("µs"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }

    #[test]
    fn report_roundtrips_as_json() {
        let r = BenchResult {
            name: "x".into(),
            iters: 3,
            mean_ns: 1.5e6,
            p50_ns: 1.4e6,
            p99_ns: 2.0e6,
            min_ns: 1.2e6,
        };
        let dir = std::env::temp_dir().join("marvel_bench_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("r.json");
        write_report(&path, &[&r], &[("mb_per_s", 123.5)]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap();
        assert_eq!(
            j.get("results").unwrap().get("x").unwrap()
                .get("iters").unwrap().as_u64(),
            Some(3)
        );
        assert_eq!(
            j.get("metrics").unwrap().get("mb_per_s").unwrap().as_f64(),
            Some(123.5)
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            p50_ns: 1e9,
            p99_ns: 1e9,
            min_ns: 1e9,
        };
        assert!((r.throughput(100.0) - 100.0).abs() < 1e-9);
    }
}
