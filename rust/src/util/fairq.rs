//! Deterministic weighted fair queue (start-time fair queueing over
//! integer virtual time).
//!
//! One `FairQueue` multiplexes any number of *classes* (tenants) over a
//! shared grant stream: each class holds a FIFO of waiting items plus a
//! virtual-time tag, and every grant charges the served class
//! `SCALE / weight` of virtual service. `pop` always serves the
//! backlogged class with the smallest tag (ties broken by class id), so
//! over a contended span class *i* receives grants in proportion to its
//! weight — while an idle class's unused capacity is redistributed to
//! the backlogged ones automatically (preemption-free backfill: nothing
//! already granted is ever revoked).
//!
//! All arithmetic is integer and all iteration order is `BTreeMap`,
//! so the grant sequence is a pure function of the push/pop sequence —
//! the determinism the DES engine (`crate::sim`) and the YARN fair
//! scheduler (`crate::yarn`) both build on. See `ARCHITECTURE.md`
//! (Multi-tenancy) for how the two layers share this queue.

use std::collections::{BTreeMap, VecDeque};

/// Virtual-service units charged per grant at weight 1. A weight-`w`
/// class is charged `SCALE / w`, so weights up to `SCALE` stay
/// non-degenerate; integer division keeps everything deterministic.
pub const SCALE: u64 = 1 << 20;

/// A weighted fair queue over classes of `T`.
#[derive(Debug)]
pub struct FairQueue<T> {
    queues: BTreeMap<u32, VecDeque<T>>,
    vtime: BTreeMap<u32, u64>,
    /// Virtual clock: the start tag of the most recent grant. Newly
    /// backlogged classes are caught up to it so an idle spell cannot
    /// bank credit.
    vclock: u64,
}

impl<T> Default for FairQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> FairQueue<T> {
    pub fn new() -> FairQueue<T> {
        FairQueue {
            queues: BTreeMap::new(),
            vtime: BTreeMap::new(),
            vclock: 0,
        }
    }

    /// Number of waiting items across all classes.
    pub fn len(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.queues.values().all(|q| q.is_empty())
    }

    /// Enqueue `item` under `class`. A class going from idle to
    /// backlogged has its virtual time caught up to the queue's clock.
    pub fn push(&mut self, class: u32, item: T) {
        let q = self.queues.entry(class).or_default();
        if q.is_empty() {
            let v = self.vtime.entry(class).or_insert(self.vclock);
            *v = (*v).max(self.vclock);
        }
        q.push_back(item);
    }

    /// Dequeue the head of the backlogged class with the smallest
    /// virtual time (ties: smallest class id) and charge it one grant.
    /// `weight_of` maps a class to its share (0 is treated as 1).
    ///
    /// A class drained by this pop has its (now empty) queue pruned: a
    /// long-lived server cycles through unboundedly many tenant
    /// classes, and an empty `VecDeque` per ever-seen class is an
    /// unbounded leak. The class's virtual-time *tag* is deliberately
    /// kept — dropping it would shed the grant just charged, letting a
    /// class that drains on every grant (the crash-retry
    /// Release→Acquire shape) re-enter at the clock and outcompete or
    /// even starve heavier backlogged classes. Stale tags are
    /// reclaimed by the amortized sweep below once the clock passes
    /// them, at which point they are indistinguishable from absent
    /// ([`FairQueue::push`] catches a re-arriving class up to the
    /// clock either way).
    pub fn pop(&mut self, weight_of: impl Fn(u32) -> u64) -> Option<(u32, T)> {
        let class = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .map(|(c, _)| (self.vtime.get(c).copied().unwrap_or(self.vclock), *c))
            .min()?
            .1;
        let item = self.queues.get_mut(&class)?.pop_front()?;
        self.charge(class, weight_of(class));
        if self.queues.get(&class).is_some_and(|q| q.is_empty()) {
            self.queues.remove(&class);
        }
        self.sweep_stale();
        Some((class, item))
    }

    /// Hand the most recently pushed item of `class` back to the
    /// caller *without* charging any virtual service — the admission
    /// path for a saturated pool, which must bounce a submission it
    /// just queued. Undoing the push must not leave residue: if the
    /// bounce drains the class, its queue entry is pruned like a
    /// drained pop's would be, and its virtual-time tag is dropped
    /// *iff* it is information-free (at or behind the clock, where
    /// [`FairQueue::push`] would recreate it identically). A tag ahead
    /// of the clock records real granted service and is kept — shedding
    /// it would let a reject-looping class outcompete honest ones
    /// (same reasoning as the drain-requeue rule on [`FairQueue::pop`]).
    pub fn take_back(&mut self, class: u32) -> Option<T> {
        let q = self.queues.get_mut(&class)?;
        let item = q.pop_back()?;
        if q.is_empty() {
            self.queues.remove(&class);
            if self.vtime.get(&class).is_some_and(|v| *v <= self.vclock) {
                self.vtime.remove(&class);
            }
        }
        Some(item)
    }

    /// Charge `class` one grant of virtual service without dequeueing —
    /// used when a grant bypasses the queue entirely (an uncontended
    /// slot acquire), so backfilled service still counts against the
    /// class when contention later arrives. The per-grant charge is
    /// floored at 1 so a weight above [`SCALE`] still advances the
    /// class's tag (otherwise it would monopolize the queue). Also
    /// sweeps — a pool that never contends only ever calls `charge`,
    /// and its per-class tags must not leak either.
    pub fn charge(&mut self, class: u32, weight: u64) {
        let v = self.vtime.entry(class).or_insert(self.vclock);
        let start = (*v).max(self.vclock);
        self.vclock = start;
        *v = start + (SCALE / weight.max(1)).max(1);
        self.sweep_stale();
    }

    /// Amortized sweep of stale tags (drained classes, and classes
    /// that only ever consumed uncontended grants): once the clock has
    /// caught up to a queue-less class's tag it carries no
    /// information, so it can go. Triggered only when the stale set
    /// dominates the backlogged one, keeping pop/charge
    /// O(backlogged classes) amortized under a moving clock. (A clock
    /// that never advances — every class granted exactly once, ever —
    /// keeps its tags; reclamation rides on classes being granted
    /// repeatedly, which is what real pools do.)
    fn sweep_stale(&mut self) {
        if self.vtime.len() > 2 * self.queues.len() + 8 {
            let (vclock, queues) = (self.vclock, &self.queues);
            self.vtime.retain(|c, v| queues.contains_key(c) || *v > vclock);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn weights(w: &[(u32, u64)]) -> impl Fn(u32) -> u64 + '_ {
        move |c| {
            w.iter()
                .find(|(cc, _)| *cc == c)
                .map(|(_, ww)| *ww)
                .unwrap_or(1)
        }
    }

    #[test]
    fn single_class_is_fifo() {
        let mut q = FairQueue::new();
        for i in 0..5 {
            q.push(0, i);
        }
        let got: Vec<i32> = (0..5).map(|_| q.pop(|_| 1).unwrap().1).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
    }

    #[test]
    fn three_to_one_grant_ratio() {
        // Two saturated classes with 3:1 weights: over 40 grants, class
        // 1 gets ~30 and class 2 gets ~10.
        let mut q = FairQueue::new();
        for i in 0..40 {
            q.push(1, i);
            q.push(2, i);
        }
        let w = [(1u32, 3u64), (2, 1)];
        let first40: Vec<u32> =
            (0..40).map(|_| q.pop(weights(&w)).unwrap().0).collect();
        let c1 = first40.iter().filter(|c| **c == 1).count();
        assert!((28..=31).contains(&c1), "class 1 got {c1}/40 grants");
        // Remaining 40 items still drain completely.
        let rest = (0..40).map(|_| q.pop(weights(&w)).unwrap()).count();
        assert_eq!(rest, 40);
        assert!(q.pop(weights(&w)).is_none());
    }

    #[test]
    fn idle_class_capacity_is_backfilled() {
        // Class 2 idle: class 1 takes every grant (no reserved waste).
        let mut q = FairQueue::new();
        for i in 0..8 {
            q.push(1, i);
        }
        let w = [(1u32, 1u64), (2, 100)];
        for _ in 0..8 {
            assert_eq!(q.pop(weights(&w)).unwrap().0, 1);
        }
    }

    #[test]
    fn late_arrival_cannot_bank_credit() {
        // Class 2 arrives after class 1 consumed many grants: it is
        // caught up to the virtual clock, not handed the entire backlog.
        let mut q = FairQueue::new();
        for i in 0..100 {
            q.push(1, i);
        }
        let w = [(1u32, 1u64), (2, 1)];
        for _ in 0..50 {
            q.pop(weights(&w)).unwrap();
        }
        for i in 0..10 {
            q.push(2, i);
        }
        // From here grants alternate ~1:1 — class 2 never gets a run of
        // 10 consecutive grants.
        let next20: Vec<u32> =
            (0..20).map(|_| q.pop(weights(&w)).unwrap().0).collect();
        let c2 = next20.iter().filter(|c| **c == 2).count();
        assert!((8..=12).contains(&c2), "class 2 got {c2}/20 after idle");
    }

    #[test]
    fn charge_counts_untracked_grants() {
        // Class 1 burns 12 uncontended grants via charge(); when class 2
        // becomes backlogged it is *not* owed the past (vclock caught
        // up), but future grants still honor the weights.
        let mut q: FairQueue<u32> = FairQueue::new();
        for _ in 0..12 {
            q.charge(1, 1);
        }
        let w = [(1u32, 1u64), (2, 1)];
        for i in 0..4 {
            q.push(1, i);
            q.push(2, i);
        }
        let order: Vec<u32> =
            (0..8).map(|_| q.pop(weights(&w)).unwrap().0).collect();
        let c2 = order.iter().filter(|c| **c == 2).count();
        assert_eq!(c2, 4);
        // Class 2 is served first (class 1 is behind in virtual time).
        assert_eq!(order[0], 2);
    }

    #[test]
    fn astronomic_weight_cannot_monopolize() {
        // weight > SCALE: the integer charge floors at 1, so the heavy
        // class still advances its tag and the light class is served
        // within a couple of grants instead of starving behind a
        // never-moving tag.
        let mut q = FairQueue::new();
        for i in 0..8 {
            q.push(1, i);
        }
        q.push(2, 0);
        let w = [(1u32, u64::MAX), (2, 1)];
        let first3: Vec<u32> =
            (0..3).map(|_| q.pop(weights(&w)).unwrap().0).collect();
        assert!(first3.contains(&2),
                "light class starved by over-SCALE weight: {first3:?}");
    }

    #[test]
    fn drained_classes_are_pruned() {
        // Regression: a long-lived JobServer cycles through unbounded
        // tenant classes; drained classes used to leave an empty
        // VecDeque behind forever.
        let mut q = FairQueue::new();
        for class in 0..1000u32 {
            q.push(class, class);
        }
        while q.pop(|_| 1).is_some() {}
        assert!(q.is_empty());
        assert_eq!(q.queues.len(), 0, "drained queues must be pruned");
        // Tags outlive their queues just long enough to keep fairness
        // exact; once later traffic advances the clock past them, the
        // amortized sweep reclaims them too.
        for i in 0..2000 {
            q.push(1000, i);
        }
        while q.pop(|_| 1).is_some() {}
        assert!(q.vtime.len() <= 9,
                "stale tags not reclaimed: {}", q.vtime.len());
        // Pruning does not change scheduling: a re-arriving class is
        // caught up to the clock exactly as an idle class would be.
        q.push(7, 1);
        assert_eq!(q.pop(|_| 1), Some((7, 1)));
    }

    #[test]
    fn drain_requeue_class_cannot_shed_its_charge() {
        // A class whose queue drains on every grant (the crash-retry
        // Release→Acquire shape) must keep its virtual-time charge:
        // if draining dropped the tag, a low-id single-item cycler
        // would re-enter at the clock and starve heavier backlogged
        // classes outright.
        let mut q = FairQueue::new();
        let w = [(1u32, 1u64), (2, 3)];
        for i in 0..30 {
            q.push(2, i); // weight-3 class, steadily backlogged
        }
        q.push(1, 100); // weight-1 class, re-queued after every grant
        let mut grants1 = 0;
        for _ in 0..24 {
            let (c, _) = q.pop(weights(&w)).unwrap();
            if c == 1 {
                grants1 += 1;
                q.push(1, 100);
            }
        }
        // 1:3 weights → the cycler gets ~1/4 of grants, not 1/2+.
        assert!((4..=8).contains(&grants1),
                "drain-requeue class took {grants1}/24 grants");
    }

    #[test]
    fn uncontended_pool_tags_are_swept_from_charge() {
        // A pool with spare capacity never queues — only charge()
        // runs. One-shot tenant classes must still be reclaimed once
        // the clock moves past them (a pop may never come).
        let mut q: FairQueue<u32> = FairQueue::new();
        for _ in 0..20 {
            q.charge(1, 1); // a busy class advances the clock
        }
        for class in 100..200 {
            q.charge(class, 1); // one-shot tenants, never seen again
        }
        for _ in 0..2 {
            q.charge(1, 1); // the clock passes the stale tags
        }
        assert!(q.vtime.len() <= 9,
                "charge-only tags leaked: {}", q.vtime.len());
    }

    #[test]
    fn charge_only_tags_are_swept() {
        // Classes that only ever consumed uncontended grants (charge
        // without push) must not leak tags once the clock passes them.
        let mut q: FairQueue<u32> = FairQueue::new();
        for class in 0..100u32 {
            q.charge(class, 1);
        }
        // A later backlogged stream advances the clock past the stale
        // tags; the amortized sweep reclaims them.
        for i in 0..200 {
            q.push(1000, i);
        }
        while q.pop(|_| 1).is_some() {}
        assert!(q.vtime.len() <= 9, "stale charge tags: {}", q.vtime.len());
    }

    #[test]
    fn take_back_leaves_no_residue() {
        // Regression (alongside drained_classes_are_pruned): a
        // saturated admission pool queues a submission and immediately
        // bounces it. The bounce must not leave an empty queue entry or
        // a stale vtime tag behind — a long-lived server rejecting
        // one-shot tenant classes would otherwise leak both maps.
        let mut q = FairQueue::new();
        for class in 0..1000u32 {
            q.push(class, class);
            assert_eq!(q.take_back(class), Some(class));
        }
        assert!(q.is_empty());
        assert_eq!(q.queues.len(), 0, "bounced queues must be pruned");
        assert_eq!(q.vtime.len(), 0, "bounced never-served tags must go");
        // Bouncing a class that was never pushed is a no-op.
        assert_eq!(q.take_back(7), None);
    }

    #[test]
    fn take_back_undoes_the_push_not_the_service() {
        // A class with real granted service keeps its charge through a
        // bounce: push → take_back must not reset its tag to the clock.
        let mut q = FairQueue::new();
        let w = [(1u32, 1u64), (2, 3)];
        for i in 0..30 {
            q.push(2, i);
        }
        // Class 1 is granted once (charged SCALE), then reject-loops.
        q.push(1, 100);
        assert_eq!(q.pop(weights(&w)).unwrap().0, 1);
        let mut grants1 = 0;
        for _ in 0..24 {
            q.push(1, 100);
            let (c, _) = q.pop(weights(&w)).unwrap();
            if c == 1 {
                grants1 += 1;
            } else {
                // Not served this round: bounce the queued item, as the
                // admission path does on a saturated pool.
                assert_eq!(q.take_back(1), Some(100));
            }
        }
        // 1:3 weights → the reject-looper still gets ~1/4 of grants;
        // if take_back shed the charge it would win every other grant.
        assert!((4..=9).contains(&grants1),
                "reject-loop class took {grants1}/24 grants");
        // FIFO order within the class survives a partial take_back.
        let mut q2: FairQueue<u32> = FairQueue::new();
        q2.push(5, 1);
        q2.push(5, 2);
        q2.push(5, 3);
        assert_eq!(q2.take_back(5), Some(3), "take_back is LIFO (undo)");
        assert_eq!(q2.pop(|_| 1), Some((5, 1)));
        assert_eq!(q2.pop(|_| 1), Some((5, 2)));
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut q = FairQueue::new();
            let w = [(1u32, 3u64), (2, 2), (3, 1)];
            let mut out = Vec::new();
            for i in 0..30 {
                q.push(1 + (i % 3) as u32, i);
                if i % 2 == 0 {
                    if let Some((c, v)) = q.pop(weights(&w)) {
                        out.push((c, v));
                    }
                }
            }
            while let Some(x) = q.pop(weights(&w)) {
                out.push(x);
            }
            out
        };
        assert_eq!(run(), run());
    }
}
