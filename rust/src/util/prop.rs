//! Mini property-testing framework (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`Gen`]; the runner executes it
//! for `cases` random seeds and, on failure, re-runs with progressively
//! simpler size hints to report a smaller counterexample seed. Failures
//! print the seed so they replay deterministically.

use super::rng::Rng;

/// Generation context handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Size hint in [1, 100]; generators should scale collection sizes
    /// and magnitudes by this so shrinking finds small counterexamples.
    pub size: u64,
}

impl Gen {
    pub fn usize_up_to(&mut self, max: usize) -> usize {
        let cap = ((max as u64) * self.size / 100).max(1);
        self.rng.below(cap + 1) as usize
    }

    pub fn u64_up_to(&mut self, max: u64) -> u64 {
        let cap = (max * self.size / 100).max(1);
        self.rng.below(cap + 1)
    }

    pub fn vec_u64(&mut self, max_len: usize, max_val: u64) -> Vec<u64> {
        let len = self.usize_up_to(max_len);
        (0..len).map(|_| self.rng.below(max_val.max(1))).collect()
    }

    pub fn bytes(&mut self, max_len: usize) -> Vec<u8> {
        let len = self.usize_up_to(max_len);
        (0..len).map(|_| self.rng.next_u64() as u8).collect()
    }

    pub fn word(&mut self, max_len: usize) -> Vec<u8> {
        let len = self.usize_up_to(max_len).max(1);
        (0..len)
            .map(|_| b'a' + (self.rng.below(26) as u8))
            .collect()
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Result of a single property case.
pub type PropResult = Result<(), String>;

/// Run `prop` for `cases` cases. Panics (test failure) with the seed and
/// message of the first failing case, after attempting seed-level
/// shrinking via smaller size hints.
pub fn check<F: Fn(&mut Gen) -> PropResult>(name: &str, cases: u64, prop: F) {
    check_seeded(name, cases, 0xC0FFEE, prop)
}

pub fn check_seeded<F: Fn(&mut Gen) -> PropResult>(
    name: &str,
    cases: u64,
    base_seed: u64,
    prop: F,
) {
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let size = 1 + (case * 100 / cases.max(1)).min(99);
        let mut g = Gen { rng: Rng::new(seed), size };
        if let Err(msg) = prop(&mut g) {
            // Shrink: retry the same seed with smaller sizes to report
            // the simplest reproducing size.
            let mut simplest = (size, msg.clone());
            let mut sz = size / 2;
            while sz >= 1 {
                let mut g = Gen { rng: Rng::new(seed), size: sz };
                if let Err(m) = prop(&mut g) {
                    simplest = (sz, m);
                }
                if sz == 1 {
                    break;
                }
                sz /= 2;
            }
            panic!(
                "property {name:?} failed: {} \
                 [replay: seed={seed:#x} size={}]",
                simplest.1, simplest.0
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |g| {
            let a = g.rng.next_u32() as u64;
            let b = g.rng.next_u32() as u64;
            prop_assert!(a + b == b + a);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always-small", 50, |g| {
            let v = g.vec_u64(100, 1000);
            prop_assert!(v.len() < 5, "len was {}", v.len());
            Ok(())
        });
    }

    #[test]
    fn sizes_scale_up() {
        let mut max_len = 0usize;
        check("observe-size", 50, |g| {
            let v = g.vec_u64(100, 10);
            // Not a real assertion; just observe.
            if v.len() > 50 {
                // large sizes do occur by the end
            }
            Ok(())
        });
        // generate directly at size 100
        let mut g = Gen { rng: Rng::new(1), size: 100 };
        for _ in 0..50 {
            max_len = max_len.max(g.vec_u64(100, 10).len());
        }
        assert!(max_len > 50);
    }
}
