//! Hashing — MUST stay in lock-step with the partition scheme baked into
//! the AOT artifacts (python/compile/model.py):
//!
//! ```text
//! h      = fnv1a32(word) & 0x7fff_ffff      (non-negative i32)
//! bucket = h & (B - 1)                      (B = 1024)
//! part   = (h >> 10) & (R - 1)              (R = 32)
//! ```
//!
//! `runtime::oracle` and the integration tests cross-check Rust-side and
//! kernel-side placement for every word.

/// FNV-1a 32-bit.
#[inline]
pub fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// FNV-1a 64-bit (internal hash maps / rendezvous hashing).
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// 64-bit finalizer (splitmix-style avalanche) for combining ids.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The non-negative token hash fed to the combine kernels.
#[inline]
pub fn token_hash(word: &[u8]) -> i32 {
    (fnv1a32(word) & 0x7fff_ffff) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv32_known_vectors() {
        // Published FNV-1a test vectors.
        assert_eq!(fnv1a32(b""), 0x811c9dc5);
        assert_eq!(fnv1a32(b"a"), 0xe40c292c);
        assert_eq!(fnv1a32(b"foobar"), 0xbf9cf968);
    }

    #[test]
    fn fnv64_known_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn token_hash_non_negative() {
        for w in [&b"the"[..], b"zipf", b"x", b"antidisestablishment"] {
            assert!(token_hash(w) >= 0);
        }
    }

    #[test]
    fn mix64_changes_bits() {
        assert_ne!(mix64(1), mix64(2));
        // mix64 is a bijective finalizer with fixed point 0.
        assert_ne!(mix64(1), 0);
    }
}
