//! Foundation utilities built in-repo (the offline crate set has no
//! rand/serde/toml/proptest/criterion — see ARCHITECTURE.md, Offline
//! constraint).

pub mod bench;
pub mod bytes;
pub mod fairq;
pub mod hash;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod toml_mini;
