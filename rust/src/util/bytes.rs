//! Byte-size formatting and parsing ("5GB", "512MiB", "1.2 GiB/s").

pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * KIB;
pub const GIB: u64 = 1024 * MIB;
pub const KB: u64 = 1000;
pub const MB: u64 = 1000 * KB;
pub const GB: u64 = 1000 * MB;

/// Human-readable binary size ("1.50 GiB").
pub fn human(bytes: u64) -> String {
    let b = bytes as f64;
    if bytes >= GIB {
        format!("{:.2} GiB", b / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.2} MiB", b / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.2} KiB", b / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// Gigabits-per-second from bytes over seconds (paper Figure 6 unit).
pub fn gbps(bytes: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    (bytes as f64 * 8.0) / secs / 1e9
}

/// Parse "512", "4KB", "4KiB", "1.5GB", "2 GiB" (case-insensitive).
pub fn parse_size(s: &str) -> Result<u64, String> {
    let t = s.trim().to_ascii_lowercase().replace(' ', "");
    let split = t
        .find(|c: char| c.is_ascii_alphabetic())
        .unwrap_or(t.len());
    let (num, unit) = t.split_at(split);
    let v: f64 = num
        .parse()
        .map_err(|_| format!("bad size number in {s:?}"))?;
    let mult = match unit {
        "" | "b" => 1,
        "k" | "kb" => KB,
        "kib" => KIB,
        "m" | "mb" => MB,
        "mib" => MIB,
        "g" | "gb" => GB,
        "gib" => GIB,
        _ => return Err(format!("bad size unit in {s:?}")),
    };
    Ok((v * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_variants() {
        assert_eq!(parse_size("512").unwrap(), 512);
        assert_eq!(parse_size("4KB").unwrap(), 4000);
        assert_eq!(parse_size("4KiB").unwrap(), 4096);
        assert_eq!(parse_size("1.5GB").unwrap(), 1_500_000_000);
        assert_eq!(parse_size("2 GiB").unwrap(), 2 * GIB);
        assert!(parse_size("x5").is_err());
        assert!(parse_size("5xx").is_err());
    }

    #[test]
    fn human_sizes() {
        assert_eq!(human(42), "42 B");
        assert_eq!(human(2048), "2.00 KiB");
        assert_eq!(human(3 * GIB / 2), "1.50 GiB");
    }

    #[test]
    fn gbps_math() {
        // 1.25 GB in 1s = 10 Gbps
        assert!((gbps(1_250_000_000, 1.0) - 10.0).abs() < 1e-9);
        assert_eq!(gbps(100, 0.0), 0.0);
    }
}
