//! ASCII table renderer for the bench harness — prints the same rows the
//! paper's tables/figures report.

/// Aligned text table printer for the paper's tables/figures.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.chars().count();
                s.push_str(&format!("| {}{} ", c, " ".repeat(pad)));
            }
            s.push('|');
            s
        };
        let mut out = String::new();
        out.push_str(&format!("=== {} ===\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds with sensible precision for job-time tables.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 10.0 {
        format!("{s:.1}")
    } else {
        format!("{s:.2}")
    }
}

/// Format a ratio as a percentage string.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.1} %", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "long-header"]);
        t.row_strs(&["1", "2"]);
        t.row_strs(&["333333", "4"]);
        let r = t.render();
        assert!(r.contains("=== T ==="));
        assert!(r.contains("| a      | long-header |"));
        // all lines same width
        let widths: Vec<usize> = r
            .lines()
            .skip(1)
            .map(|l| l.chars().count())
            .collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_strs(&["1"]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(123.4), "123");
        assert_eq!(fmt_secs(12.34), "12.3");
        assert_eq!(fmt_secs(1.234), "1.23");
        assert_eq!(fmt_pct(0.866), "86.6 %");
    }
}
