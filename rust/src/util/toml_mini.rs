//! Mini-TOML parser for the config system (no `toml` crate offline).
//!
//! Supported subset: `[section]`, `[section.sub]`, `key = value` with
//! string / integer / float / bool / size-string values, `#` comments.
//! Flat enough for cluster + experiment configs, strict enough to reject
//! typos (unknown syntax is an error, not silently ignored).

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
/// A parsed TOML scalar.
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: section path ("a.b") → key → value.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut doc = Doc::default();
        let mut section = String::new();
        doc.sections.entry(String::new()).or_default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section"))?
                    .trim();
                if name.is_empty() {
                    return Err(err(lineno, "empty section name"));
                }
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
            } else if let Some(eq) = line.find('=') {
                let key = line[..eq].trim();
                let val = line[eq + 1..].trim();
                if key.is_empty() {
                    return Err(err(lineno, "empty key"));
                }
                let v = parse_value(val)
                    .map_err(|e| err(lineno, &e))?;
                doc.sections
                    .get_mut(&section)
                    .unwrap()
                    .insert(key.to_string(), v);
            } else {
                return Err(err(lineno, "expected `[section]` or `key = value`"));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Byte size: accepts int (bytes) or size string ("4GiB").
    pub fn size_or(&self, section: &str, key: &str, default: u64) -> u64 {
        match self.get(section, key) {
            Some(Value::Int(i)) => *i as u64,
            Some(Value::Str(s)) => {
                crate::util::bytes::parse_size(s).unwrap_or(default)
            }
            _ => default,
        }
    }
}

fn err(lineno: usize, msg: &str) -> String {
    format!("line {}: {}", lineno + 1, msg)
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.starts_with('"') {
        let inner = s
            .strip_prefix('"')
            .and_then(|t| t.strip_suffix('"'))
            .ok_or_else(|| format!("unterminated string {s:?}"))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# cluster config
name = "test"          # inline comment
[cluster]
nodes = 4
pmem_per_node = "700GiB"
replication = 3
fast = true
[cluster.nic]
gbps = 10.0
"#;

    #[test]
    fn parse_sample() {
        let d = Doc::parse(SAMPLE).unwrap();
        assert_eq!(d.str_or("", "name", "?"), "test");
        assert_eq!(d.i64_or("cluster", "nodes", 0), 4);
        assert_eq!(d.size_or("cluster", "pmem_per_node", 0), 700 * 1024 * 1024 * 1024);
        assert!(d.bool_or("cluster", "fast", false));
        assert_eq!(d.f64_or("cluster.nic", "gbps", 0.0), 10.0);
    }

    #[test]
    fn defaults_apply() {
        let d = Doc::parse("").unwrap();
        assert_eq!(d.i64_or("x", "y", 7), 7);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Doc::parse("not a kv line").is_err());
        assert!(Doc::parse("[unterminated").is_err());
        assert!(Doc::parse("k = @bad").is_err());
    }

    #[test]
    fn hash_inside_string() {
        let d = Doc::parse("k = \"a#b\"").unwrap();
        assert_eq!(d.str_or("", "k", ""), "a#b");
    }

    #[test]
    fn underscored_ints() {
        let d = Doc::parse("n = 1_000_000").unwrap();
        assert_eq!(d.i64_or("", "n", 0), 1_000_000);
    }
}
