//! AWS Lambda platform model — the baseline substrate under Corral.
//!
//! Quotas modeled from AWS's published limits (paper refs [5, 7]):
//! account-level concurrent executions, per-invocation startup, maximum
//! function memory (the paper configured 10 GB), and the ephemeral
//! payload ceiling that, combined with S3 throttling, makes Corral jobs
//! *fail outright* at ≈15 GB input (paper §4.2.1 observation 1).

use crate::sim::{Engine, PoolId, SimNs};

#[derive(Clone, Debug)]
/// AWS Lambda account limits and latencies (Corral baseline).
pub struct LambdaConfig {
    /// Account-level concurrent execution quota (AWS default 1000).
    pub max_concurrency: usize,
    /// Max memory per function instance; the paper used the 10 GB cap.
    pub memory_mb: u64,
    /// Cold init for a packaged MapReduce runtime.
    pub cold_start: SimNs,
    pub warm_start: SimNs,
    /// Aggregate input bytes past which the job hits the transfer/
    /// concurrency wall and fails (Corral observed 15 GB).
    pub transfer_limit: u64,
    /// Function wall-clock timeout (15 min AWS max).
    pub timeout: SimNs,
}

impl Default for LambdaConfig {
    fn default() -> Self {
        LambdaConfig {
            max_concurrency: 1000,
            memory_mb: 10_240,
            cold_start: SimNs::from_millis(800),
            warm_start: SimNs::from_millis(10),
            transfer_limit: 15_000_000_000,
            timeout: SimNs::from_secs_f64(900.0),
        }
    }
}

/// The Lambda platform instance: account concurrency pool + warm
/// execution-environment reuse + quota admission.
pub struct Lambda {
    pub cfg: LambdaConfig,
    /// One shared concurrency pool for the whole account.
    pub concurrency: PoolId,
    warm: usize,
    pub cold_starts: u64,
    /// Invocations served by a reused (warm) execution environment.
    pub warm_starts: u64,
    /// Execution environments that died mid-invocation (injected
    /// faults) — never returned to the warm set.
    pub crashes: u64,
}

impl Lambda {
    pub fn new(engine: &mut Engine, cfg: LambdaConfig) -> Lambda {
        let concurrency = engine.add_pool(cfg.max_concurrency);
        Lambda {
            cfg,
            concurrency,
            warm: 0,
            cold_starts: 0,
            warm_starts: 0,
            crashes: 0,
        }
    }

    /// Admission check a Corral job must pass before launching.
    pub fn admit_job(&self, total_input_bytes: u64, tasks: usize)
        -> Result<(), String>
    {
        if total_input_bytes > self.cfg.transfer_limit {
            return Err(format!(
                "S3/Lambda transfer limit exceeded: input {} B > {} B \
                 (concurrency quota + S3 rate limiting abort the job)",
                total_input_bytes, self.cfg.transfer_limit
            ));
        }
        // Far over-quota task fan-out also gets rejected upfront
        // (throttle-retry storms exhaust Corral's retry budget).
        if tasks > self.cfg.max_concurrency * 20 {
            return Err(format!(
                "invocation storm: {tasks} tasks vs quota {}",
                self.cfg.max_concurrency
            ));
        }
        Ok(())
    }

    /// Startup latency of the next invocation (Lambda reuses execution
    /// environments aggressively once warmed).
    pub fn startup(&mut self) -> (SimNs, bool) {
        if self.warm > 0 {
            self.warm -= 1;
            self.warm_starts += 1;
            (self.cfg.warm_start, false)
        } else {
            self.cold_starts += 1;
            (self.cfg.cold_start, true)
        }
    }

    pub fn finish(&mut self) {
        if self.warm < self.cfg.max_concurrency {
            self.warm += 1;
        }
    }

    /// The execution environment died mid-invocation (injected fault):
    /// nothing returns to the warm set — the retry may cold-start.
    pub fn crash(&mut self) {
        self.crashes += 1;
    }

    /// Memory-based split sizing: Corral sizes splits so a task's input
    /// fits the function memory with working-space headroom.
    pub fn max_split_bytes(&self) -> u64 {
        (self.cfg.memory_mb * 1024 * 1024) / 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_past_transfer_limit_fails() {
        let mut e = Engine::new();
        let l = Lambda::new(&mut e, LambdaConfig::default());
        assert!(l.admit_job(20_000_000_000, 100).is_err());
        assert!(l.admit_job(10_000_000_000, 100).is_ok());
    }

    #[test]
    fn boundary_at_15gb() {
        let mut e = Engine::new();
        let l = Lambda::new(&mut e, LambdaConfig::default());
        assert!(l.admit_job(15_000_000_000, 10).is_ok());
        assert!(l.admit_job(15_000_000_001, 10).is_err());
    }

    #[test]
    fn warm_reuse() {
        let mut e = Engine::new();
        let mut l = Lambda::new(&mut e, LambdaConfig::default());
        let (_, cold) = l.startup();
        assert!(cold);
        l.finish();
        let (lat, cold) = l.startup();
        assert!(!cold);
        assert_eq!(lat, SimNs::from_millis(10));
        assert_eq!(l.cold_starts, 1);
        assert_eq!(l.warm_starts, 1);
    }

    #[test]
    fn invocation_storm_rejected() {
        let mut e = Engine::new();
        let l = Lambda::new(&mut e, LambdaConfig::default());
        assert!(l.admit_job(1_000, 1000 * 20 + 1).is_err());
    }

    #[test]
    fn split_sizing_from_memory() {
        let mut e = Engine::new();
        let l = Lambda::new(&mut e, LambdaConfig::default());
        // 10 GiB memory / 4 = 2.56 GiB splits.
        assert_eq!(l.max_split_bytes(), 10_240 * 1024 * 1024 / 4);
    }
}
