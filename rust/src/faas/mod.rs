//! FaaS substrate: the OpenWhisk analog Marvel runs on (controller,
//! per-node invokers, warm/cold container pools) and the AWS Lambda
//! model under the Corral baseline.
//!
//! See `ARCHITECTURE.md` (Layer 2) for the warm-pool sharing model and
//! "Open-loop serving & autoscaling" for how [`Controller::autoscale`]
//! tracks an arrival rate with an [`AutoscaleConfig`] policy.

pub mod action;
pub mod container;
pub mod controller;
pub mod invoker;
pub mod lambda;

pub use action::{ActionKind, ActionSpec, Invocation, HADOOP_RUNTIME};
pub use container::{ContainerConfig, ContainerPool};
pub use controller::{AutoscaleConfig, Controller};
pub use invoker::Invoker;
pub use lambda::{Lambda, LambdaConfig};
