//! Warm/cold container pool per invoker node.
//!
//! OpenWhisk keeps paused containers per (action runtime) and resumes
//! them in ~ms; a cold start pulls + boots the Docker runtime (hundreds
//! of ms). Marvel's Hadoop runtime image is heavyweight, so cold starts
//! matter at small input sizes (visible as the flat left end of the
//! Figure 4/5 curves).

use std::collections::HashMap;

use crate::sim::SimNs;

#[derive(Clone, Debug)]
/// Container lifecycle latencies and warm-pool sizing.
pub struct ContainerConfig {
    /// Docker pull + boot + runtime init.
    pub cold_start: SimNs,
    /// Unpause + handshake.
    pub warm_start: SimNs,
    /// How many idle containers per runtime are kept warm.
    pub keep_warm: usize,
}

impl Default for ContainerConfig {
    fn default() -> Self {
        ContainerConfig {
            cold_start: SimNs::from_millis(500),
            warm_start: SimNs::from_millis(5),
            keep_warm: 32,
        }
    }
}

/// Tracks warm-container counts per runtime image on one node.
#[derive(Debug)]
pub struct ContainerPool {
    cfg: ContainerConfig,
    warm: HashMap<String, usize>,
    pub cold_starts: u64,
    pub warm_starts: u64,
    /// Containers that died mid-action (injected failures). A crashed
    /// container never returns to the warm pool — its warm state is
    /// lost with it, so a later acquire may go cold again.
    pub crashes: u64,
}

impl ContainerPool {
    pub fn new(cfg: ContainerConfig) -> ContainerPool {
        ContainerPool {
            cfg,
            warm: HashMap::new(),
            cold_starts: 0,
            warm_starts: 0,
            crashes: 0,
        }
    }

    /// Acquire a container for `runtime`; returns the startup latency
    /// and whether it was a cold start.
    pub fn acquire(&mut self, runtime: &str) -> (SimNs, bool) {
        let warm = self.warm.entry(runtime.to_string()).or_insert(0);
        if *warm > 0 {
            *warm -= 1;
            self.warm_starts += 1;
            (self.cfg.warm_start, false)
        } else {
            self.cold_starts += 1;
            (self.cfg.cold_start, true)
        }
    }

    /// Release a container back; it stays warm up to `keep_warm`.
    pub fn release(&mut self, runtime: &str) {
        let warm = self.warm.entry(runtime.to_string()).or_insert(0);
        if *warm < self.cfg.keep_warm {
            *warm += 1;
        }
    }

    /// The container running an action died (injected fault): it is
    /// destroyed, not returned — the pool permanently loses the warm
    /// state `release` would have preserved.
    pub fn crash(&mut self, _runtime: &str) {
        self.crashes += 1;
    }

    /// Pre-warm `n` containers (deployment-time provisioning).
    pub fn prewarm(&mut self, runtime: &str, n: usize) {
        let warm = self.warm.entry(runtime.to_string()).or_insert(0);
        *warm = (*warm + n).min(self.cfg.keep_warm);
    }

    pub fn warm_count(&self, runtime: &str) -> usize {
        self.warm.get(runtime).copied().unwrap_or(0)
    }

    /// Evict up to `n` idle warm containers for `runtime` (the
    /// autoscaler's scale-down path: an over-provisioned pool drains so
    /// idle containers stop holding memory). Returns how many were
    /// actually evicted — never more than are warm, and containers
    /// currently running actions are untouched.
    pub fn drain(&mut self, runtime: &str, n: usize) -> usize {
        let Some(warm) = self.warm.get_mut(runtime) else {
            return 0;
        };
        let k = n.min(*warm);
        *warm -= k;
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_acquire_is_cold() {
        let mut p = ContainerPool::new(ContainerConfig::default());
        let (lat, cold) = p.acquire("img");
        assert!(cold);
        assert_eq!(lat, SimNs::from_millis(500));
        assert_eq!(p.cold_starts, 1);
    }

    #[test]
    fn release_then_acquire_is_warm() {
        let mut p = ContainerPool::new(ContainerConfig::default());
        p.acquire("img");
        p.release("img");
        let (lat, cold) = p.acquire("img");
        assert!(!cold);
        assert_eq!(lat, SimNs::from_millis(5));
    }

    #[test]
    fn crashed_container_is_not_returned_warm() {
        let mut p = ContainerPool::new(ContainerConfig::default());
        p.prewarm("img", 1);
        let (_, cold) = p.acquire("img");
        assert!(!cold);
        p.crash("img"); // container died mid-action
        assert_eq!(p.crashes, 1);
        assert_eq!(p.warm_count("img"), 0, "warm state lost with it");
        let (_, cold) = p.acquire("img");
        assert!(cold, "retry pays a cold start");
    }

    #[test]
    fn keep_warm_caps_pool() {
        let cfg = ContainerConfig { keep_warm: 2, ..Default::default() };
        let mut p = ContainerPool::new(cfg);
        for _ in 0..5 {
            p.release("img");
        }
        assert_eq!(p.warm_count("img"), 2);
    }

    #[test]
    fn runtimes_are_isolated() {
        let mut p = ContainerPool::new(ContainerConfig::default());
        p.prewarm("a", 1);
        let (_, cold_b) = p.acquire("b");
        assert!(cold_b);
        let (_, cold_a) = p.acquire("a");
        assert!(!cold_a);
    }

    #[test]
    fn drain_evicts_only_idle_warm_stock() {
        let mut p = ContainerPool::new(ContainerConfig::default());
        p.prewarm("img", 4);
        assert_eq!(p.drain("img", 3), 3);
        assert_eq!(p.warm_count("img"), 1);
        // Draining past the stock (or an unknown runtime) is bounded.
        assert_eq!(p.drain("img", 10), 1);
        assert_eq!(p.drain("other", 5), 0);
        // The next acquire after a full drain goes cold again.
        let (_, cold) = p.acquire("img");
        assert!(cold);
    }

    #[test]
    fn prewarm_respects_cap() {
        let cfg = ContainerConfig { keep_warm: 3, ..Default::default() };
        let mut p = ContainerPool::new(cfg);
        p.prewarm("img", 100);
        assert_eq!(p.warm_count("img"), 3);
    }
}
