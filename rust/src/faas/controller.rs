//! OpenWhisk controller / load balancer: routes action invocations to
//! invokers. Marvel's modification (paper §3.4.2): the controller is
//! topology-aware — it honors locality hints from the NameNode so map
//! actions land where their split's blocks live, and it deploys every
//! container on the shared overlay network.

use crate::net::NodeId;
use crate::sim::{Engine, SimNs};

use super::action::{ActionSpec, Invocation};
use super::container::ContainerConfig;
use super::invoker::Invoker;

/// Elastic warm-pool sizing policy: the controller tracks the observed
/// arrival rate and grows/shrinks the warm stock toward
/// `rate × warm_per_rate`, with hysteresis so the pool neither flaps on
/// noise nor drains the instant load dips. Disabled by default — the
/// closed-loop paths keep their static `prewarm` provisioning.
#[derive(Clone, Debug)]
pub struct AutoscaleConfig {
    /// Master switch; `false` leaves the warm pool entirely static.
    pub enabled: bool,
    /// Warm containers to hold per observed job arrival per second
    /// (each admitted job fans out into several container waves).
    pub warm_per_rate: f64,
    /// Scale up only when the desired stock exceeds the current target
    /// by this factor (e.g. 1.25 = 25% headroom before growing).
    pub up_threshold: f64,
    /// Scale down only when the desired stock falls below the current
    /// target by this factor (e.g. 0.5 = halve before shrinking).
    pub down_threshold: f64,
    /// Floor on the warm target once the autoscaler is live.
    pub min_warm: usize,
    /// Ceiling on the warm target (bounded by node count × keep_warm).
    pub max_warm: usize,
    /// Trailing window over which the serve loop observes arrival rate.
    pub window: SimNs,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            enabled: false,
            warm_per_rate: 8.0,
            up_threshold: 1.25,
            down_threshold: 0.5,
            min_warm: 0,
            max_warm: 256,
            window: SimNs::from_secs_f64(30.0),
        }
    }
}

/// The OpenWhisk controller/load-balancer: routes invocations to
/// per-node invokers; pools survive across jobs on a shared cluster.
pub struct Controller {
    pub invokers: Vec<Invoker>,
    /// Controller-side per-invocation overhead (auth, routing, queueing).
    pub dispatch_overhead: SimNs,
    rr: usize,
    /// Current autoscaler warm target (0 until the first scale-up).
    warm_target: usize,
    /// Scale-up decisions the autoscaler has taken.
    pub scale_ups: u64,
    /// Scale-down decisions the autoscaler has taken.
    pub scale_downs: u64,
}

impl Controller {
    pub fn new(
        engine: &mut Engine,
        slots_per_node: &[usize],
        cfg: ContainerConfig,
    ) -> Controller {
        let invokers = slots_per_node
            .iter()
            .enumerate()
            .map(|(i, s)| Invoker::new(engine, NodeId(i), *s, cfg.clone()))
            .collect();
        Controller {
            invokers,
            dispatch_overhead: SimNs::from_millis(2),
            rr: 0,
            warm_target: 0,
            scale_ups: 0,
            scale_downs: 0,
        }
    }

    pub fn n_invokers(&self) -> usize {
        self.invokers.len()
    }

    /// Choose an invoker: first preference that has an invoker, else
    /// round-robin (OpenWhisk's hash-based balancing degenerates to RR
    /// under uniform load).
    pub fn place(&mut self, locality: &[NodeId]) -> NodeId {
        for pref in locality {
            if pref.0 < self.invokers.len() {
                return *pref;
            }
        }
        let n = NodeId(self.rr % self.invokers.len());
        self.rr += 1;
        n
    }

    /// Plan an invocation on a chosen node: returns the invocation
    /// record; the caller builds stages with
    /// [Acquire(slots), Delay(dispatch+startup), <body>, Release].
    pub fn invoke(&mut self, spec: &ActionSpec, node: NodeId) -> Invocation {
        let inv = &mut self.invokers[node.0];
        let (startup, cold) = inv.startup(&spec.runtime);
        Invocation {
            action: spec.name.clone(),
            node,
            cold,
            startup: self.dispatch_overhead + startup,
        }
    }

    /// Return the container after the action body completes.
    pub fn complete(&mut self, spec: &ActionSpec, node: NodeId) {
        self.invokers[node.0].finish(&spec.runtime);
    }

    /// The container running an action crashed (injected fault): it is
    /// destroyed instead of returning to the warm pool, so the node
    /// permanently loses that warm slot and a retry may go cold.
    pub fn crash(&mut self, spec: &ActionSpec, node: NodeId) {
        self.invokers[node.0].containers.crash(&spec.runtime);
    }

    /// Containers that died mid-action across all invokers.
    pub fn crashes(&self) -> u64 {
        self.invokers.iter().map(|i| i.containers.crashes).sum()
    }

    /// Pre-warm the Hadoop runtime across all invokers (deployment step
    /// of the Marvel stack).
    pub fn prewarm(&mut self, runtime: &str, per_node: usize) {
        for inv in &mut self.invokers {
            inv.containers.prewarm(runtime, per_node);
        }
    }

    pub fn cold_starts(&self) -> u64 {
        self.invokers.iter().map(|i| i.containers.cold_starts).sum()
    }

    /// Warm (pool-reuse) starts across all invokers.
    pub fn warm_starts(&self) -> u64 {
        self.invokers.iter().map(|i| i.containers.warm_starts).sum()
    }

    /// Containers currently kept warm for `runtime` across the cluster
    /// — what a newly admitted job can reuse without a cold start.
    pub fn warm_count(&self, runtime: &str) -> usize {
        self.invokers
            .iter()
            .map(|i| i.containers.warm_count(runtime))
            .sum()
    }

    pub fn slots_of(&self, node: NodeId) -> crate::sim::PoolId {
        self.invokers[node.0].slots
    }

    /// Current autoscaler warm target (0 until the first scale-up).
    pub fn warm_target(&self) -> usize {
        self.warm_target
    }

    /// One elastic warm-pool step against the observed arrival rate
    /// (jobs per second over the policy's trailing window). Desired
    /// stock is `rate × warm_per_rate`, clamped to `[min, max]`; the
    /// target only moves when desired clears the hysteresis band, so
    /// the pool neither flaps on noise nor drains on a momentary dip.
    /// Growing prewarms round-robin across invokers; shrinking drains
    /// idle stock (running containers are never reclaimed). All
    /// arithmetic is a pure function of the inputs — deterministic for
    /// a deterministic arrival schedule.
    pub fn autoscale(
        &mut self,
        runtime: &str,
        rate_per_s: f64,
        cfg: &AutoscaleConfig,
    ) {
        if !cfg.enabled || self.invokers.is_empty() {
            return;
        }
        let desired = ((rate_per_s * cfg.warm_per_rate).ceil() as usize)
            .clamp(cfg.min_warm, cfg.max_warm);
        let target = self.warm_target as f64;
        if (desired as f64) > target * cfg.up_threshold
            && desired > self.warm_target
        {
            self.warm_target = desired;
            self.scale_ups += 1;
        } else if (desired as f64) < target * cfg.down_threshold {
            self.warm_target = desired;
            self.scale_downs += 1;
        } else {
            return;
        }
        // Converge the idle stock toward the new target.
        let cur = self.warm_count(runtime);
        let n = self.invokers.len();
        if self.warm_target > cur {
            for k in 0..self.warm_target - cur {
                self.invokers[k % n].containers.prewarm(runtime, 1);
            }
        } else {
            let mut need = cur - self.warm_target;
            for inv in &mut self.invokers {
                if need == 0 {
                    break;
                }
                need -= inv.drain(runtime, need);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(nodes: usize) -> (Engine, Controller) {
        let mut e = Engine::new();
        let c = Controller::new(
            &mut e,
            &vec![4; nodes],
            ContainerConfig::default(),
        );
        (e, c)
    }

    #[test]
    fn locality_preferred() {
        let (_, mut c) = setup(4);
        assert_eq!(c.place(&[NodeId(2)]), NodeId(2));
        assert_eq!(c.place(&[NodeId(9), NodeId(1)]), NodeId(1));
    }

    #[test]
    fn round_robin_without_hints() {
        let (_, mut c) = setup(3);
        let seq: Vec<usize> = (0..6).map(|_| c.place(&[]).0).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn prewarm_avoids_cold_starts() {
        let (_, mut c) = setup(2);
        c.prewarm("marvel-hadoop:latest", 8);
        let spec = ActionSpec::map("wc", 1024);
        let inv = c.invoke(&spec, NodeId(0));
        assert!(!inv.cold);
        assert_eq!(c.cold_starts(), 0);
    }

    #[test]
    fn cold_start_recorded_then_warm_after_complete() {
        let (_, mut c) = setup(1);
        let spec = ActionSpec::map("wc", 1024);
        let first = c.invoke(&spec, NodeId(0));
        assert!(first.cold);
        c.complete(&spec, NodeId(0));
        let second = c.invoke(&spec, NodeId(0));
        assert!(!second.cold);
        assert_eq!(c.cold_starts(), 1);
    }

    #[test]
    fn warm_accounting_spans_invokers() {
        let (_, mut c) = setup(2);
        let spec = ActionSpec::map("wc", 1024);
        // Cold on both nodes, then complete → both warm.
        c.invoke(&spec, NodeId(0));
        c.invoke(&spec, NodeId(1));
        c.complete(&spec, NodeId(0));
        c.complete(&spec, NodeId(1));
        assert_eq!(c.cold_starts(), 2);
        assert_eq!(c.warm_starts(), 0);
        assert_eq!(c.warm_count(&spec.runtime), 2);
        // A second "job" reuses the pool: zero new cold starts.
        c.invoke(&spec, NodeId(0));
        c.invoke(&spec, NodeId(1));
        assert_eq!(c.cold_starts(), 2);
        assert_eq!(c.warm_starts(), 2);
    }

    #[test]
    fn crashed_container_drains_the_warm_pool() {
        let (_, mut c) = setup(1);
        let spec = ActionSpec::map("wc", 1024);
        c.prewarm(&spec.runtime, 1);
        assert!(!c.invoke(&spec, NodeId(0)).cold);
        c.crash(&spec, NodeId(0));
        assert_eq!(c.crashes(), 1);
        assert_eq!(c.warm_count(&spec.runtime), 0);
        // The retry pays a cold start: the crashed container's warm
        // state went with it.
        assert!(c.invoke(&spec, NodeId(0)).cold);
    }

    #[test]
    fn autoscale_grows_and_shrinks_with_hysteresis() {
        let (_, mut c) = setup(4);
        let cfg = AutoscaleConfig {
            enabled: true,
            warm_per_rate: 4.0,
            up_threshold: 1.25,
            down_threshold: 0.5,
            min_warm: 0,
            max_warm: 64,
            ..Default::default()
        };
        let rt = "marvel-hadoop:latest";
        // First observed load: target 0 → any demand scales up.
        c.autoscale(rt, 2.0, &cfg); // desired 8
        assert_eq!(c.warm_target(), 8);
        assert_eq!(c.scale_ups, 1);
        assert_eq!(c.warm_count(rt), 8);
        // Within the hysteresis band: desired 9 < 8 * 1.25 → no move.
        c.autoscale(rt, 2.2, &cfg);
        assert_eq!(c.warm_target(), 8);
        assert_eq!(c.scale_ups, 1);
        // Past the band: desired 16 > 10 → grow.
        c.autoscale(rt, 4.0, &cfg);
        assert_eq!(c.warm_target(), 16);
        assert_eq!(c.warm_count(rt), 16);
        // Mild dip (desired 12 >= 16 * 0.5): hold, don't flap.
        c.autoscale(rt, 3.0, &cfg);
        assert_eq!(c.warm_target(), 16);
        assert_eq!(c.scale_downs, 0);
        // Deep dip: desired 4 < 8 → drain idle stock.
        c.autoscale(rt, 1.0, &cfg);
        assert_eq!(c.warm_target(), 4);
        assert_eq!(c.scale_downs, 1);
        assert_eq!(c.warm_count(rt), 4);
        // Disabled policy never touches the pool.
        let off = AutoscaleConfig::default();
        c.autoscale(rt, 100.0, &off);
        assert_eq!(c.warm_target(), 4);
    }

    #[test]
    fn autoscale_respects_bounds() {
        let (_, mut c) = setup(2);
        let cfg = AutoscaleConfig {
            enabled: true,
            warm_per_rate: 10.0,
            min_warm: 2,
            max_warm: 12,
            ..Default::default()
        };
        let rt = "marvel-hadoop:latest";
        c.autoscale(rt, 1000.0, &cfg);
        assert_eq!(c.warm_target(), 12, "capped at max_warm");
        // Zero rate clamps to the floor, not to zero.
        c.autoscale(rt, 0.0, &cfg);
        assert_eq!(c.warm_target(), 2);
        assert_eq!(c.warm_count(rt), 2);
    }

    #[test]
    fn dispatch_overhead_included() {
        let (_, mut c) = setup(1);
        c.prewarm("marvel-hadoop:latest", 1);
        let spec = ActionSpec::map("wc", 1024);
        let inv = c.invoke(&spec, NodeId(0));
        // 2 ms dispatch + 5 ms warm start.
        assert_eq!(inv.startup, SimNs::from_millis(7));
    }
}
