//! OpenWhisk controller / load balancer: routes action invocations to
//! invokers. Marvel's modification (paper §3.4.2): the controller is
//! topology-aware — it honors locality hints from the NameNode so map
//! actions land where their split's blocks live, and it deploys every
//! container on the shared overlay network.

use crate::net::NodeId;
use crate::sim::{Engine, SimNs};

use super::action::{ActionSpec, Invocation};
use super::container::ContainerConfig;
use super::invoker::Invoker;

/// The OpenWhisk controller/load-balancer: routes invocations to
/// per-node invokers; pools survive across jobs on a shared cluster.
pub struct Controller {
    pub invokers: Vec<Invoker>,
    /// Controller-side per-invocation overhead (auth, routing, queueing).
    pub dispatch_overhead: SimNs,
    rr: usize,
}

impl Controller {
    pub fn new(
        engine: &mut Engine,
        slots_per_node: &[usize],
        cfg: ContainerConfig,
    ) -> Controller {
        let invokers = slots_per_node
            .iter()
            .enumerate()
            .map(|(i, s)| Invoker::new(engine, NodeId(i), *s, cfg.clone()))
            .collect();
        Controller {
            invokers,
            dispatch_overhead: SimNs::from_millis(2),
            rr: 0,
        }
    }

    pub fn n_invokers(&self) -> usize {
        self.invokers.len()
    }

    /// Choose an invoker: first preference that has an invoker, else
    /// round-robin (OpenWhisk's hash-based balancing degenerates to RR
    /// under uniform load).
    pub fn place(&mut self, locality: &[NodeId]) -> NodeId {
        for pref in locality {
            if pref.0 < self.invokers.len() {
                return *pref;
            }
        }
        let n = NodeId(self.rr % self.invokers.len());
        self.rr += 1;
        n
    }

    /// Plan an invocation on a chosen node: returns the invocation
    /// record; the caller builds stages with
    /// [Acquire(slots), Delay(dispatch+startup), <body>, Release].
    pub fn invoke(&mut self, spec: &ActionSpec, node: NodeId) -> Invocation {
        let inv = &mut self.invokers[node.0];
        let (startup, cold) = inv.startup(&spec.runtime);
        Invocation {
            action: spec.name.clone(),
            node,
            cold,
            startup: self.dispatch_overhead + startup,
        }
    }

    /// Return the container after the action body completes.
    pub fn complete(&mut self, spec: &ActionSpec, node: NodeId) {
        self.invokers[node.0].finish(&spec.runtime);
    }

    /// The container running an action crashed (injected fault): it is
    /// destroyed instead of returning to the warm pool, so the node
    /// permanently loses that warm slot and a retry may go cold.
    pub fn crash(&mut self, spec: &ActionSpec, node: NodeId) {
        self.invokers[node.0].containers.crash(&spec.runtime);
    }

    /// Containers that died mid-action across all invokers.
    pub fn crashes(&self) -> u64 {
        self.invokers.iter().map(|i| i.containers.crashes).sum()
    }

    /// Pre-warm the Hadoop runtime across all invokers (deployment step
    /// of the Marvel stack).
    pub fn prewarm(&mut self, runtime: &str, per_node: usize) {
        for inv in &mut self.invokers {
            inv.containers.prewarm(runtime, per_node);
        }
    }

    pub fn cold_starts(&self) -> u64 {
        self.invokers.iter().map(|i| i.containers.cold_starts).sum()
    }

    /// Warm (pool-reuse) starts across all invokers.
    pub fn warm_starts(&self) -> u64 {
        self.invokers.iter().map(|i| i.containers.warm_starts).sum()
    }

    /// Containers currently kept warm for `runtime` across the cluster
    /// — what a newly admitted job can reuse without a cold start.
    pub fn warm_count(&self, runtime: &str) -> usize {
        self.invokers
            .iter()
            .map(|i| i.containers.warm_count(runtime))
            .sum()
    }

    pub fn slots_of(&self, node: NodeId) -> crate::sim::PoolId {
        self.invokers[node.0].slots
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(nodes: usize) -> (Engine, Controller) {
        let mut e = Engine::new();
        let c = Controller::new(
            &mut e,
            &vec![4; nodes],
            ContainerConfig::default(),
        );
        (e, c)
    }

    #[test]
    fn locality_preferred() {
        let (_, mut c) = setup(4);
        assert_eq!(c.place(&[NodeId(2)]), NodeId(2));
        assert_eq!(c.place(&[NodeId(9), NodeId(1)]), NodeId(1));
    }

    #[test]
    fn round_robin_without_hints() {
        let (_, mut c) = setup(3);
        let seq: Vec<usize> = (0..6).map(|_| c.place(&[]).0).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn prewarm_avoids_cold_starts() {
        let (_, mut c) = setup(2);
        c.prewarm("marvel-hadoop:latest", 8);
        let spec = ActionSpec::map("wc", 1024);
        let inv = c.invoke(&spec, NodeId(0));
        assert!(!inv.cold);
        assert_eq!(c.cold_starts(), 0);
    }

    #[test]
    fn cold_start_recorded_then_warm_after_complete() {
        let (_, mut c) = setup(1);
        let spec = ActionSpec::map("wc", 1024);
        let first = c.invoke(&spec, NodeId(0));
        assert!(first.cold);
        c.complete(&spec, NodeId(0));
        let second = c.invoke(&spec, NodeId(0));
        assert!(!second.cold);
        assert_eq!(c.cold_starts(), 1);
    }

    #[test]
    fn warm_accounting_spans_invokers() {
        let (_, mut c) = setup(2);
        let spec = ActionSpec::map("wc", 1024);
        // Cold on both nodes, then complete → both warm.
        c.invoke(&spec, NodeId(0));
        c.invoke(&spec, NodeId(1));
        c.complete(&spec, NodeId(0));
        c.complete(&spec, NodeId(1));
        assert_eq!(c.cold_starts(), 2);
        assert_eq!(c.warm_starts(), 0);
        assert_eq!(c.warm_count(&spec.runtime), 2);
        // A second "job" reuses the pool: zero new cold starts.
        c.invoke(&spec, NodeId(0));
        c.invoke(&spec, NodeId(1));
        assert_eq!(c.cold_starts(), 2);
        assert_eq!(c.warm_starts(), 2);
    }

    #[test]
    fn crashed_container_drains_the_warm_pool() {
        let (_, mut c) = setup(1);
        let spec = ActionSpec::map("wc", 1024);
        c.prewarm(&spec.runtime, 1);
        assert!(!c.invoke(&spec, NodeId(0)).cold);
        c.crash(&spec, NodeId(0));
        assert_eq!(c.crashes(), 1);
        assert_eq!(c.warm_count(&spec.runtime), 0);
        // The retry pays a cold start: the crashed container's warm
        // state went with it.
        assert!(c.invoke(&spec, NodeId(0)).cold);
    }

    #[test]
    fn dispatch_overhead_included() {
        let (_, mut c) = setup(1);
        c.prewarm("marvel-hadoop:latest", 1);
        let spec = ActionSpec::map("wc", 1024);
        let inv = c.invoke(&spec, NodeId(0));
        // 2 ms dispatch + 5 ms warm start.
        assert_eq!(inv.startup, SimNs::from_millis(7));
    }
}
