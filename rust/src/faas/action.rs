//! Action definitions and invocation records.

use crate::net::NodeId;
use crate::sim::SimNs;

/// The Hadoop-enabled runtime image Marvel ships (paper §3.4.2). One
/// shared image across all jobs and tenants is what makes warm
/// containers reusable cluster-wide: a container warmed by one job
/// serves the next job's actions without a cold start.
pub const HADOOP_RUNTIME: &str = "marvel-hadoop:latest";

/// What kind of function an invocation runs (drives runtime image
/// selection and the Hadoop-runtime container reuse policy).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ActionKind {
    Map,
    Reduce,
    Driver,
}

/// A registered action (OpenWhisk `wsk action create` analog).
#[derive(Clone, Debug)]
pub struct ActionSpec {
    pub name: String,
    /// Runtime image — Marvel ships a Hadoop-enabled Docker runtime so
    /// actions can talk to HDFS/IGFS (paper §3.4.2).
    pub runtime: String,
    pub memory_mb: u64,
    pub kind: ActionKind,
}

impl ActionSpec {
    pub fn map(job: &str, memory_mb: u64) -> ActionSpec {
        ActionSpec {
            name: format!("{job}/map"),
            runtime: HADOOP_RUNTIME.into(),
            memory_mb,
            kind: ActionKind::Map,
        }
    }

    pub fn reduce(job: &str, memory_mb: u64) -> ActionSpec {
        ActionSpec {
            name: format!("{job}/reduce"),
            runtime: HADOOP_RUNTIME.into(),
            memory_mb,
            kind: ActionKind::Reduce,
        }
    }
}

/// One scheduled invocation (plan-time record; the DES charges its
/// startup latency and slot occupancy).
#[derive(Clone, Debug)]
pub struct Invocation {
    pub action: String,
    pub node: NodeId,
    pub cold: bool,
    pub startup: SimNs,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_carry_runtime() {
        let m = ActionSpec::map("wc", 2048);
        assert_eq!(m.kind, ActionKind::Map);
        assert!(m.runtime.contains("hadoop"));
        let r = ActionSpec::reduce("wc", 2048);
        assert_eq!(r.kind, ActionKind::Reduce);
        assert_ne!(m.name, r.name);
    }
}
