//! Per-node invoker: owns the node's container pool and its DES slot
//! pool (concurrent action capacity = node vCPU slots).

use crate::net::NodeId;
use crate::sim::{Engine, PoolId, SimNs};

use super::container::{ContainerConfig, ContainerPool};

/// Per-node invoker: the node's container pool + DES slot pool.
pub struct Invoker {
    pub node: NodeId,
    pub slots: PoolId,
    pub containers: ContainerPool,
}

impl Invoker {
    pub fn new(
        engine: &mut Engine,
        node: NodeId,
        slots: usize,
        cfg: ContainerConfig,
    ) -> Invoker {
        Invoker {
            node,
            slots: engine.add_pool(slots),
            containers: ContainerPool::new(cfg),
        }
    }

    /// Plan an invocation start: container acquisition latency (cold or
    /// warm). Slot occupancy is expressed by Acquire/Release stages the
    /// caller wraps around the action body.
    pub fn startup(&mut self, runtime: &str) -> (SimNs, bool) {
        self.containers.acquire(runtime)
    }

    pub fn finish(&mut self, runtime: &str) {
        self.containers.release(runtime);
    }

    /// Idle warm stock this node holds for `runtime`.
    pub fn warm_count(&self, runtime: &str) -> usize {
        self.containers.warm_count(runtime)
    }

    /// Evict up to `n` idle warm containers (autoscaler scale-down);
    /// returns how many actually went.
    pub fn drain(&mut self, runtime: &str, n: usize) -> usize {
        self.containers.drain(runtime, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{ProcState, Stage};

    #[test]
    fn slot_pool_limits_concurrency() {
        let mut e = Engine::new();
        let mut inv = Invoker::new(
            &mut e,
            NodeId(0),
            2,
            ContainerConfig::default(),
        );
        inv.containers.prewarm("img", 10);
        // 4 actions of (5 ms warm start + 10 ms body) on 2 slots
        // → two waves of 15 ms = 30 ms.
        for i in 0..4 {
            let (lat, _) = inv.startup("img");
            e.spawn(&format!("a{i}"), vec![
                Stage::Acquire(inv.slots),
                Stage::Delay(lat),
                Stage::Delay(SimNs::from_millis(10)),
                Stage::Release(inv.slots),
            ]);
        }
        let end = e.run().unwrap();
        assert_eq!(end, SimNs::from_millis(30));
        assert_eq!(e.failures().len(), 0);
        assert!(matches!(e.state(crate::sim::ProcId(0)), ProcState::Finished));
    }
}
