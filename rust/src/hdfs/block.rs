//! HDFS block primitives.

use crate::util::bytes::MIB;

/// Default HDFS block size (Hadoop 3.x default).
pub const DEFAULT_BLOCK_SIZE: u64 = 128 * MIB;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
/// Globally unique block identifier.
pub struct BlockId(pub u64);

/// Metadata for one block of a file.
#[derive(Clone, Debug)]
pub struct BlockMeta {
    pub id: BlockId,
    /// Offset of this block within its file.
    pub offset: u64,
    pub len: u64,
}

/// Split a file length into block-sized extents.
pub fn split_into_blocks(len: u64, block_size: u64) -> Vec<(u64, u64)> {
    assert!(block_size > 0);
    if len == 0 {
        return vec![(0, 0)];
    }
    let mut out = Vec::with_capacity((len / block_size + 1) as usize);
    let mut off = 0;
    while off < len {
        let l = block_size.min(len - off);
        out.push((off, l));
        off += l;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_multiple() {
        assert_eq!(split_into_blocks(256, 128),
                   vec![(0, 128), (128, 128)]);
    }

    #[test]
    fn remainder_block() {
        assert_eq!(split_into_blocks(300, 128),
                   vec![(0, 128), (128, 128), (256, 44)]);
    }

    #[test]
    fn small_file_single_block() {
        assert_eq!(split_into_blocks(5, 128), vec![(0, 5)]);
    }

    #[test]
    fn empty_file_one_empty_block() {
        assert_eq!(split_into_blocks(0, 128), vec![(0, 0)]);
    }

    #[test]
    fn lengths_sum_to_file() {
        for len in [1u64, 127, 128, 129, 1000, 12345] {
            let total: u64 = split_into_blocks(len, 128)
                .iter()
                .map(|(_, l)| l)
                .sum();
            assert_eq!(total, len);
        }
    }
}
