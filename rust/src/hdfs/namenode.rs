//! NameNode: the HDFS namespace, block map, and replica placement.
//!
//! Placement mirrors Hadoop's default policy: first replica on the
//! writer's node (data/compute co-location — the property Marvel's
//! Figure 4 improvement rests on), subsequent replicas round-robin over
//! the remaining nodes, skipping nodes whose target device is full.

use std::collections::{BTreeMap, HashMap};

use crate::net::NodeId;

use super::block::{BlockId, BlockMeta};

#[derive(Clone, Debug)]
/// Namespace entry: a file's block list and total length.
pub struct INode {
    pub path: String,
    pub len: u64,
    pub blocks: Vec<BlockMeta>,
}

#[derive(Clone, Debug)]
/// The HDFS namespace + block map + replica placement authority.
pub struct NameNode {
    namespace: BTreeMap<String, INode>,
    /// block → replica holders (order = pipeline order, [0] is primary).
    block_map: HashMap<BlockId, Vec<NodeId>>,
    next_block: u64,
    rr_cursor: usize,
    pub replication: usize,
}

impl NameNode {
    pub fn new(replication: usize) -> NameNode {
        NameNode {
            namespace: BTreeMap::new(),
            block_map: HashMap::new(),
            next_block: 0,
            rr_cursor: 0,
            replication: replication.max(1),
        }
    }

    pub fn exists(&self, path: &str) -> bool {
        self.namespace.contains_key(path)
    }

    pub fn stat(&self, path: &str) -> Option<&INode> {
        self.namespace.get(path)
    }

    pub fn list(&self, prefix: &str) -> Vec<&INode> {
        self.namespace
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(_, v)| v)
            .collect()
    }

    pub fn delete(&mut self, path: &str) -> Option<INode> {
        let inode = self.namespace.remove(path)?;
        for b in &inode.blocks {
            self.block_map.remove(&b.id);
        }
        Some(inode)
    }

    /// Replica holders of a block, pipeline order.
    pub fn locations(&self, block: BlockId) -> &[NodeId] {
        self.block_map
            .get(&block)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Allocate a block for `path` being written from `writer`, choosing
    /// replicas among `eligible` nodes (those hosting live DataNodes
    /// with free space).
    pub fn allocate_block(
        &mut self,
        writer: NodeId,
        eligible: &[NodeId],
        offset: u64,
        len: u64,
    ) -> Result<(BlockMeta, Vec<NodeId>), String> {
        if eligible.is_empty() {
            return Err("no eligible datanodes".into());
        }
        let id = BlockId(self.next_block);
        self.next_block += 1;
        let mut replicas = Vec::with_capacity(self.replication);
        // First replica local if the writer hosts a datanode.
        if eligible.contains(&writer) {
            replicas.push(writer);
        }
        // Fill remaining round-robin, skipping already-chosen nodes.
        let mut scanned = 0;
        while replicas.len() < self.replication.min(eligible.len())
            && scanned < eligible.len()
        {
            let cand = eligible[self.rr_cursor % eligible.len()];
            self.rr_cursor = (self.rr_cursor + 1) % eligible.len().max(1);
            scanned += 1;
            if !replicas.contains(&cand) {
                replicas.push(cand);
                scanned = 0;
            }
        }
        let meta = BlockMeta { id, offset, len };
        self.block_map.insert(id, replicas.clone());
        Ok((meta, replicas))
    }

    /// Commit a fully-written file into the namespace.
    pub fn commit_file(&mut self, path: &str, blocks: Vec<BlockMeta>) {
        let len = blocks.iter().map(|b| b.len).sum();
        self.namespace.insert(
            path.to_string(),
            INode { path: path.to_string(), len, blocks },
        );
    }

    /// Total bytes across the namespace.
    pub fn total_bytes(&self) -> u64 {
        self.namespace.values().map(|i| i.len).sum()
    }

    pub fn file_count(&self) -> usize {
        self.namespace.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize) -> Vec<NodeId> {
        (0..n).map(NodeId).collect()
    }

    #[test]
    fn first_replica_is_local() {
        let mut nn = NameNode::new(3);
        let (_, reps) = nn
            .allocate_block(NodeId(2), &nodes(4), 0, 100)
            .unwrap();
        assert_eq!(reps[0], NodeId(2));
        assert_eq!(reps.len(), 3);
    }

    #[test]
    fn replicas_distinct() {
        let mut nn = NameNode::new(3);
        for i in 0..20 {
            let (_, reps) = nn
                .allocate_block(NodeId(i % 4), &nodes(4), 0, 1)
                .unwrap();
            let mut d = reps.clone();
            d.sort();
            d.dedup();
            assert_eq!(d.len(), reps.len(), "dup replicas {reps:?}");
        }
    }

    #[test]
    fn replication_capped_by_cluster_size() {
        let mut nn = NameNode::new(3);
        let (_, reps) = nn.allocate_block(NodeId(0), &nodes(2), 0, 1).unwrap();
        assert_eq!(reps.len(), 2);
    }

    #[test]
    fn commit_and_stat() {
        let mut nn = NameNode::new(1);
        let (m1, _) = nn.allocate_block(NodeId(0), &nodes(1), 0, 128).unwrap();
        let (m2, _) = nn.allocate_block(NodeId(0), &nodes(1), 128, 72).unwrap();
        nn.commit_file("/data/in.txt", vec![m1, m2]);
        let inode = nn.stat("/data/in.txt").unwrap();
        assert_eq!(inode.len, 200);
        assert_eq!(inode.blocks.len(), 2);
        assert_eq!(nn.total_bytes(), 200);
    }

    #[test]
    fn list_by_prefix() {
        let mut nn = NameNode::new(1);
        for p in ["/a/1", "/a/2", "/b/1"] {
            let (m, _) = nn.allocate_block(NodeId(0), &nodes(1), 0, 1).unwrap();
            nn.commit_file(p, vec![m]);
        }
        assert_eq!(nn.list("/a/").len(), 2);
        assert_eq!(nn.list("/").len(), 3);
    }

    #[test]
    fn delete_clears_block_map() {
        let mut nn = NameNode::new(1);
        let (m, _) = nn.allocate_block(NodeId(0), &nodes(1), 0, 9).unwrap();
        let id = m.id;
        nn.commit_file("/x", vec![m]);
        nn.delete("/x");
        assert!(nn.locations(id).is_empty());
        assert!(!nn.exists("/x"));
    }

    #[test]
    fn no_eligible_nodes_errors() {
        let mut nn = NameNode::new(3);
        assert!(nn.allocate_block(NodeId(0), &[], 0, 1).is_err());
    }
}
