//! DFS client: file write (with streamed replication pipeline) and
//! locality-aware read. Every operation returns both the data-plane
//! result and the `Stage` list that charges its cost to the DES — the
//! MapReduce driver splices those stages into task procs.
//!
//! Streamed pipeline modeling: Hadoop chains DN1→DN2→DN3 and streams,
//! so a block write proceeds at the rate of the slowest pipeline
//! element. A single flow whose path contains *all* replica devices and
//! the connecting NICs reproduces exactly that (fluid min over the
//! path), instead of serializing replica copies.

use std::collections::HashMap;

use crate::net::{DeviceRole, NodeId, Topology};
use crate::sim::Stage;
use crate::storage::{Access, Dir, Payload};

use super::block::{split_into_blocks, BlockId, BlockMeta, DEFAULT_BLOCK_SIZE};
use super::datanode::DataNode;
use super::namenode::NameNode;

/// The whole HDFS deployment: one NameNode + one DataNode per node.
pub struct Hdfs {
    pub namenode: NameNode,
    pub datanodes: HashMap<NodeId, DataNode>,
    pub block_size: u64,
    /// Which device role DataNodes sit on (Pmem for Marvel, Ssd/Hdd
    /// for ablations — the paper's Figure 1 storage-backend sweep).
    pub role: DeviceRole,
}

impl Hdfs {
    pub fn new(topo: &Topology, role: DeviceRole, replication: usize) -> Hdfs {
        let mut datanodes = HashMap::new();
        for (i, _) in topo.nodes.iter().enumerate() {
            let node = NodeId(i);
            let dev = topo
                .device_of(node, role)
                .unwrap_or_else(|| panic!("node {i} lacks {role:?}"));
            datanodes.insert(node, DataNode::new(node, dev));
        }
        Hdfs {
            namenode: NameNode::new(replication),
            datanodes,
            block_size: DEFAULT_BLOCK_SIZE,
            role,
        }
    }

    fn eligible(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self
            .datanodes
            .iter()
            .filter(|(_, dn)| !dn.dead)
            .map(|(n, _)| *n)
            .collect();
        v.sort();
        v
    }

    /// Kill one DataNode (failure injection): its block replicas are
    /// lost and it stops serving reads or taking writes. Reads of its
    /// blocks fall back to surviving replicas; a block whose only
    /// replica lived there is data loss and surfaces as a read error.
    /// Idempotent. Returns how many block replicas were lost.
    pub fn fail_datanode(&mut self, node: NodeId) -> usize {
        self.datanodes.get_mut(&node).map_or(0, |dn| {
            if dn.dead {
                0
            } else {
                dn.fail()
            }
        })
    }

    /// Pick the replica of `block` to read from: the reader's own live
    /// copy if it has one, else the first live replica in NameNode
    /// order. `None` when every replica is dead — data loss.
    fn live_replica(
        &self,
        locs: &[NodeId],
        reader: NodeId,
        block: BlockId,
    ) -> Option<NodeId> {
        let alive = |n: &NodeId| {
            self.datanodes
                .get(n)
                .is_some_and(|dn| !dn.dead && dn.has(block))
        };
        if locs.contains(&reader) && alive(&reader) {
            return Some(reader);
        }
        locs.iter().find(|n| alive(n)).copied()
    }

    /// Write a file from memory on `writer`. Returns the stages charging
    /// the write (one streamed pipeline flow per block + access latency).
    pub fn put(
        &mut self,
        topo: &Topology,
        writer: NodeId,
        path: &str,
        data: Payload,
        tag: u32,
    ) -> Result<Vec<Stage>, String> {
        if self.namenode.exists(path) {
            return Err(format!("{path} already exists"));
        }
        let eligible = self.eligible();
        let mut stages = Vec::new();
        let mut metas: Vec<BlockMeta> = Vec::new();
        for (off, len) in split_into_blocks(data.len(), self.block_size) {
            let (meta, replicas) =
                self.namenode.allocate_block(writer, &eligible, off, len)?;
            // Data plane: store the block slice on every replica.
            let slice = data.slice(off, len);
            for r in &replicas {
                let dn = self.datanodes.get_mut(r).unwrap();
                dn.store(meta.id, slice.clone());
            }
            // Time plane: streamed pipeline flow through every replica
            // device + the inter-node links.
            let mut path_res = Vec::new();
            let mut lat = crate::sim::SimNs::ZERO;
            let mut prev = writer;
            for (i, r) in replicas.iter().enumerate() {
                if *r != prev {
                    path_res.extend(topo.lan_path(prev, *r));
                }
                let dev = topo.device(self.datanodes[r].dev);
                path_res.push(dev.channel(Dir::Write));
                if i == 0 {
                    lat = dev.latency(Access::Seq, Dir::Write);
                }
                prev = *r;
            }
            stages.push(Stage::Delay(lat));
            stages.push(Stage::Flow {
                bytes: len as f64,
                path: path_res,
                tag,
                timeout: None,
            });
            metas.push(meta);
        }
        self.namenode.commit_file(path, metas);
        Ok(stages)
    }

    /// Block locations for locality-aware task placement (YARN asks the
    /// NameNode exactly this).
    pub fn block_locations(&self, path: &str) -> Vec<(BlockMeta, Vec<NodeId>)> {
        match self.namenode.stat(path) {
            None => Vec::new(),
            Some(inode) => inode
                .blocks
                .iter()
                .map(|b| (b.clone(), self.namenode.locations(b.id).to_vec()))
                .collect(),
        }
    }

    /// Read a whole file on `reader`, preferring local replicas.
    /// Returns (data, stages, local_bytes, remote_bytes). The data is
    /// a zero-copy view assembly over the DataNodes' block buffers —
    /// chunked when the file spans blocks, never memcpy'd.
    pub fn read(
        &self,
        topo: &Topology,
        reader: NodeId,
        path: &str,
        tag: u32,
    ) -> Result<(Payload, Vec<Stage>, u64, u64), String> {
        let inode = self
            .namenode
            .stat(path)
            .ok_or_else(|| format!("{path} not found"))?;
        let mut parts = Vec::with_capacity(inode.blocks.len());
        let mut stages = Vec::new();
        let mut local = 0u64;
        let mut remote = 0u64;
        for b in &inode.blocks {
            let locs = self.namenode.locations(b.id);
            let src = self.live_replica(locs, reader, b.id).ok_or_else(
                || format!("block {:?} of {path} lost: no live replica", b.id),
            )?;
            let dn = &self.datanodes[&src];
            let data = dn.fetch(b.id).expect("live replica holds the block");
            parts.push(data.clone());
            let dev = topo.device(dn.dev);
            let mut path_res = vec![dev.channel(Dir::Read)];
            if src != reader {
                path_res.extend(topo.lan_path(src, reader));
                remote += b.len;
            } else {
                local += b.len;
            }
            stages.push(Stage::Delay(dev.latency(Access::Seq, Dir::Read)));
            stages.push(Stage::Flow {
                bytes: dev.effective_bytes(b.len, Access::Seq, Dir::Read),
                path: path_res,
                tag,
                timeout: None,
            });
        }
        Ok((Payload::concat(&parts), stages, local, remote))
    }

    /// Read one byte range (a map task's input split). Zero-copy: each
    /// intersecting block contributes an O(1) sub-view, and the parts
    /// assemble into a (possibly chunked) view — a split that falls
    /// inside one block (the planner's common case) comes back as a
    /// single contiguous borrow of the DataNode's buffer.
    pub fn read_range(
        &self,
        topo: &Topology,
        reader: NodeId,
        path: &str,
        offset: u64,
        len: u64,
        tag: u32,
    ) -> Result<(Payload, Vec<Stage>, bool), String> {
        let inode = self
            .namenode
            .stat(path)
            .ok_or_else(|| format!("{path} not found"))?;
        let mut parts = Vec::new();
        let mut stages = Vec::new();
        let mut all_local = true;
        for b in &inode.blocks {
            let b_end = b.offset + b.len;
            let s = offset.max(b.offset);
            let e = (offset + len).min(b_end);
            if s >= e {
                continue;
            }
            let locs = self.namenode.locations(b.id);
            let src = self.live_replica(locs, reader, b.id).ok_or_else(
                || format!("block {:?} of {path} lost: no live replica", b.id),
            )?;
            if src != reader {
                all_local = false;
            }
            let dn = &self.datanodes[&src];
            let data = dn.fetch(b.id).expect("live replica holds the block");
            parts.push(data.slice(s - b.offset, e - s));
            let dev = topo.device(dn.dev);
            let mut path_res = vec![dev.channel(Dir::Read)];
            if src != reader {
                path_res.extend(topo.lan_path(src, reader));
            }
            stages.push(Stage::Delay(dev.latency(Access::Seq, Dir::Read)));
            stages.push(Stage::Flow {
                bytes: dev.effective_bytes(e - s, Access::Seq, Dir::Read),
                path: path_res,
                tag,
                timeout: None,
            });
        }
        Ok((Payload::concat(&parts), stages, all_local))
    }

    pub fn delete(&mut self, path: &str) -> bool {
        if let Some(inode) = self.namenode.delete(path) {
            for b in &inode.blocks {
                for dn in self.datanodes.values_mut() {
                    dn.drop_block(b.id);
                }
            }
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::TopologyBuilder;
    use crate::sim::Engine;

    fn setup(nodes: usize, replication: usize) -> (Engine, Topology, Hdfs) {
        let mut e = Engine::new();
        let t = TopologyBuilder { nodes, ..Default::default() }.build(&mut e);
        let h = Hdfs::new(&t, DeviceRole::Pmem, replication);
        (e, t, h)
    }

    #[test]
    fn write_read_roundtrip() {
        let (mut e, t, mut h) = setup(3, 2);
        let data: Vec<u8> = (0..1000u32).map(|i| i as u8).collect();
        let st = h
            .put(&t, NodeId(0), "/f", Payload::real(data.clone()), 0)
            .unwrap();
        e.spawn("w", st);
        let (got, st, local, remote) =
            h.read(&t, NodeId(0), "/f", 0).unwrap();
        e.spawn("r", st);
        e.run().unwrap();
        assert_eq!(got.bytes().unwrap(), &data[..]);
        assert_eq!(local, 1000); // writer-local replica read back locally
        assert_eq!(remote, 0);
    }

    #[test]
    fn multi_block_files_split() {
        let (_, t, mut h) = setup(2, 1);
        h.block_size = 100;
        h.put(&t, NodeId(0), "/big", Payload::synthetic(350), 0)
            .unwrap();
        let locs = h.block_locations("/big");
        assert_eq!(locs.len(), 4);
        assert_eq!(locs[3].0.len, 50);
    }

    #[test]
    fn remote_read_when_no_local_replica() {
        let (_, t, mut h) = setup(3, 1);
        h.put(&t, NodeId(0), "/f", Payload::synthetic(10), 0).unwrap();
        let (_, _, local, remote) = h.read(&t, NodeId(2), "/f", 0).unwrap();
        assert_eq!(local, 0);
        assert_eq!(remote, 10);
    }

    #[test]
    fn read_range_extracts_split() {
        let (_, t, mut h) = setup(1, 1);
        h.block_size = 10;
        let data: Vec<u8> = (0..30u8).collect();
        h.put(&t, NodeId(0), "/f", Payload::real(data), 0).unwrap();
        let (got, _, local) =
            h.read_range(&t, NodeId(0), "/f", 5, 10, 0).unwrap();
        // The range spans two blocks: a zero-copy chunked view.
        assert_eq!(got.n_chunks(), 2);
        assert_eq!(got.gather().unwrap(), (5..15u8).collect::<Vec<_>>());
        assert!(local);
    }

    #[test]
    fn in_block_range_is_contiguous_borrow() {
        let (_, t, mut h) = setup(1, 1);
        h.block_size = 100;
        let data: Vec<u8> = (0..200u8).collect();
        h.put(&t, NodeId(0), "/f", Payload::real(data), 0).unwrap();
        let (got, _, _) =
            h.read_range(&t, NodeId(0), "/f", 110, 20, 0).unwrap();
        // Falls inside block 1: contiguous, no gather needed.
        assert_eq!(got.bytes().unwrap(),
                   &(110..130).map(|i| i as u8).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn duplicate_put_rejected() {
        let (_, t, mut h) = setup(1, 1);
        h.put(&t, NodeId(0), "/f", Payload::synthetic(1), 0).unwrap();
        assert!(h.put(&t, NodeId(0), "/f", Payload::synthetic(1), 0).is_err());
    }

    #[test]
    fn delete_frees_datanodes() {
        let (_, t, mut h) = setup(2, 2);
        h.put(&t, NodeId(0), "/f", Payload::synthetic(100), 0).unwrap();
        assert!(h.delete("/f"));
        for dn in h.datanodes.values() {
            assert_eq!(dn.block_count(), 0);
        }
        assert!(!h.delete("/f"));
    }

    #[test]
    fn datanode_loss_falls_back_to_surviving_replica() {
        let (_, t, mut h) = setup(3, 2);
        let data: Vec<u8> = (0..500u32).map(|i| (i % 251) as u8).collect();
        h.put(&t, NodeId(0), "/f", Payload::real(data.clone()), 0)
            .unwrap();
        let lost = h.fail_datanode(NodeId(0));
        assert!(lost > 0, "writer-local replicas lived on node 0");
        assert_eq!(h.fail_datanode(NodeId(0)), 0, "idempotent");
        // Reads survive through the second replica, byte-identical.
        let (got, _, local, remote) = h.read(&t, NodeId(0), "/f", 0).unwrap();
        assert_eq!(got.gather().unwrap(), data);
        assert_eq!(local, 0, "local replica is gone");
        assert_eq!(remote, 500);
        let (got, _, all_local) =
            h.read_range(&t, NodeId(0), "/f", 100, 50, 0).unwrap();
        assert_eq!(got.gather().unwrap(), &data[100..150]);
        assert!(!all_local);
        // New writes avoid the dead node.
        let st = h.put(&t, NodeId(1), "/g", Payload::synthetic(64), 0);
        assert!(st.is_ok());
        assert_eq!(h.datanodes[&NodeId(0)].block_count(), 0);
    }

    #[test]
    fn sole_replica_loss_is_a_read_error() {
        let (_, t, mut h) = setup(2, 1);
        h.put(&t, NodeId(0), "/f", Payload::synthetic(10), 0).unwrap();
        h.fail_datanode(NodeId(0));
        let err = h.read(&t, NodeId(1), "/f", 0).unwrap_err();
        assert!(err.contains("no live replica"), "{err}");
        assert!(h
            .read_range(&t, NodeId(1), "/f", 0, 10, 0)
            .is_err());
    }

    #[test]
    fn replication_pipeline_slower_than_single() {
        let time = |replication| {
            let (mut e, t, mut h) = setup(3, replication);
            let st = h
                .put(&t, NodeId(0), "/f", Payload::synthetic(1_250_000_000), 0)
                .unwrap();
            e.spawn("w", st);
            e.run().unwrap().as_secs_f64()
        };
        let single = time(1);
        let triple = time(3);
        // Pipeline rate bound by 10 Gb/s NIC vs PMEM write 13.6 GiB/s.
        assert!(triple > 5.0 * single, "single={single} triple={triple}");
    }
}
