//! DataNode: block storage on one node's device (PMEM in Marvel's
//! deployment, SSD/HDD in the ablations). Holds the data plane; the
//! time plane is charged by `client` through the device channels.

use std::collections::HashMap;

use crate::net::{DevId, NodeId};
use crate::storage::Payload;

use super::block::BlockId;

#[derive(Clone, Debug)]
/// One node's block storage on its backing device.
pub struct DataNode {
    pub node: NodeId,
    pub dev: DevId,
    /// Killed by failure injection: serves no reads, takes no writes,
    /// and its replicas are gone (clients fall back to survivors).
    pub dead: bool,
    blocks: HashMap<BlockId, Payload>,
}

impl DataNode {
    pub fn new(node: NodeId, dev: DevId) -> DataNode {
        DataNode { node, dev, dead: false, blocks: HashMap::new() }
    }

    /// Kill this DataNode: every block replica it held is lost.
    /// Returns how many blocks went with it.
    pub fn fail(&mut self) -> usize {
        self.dead = true;
        let n = self.blocks.len();
        self.blocks.clear();
        n
    }

    pub fn store(&mut self, id: BlockId, data: Payload) {
        self.blocks.insert(id, data);
    }

    pub fn fetch(&self, id: BlockId) -> Option<&Payload> {
        self.blocks.get(&id)
    }

    pub fn drop_block(&mut self, id: BlockId) -> Option<Payload> {
        self.blocks.remove(&id)
    }

    pub fn has(&self, id: BlockId) -> bool {
        self.blocks.contains_key(&id)
    }

    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }

    pub fn used_bytes(&self) -> u64 {
        self.blocks.values().map(|p| p.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_fetch_drop() {
        let mut dn = DataNode::new(NodeId(0), DevId(0));
        dn.store(BlockId(1), Payload::real(vec![1, 2, 3]));
        dn.store(BlockId(2), Payload::synthetic(100));
        assert!(dn.has(BlockId(1)));
        assert_eq!(dn.fetch(BlockId(1)).unwrap().len(), 3);
        assert_eq!(dn.used_bytes(), 103);
        assert_eq!(dn.block_count(), 2);
        assert!(dn.drop_block(BlockId(1)).is_some());
        assert!(!dn.has(BlockId(1)));
        assert!(dn.fetch(BlockId(1)).is_none());
    }

    #[test]
    fn failed_datanode_loses_everything() {
        let mut dn = DataNode::new(NodeId(0), DevId(0));
        dn.store(BlockId(1), Payload::synthetic(10));
        dn.store(BlockId(2), Payload::synthetic(20));
        assert_eq!(dn.fail(), 2);
        assert!(dn.dead);
        assert_eq!(dn.block_count(), 0);
        assert!(!dn.has(BlockId(1)));
    }
}
