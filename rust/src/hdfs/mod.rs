//! HDFS analog: NameNode (namespace + block map + placement), DataNodes
//! (blocks on the node's PMEM/SSD device), and a locality-aware client.
//! Data/compute co-location — the core of the paper's I/O argument —
//! emerges from placement + local reads here.
//!
//! See `ARCHITECTURE.md` (Layer 1).

pub mod block;
pub mod client;
pub mod datanode;
pub mod namenode;

pub use block::{BlockId, BlockMeta, DEFAULT_BLOCK_SIZE};
pub use client::Hdfs;
pub use datanode::DataNode;
pub use namenode::NameNode;
