//! Storage media models, calibrated to the paper's **own Table 2**
//! (FIO, 4 KiB blocks, 8 streams: IOPS / bandwidth / latency for PMEM in
//! AppDirect mode vs. enterprise SSD). The substitution argument
//! (ARCHITECTURE.md, Layer 1): every downstream result that depends on "PMEM is
//! 10–100× faster than SSD" flows from the very numbers the authors
//! measured on real Optane hardware.

use crate::sim::SimNs;
use crate::util::bytes::GIB;

/// Access pattern classes as in Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Access {
    Seq,
    Rand,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
/// Transfer direction of a storage operation.
pub enum Dir {
    Read,
    Write,
}

/// Service parameters for one (access, dir) class.
#[derive(Clone, Copy, Debug)]
pub struct OpClass {
    /// Sustained bandwidth, bytes/sec.
    pub bandwidth: f64,
    /// Per-request access latency.
    pub latency: SimNs,
}

/// A storage medium: four op classes + a capacity.
#[derive(Clone, Debug)]
pub struct MediaSpec {
    pub name: &'static str,
    pub seq_read: OpClass,
    pub seq_write: OpClass,
    pub rand_read: OpClass,
    pub rand_write: OpClass,
    pub capacity: u64,
}

impl MediaSpec {
    /// Scale this medium's *bandwidths* by a node speed factor (the
    /// straggler model): the device's DES channels deploy at
    /// `speed` × their healthy capacity, slowing every flow through
    /// them — local or remote. Access latencies are deliberately NOT
    /// scaled here: a task's fixed-latency stages are stretched once,
    /// by the engine's per-proc speed scaling (`Engine::spawn_scaled`)
    /// — scaling both would double-count the slowdown on local device
    /// access. `scaled(1.0)` is the identity, so uniform clusters keep
    /// bit-for-bit legacy device timings.
    pub fn scaled(mut self, speed: f64) -> MediaSpec {
        if !speed.is_finite() || speed <= 0.0 || speed == 1.0 {
            return self;
        }
        for c in [
            &mut self.seq_read,
            &mut self.seq_write,
            &mut self.rand_read,
            &mut self.rand_write,
        ] {
            c.bandwidth *= speed;
        }
        self
    }

    pub fn class(&self, access: Access, dir: Dir) -> OpClass {
        match (access, dir) {
            (Access::Seq, Dir::Read) => self.seq_read,
            (Access::Seq, Dir::Write) => self.seq_write,
            (Access::Rand, Dir::Read) => self.rand_read,
            (Access::Rand, Dir::Write) => self.rand_write,
        }
    }

    /// Implied IOPS at a given block size (Table 2 reports 4 KiB).
    pub fn iops(&self, access: Access, dir: Dir, block: u64) -> f64 {
        self.class(access, dir).bandwidth / block as f64
    }

    /// Intel Optane DC PMEM, AppDirect mode, DAX ext4, libpmem —
    /// paper Table 2 PMEM rows.
    pub fn pmem(capacity: u64) -> MediaSpec {
        MediaSpec {
            name: "pmem",
            seq_read: OpClass {
                bandwidth: 41.0 * GIB as f64,
                latency: SimNs::from_nanos(600), // 0.6 µs
            },
            seq_write: OpClass {
                bandwidth: 13.6 * GIB as f64,
                latency: SimNs::from_nanos(1_900), // 1.9 µs
            },
            rand_read: OpClass {
                bandwidth: 4.6 * GIB as f64,
                latency: SimNs::from_nanos(600), // 0.6 µs
            },
            rand_write: OpClass {
                bandwidth: 1.4 * GIB as f64,
                latency: SimNs::from_nanos(2_300), // 2.3 µs
            },
            capacity,
        }
    }

    /// Enterprise SATA/NVMe-class SSD with libaio — paper Table 2 SSD rows.
    pub fn ssd(capacity: u64) -> MediaSpec {
        MediaSpec {
            name: "ssd",
            seq_read: OpClass {
                bandwidth: 0.4 * GIB as f64,
                latency: SimNs::from_micros(4_700), // 4.7 ms
            },
            seq_write: OpClass {
                bandwidth: 0.5 * GIB as f64,
                latency: SimNs::from_micros(5_000), // 5.0 ms
            },
            rand_read: OpClass {
                bandwidth: 0.3 * GIB as f64,
                latency: SimNs::from_micros(800), // 0.8 ms
            },
            rand_write: OpClass {
                bandwidth: 0.3 * GIB as f64,
                latency: SimNs::from_micros(1_000), // 1.0 ms
            },
            capacity,
        }
    }

    /// DRAM tier for the IGFS in-memory cache (not in Table 2; standard
    /// DDR4 stream numbers, far above PMEM so the cache is never the
    /// media bottleneck — matching the paper's "near-DRAM" framing).
    pub fn dram(capacity: u64) -> MediaSpec {
        MediaSpec {
            name: "dram",
            seq_read: OpClass {
                bandwidth: 90.0 * GIB as f64,
                latency: SimNs::from_nanos(100),
            },
            seq_write: OpClass {
                bandwidth: 60.0 * GIB as f64,
                latency: SimNs::from_nanos(100),
            },
            rand_read: OpClass {
                bandwidth: 30.0 * GIB as f64,
                latency: SimNs::from_nanos(100),
            },
            rand_write: OpClass {
                bandwidth: 20.0 * GIB as f64,
                latency: SimNs::from_nanos(100),
            },
            capacity,
        }
    }

    /// Spinning disk (ablation baseline; not in the paper's table).
    pub fn hdd(capacity: u64) -> MediaSpec {
        MediaSpec {
            name: "hdd",
            seq_read: OpClass {
                bandwidth: 0.18 * GIB as f64,
                latency: SimNs::from_micros(8_500),
            },
            seq_write: OpClass {
                bandwidth: 0.16 * GIB as f64,
                latency: SimNs::from_micros(9_500),
            },
            rand_read: OpClass {
                bandwidth: 0.002 * GIB as f64,
                latency: SimNs::from_micros(12_000),
            },
            rand_write: OpClass {
                bandwidth: 0.002 * GIB as f64,
                latency: SimNs::from_micros(14_000),
            },
            capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::KIB;

    #[test]
    fn table2_iops_reproduced() {
        // Table 2 reports IOPS at 4 KiB blocks; bandwidth / 4 KiB must
        // land near the published IOPS column.
        let pmem = MediaSpec::pmem(GIB);
        let iops = pmem.iops(Access::Seq, Dir::Read, 4 * KIB);
        assert!((iops / 1000.0 - 10700.0).abs() / 10700.0 < 0.01, "{iops}");
        let iops = pmem.iops(Access::Rand, Dir::Write, 4 * KIB);
        assert!((iops / 1000.0 - 335.0).abs() / 335.0 < 0.10, "{iops}");

        let ssd = MediaSpec::ssd(GIB);
        let iops = ssd.iops(Access::Seq, Dir::Read, 4 * KIB);
        assert!((iops / 1000.0 - 108.0).abs() / 108.0 < 0.05, "{iops}");
    }

    #[test]
    fn pmem_dominates_ssd() {
        let p = MediaSpec::pmem(GIB);
        let s = MediaSpec::ssd(GIB);
        for access in [Access::Seq, Access::Rand] {
            for dir in [Dir::Read, Dir::Write] {
                let pc = p.class(access, dir);
                let sc = s.class(access, dir);
                assert!(pc.bandwidth > 4.0 * sc.bandwidth);
                assert!(pc.latency < sc.latency);
            }
        }
    }

    #[test]
    fn scaled_media_slow_down_proportionally() {
        let p = MediaSpec::pmem(GIB);
        let s = p.clone().scaled(0.25);
        for access in [Access::Seq, Access::Rand] {
            for dir in [Dir::Read, Dir::Write] {
                let (pc, sc) = (p.class(access, dir), s.class(access, dir));
                assert!((pc.bandwidth / sc.bandwidth - 4.0).abs() < 1e-9);
                // Latencies are untouched: the engine's per-proc speed
                // scaling stretches them exactly once.
                assert_eq!(sc.latency, pc.latency);
            }
        }
        // Identity and degenerate factors leave the spec untouched.
        let id = p.clone().scaled(1.0);
        assert_eq!(
            id.class(Access::Seq, Dir::Read).bandwidth,
            p.class(Access::Seq, Dir::Read).bandwidth
        );
        let bad = p.clone().scaled(0.0);
        assert_eq!(
            bad.class(Access::Seq, Dir::Read).bandwidth,
            p.class(Access::Seq, Dir::Read).bandwidth
        );
    }

    #[test]
    fn class_lookup() {
        let p = MediaSpec::pmem(GIB);
        assert_eq!(p.class(Access::Seq, Dir::Write).latency,
                   SimNs::from_nanos(1_900));
    }
}
