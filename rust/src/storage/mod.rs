//! Storage substrate: media models calibrated to the paper's Table 2,
//! device instances wired into the DES, payload data plane, and the
//! fio-style microbenchmark that regenerates Table 2.
//!
//! See `ARCHITECTURE.md` (Layer 1, Two-plane execution model).

pub mod device;
pub mod fio;
pub mod media;
pub mod payload;

pub use device::Device;
pub use media::{Access, Dir, MediaSpec, OpClass};
pub use payload::{Payload, PayloadCursor};
