//! FIO-style microbenchmark over the device model — regenerates the
//! paper's **Table 2** (IOPS, bandwidth, latency for PMEM vs SSD;
//! 4 KiB blocks, up to 8 parallel streams).

use crate::sim::{Engine, Stage};
use crate::util::bytes::{GIB, KIB};

use super::device::Device;
use super::media::{Access, Dir, MediaSpec};

#[derive(Clone, Debug)]
/// One fio-style measurement row (Table 2).
pub struct FioResult {
    pub media: &'static str,
    pub access: Access,
    pub dir: Dir,
    pub kiops: f64,
    pub bandwidth_gib_s: f64,
    pub latency: crate::sim::SimNs,
}

/// Run one fio job: `streams` parallel workers, each issuing
/// `ops_per_stream` requests of `block` bytes.
pub fn run_job(
    spec: &MediaSpec,
    access: Access,
    dir: Dir,
    block: u64,
    streams: u32,
    ops_per_stream: u64,
) -> FioResult {
    let mut e = Engine::new();
    let d = Device::new(&mut e, spec.name, spec.clone());
    let media = spec.name;
    for s in 0..streams {
        // A stream is one request batch: latency paid per op would model
        // sync I/O; fio with iodepth>1 pipelines, so we charge the
        // latency once per stream and let bandwidth dominate, exactly
        // how Table 2's bandwidth/IOPS columns relate at 4 KiB.
        let bytes = block * ops_per_stream;
        let mut stages = vec![Stage::Delay(d.latency(access, dir))];
        stages.push(Stage::Flow {
            bytes: d.effective_bytes(bytes, access, dir),
            path: vec![d.channel(dir)],
            tag: s,
            timeout: None,
        });
        e.spawn(&format!("fio-{s}"), stages);
    }
    let end = e.run().expect("fio deadlock");
    let secs = end.as_secs_f64();
    let total_ops = ops_per_stream * streams as u64;
    let total_bytes = block * total_ops;
    FioResult {
        media,
        access,
        dir,
        kiops: total_ops as f64 / secs / 1e3,
        bandwidth_gib_s: total_bytes as f64 / secs / GIB as f64,
        latency: d.latency(access, dir),
    }
}

/// The full Table 2 grid.
pub fn table2(streams: u32, ops_per_stream: u64) -> Vec<FioResult> {
    let mut out = Vec::new();
    for (access, dir) in [
        (Access::Seq, Dir::Read),
        (Access::Seq, Dir::Write),
        (Access::Rand, Dir::Read),
        (Access::Rand, Dir::Write),
    ] {
        for spec in [MediaSpec::pmem(GIB * 700), MediaSpec::ssd(GIB * 960)] {
            out.push(run_job(&spec, access, dir, 4 * KIB, streams,
                             ops_per_stream));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmem_seq_read_matches_table2() {
        let r = run_job(&MediaSpec::pmem(700 * GIB), Access::Seq, Dir::Read,
                        4 * KIB, 8, 100_000);
        // Paper: 10 700 K IOPS, 41.0 GiB/s.
        assert!((r.bandwidth_gib_s - 41.0).abs() < 0.5, "{r:?}");
        assert!((r.kiops - 10_700.0).abs() / 10_700.0 < 0.02, "{r:?}");
    }

    #[test]
    fn ssd_rand_write_matches_table2() {
        let r = run_job(&MediaSpec::ssd(960 * GIB), Access::Rand, Dir::Write,
                        4 * KIB, 8, 20_000);
        // Paper: 66.2 K IOPS, 0.3 GiB/s.
        assert!((r.bandwidth_gib_s - 0.3).abs() < 0.02, "{r:?}");
        assert!((r.kiops - 66.2).abs() / 66.2 < 0.20, "{r:?}");
    }

    #[test]
    fn grid_covers_all_classes() {
        let rows = table2(2, 1000);
        assert_eq!(rows.len(), 8);
        // PMEM beats SSD in every class.
        for pair in rows.chunks(2) {
            assert_eq!(pair[0].media, "pmem");
            assert_eq!(pair[1].media, "ssd");
            assert!(pair[0].kiops > pair[1].kiops);
            assert!(pair[0].latency < pair[1].latency);
        }
    }
}
