//! A block device instance wired into the DES: two fair-shared channels
//! (read / write) whose capacity is the medium's *sequential* bandwidth.
//! Random-access requests consume "effective bytes" scaled by the
//! seq/rand bandwidth ratio, so a lone random stream achieves exactly
//! the Table 2 random bandwidth while still contending with sequential
//! streams on the same channel. Each request additionally pays the
//! class's access latency once.

use crate::sim::{Engine, ResourceId, SimNs, Stage};

use super::media::{Access, Dir, MediaSpec};

#[derive(Clone, Debug)]
/// A storage device instance: media spec + capacity accounting +
/// DES bandwidth channels.
pub struct Device {
    pub spec: MediaSpec,
    pub read_chan: ResourceId,
    pub write_chan: ResourceId,
    used: u64,
}

impl Device {
    /// Register the device's channels on the engine.
    pub fn new(engine: &mut Engine, name: &str, spec: MediaSpec) -> Device {
        let read_chan = engine
            .add_resource(&format!("{name}.read"), spec.seq_read.bandwidth);
        let write_chan = engine
            .add_resource(&format!("{name}.write"), spec.seq_write.bandwidth);
        Device { spec, read_chan, write_chan, used: 0 }
    }

    pub fn channel(&self, dir: Dir) -> ResourceId {
        match dir {
            Dir::Read => self.read_chan,
            Dir::Write => self.write_chan,
        }
    }

    /// Effective bytes after the seq/rand scaling for this class.
    pub fn effective_bytes(&self, bytes: u64, access: Access, dir: Dir) -> f64 {
        let seq = self.spec.class(Access::Seq, dir).bandwidth;
        let cls = self.spec.class(access, dir).bandwidth;
        bytes as f64 * (seq / cls)
    }

    /// Access latency paid once per request.
    pub fn latency(&self, access: Access, dir: Dir) -> SimNs {
        self.spec.class(access, dir).latency
    }

    /// Stages for a standalone (node-local) request.
    pub fn io_stages(&self, bytes: u64, access: Access, dir: Dir, tag: u32)
        -> Vec<Stage>
    {
        vec![
            Stage::Delay(self.latency(access, dir)),
            Stage::Flow {
                bytes: self.effective_bytes(bytes, access, dir),
                path: vec![self.channel(dir)],
                tag,
                timeout: None,
            },
        ]
    }

    /// Capacity bookkeeping (namenode placement / cache admission use it).
    pub fn capacity(&self) -> u64 {
        self.spec.capacity
    }
    pub fn used(&self) -> u64 {
        self.used
    }
    pub fn free(&self) -> u64 {
        self.spec.capacity.saturating_sub(self.used)
    }
    pub fn reserve(&mut self, bytes: u64) -> Result<(), String> {
        if self.free() < bytes {
            return Err(format!(
                "device {} full: need {bytes}, free {}",
                self.spec.name,
                self.free()
            ));
        }
        self.used += bytes;
        Ok(())
    }
    pub fn release(&mut self, bytes: u64) {
        self.used = self.used.saturating_sub(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ProcState;
    use crate::util::bytes::GIB;

    #[test]
    fn seq_read_takes_expected_time() {
        let mut e = Engine::new();
        let d = Device::new(&mut e, "pmem0", MediaSpec::pmem(100 * GIB));
        let stages = d.io_stages(41 * GIB, Access::Seq, Dir::Read, 0);
        let p = e.spawn("rd", stages);
        let end = e.run().unwrap();
        // 41 GiB at 41 GiB/s ≈ 1 s (+0.6 µs latency)
        assert!((end.as_secs_f64() - 1.0).abs() < 1e-3, "{end}");
        assert_eq!(*e.state(p), ProcState::Finished);
    }

    #[test]
    fn rand_write_is_slower_than_seq() {
        let run = |access| {
            let mut e = Engine::new();
            let d = Device::new(&mut e, "pmem0", MediaSpec::pmem(100 * GIB));
            e.spawn("wr", d.io_stages(GIB, access, Dir::Write, 0));
            e.run().unwrap().as_secs_f64()
        };
        let seq = run(Access::Seq);
        let rand = run(Access::Rand);
        // PMEM: 13.6 vs 1.4 GiB/s → ~9.7× slower
        assert!(rand / seq > 8.0 && rand / seq < 12.0, "{}", rand / seq);
    }

    #[test]
    fn reads_and_writes_do_not_contend() {
        let mut e = Engine::new();
        let d = Device::new(&mut e, "ssd0", MediaSpec::ssd(100 * GIB));
        let mut st_r = d.io_stages((0.4 * GIB as f64) as u64, Access::Seq, Dir::Read, 0);
        let mut st_w = d.io_stages((0.5 * GIB as f64) as u64, Access::Seq, Dir::Write, 1);
        e.spawn("r", std::mem::take(&mut st_r));
        e.spawn("w", std::mem::take(&mut st_w));
        let end = e.run().unwrap();
        // Full duplex: both finish in ≈1 s, not 2 s.
        assert!(end.as_secs_f64() < 1.1, "{end}");
    }

    #[test]
    fn two_readers_share_channel() {
        let mut e = Engine::new();
        let d = Device::new(&mut e, "ssd0", MediaSpec::ssd(100 * GIB));
        for i in 0..2 {
            e.spawn("r", d.io_stages((0.4 * GIB as f64) as u64, Access::Seq, Dir::Read, i));
        }
        let end = e.run().unwrap();
        assert!((end.as_secs_f64() - 2.0).abs() < 0.05, "{end}");
    }

    #[test]
    fn capacity_accounting() {
        let mut e = Engine::new();
        let mut d = Device::new(&mut e, "x", MediaSpec::pmem(1000));
        assert!(d.reserve(800).is_ok());
        assert!(d.reserve(300).is_err());
        d.release(500);
        assert!(d.reserve(300).is_ok());
        assert_eq!(d.used(), 600);
    }
}
