//! The data plane's unit of storage: real bytes for small runs (so the
//! whole stack moves actual data through actual code), or an exact byte
//! *accounting* for multi-GB sweeps (same code path, no materialization).
//! The two modes are cross-validated in tests (ARCHITECTURE.md, Layer 1).
//!
//! Real payloads are zero-copy `Arc`-backed views: `slice()` is an O(1)
//! refcount bump, and `concat()` assembles a chunked view instead of
//! memcpying parts into a fresh buffer. Consumers that can tolerate
//! discontiguous data walk `chunks()` or a [`PayloadCursor`]; `gather()`
//! is the only place a copy ever happens.

use std::borrow::Cow;
use std::sync::Arc;

/// A borrowed window into one shared buffer. Cloning bumps the
/// refcount; the underlying bytes are never copied.
#[derive(Clone, Debug)]
pub struct View {
    buf: Arc<Vec<u8>>,
    off: usize,
    len: usize,
}

impl View {
    fn full(buf: Arc<Vec<u8>>) -> View {
        let len = buf.len();
        View { buf, off: 0, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf[self.off..self.off + self.len]
    }

    /// Sub-view (clamped): O(1), shares the backing buffer.
    fn subview(&self, start: usize, len: usize) -> View {
        let start = start.min(self.len);
        let end = start.saturating_add(len).min(self.len);
        View { buf: Arc::clone(&self.buf), off: self.off + start, len: end - start }
    }
}

#[derive(Clone, Debug)]
/// Job data: either real bytes (zero-copy `Arc`-backed views) or an
/// exact synthetic byte count — both flow through the same planes.
pub enum Payload {
    /// One contiguous Arc-backed view.
    Real(View),
    /// ≥2 non-empty views, possibly over different buffers — the
    /// zero-copy result of `concat` (e.g. a multi-block HDFS read).
    Chunked { parts: Vec<View>, len: u64 },
    Synthetic { len: u64 },
}

impl Payload {
    pub fn real(bytes: Vec<u8>) -> Payload {
        Payload::Real(View::full(Arc::new(bytes)))
    }

    pub fn synthetic(len: u64) -> Payload {
        Payload::Synthetic { len }
    }

    pub fn len(&self) -> u64 {
        match self {
            Payload::Real(v) => v.len() as u64,
            Payload::Chunked { len, .. } => *len,
            Payload::Synthetic { len } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_real(&self) -> bool {
        matches!(self, Payload::Real(_) | Payload::Chunked { .. })
    }

    /// Number of contiguous runs backing this payload (0 for synthetic).
    pub fn n_chunks(&self) -> usize {
        match self {
            Payload::Real(_) => 1,
            Payload::Chunked { parts, .. } => parts.len(),
            Payload::Synthetic { .. } => 0,
        }
    }

    /// Borrow the real bytes when contiguous; None for chunked or
    /// synthetic payloads (use `chunks()`/`contiguous()` for those).
    pub fn bytes(&self) -> Option<&[u8]> {
        match self {
            Payload::Real(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    /// Real bytes as one run: borrowed (zero-copy) when contiguous,
    /// gathered into a fresh buffer only when chunked. None = synthetic.
    pub fn contiguous(&self) -> Option<Cow<'_, [u8]>> {
        match self {
            Payload::Real(v) => Some(Cow::Borrowed(v.as_slice())),
            Payload::Chunked { .. } => self.gather().map(Cow::Owned),
            Payload::Synthetic { .. } => None,
        }
    }

    /// Materialize real bytes into an owned buffer; None for synthetic.
    pub fn gather(&self) -> Option<Vec<u8>> {
        match self {
            Payload::Real(v) => Some(v.as_slice().to_vec()),
            Payload::Chunked { parts, len } => {
                let mut out = Vec::with_capacity(*len as usize);
                for p in parts {
                    out.extend_from_slice(p.as_slice());
                }
                Some(out)
            }
            Payload::Synthetic { .. } => None,
        }
    }

    /// Iterate the contiguous runs (empty iterator for synthetic).
    pub fn chunks(&self) -> impl Iterator<Item = &[u8]> + '_ {
        let parts: &[View] = match self {
            Payload::Real(v) => std::slice::from_ref(v),
            Payload::Chunked { parts, .. } => parts,
            Payload::Synthetic { .. } => &[],
        };
        parts.iter().map(|v| v.as_slice())
    }

    /// Record-oriented reader over the chunk sequence (real payloads).
    pub fn cursor(&self) -> PayloadCursor<'_> {
        PayloadCursor::new(self)
    }

    /// Concatenate payloads *by reference*: no byte is copied. Result
    /// is synthetic if any part is; single-run results collapse to
    /// `Real`, multi-run to `Chunked`.
    pub fn concat(parts: &[Payload]) -> Payload {
        if !parts.iter().all(|p| p.is_real()) {
            return Payload::synthetic(parts.iter().map(|p| p.len()).sum());
        }
        let mut views: Vec<View> = Vec::new();
        for p in parts {
            match p {
                Payload::Real(v) if !v.is_empty() => views.push(v.clone()),
                Payload::Chunked { parts, .. } => {
                    views.extend(parts.iter().cloned())
                }
                _ => {}
            }
        }
        Payload::from_views(views)
    }

    fn from_views(views: Vec<View>) -> Payload {
        let mut views: Vec<View> =
            views.into_iter().filter(|v| !v.is_empty()).collect();
        match views.len() {
            0 => Payload::real(Vec::new()),
            1 => Payload::Real(views.pop().unwrap()),
            _ => {
                let len = views.iter().map(|v| v.len() as u64).sum();
                Payload::Chunked { parts: views, len }
            }
        }
    }

    /// Slice by byte range (clamped); O(runs) refcount bumps, zero
    /// copies. Synthetic slices stay synthetic.
    pub fn slice(&self, start: u64, len: u64) -> Payload {
        let total = self.len();
        let start = start.min(total);
        let end = start.saturating_add(len).min(total);
        let want = end - start;
        match self {
            Payload::Real(v) => {
                Payload::Real(v.subview(start as usize, want as usize))
            }
            Payload::Chunked { parts, .. } => {
                let mut views = Vec::new();
                let (mut skip, mut need) = (start as usize, want as usize);
                for p in parts {
                    if need == 0 {
                        break;
                    }
                    if skip >= p.len() {
                        skip -= p.len();
                        continue;
                    }
                    let take = need.min(p.len() - skip);
                    views.push(p.subview(skip, take));
                    skip = 0;
                    need -= take;
                }
                Payload::from_views(views)
            }
            Payload::Synthetic { .. } => Payload::synthetic(want),
        }
    }
}

/// Sequential reader across a payload's chunk sequence. `read` hands
/// back borrowed slices whenever the requested run is contiguous and
/// copies only the (rare) records that straddle a chunk boundary —
/// reducers parse multi-mapper input without a concatenated buffer.
pub struct PayloadCursor<'a> {
    parts: Vec<&'a [u8]>,
    part: usize,
    off: usize,
    remaining: usize,
}

impl<'a> PayloadCursor<'a> {
    fn new(p: &'a Payload) -> PayloadCursor<'a> {
        let parts: Vec<&'a [u8]> =
            p.chunks().filter(|c| !c.is_empty()).collect();
        let remaining = parts.iter().map(|c| c.len()).sum();
        PayloadCursor { parts, part: 0, off: 0, remaining }
    }

    pub fn remaining(&self) -> usize {
        self.remaining
    }

    fn advance(&mut self, mut n: usize) {
        self.remaining -= n;
        while n > 0 {
            let left = self.parts[self.part].len() - self.off;
            if n < left {
                self.off += n;
                return;
            }
            n -= left;
            self.part += 1;
            self.off = 0;
        }
    }

    /// Consume `n` bytes; None if fewer remain. Borrowed when the run
    /// lies within one chunk, owned only when it straddles a boundary.
    pub fn read(&mut self, n: usize) -> Option<Cow<'a, [u8]>> {
        if n > self.remaining {
            return None;
        }
        if n == 0 {
            return Some(Cow::Borrowed(&[]));
        }
        let cur = self.parts[self.part];
        if self.off + n <= cur.len() {
            let s = &cur[self.off..self.off + n];
            self.advance(n);
            return Some(Cow::Borrowed(s));
        }
        let mut out = Vec::with_capacity(n);
        let mut need = n;
        while need > 0 {
            let cur = self.parts[self.part];
            let take = need.min(cur.len() - self.off);
            out.extend_from_slice(&cur[self.off..self.off + take]);
            self.advance(take);
            need -= take;
        }
        Some(Cow::Owned(out))
    }

    /// Skip `n` bytes; false (cursor exhausted) if fewer remain.
    pub fn skip(&mut self, n: usize) -> bool {
        if n > self.remaining {
            self.remaining = 0;
            self.part = self.parts.len();
            self.off = 0;
            return false;
        }
        self.advance(n);
        true
    }

    pub fn read_u16_le(&mut self) -> Option<u16> {
        self.read(2)
            .map(|b| u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn read_u32_le(&mut self) -> Option<u32> {
        self.read(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn read_u64_le(&mut self) -> Option<u64> {
        self.read(8).map(|b| {
            u64::from_le_bytes([
                b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
            ])
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_roundtrip() {
        let p = Payload::real(vec![1, 2, 3, 4]);
        assert_eq!(p.len(), 4);
        assert_eq!(p.bytes(), Some(&[1u8, 2, 3, 4][..]));
        assert_eq!(p.gather(), Some(vec![1, 2, 3, 4]));
    }

    #[test]
    fn synthetic_accounting() {
        let p = Payload::synthetic(1 << 40);
        assert_eq!(p.len(), 1 << 40);
        assert!(p.bytes().is_none());
        assert!(p.gather().is_none());
        assert_eq!(p.chunks().count(), 0);
    }

    #[test]
    fn concat_mixed_degrades_to_synthetic() {
        let c = Payload::concat(&[Payload::real(vec![1; 10]),
                                  Payload::synthetic(5)]);
        assert_eq!(c.len(), 15);
        assert!(!c.is_real());
    }

    #[test]
    fn concat_real_stays_real() {
        let c = Payload::concat(&[Payload::real(vec![1, 2]),
                                  Payload::real(vec![3])]);
        assert!(c.is_real());
        assert_eq!(c.gather(), Some(vec![1, 2, 3]));
    }

    #[test]
    fn slice_clamps() {
        let p = Payload::real(vec![0, 1, 2, 3, 4]);
        assert_eq!(p.slice(3, 10).bytes(), Some(&[3u8, 4][..]));
        assert_eq!(p.slice(9, 1).len(), 0);
        assert_eq!(Payload::synthetic(100).slice(90, 20).len(), 10);
    }

    #[test]
    fn slice_is_zero_copy_alias() {
        // A slice shares the parent's buffer: no allocation of the
        // payload bytes, just a refcount bump.
        let p = Payload::real((0..100u8).collect());
        let s = p.slice(10, 20);
        let (pb, sb) = (p.bytes().unwrap(), s.bytes().unwrap());
        assert_eq!(sb, &pb[10..30]);
        assert!(std::ptr::eq(&pb[10], &sb[0]), "slice must alias parent");
    }

    #[test]
    fn slice_of_slice_composes() {
        let p = Payload::real((0..50u8).collect());
        let a = p.slice(10, 30); // bytes 10..40
        let b = a.slice(5, 100); // clamped: bytes 15..40
        assert_eq!(b.bytes(), Some(&(15..40u8).collect::<Vec<_>>()[..]));
        // Still aliasing the original buffer.
        assert!(std::ptr::eq(&p.bytes().unwrap()[15], &b.bytes().unwrap()[0]));
    }

    #[test]
    fn concat_of_views_roundtrips() {
        let base = Payload::real((0..40u8).collect());
        let c = Payload::concat(&[
            base.slice(0, 10),
            base.slice(20, 10),
            Payload::real(vec![9; 3]),
        ]);
        assert_eq!(c.len(), 23);
        assert_eq!(c.n_chunks(), 3);
        let mut want: Vec<u8> = (0..10u8).collect();
        want.extend(20..30u8);
        want.extend([9; 3]);
        assert_eq!(c.gather(), Some(want.clone()));
        assert_eq!(c.contiguous().unwrap().into_owned(), want);
        // Chunked concat is a view assembly: chunk 0 aliases base.
        let first = c.chunks().next().unwrap();
        assert!(std::ptr::eq(&base.bytes().unwrap()[0], &first[0]));
    }

    #[test]
    fn concat_flattens_and_collapses() {
        let inner = Payload::concat(&[Payload::real(vec![1, 2]),
                                      Payload::real(vec![3])]);
        let outer = Payload::concat(&[inner, Payload::real(vec![4])]);
        assert_eq!(outer.n_chunks(), 3);
        assert_eq!(outer.gather(), Some(vec![1, 2, 3, 4]));
        // Single non-empty part collapses back to contiguous Real.
        let one = Payload::concat(&[Payload::real(Vec::new()),
                                    Payload::real(vec![7, 8])]);
        assert_eq!(one.n_chunks(), 1);
        assert_eq!(one.bytes(), Some(&[7u8, 8][..]));
    }

    #[test]
    fn chunked_slice_clamps_and_aliases() {
        let c = Payload::concat(&[Payload::real(vec![0, 1, 2, 3]),
                                  Payload::real(vec![4, 5, 6, 7])]);
        assert_eq!(c.slice(2, 4).gather(), Some(vec![2, 3, 4, 5]));
        assert_eq!(c.slice(6, 100).gather(), Some(vec![6, 7]));
        assert_eq!(c.slice(100, 5).len(), 0);
        // Slice within one run collapses to contiguous.
        assert_eq!(c.slice(4, 4).bytes(), Some(&[4u8, 5, 6, 7][..]));
    }

    #[test]
    fn cursor_reads_across_boundaries() {
        let c = Payload::concat(&[Payload::real(vec![0, 1, 2]),
                                  Payload::real(vec![3, 4, 5, 6])]);
        let mut cur = c.cursor();
        assert_eq!(cur.remaining(), 7);
        // In-chunk read borrows...
        match cur.read(2).unwrap() {
            Cow::Borrowed(s) => assert_eq!(s, &[0, 1]),
            Cow::Owned(_) => panic!("in-chunk read must borrow"),
        }
        // ...straddling read copies exactly the straddled record.
        match cur.read(3).unwrap() {
            Cow::Owned(v) => assert_eq!(v, vec![2, 3, 4]),
            Cow::Borrowed(_) => panic!("straddling read must gather"),
        }
        assert!(cur.skip(1));
        assert_eq!(cur.read_u16_le(), None); // only 1 byte left
        assert_eq!(cur.read(1).unwrap().as_ref(), &[6]);
        assert!(cur.read(1).is_none());
        assert!(!cur.skip(1));
    }

    #[test]
    fn cursor_helpers() {
        let p = Payload::real(vec![0x34, 0x12, 0x78, 0x56, 0x00, 0x00]);
        let mut cur = p.cursor();
        assert_eq!(cur.read_u16_le(), Some(0x1234));
        assert_eq!(cur.read_u32_le(), Some(0x5678));
        assert_eq!(cur.remaining(), 0);
        let q = Payload::real(0x1122_3344_5566_7788u64.to_le_bytes().to_vec());
        assert_eq!(q.cursor().read_u64_le(), Some(0x1122_3344_5566_7788));
    }
}
