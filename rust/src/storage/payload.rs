//! The data plane's unit of storage: real bytes for small runs (so the
//! whole stack moves actual data through actual code), or an exact byte
//! *accounting* for multi-GB sweeps (same code path, no materialization).
//! The two modes are cross-validated in tests (DESIGN.md §2).

use std::sync::Arc;

#[derive(Clone, Debug)]
pub enum Payload {
    Real(Arc<Vec<u8>>),
    Synthetic { len: u64 },
}

impl Payload {
    pub fn real(bytes: Vec<u8>) -> Payload {
        Payload::Real(Arc::new(bytes))
    }

    pub fn synthetic(len: u64) -> Payload {
        Payload::Synthetic { len }
    }

    pub fn len(&self) -> u64 {
        match self {
            Payload::Real(b) => b.len() as u64,
            Payload::Synthetic { len } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_real(&self) -> bool {
        matches!(self, Payload::Real(_))
    }

    /// Borrow the real bytes; None for synthetic payloads.
    pub fn bytes(&self) -> Option<&[u8]> {
        match self {
            Payload::Real(b) => Some(b),
            Payload::Synthetic { .. } => None,
        }
    }

    /// Concatenate payloads; result is synthetic if any part is.
    pub fn concat(parts: &[Payload]) -> Payload {
        if parts.iter().all(|p| p.is_real()) {
            let total: usize = parts.iter().map(|p| p.len() as usize).sum();
            let mut out = Vec::with_capacity(total);
            for p in parts {
                out.extend_from_slice(p.bytes().unwrap());
            }
            Payload::real(out)
        } else {
            Payload::synthetic(parts.iter().map(|p| p.len()).sum())
        }
    }

    /// Slice by byte range (clamped); synthetic slices stay synthetic.
    pub fn slice(&self, start: u64, len: u64) -> Payload {
        let end = (start + len).min(self.len());
        let start = start.min(self.len());
        match self {
            Payload::Real(b) => {
                Payload::real(b[start as usize..end as usize].to_vec())
            }
            Payload::Synthetic { .. } => Payload::synthetic(end - start),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_roundtrip() {
        let p = Payload::real(vec![1, 2, 3, 4]);
        assert_eq!(p.len(), 4);
        assert_eq!(p.bytes(), Some(&[1u8, 2, 3, 4][..]));
    }

    #[test]
    fn synthetic_accounting() {
        let p = Payload::synthetic(1 << 40);
        assert_eq!(p.len(), 1 << 40);
        assert!(p.bytes().is_none());
    }

    #[test]
    fn concat_mixed_degrades_to_synthetic() {
        let c = Payload::concat(&[Payload::real(vec![1; 10]),
                                  Payload::synthetic(5)]);
        assert_eq!(c.len(), 15);
        assert!(!c.is_real());
    }

    #[test]
    fn concat_real_stays_real() {
        let c = Payload::concat(&[Payload::real(vec![1, 2]),
                                  Payload::real(vec![3])]);
        assert_eq!(c.bytes(), Some(&[1u8, 2, 3][..]));
    }

    #[test]
    fn slice_clamps() {
        let p = Payload::real(vec![0, 1, 2, 3, 4]);
        assert_eq!(p.slice(3, 10).bytes(), Some(&[3u8, 4][..]));
        assert_eq!(p.slice(9, 1).len(), 0);
        assert_eq!(Payload::synthetic(100).slice(90, 20).len(), 10);
    }
}
