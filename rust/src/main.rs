//! `marvel` binary — the Layer-3 leader entrypoint. All heavy lifting
//! lives in the library; this is argv plumbing.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(marvel::cli::main_with_args(&argv));
}
