//! Node / NIC / device wiring for the simulated cluster.

use std::collections::BTreeMap;

use crate::sim::{Engine, ResourceId, SimNs};
use crate::storage::{Device, MediaSpec};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
/// Index of a server node in the cluster topology.
pub struct NodeId(pub usize);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
/// Index of a device (NIC channel, DRAM/PMEM/SSD/HDD) in the topology.
pub struct DevId(pub usize);

/// Which storage role a device plays on its node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DeviceRole {
    Pmem,
    Ssd,
    Hdd,
    Dram,
}

#[derive(Clone, Debug)]
/// One server: its devices by role plus NIC channels.
pub struct Node {
    pub name: String,
    pub nic_in: ResourceId,
    pub nic_out: ResourceId,
    pub devices: BTreeMap<DeviceRole, DevId>,
    /// Container slots this node can host (invoker capacity).
    pub slots: usize,
}

/// The deployed cluster: nodes, devices, LAN/WAN shared links.
pub struct Topology {
    pub nodes: Vec<Node>,
    pub devices: Vec<Device>,
    /// Shared WAN pipe to the remote object store (both directions).
    pub wan_up: ResourceId,
    pub wan_down: ResourceId,
    pub wan_rtt: SimNs,
    /// Intra-node memory bus (loopback transfers, IGFS local hits).
    pub membus: Vec<ResourceId>,
}

impl Topology {
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    pub fn device(&self, id: DevId) -> &Device {
        &self.devices[id.0]
    }

    pub fn device_mut(&mut self, id: DevId) -> &mut Device {
        &mut self.devices[id.0]
    }

    pub fn device_of(&self, node: NodeId, role: DeviceRole) -> Option<DevId> {
        self.node(node).devices.get(&role).copied()
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// NIC resources a transfer from `src` to `dst` traverses; empty for
    /// node-local transfers (loopback never leaves the host).
    pub fn lan_path(&self, src: NodeId, dst: NodeId) -> Vec<ResourceId> {
        if src == dst {
            vec![self.membus[src.0]]
        } else {
            vec![self.node(src).nic_out, self.node(dst).nic_in]
        }
    }

    /// Path from a node up to the object store (PUT direction).
    pub fn wan_put_path(&self, src: NodeId) -> Vec<ResourceId> {
        vec![self.node(src).nic_out, self.wan_up]
    }

    /// Path from the object store down to a node (GET direction).
    pub fn wan_get_path(&self, dst: NodeId) -> Vec<ResourceId> {
        vec![self.wan_down, self.node(dst).nic_in]
    }
}

/// Builder mirroring the paper's testbed shape (§4.1): one or more
/// servers, each with DRAM, PMEM (AppDirect) and SSD, on a 10 Gb/s
/// overlay; WAN to S3 at ~5 Gb/s effective with ~20 ms RTT.
pub struct TopologyBuilder {
    pub nodes: usize,
    pub slots_per_node: usize,
    pub nic_gbps: f64,
    pub pmem_capacity: u64,
    pub ssd_capacity: u64,
    pub dram_capacity: u64,
    pub wan_gbps: f64,
    pub wan_rtt: SimNs,
    pub with_hdd: bool,
}

impl Default for TopologyBuilder {
    fn default() -> Self {
        use crate::util::bytes::GIB;
        TopologyBuilder {
            nodes: 1,
            // Paper testbed: 32 CPUs on the single server.
            slots_per_node: 32,
            nic_gbps: 10.0,
            pmem_capacity: 700 * GIB,
            ssd_capacity: 960 * GIB,
            dram_capacity: 360 * GIB,
            wan_gbps: 5.0,
            wan_rtt: SimNs::from_millis(20),
            with_hdd: false,
        }
    }
}

impl TopologyBuilder {
    pub fn build(&self, engine: &mut Engine) -> Topology {
        assert!(self.nodes > 0);
        let gbps = |g: f64| g * 1e9 / 8.0; // bytes/sec
        let mut nodes = Vec::with_capacity(self.nodes);
        let mut devices = Vec::new();
        let mut membus = Vec::with_capacity(self.nodes);
        for i in 0..self.nodes {
            let name = format!("node{i}");
            let nic_in = engine
                .add_resource(&format!("{name}.nic.in"), gbps(self.nic_gbps));
            let nic_out = engine
                .add_resource(&format!("{name}.nic.out"), gbps(self.nic_gbps));
            membus.push(engine.add_resource(
                &format!("{name}.membus"),
                // Loopback/DRAM copy bandwidth — far above NIC speed.
                40.0 * crate::util::bytes::GIB as f64,
            ));
            let mut map = BTreeMap::new();
            let mut add = |role: DeviceRole, spec: MediaSpec,
                           devices: &mut Vec<Device>,
                           engine: &mut Engine| {
                let dev = Device::new(
                    engine,
                    &format!("{name}.{:?}", role).to_lowercase(),
                    spec,
                );
                devices.push(dev);
                map.insert(role, DevId(devices.len() - 1));
            };
            add(DeviceRole::Pmem, MediaSpec::pmem(self.pmem_capacity),
                &mut devices, engine);
            add(DeviceRole::Ssd, MediaSpec::ssd(self.ssd_capacity),
                &mut devices, engine);
            add(DeviceRole::Dram, MediaSpec::dram(self.dram_capacity),
                &mut devices, engine);
            if self.with_hdd {
                add(DeviceRole::Hdd, MediaSpec::hdd(4 * self.ssd_capacity),
                    &mut devices, engine);
            }
            nodes.push(Node {
                name,
                nic_in,
                nic_out,
                devices: map,
                slots: self.slots_per_node,
            });
        }
        let wan_up = engine.add_resource("wan.up", gbps(self.wan_gbps));
        let wan_down = engine.add_resource("wan.down", gbps(self.wan_gbps));
        Topology {
            nodes,
            devices,
            wan_up,
            wan_down,
            wan_rtt: self.wan_rtt,
            membus,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Stage;

    fn topo(nodes: usize) -> (Engine, Topology) {
        let mut e = Engine::new();
        let t = TopologyBuilder { nodes, ..Default::default() }.build(&mut e);
        (e, t)
    }

    #[test]
    fn builds_roles_per_node() {
        let (_, t) = topo(3);
        assert_eq!(t.n_nodes(), 3);
        for i in 0..3 {
            let n = NodeId(i);
            assert!(t.device_of(n, DeviceRole::Pmem).is_some());
            assert!(t.device_of(n, DeviceRole::Ssd).is_some());
            assert!(t.device_of(n, DeviceRole::Dram).is_some());
            assert!(t.device_of(n, DeviceRole::Hdd).is_none());
        }
    }

    #[test]
    fn local_path_uses_membus_not_nic() {
        let (_, t) = topo(2);
        let local = t.lan_path(NodeId(0), NodeId(0));
        assert_eq!(local, vec![t.membus[0]]);
        let remote = t.lan_path(NodeId(0), NodeId(1));
        assert_eq!(remote.len(), 2);
    }

    #[test]
    fn nic_caps_cross_node_transfer() {
        let (mut e, t) = topo(2);
        // 1.25 GB over a 10 Gb/s NIC ≈ 1 s.
        e.spawn("xfer", vec![Stage::Flow {
            bytes: 1.25e9,
            path: t.lan_path(NodeId(0), NodeId(1)),
            tag: 0,
        }]);
        let end = e.run().unwrap();
        assert!((end.as_secs_f64() - 1.0).abs() < 0.01, "{end}");
    }

    #[test]
    fn wan_slower_than_lan() {
        let (mut e, t) = topo(1);
        e.spawn("up", vec![Stage::Flow {
            bytes: 1.25e9,
            path: t.wan_put_path(NodeId(0)),
            tag: 0,
        }]);
        let end = e.run().unwrap();
        // 1.25 GB at 5 Gb/s = 2 s.
        assert!((end.as_secs_f64() - 2.0).abs() < 0.01, "{end}");
    }
}
