//! Node / NIC / device wiring for the simulated cluster, including the
//! heterogeneous-node-speed (straggler) model: each node carries a
//! speed factor that scales its compute delays (via
//! `sim::Engine::spawn_scaled`) and its storage devices' channel
//! capacities/latencies (via `storage::MediaSpec::scaled`).

use std::collections::BTreeMap;

use crate::sim::{Engine, ResourceId, SimNs};
use crate::storage::{Device, MediaSpec};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
/// Index of a server node in the cluster topology.
pub struct NodeId(pub usize);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
/// Index of a device (NIC channel, DRAM/PMEM/SSD/HDD) in the topology.
pub struct DevId(pub usize);

/// Which storage role a device plays on its node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DeviceRole {
    Pmem,
    Ssd,
    Hdd,
    Dram,
}

/// Seed-driven heterogeneous node speeds — the straggler model. Real
/// FaaS fleets are not uniform: a fraction of hosts run slow (thermal
/// throttling, noisy neighbors, degraded media), and tail latency is
/// set by them. Disabled by default (`prob == 0.0`): every node runs
/// at speed 1.0 and the deployed cluster is bit-for-bit the legacy
/// uniform one.
///
/// Determinism contract: a node's speed factor is a pure function of
/// `(seed, node index)` — never of job data, worker counts, admission
/// order, or co-tenants — so arming a profile moves only virtual time.
/// Outputs stay byte-identical because the data plane never consults
/// node speeds.
#[derive(Clone, Debug, PartialEq)]
pub struct StragglerProfile {
    /// Seed driving the per-node straggler draw (independent of the
    /// data seed; CI sweeps it via `MARVEL_STRAGGLER_SEED`).
    pub seed: u64,
    /// Per-node probability of being a straggler.
    pub prob: f64,
    /// Slowdown factor (≥ 1) for straggler nodes: every fixed-latency
    /// stage of a task hosted there stretches by it (compute, startup,
    /// access latencies, request RTTs — a slow host is slow at
    /// everything it executes), and the node's storage devices serve
    /// at `1/slowdown` of their healthy channel bandwidth. Link
    /// *capacities* (NIC, WAN) stay uniform.
    pub slowdown: f64,
}

impl Default for StragglerProfile {
    fn default() -> Self {
        StragglerProfile { seed: 17, prob: 0.0, slowdown: 4.0 }
    }
}

impl StragglerProfile {
    /// An inert profile (the default for every `SystemConfig` preset).
    pub fn disabled() -> StragglerProfile {
        StragglerProfile::default()
    }

    /// Whether this profile can slow any node at all.
    pub fn enabled(&self) -> bool {
        self.prob > 0.0 && self.slowdown > 1.0
    }

    /// Speed factor of one node: 1.0 for healthy nodes, `1/slowdown`
    /// for stragglers. Pure function of `(seed, node)`.
    pub fn speed_of(&self, node: usize) -> f64 {
        if !self.enabled() {
            return 1.0;
        }
        let mut rng = Rng::new(
            self.seed
                ^ (node as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        if rng.chance(self.prob) {
            1.0 / self.slowdown.max(1.0)
        } else {
            1.0
        }
    }

    /// Speed factors for a cluster of `n` nodes (feeds
    /// [`TopologyBuilder::node_speeds`]).
    pub fn speeds(&self, n: usize) -> Vec<f64> {
        (0..n).map(|i| self.speed_of(i)).collect()
    }
}

#[derive(Clone, Debug)]
/// One server: its devices by role plus NIC channels.
pub struct Node {
    pub name: String,
    pub nic_in: ResourceId,
    pub nic_out: ResourceId,
    pub devices: BTreeMap<DeviceRole, DevId>,
    /// Container slots this node can host (invoker capacity).
    pub slots: usize,
    /// Compute/device speed factor (1.0 = healthy; a 0.25-speed node
    /// is a 4× straggler). The driver spawns this node's task procs
    /// with it and the builder scales the node's device media by it.
    pub speed: f64,
}

/// The deployed cluster: nodes, devices, LAN/WAN shared links.
pub struct Topology {
    pub nodes: Vec<Node>,
    pub devices: Vec<Device>,
    /// Shared WAN pipe to the remote object store (both directions).
    pub wan_up: ResourceId,
    pub wan_down: ResourceId,
    pub wan_rtt: SimNs,
    /// Intra-node memory bus (loopback transfers, IGFS local hits).
    pub membus: Vec<ResourceId>,
}

impl Topology {
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    pub fn device(&self, id: DevId) -> &Device {
        &self.devices[id.0]
    }

    pub fn device_mut(&mut self, id: DevId) -> &mut Device {
        &mut self.devices[id.0]
    }

    pub fn device_of(&self, node: NodeId, role: DeviceRole) -> Option<DevId> {
        self.node(node).devices.get(&role).copied()
    }

    /// Speed factor of a node (1.0 = healthy, `< 1` = straggler).
    pub fn speed_of(&self, id: NodeId) -> f64 {
        self.node(id).speed
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// NIC resources a transfer from `src` to `dst` traverses; empty for
    /// node-local transfers (loopback never leaves the host).
    pub fn lan_path(&self, src: NodeId, dst: NodeId) -> Vec<ResourceId> {
        if src == dst {
            vec![self.membus[src.0]]
        } else {
            vec![self.node(src).nic_out, self.node(dst).nic_in]
        }
    }

    /// Path from a node up to the object store (PUT direction).
    pub fn wan_put_path(&self, src: NodeId) -> Vec<ResourceId> {
        vec![self.node(src).nic_out, self.wan_up]
    }

    /// Path from the object store down to a node (GET direction).
    pub fn wan_get_path(&self, dst: NodeId) -> Vec<ResourceId> {
        vec![self.wan_down, self.node(dst).nic_in]
    }
}

/// Builder mirroring the paper's testbed shape (§4.1): one or more
/// servers, each with DRAM, PMEM (AppDirect) and SSD, on a 10 Gb/s
/// overlay; WAN to S3 at ~5 Gb/s effective with ~20 ms RTT.
pub struct TopologyBuilder {
    pub nodes: usize,
    pub slots_per_node: usize,
    pub nic_gbps: f64,
    pub pmem_capacity: u64,
    pub ssd_capacity: u64,
    pub dram_capacity: u64,
    pub wan_gbps: f64,
    pub wan_rtt: SimNs,
    pub with_hdd: bool,
    /// Per-node speed factors (index = node id; missing entries and
    /// non-positive values mean 1.0). Typically produced by
    /// [`StragglerProfile::speeds`]. NICs and the WAN stay uniform —
    /// the model is heterogeneous *compute and storage*, not links.
    pub node_speeds: Vec<f64>,
}

impl Default for TopologyBuilder {
    fn default() -> Self {
        use crate::util::bytes::GIB;
        TopologyBuilder {
            nodes: 1,
            // Paper testbed: 32 CPUs on the single server.
            slots_per_node: 32,
            nic_gbps: 10.0,
            pmem_capacity: 700 * GIB,
            ssd_capacity: 960 * GIB,
            dram_capacity: 360 * GIB,
            wan_gbps: 5.0,
            wan_rtt: SimNs::from_millis(20),
            with_hdd: false,
            node_speeds: Vec::new(),
        }
    }
}

impl TopologyBuilder {
    pub fn build(&self, engine: &mut Engine) -> Topology {
        assert!(self.nodes > 0);
        let gbps = |g: f64| g * 1e9 / 8.0; // bytes/sec
        let mut nodes = Vec::with_capacity(self.nodes);
        let mut devices = Vec::new();
        let mut membus = Vec::with_capacity(self.nodes);
        for i in 0..self.nodes {
            let name = format!("node{i}");
            let speed = self
                .node_speeds
                .get(i)
                .copied()
                .filter(|s| s.is_finite() && *s > 0.0)
                .unwrap_or(1.0);
            let nic_in = engine
                .add_resource(&format!("{name}.nic.in"), gbps(self.nic_gbps));
            let nic_out = engine
                .add_resource(&format!("{name}.nic.out"), gbps(self.nic_gbps));
            membus.push(engine.add_resource(
                &format!("{name}.membus"),
                // Loopback/DRAM copy bandwidth — far above NIC speed.
                40.0 * crate::util::bytes::GIB as f64,
            ));
            let mut map = BTreeMap::new();
            let mut add = |role: DeviceRole, spec: MediaSpec,
                           devices: &mut Vec<Device>,
                           engine: &mut Engine| {
                let dev = Device::new(
                    engine,
                    &format!("{name}.{:?}", role).to_lowercase(),
                    // A straggler node's media serve proportionally
                    // slower (scaled channel capacity + latency).
                    spec.scaled(speed),
                );
                devices.push(dev);
                map.insert(role, DevId(devices.len() - 1));
            };
            add(DeviceRole::Pmem, MediaSpec::pmem(self.pmem_capacity),
                &mut devices, engine);
            add(DeviceRole::Ssd, MediaSpec::ssd(self.ssd_capacity),
                &mut devices, engine);
            add(DeviceRole::Dram, MediaSpec::dram(self.dram_capacity),
                &mut devices, engine);
            if self.with_hdd {
                add(DeviceRole::Hdd, MediaSpec::hdd(4 * self.ssd_capacity),
                    &mut devices, engine);
            }
            nodes.push(Node {
                name,
                nic_in,
                nic_out,
                devices: map,
                slots: self.slots_per_node,
                speed,
            });
        }
        let wan_up = engine.add_resource("wan.up", gbps(self.wan_gbps));
        let wan_down = engine.add_resource("wan.down", gbps(self.wan_gbps));
        Topology {
            nodes,
            devices,
            wan_up,
            wan_down,
            wan_rtt: self.wan_rtt,
            membus,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Stage;

    fn topo(nodes: usize) -> (Engine, Topology) {
        let mut e = Engine::new();
        let t = TopologyBuilder { nodes, ..Default::default() }.build(&mut e);
        (e, t)
    }

    #[test]
    fn builds_roles_per_node() {
        let (_, t) = topo(3);
        assert_eq!(t.n_nodes(), 3);
        for i in 0..3 {
            let n = NodeId(i);
            assert!(t.device_of(n, DeviceRole::Pmem).is_some());
            assert!(t.device_of(n, DeviceRole::Ssd).is_some());
            assert!(t.device_of(n, DeviceRole::Dram).is_some());
            assert!(t.device_of(n, DeviceRole::Hdd).is_none());
        }
    }

    #[test]
    fn local_path_uses_membus_not_nic() {
        let (_, t) = topo(2);
        let local = t.lan_path(NodeId(0), NodeId(0));
        assert_eq!(local, vec![t.membus[0]]);
        let remote = t.lan_path(NodeId(0), NodeId(1));
        assert_eq!(remote.len(), 2);
    }

    #[test]
    fn nic_caps_cross_node_transfer() {
        let (mut e, t) = topo(2);
        // 1.25 GB over a 10 Gb/s NIC ≈ 1 s.
        e.spawn("xfer", vec![Stage::Flow {
            bytes: 1.25e9,
            path: t.lan_path(NodeId(0), NodeId(1)),
            tag: 0,
            timeout: None,
        }]);
        let end = e.run().unwrap();
        assert!((end.as_secs_f64() - 1.0).abs() < 0.01, "{end}");
    }

    #[test]
    fn default_nodes_run_at_full_speed() {
        let (_, t) = topo(3);
        for i in 0..3 {
            assert_eq!(t.speed_of(NodeId(i)), 1.0);
        }
    }

    #[test]
    fn straggler_profile_is_deterministic_and_inert_by_default() {
        let off = StragglerProfile::disabled();
        assert!(!off.enabled());
        assert_eq!(off.speeds(8), vec![1.0; 8]);
        let p = StragglerProfile { seed: 3, prob: 0.5, slowdown: 4.0 };
        assert!(p.enabled());
        assert_eq!(p.speeds(16), p.speeds(16), "pure function of seed");
        for s in p.speeds(64) {
            assert!(s == 1.0 || (s - 0.25).abs() < 1e-12, "{s}");
        }
        // Probability 1 slows every node; slowdown 1 slows none.
        let all = StragglerProfile { seed: 1, prob: 1.0, slowdown: 2.0 };
        assert!(all.speeds(4).iter().all(|s| (*s - 0.5).abs() < 1e-12));
        let none = StragglerProfile { seed: 1, prob: 1.0, slowdown: 1.0 };
        assert!(!none.enabled());
        assert_eq!(none.speeds(4), vec![1.0; 4]);
        // Different seeds draw different straggler sets (for some n).
        let a = StragglerProfile { seed: 1, prob: 0.5, slowdown: 4.0 };
        let b = StragglerProfile { seed: 2, prob: 0.5, slowdown: 4.0 };
        assert!(
            (0..64).any(|i| a.speed_of(i) != b.speed_of(i)),
            "seed must matter"
        );
    }

    #[test]
    fn straggler_node_devices_are_slower() {
        use crate::storage::{Access, Dir};
        let mut e = Engine::new();
        let t = TopologyBuilder {
            nodes: 2,
            node_speeds: vec![1.0, 0.25],
            ..Default::default()
        }
        .build(&mut e);
        assert_eq!(t.speed_of(NodeId(0)), 1.0);
        assert_eq!(t.speed_of(NodeId(1)), 0.25);
        let healthy = t.device(t.device_of(NodeId(0), DeviceRole::Pmem)
            .unwrap());
        let slow = t.device(t.device_of(NodeId(1), DeviceRole::Pmem)
            .unwrap());
        let hb = healthy.spec.class(Access::Seq, Dir::Read).bandwidth;
        let sb = slow.spec.class(Access::Seq, Dir::Read).bandwidth;
        assert!((hb / sb - 4.0).abs() < 1e-9, "{hb} vs {sb}");
        // Latencies are NOT device-scaled (the engine's per-proc speed
        // scaling stretches a straggler task's fixed latencies exactly
        // once — scaling both would double-count).
        assert_eq!(
            slow.latency(Access::Seq, Dir::Read),
            healthy.latency(Access::Seq, Dir::Read)
        );
        // Same transfer through each node's PMEM write channel: the
        // straggler's takes 4× as long (channel capacity).
        let time = |node: usize| {
            let mut e = Engine::new();
            let t = TopologyBuilder {
                nodes: 2,
                node_speeds: vec![1.0, 0.25],
                ..Default::default()
            }
            .build(&mut e);
            let dev = t.device(
                t.device_of(NodeId(node), DeviceRole::Pmem).unwrap(),
            );
            e.spawn("w", dev.io_stages(
                10 * crate::util::bytes::GIB,
                Access::Seq,
                Dir::Write,
                0,
            ));
            e.run().unwrap().as_secs_f64()
        };
        let (fast, slow) = (time(0), time(1));
        assert!((slow / fast - 4.0).abs() < 0.01, "{fast} vs {slow}");
    }

    #[test]
    fn wan_slower_than_lan() {
        let (mut e, t) = topo(1);
        e.spawn("up", vec![Stage::Flow {
            bytes: 1.25e9,
            path: t.wan_put_path(NodeId(0)),
            tag: 0,
            timeout: None,
        }]);
        let end = e.run().unwrap();
        // 1.25 GB at 5 Gb/s = 2 s.
        assert!((end.as_secs_f64() - 2.0).abs() < 0.01, "{end}");
    }
}
