//! Cluster topology and network model: per-node full-duplex NICs on a
//! Docker-overlay-style LAN, plus a shared WAN path to the remote object
//! store. Transfers are flows whose path threads the source device read
//! channel, the NICs, and the destination device write channel — so the
//! bottleneck (the paper's "network quickly becomes the bottleneck")
//! emerges from capacities instead of being scripted.
//!
//! See `ARCHITECTURE.md` (Layer 1).

pub mod netfault;
pub mod topology;

pub use netfault::{NetFaultPlan, MAX_FLOW_RETRIES};
pub use topology::{
    DevId, DeviceRole, NodeId, StragglerProfile, Topology, TopologyBuilder,
};
