//! Seed-driven network fault injection — the I/O half of the fault
//! story. A [`NetFaultPlan`] draws, per link (each node's NIC in/out
//! channel plus the WAN up/down pipes), an optional *fault window*
//! `[t0, t1)`: during it the link serves at a fraction of its healthy
//! capacity (a congested/flapping link) or at zero (a blackout). The
//! windows install into [`crate::sim::flow::FlowSim`] as time-varying
//! capacity, so every flow crossing a faulted link re-rates
//! deterministically at the window edges.
//!
//! Determinism contract (same as [`super::StragglerProfile`]): a
//! link's window is a pure function of `(seed, link index)` — never of
//! job data, worker counts, or co-tenants — so arming a plan moves
//! only virtual time and attempt/degradation counters. Outputs stay
//! byte-identical because the data plane never consults link state.
//!
//! See `ARCHITECTURE.md` ("Degraded-mode I/O").

use crate::sim::{Engine, ResourceId, SimNs};
use crate::util::rng::Rng;

use super::Topology;

/// Retry budget for a timed-out flow before the attempt fails over to
/// checkpoint recovery: 8 × the default 250 ms deadline rides out the
/// longest window a plan can draw (~1.5 s) even with zero backoff.
pub const MAX_FLOW_RETRIES: u32 = 8;

/// Seed-driven link fault windows plus the degraded-mode I/O knobs
/// that ride with them. Disabled by default (`prob == 0.0`): no
/// windows install, no flow deadlines arm, and the deployed cluster
/// is bit-for-bit the legacy fault-free one.
#[derive(Clone, Debug, PartialEq)]
pub struct NetFaultPlan {
    /// Seed driving the per-link window draw (independent of the data
    /// seed; CI sweeps it via `MARVEL_NETFAULT_SEED`).
    pub seed: u64,
    /// Per-link probability of carrying a fault window.
    pub prob: f64,
    /// Capacity divisor for non-blackout windows: a faulted link
    /// serves at `1/slowdown` of its healthy rate. (~30 % of faulted
    /// links draw a full blackout instead.)
    pub slowdown: f64,
    /// Deadline armed on every task transfer while the plan is
    /// enabled. A flow still in the air past it is reaped and retried
    /// with backoff; an exhausted budget fails the attempt like a
    /// container crash.
    pub flow_timeout: SimNs,
    /// Whether reads may degrade down the storage tiers (IGFS → HDFS
    /// → S3) when the cache can't serve. Off = a blackout victim's
    /// read is a hard error (the ablation leg of fig10).
    pub degraded_tiers: bool,
    /// Cache nodes blacked out between the map and reduce phases
    /// (DRAM + PMEM contents dropped, node leaves the partition map).
    pub lose_cachenodes: Vec<usize>,
}

impl Default for NetFaultPlan {
    fn default() -> Self {
        NetFaultPlan {
            seed: 29,
            prob: 0.0,
            slowdown: 8.0,
            flow_timeout: SimNs::from_millis(250),
            degraded_tiers: true,
            lose_cachenodes: Vec::new(),
        }
    }
}

impl NetFaultPlan {
    /// An inert plan (the default for every `SystemConfig` preset).
    pub fn disabled() -> NetFaultPlan {
        NetFaultPlan::default()
    }

    /// Whether the plan can fault any link at all (and hence whether
    /// flow deadlines arm).
    pub fn enabled(&self) -> bool {
        self.prob > 0.0
    }

    /// Whether a cache-node blackout is armed — the driver only
    /// write-through-replicates intermediates to HDFS when it is, so
    /// blackout-free runs keep their exact legacy flow schedule.
    pub fn blackout_armed(&self) -> bool {
        !self.lose_cachenodes.is_empty()
    }

    /// The fault window for link index `i`: `Some((t0, t1, factor))`
    /// in seconds with `factor ∈ [0, 1)` (0 = blackout), or `None`
    /// for a healthy link. Pure function of `(seed, i)`.
    pub fn window_of(&self, i: usize) -> Option<(f64, f64, f64)> {
        if !self.enabled() {
            return None;
        }
        let mut rng = Rng::new(
            self.seed
                ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        if !rng.chance(self.prob) {
            return None;
        }
        // Windows sit inside the first simulated seconds — where the
        // benchmark jobs live — and last long enough to starve a
        // deadline but not the whole run.
        let t0 = 0.05 + 0.80 * rng.f64();
        let len = 0.15 + 0.50 * rng.f64();
        let factor = if rng.chance(0.30) {
            0.0
        } else {
            1.0 / self.slowdown.max(1.0)
        };
        Some((t0, t0 + len, factor))
    }

    /// The faultable links of a deployed topology, in the index order
    /// `window_of` is keyed by: each node's NIC in/out pair, then the
    /// WAN up/down pipes. Memory buses and storage device channels
    /// never fault — this models the *network*, the storage tiers get
    /// their own blackout path ([`crate::igfs::Igfs::fail_cache_node`]).
    pub fn links(topo: &Topology) -> Vec<ResourceId> {
        let mut links = Vec::with_capacity(2 * topo.n_nodes() + 2);
        for n in &topo.nodes {
            links.push(n.nic_in);
            links.push(n.nic_out);
        }
        links.push(topo.wan_up);
        links.push(topo.wan_down);
        links
    }

    /// Draw and install this plan's windows into the engine's flow
    /// simulator. Returns how many links got a window. Idempotent per
    /// deploy — `ClusterSpec::deploy` calls it exactly once.
    pub fn install(&self, topo: &Topology, engine: &mut Engine) -> usize {
        if !self.enabled() {
            return 0;
        }
        let mut installed = 0;
        for (i, link) in Self::links(topo).into_iter().enumerate() {
            if let Some((t0, t1, factor)) = self.window_of(i) {
                engine.flows.add_capacity_window(link, t0, t1, factor);
                installed += 1;
            }
        }
        installed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::TopologyBuilder;

    #[test]
    fn disabled_plan_is_inert() {
        let plan = NetFaultPlan::disabled();
        assert!(!plan.enabled());
        assert!(!plan.blackout_armed());
        assert_eq!(plan.window_of(0), None);
        let mut e = Engine::new();
        let t = TopologyBuilder { nodes: 3, ..Default::default() }
            .build(&mut e);
        assert_eq!(plan.install(&t, &mut e), 0);
        assert!(e.flows.capacity_windows().is_empty());
    }

    #[test]
    fn windows_are_deterministic_and_well_formed() {
        let plan = NetFaultPlan {
            prob: 0.7,
            ..NetFaultPlan::default()
        };
        let mut faulted = 0;
        let mut blackouts = 0;
        for i in 0..200 {
            let a = plan.window_of(i);
            assert_eq!(a, plan.window_of(i), "pure fn of (seed, i)");
            if let Some((t0, t1, f)) = a {
                faulted += 1;
                assert!(t0 >= 0.05 && t1 > t0 && t1 < 2.0, "{t0}..{t1}");
                assert!((0.0..1.0).contains(&f), "factor {f}");
                if f == 0.0 {
                    blackouts += 1;
                } else {
                    assert!((f - 1.0 / plan.slowdown).abs() < 1e-12);
                }
            }
        }
        // ~70 % of links fault, ~30 % of those black out.
        assert!((100..180).contains(&faulted), "{faulted}");
        assert!(blackouts > 10, "{blackouts}");
        // A different seed draws a different plan.
        let other = NetFaultPlan { seed: 30, ..plan.clone() };
        assert!(
            (0..200).any(|i| plan.window_of(i) != other.window_of(i)),
            "seed must matter"
        );
    }

    #[test]
    fn install_covers_nics_and_wan_only() {
        let mut e = Engine::new();
        let t = TopologyBuilder { nodes: 2, ..Default::default() }
            .build(&mut e);
        let links = NetFaultPlan::links(&t);
        assert_eq!(links.len(), 2 * 2 + 2);
        for m in &t.membus {
            assert!(!links.contains(m), "membus never faults");
        }
        let plan = NetFaultPlan { prob: 1.0, ..NetFaultPlan::default() };
        let n = plan.install(&t, &mut e);
        assert_eq!(n, links.len(), "prob=1 faults every link");
        assert_eq!(e.flows.capacity_windows().len(), n);
    }
}
