//! Open-loop serving: the arrival-driven face of the
//! [`JobServer`](super::JobServer) substrate.
//!
//! Where the closed-loop [`JobServer`](super::JobServer) co-runs a
//! fixed batch, [`OpenLoopServer`] drives the same shared [`Cluster`]
//! from an [`ArrivalConfig`] schedule: tenant instances arrive over
//! simulated hours, pass admission control, queue for an in-flight job
//! token in weighted-fair order, execute, and depart. Per-job sojourn
//! and queue-wait samples feed [`crate::util::stats`] percentile
//! summaries (p50/p99/p999) surfaced in
//! [`ServerResult::open_loop`](super::ServerResult::open_loop), and the
//! [`crate::faas::Controller`] autoscaler grows/shrinks the warm pool
//! against the observed arrival rate as the schedule unfolds.
//!
//! Admission is decided by a *plan-time estimator* — a bank of
//! `max_inflight` virtual servers with a configured service-time
//! constant, fronted by a weighted-fair waiting room
//! ([`crate::util::fairq::FairQueue`]) capped at `queue_cap`. Decisions
//! therefore depend only on `(schedule, config)`, never on measured
//! engine times: the admission/rejection sequence is identical at any
//! `{map,reduce}_workers` setting, which is half of the open-loop
//! determinism contract (the other half — byte-identical per-tenant
//! outputs — holds because rejected arrivals are never planned and
//! admitted ones keep their per-arrival data seed). A rejected arrival
//! is handed back via [`FairQueue::take_back`], which must leave no
//! stale vtime tag or drained-class entry behind; `ARCHITECTURE.md`
//! (Open-loop serving & autoscaling) walks the full pipeline.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::faas::HADOOP_RUNTIME;
use crate::igfs::CacheStats;
use crate::net::NodeId;
use crate::runtime::RtEngine;
use crate::sim::{SimNs, Stage};
use crate::util::fairq::FairQueue;
use crate::util::stats::{PercentileSummary, Percentiles};

use super::super::driver::{
    finalize_stage, plan_stage, stage_named_input, Cluster, PlannedStage,
    StageInput,
};
use super::super::types::{JobResult, SystemConfig};
use super::super::workload::Workload;
use super::arrivals::{Arrival, ArrivalConfig};
use super::{JobRun, ServerResult, TenantReport};

/// One admission-control verdict, in arrival order. The sequence of
/// these is part of the determinism contract: same seeds ⇒ the same
/// log at any worker-count setting (pinned by
/// `rust/tests/openloop_e2e.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct AdmissionDecision {
    /// Arrival offset from serve start.
    pub at: SimNs,
    /// Tenant instance that arrived.
    pub tenant: String,
    /// Tenant class the instance belongs to.
    pub class: String,
    /// `true` = admitted (immediately or queued); `false` = rejected.
    pub admitted: bool,
}

/// Per-tenant-class slice of the open-loop report.
#[derive(Clone, Debug)]
pub struct ClassReport {
    /// Tenant-class name.
    pub name: String,
    /// Arrivals offered by this class.
    pub offered: u64,
    /// Arrivals admitted (immediately or queued).
    pub admitted: u64,
    /// Arrivals bounced by admission control.
    pub rejected: u64,
    /// Sojourn (arrival → last reducer done) percentiles, ms.
    pub sojourn_ms: PercentileSummary,
}

/// The open-loop serving report carried in
/// [`ServerResult::open_loop`](super::ServerResult::open_loop).
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    /// Arrivals the schedule offered.
    pub offered: u64,
    /// Arrivals admitted (immediately or queued).
    pub admitted: u64,
    /// Arrivals bounced by admission control.
    pub rejected: u64,
    /// In-flight job budget admission ran against (after auto-sizing).
    pub max_inflight: usize,
    /// Schedule seed the serve ran with.
    pub arrival_seed: u64,
    /// Sojourn (arrival → last reducer done) percentiles, ms.
    pub sojourn_ms: PercentileSummary,
    /// Admission-to-start (arrival → job-token grant) percentiles, ms.
    pub queue_wait_ms: PercentileSummary,
    /// Per-class breakdown, in first-arrival order.
    pub classes: Vec<ClassReport>,
    /// The full admission log, in arrival order.
    pub decisions: Vec<AdmissionDecision>,
    /// Autoscaler scale-up decisions taken during the serve.
    pub scale_ups: u64,
    /// Autoscaler scale-down decisions taken during the serve.
    pub scale_downs: u64,
    /// Container cold starts across admitted jobs.
    pub cold_starts: u64,
    /// Container warm (pool-reuse) starts across admitted jobs.
    pub warm_starts: u64,
}

/// Long-lived arrival-driven service over one shared [`Cluster`]:
/// builds the schedule, admits, autoscales, runs one shared time
/// plane, and reports tail latency.
///
/// ```text
/// OpenLoopServer::new(&wc, cfg, 2 * MIB)
///     .serve(&mut cluster, &mut rt)
/// ```
pub struct OpenLoopServer<'a> {
    wl: &'a dyn Workload,
    cfg: SystemConfig,
    input_bytes: u64,
}

impl<'a> OpenLoopServer<'a> {
    /// A serve loop running `wl` for every admitted arrival over a
    /// shared staged input of `input_bytes` (arrival plane and
    /// autoscale policy come from `cfg.arrivals` / `cfg.autoscale`).
    pub fn new(
        wl: &'a dyn Workload,
        cfg: SystemConfig,
        input_bytes: u64,
    ) -> OpenLoopServer<'a> {
        OpenLoopServer { wl, cfg, input_bytes }
    }

    /// Serve the whole arrival schedule and report.
    ///
    /// Phase 0: generate the schedule and decide every admission with
    /// the plan-time estimator. Phase 1 (arrival order, serial): each
    /// admitted submission autoscales the warm pool, plans its data
    /// plane eagerly, and spawns an admitter proc that delays to its
    /// arrival instant, queues weighted-fair for a job token, opens the
    /// job's gate, and holds the token to completion. Phase 2: one
    /// `engine.run()`. Phase 3: finalize + percentile summaries.
    pub fn serve(
        &self,
        cluster: &mut Cluster,
        rt: &mut RtEngine,
    ) -> ServerResult {
        let arr = &self.cfg.arrivals;
        let schedule = arr.schedule();

        // In-flight budget: explicit, or auto-sized from the cluster's
        // aggregate invoker slots (a job wave holds several slots at
        // once, so budget a quarter of them as concurrent jobs).
        let total_slots: usize = (0..cluster.controller.n_invokers())
            .map(|i| {
                cluster
                    .engine
                    .pool_capacity(cluster.controller.slots_of(NodeId(i)))
            })
            .sum();
        let max_inflight = if arr.max_inflight == 0 {
            (total_slots / 4).max(1)
        } else {
            arr.max_inflight
        };

        // Phase 0 — admission, from the schedule alone.
        let (decisions, admitted_idx) =
            decide_admissions(&schedule, arr, max_inflight);

        // One shared read-only input for every admitted submission.
        let input_name = format!("openloop/{}/in", self.wl.name());
        let input = match stage_named_input(
            cluster,
            &self.cfg,
            self.wl,
            self.input_bytes,
            arr.seed,
            &input_name,
        ) {
            Ok(p) => p,
            Err(e) => {
                return ServerResult {
                    jobs: Vec::new(),
                    tenants: Vec::new(),
                    makespan: SimNs::ZERO,
                    failed: Some(format!("input staging failed: {e}")),
                    open_loop: None,
                }
            }
        };

        let t0 = cluster.engine.now();
        let job_tokens = cluster.engine.add_pool(max_inflight);
        let window_s = self.cfg.autoscale.window.as_secs_f64().max(1e-9);

        // Phase 1 — plan admitted submissions in arrival order.
        struct PlannedArrival {
            arrival: Arrival,
            gate: crate::sim::BarrierId,
            warm_at_admission: u64,
            stage: Result<PlannedStage, JobResult>,
        }
        let mut planned: Vec<PlannedArrival> =
            Vec::with_capacity(admitted_idx.len());
        let mut stage_ns = 0u32;
        // Trailing-window cursor: arrivals are time-ordered and
        // admissions are visited in arrival order, so the left edge of
        // the autoscale window only ever moves right — one pass over
        // the schedule instead of a rescan per admission.
        let mut win_lo = 0usize;
        for &i in &admitted_idx {
            let a = &schedule[i];
            // Elastic warm pool: observed offered rate over the
            // trailing window (pure function of the schedule).
            while win_lo < i
                && schedule[win_lo].at + self.cfg.autoscale.window < a.at
            {
                win_lo += 1;
            }
            let in_window = i - win_lo + 1;
            cluster.controller.autoscale(
                HADOOP_RUNTIME,
                in_window as f64 / window_s,
                &self.cfg.autoscale,
            );
            let warm_at_admission =
                cluster.controller.warm_count(HADOOP_RUNTIME) as u64;

            let class =
                cluster.rm.register_tenant(&a.tenant, a.share) as u32;
            cluster.engine.set_class_weight(class, a.share);
            stage_ns += 1;
            cluster.set_scope(class, stage_ns);
            let job = format!("{}/j{i:03}-{}", a.tenant, self.wl.name());
            let gate = cluster.engine.add_barrier(1);
            let stage = match plan_stage(
                cluster,
                &self.cfg,
                self.wl,
                &job,
                StageInput::Path(input.clone()),
                Some(gate),
                rt,
                a.seed,
            ) {
                Ok(p) => {
                    // The admitter: delays to its arrival instant,
                    // queues (weighted-fair by tenant class) for a job
                    // token, opens the gate the job's maps await, and
                    // holds the token until the job completes — so the
                    // backlog drains at `max_inflight` concurrency
                    // without ever deadlocking the fair queue.
                    cluster.engine.spawn_as(
                        &format!("{job}/admit"),
                        class,
                        vec![
                            Stage::Delay(a.at),
                            Stage::Acquire(job_tokens),
                            Stage::Arrive(gate),
                            Stage::Await(p.job_done),
                            Stage::Release(job_tokens),
                        ],
                    );
                    Ok(p)
                }
                Err(e) => Err(JobResult::failed(&job, &self.cfg.name, 0, e)),
            };
            planned.push(PlannedArrival {
                arrival: a.clone(),
                gate,
                warm_at_admission,
                stage,
            });
        }
        cluster.set_scope(0, 0);

        // Phase 2 — one shared time plane.
        let (engine_end, failed) = match cluster.engine.run() {
            Ok(end) => (end, None),
            Err(e) => (cluster.engine.now(), Some(e)),
        };

        // Phase 3 — finalize, sample, aggregate.
        let mut jobs: Vec<JobRun> = Vec::with_capacity(planned.len());
        let mut tenants: Vec<TenantReport> =
            Vec::with_capacity(planned.len());
        let mut sojourn = Percentiles::new();
        let mut queue_wait = Percentiles::new();
        let mut by_class: Vec<(String, Percentiles)> = Vec::new();
        let (mut cold, mut warm) = (0u64, 0u64);
        for pa in planned {
            let arrived = t0 + pa.arrival.at;
            let started = cluster
                .engine
                .barrier_opened_at(pa.gate)
                .unwrap_or(engine_end);
            let (jr, done) = match pa.stage {
                Ok(p) => {
                    let done = cluster
                        .engine
                        .barrier_opened_at(p.job_done)
                        .unwrap_or(engine_end);
                    let job = p.job.clone();
                    let cfg = p.cfg_name().to_string();
                    let jr = match finalize_stage(cluster, p, engine_end) {
                        Ok(jr) => jr,
                        Err(e) => JobResult::failed(&job, &cfg, 0, e),
                    };
                    (jr, done)
                }
                Err(jr) => (jr, engine_end),
            };
            let soj_ms =
                done.saturating_sub(arrived).as_secs_f64() * 1e3;
            sojourn.push(soj_ms);
            queue_wait
                .push(started.saturating_sub(arrived).as_secs_f64() * 1e3);
            match by_class
                .iter_mut()
                .find(|(n, _)| *n == pa.arrival.class)
            {
                Some((_, p)) => p.push(soj_ms),
                None => {
                    let mut p = Percentiles::new();
                    p.push(soj_ms);
                    by_class.push((pa.arrival.class.clone(), p));
                }
            }
            cold += jr.cold_starts;
            warm += jr.warm_starts;
            let cross_job_warm =
                jr.warm_starts.min(pa.warm_at_admission);
            tenants.push(tenant_report(&pa.arrival, &jr, done, cross_job_warm));
            jobs.push(JobRun {
                tenant: pa.arrival.tenant,
                stages: vec![jr],
                completion: done,
                cross_job_warm,
            });
        }

        let classes = class_reports(&schedule, &decisions, by_class);
        let report = OpenLoopReport {
            offered: schedule.len() as u64,
            admitted: admitted_idx.len() as u64,
            rejected: (schedule.len() - admitted_idx.len()) as u64,
            max_inflight,
            arrival_seed: arr.seed,
            sojourn_ms: sojourn.summary(),
            queue_wait_ms: queue_wait.summary(),
            classes,
            decisions,
            scale_ups: cluster.controller.scale_ups,
            scale_downs: cluster.controller.scale_downs,
            cold_starts: cold,
            warm_starts: warm,
        };
        ServerResult {
            jobs,
            tenants,
            makespan: engine_end.saturating_sub(t0),
            failed,
            open_loop: Some(report),
        }
    }
}

/// Decide every admission from the schedule alone: a bank of
/// `max_inflight` virtual servers (service time = `est_service`) with
/// a weighted-fair waiting room capped at `queue_cap`. Returns the
/// decision log plus the indices of admitted arrivals.
fn decide_admissions(
    schedule: &[Arrival],
    arr: &ArrivalConfig,
    max_inflight: usize,
) -> (Vec<AdmissionDecision>, Vec<usize>) {
    // Estimator class ids, in first-appearance order; weight = share.
    let mut classes: Vec<(String, u64)> = Vec::new();
    let est = arr.est_service.0.max(1);
    let mut servers: BinaryHeap<Reverse<u64>> =
        (0..max_inflight).map(|_| Reverse(0u64)).collect();
    let mut waiting: FairQueue<usize> = FairQueue::new();
    let mut backlog = 0usize;
    let mut decisions = Vec::with_capacity(schedule.len());
    let mut admitted_idx = Vec::new();
    for (i, a) in schedule.iter().enumerate() {
        let cid = match classes.iter().position(|(n, _)| n == &a.class) {
            Some(i) => i as u32,
            None => {
                classes.push((a.class.clone(), a.share));
                (classes.len() - 1) as u32
            }
        };
        let now = a.at.0;
        // Servers freeing before this arrival pick up waiters in
        // weighted-fair order.
        while backlog > 0 {
            let Some(&Reverse(free)) = servers.peek() else { break };
            if free > now {
                break;
            }
            servers.pop();
            let shares = &classes;
            waiting
                .pop(|c| shares.get(c as usize).map_or(1, |(_, s)| *s))
                .expect("backlog count tracks the fair queue");
            backlog -= 1;
            servers.push(Reverse(free + est));
        }
        let idle = backlog == 0
            && servers.peek().is_some_and(|&Reverse(f)| f <= now);
        let admitted = if idle {
            servers.pop();
            servers.push(Reverse(now + est));
            true
        } else if backlog < arr.queue_cap {
            waiting.push(cid, i);
            backlog += 1;
            true
        } else {
            // Saturated: the submission is handed straight back. The
            // push/take_back pair must leave zero residue in the fair
            // queue (no stale vtime tag, no drained-class entry) —
            // the regression `util::fairq` pins.
            waiting.push(cid, i);
            let bounced = waiting.take_back(cid);
            debug_assert_eq!(bounced, Some(i));
            false
        };
        if admitted {
            admitted_idx.push(i);
        }
        decisions.push(AdmissionDecision {
            at: a.at,
            tenant: a.tenant.clone(),
            class: a.class.clone(),
            admitted,
        });
    }
    (decisions, admitted_idx)
}

fn tenant_report(
    a: &Arrival,
    jr: &JobResult,
    done: SimNs,
    cross_job_warm: u64,
) -> TenantReport {
    let mut igfs = CacheStats::default();
    igfs.add(&jr.igfs);
    TenantReport {
        name: a.tenant.clone(),
        share: a.share,
        jobs: 1,
        completion: done,
        cold_starts: jr.cold_starts,
        warm_starts: jr.warm_starts,
        cross_job_warm,
        task_attempts: jr.task_attempts,
        recomputed_bytes: jr.recomputed_bytes,
        checkpoints: jr.checkpoints,
        checkpoint_overhead: jr.checkpoint_overhead,
        spec_backups: jr.spec_backups,
        spec_backup_wins: jr.spec_backup_wins,
        flow_timeouts: jr.flow_timeouts,
        degraded_reads: jr.degraded_reads,
        affinity_hits: jr.affinity_hits,
        locality_ratio: jr.locality_ratio,
        partition_skew: jr.partition_skew,
        hot_keys_split: jr.hot_keys_split,
        igfs,
    }
}

fn class_reports(
    schedule: &[Arrival],
    decisions: &[AdmissionDecision],
    mut by_class: Vec<(String, Percentiles)>,
) -> Vec<ClassReport> {
    let mut out: Vec<ClassReport> = Vec::new();
    for (a, d) in schedule.iter().zip(decisions) {
        let rep = match out.iter_mut().find(|r| r.name == a.class) {
            Some(r) => r,
            None => {
                out.push(ClassReport {
                    name: a.class.clone(),
                    offered: 0,
                    admitted: 0,
                    rejected: 0,
                    sojourn_ms: PercentileSummary::default(),
                });
                out.last_mut().unwrap()
            }
        };
        rep.offered += 1;
        if d.admitted {
            rep.admitted += 1;
        } else {
            rep.rejected += 1;
        }
    }
    for rep in &mut out {
        if let Some((_, p)) =
            by_class.iter_mut().find(|(n, _)| *n == rep.name)
        {
            rep.sojourn_ms = p.summary();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::arrivals::{ArrivalModel, TenantClass};
    use super::*;
    use crate::coordinator::ClusterSpec;
    use crate::util::bytes::MIB;
    use crate::workloads::WordCount;

    fn arrivals(rate: f64) -> ArrivalConfig {
        ArrivalConfig {
            model: ArrivalModel::Poisson { rate },
            seed: 42,
            horizon: SimNs::from_secs_f64(60.0),
            max_jobs: 12,
            classes: vec![
                TenantClass::new("an", 3, 3),
                TenantClass::new("batch", 1, 1),
            ],
            max_inflight: 2,
            queue_cap: 2,
            est_service: SimNs::from_secs_f64(2.0),
        }
    }

    #[test]
    fn estimator_rejects_only_past_the_backlog_cap() {
        // 6 simultaneous arrivals, 2 servers + 2 queue slots → the
        // first 4 admitted, the last 2 rejected, in arrival order.
        let arr = ArrivalConfig {
            model: ArrivalModel::Trace(vec![5, 5, 5, 5, 5, 5]),
            max_inflight: 2,
            queue_cap: 2,
            ..Default::default()
        };
        let sched = arr.schedule();
        let (dec, adm) = decide_admissions(&sched, &arr, 2);
        assert_eq!(adm, vec![0, 1, 2, 3]);
        assert_eq!(
            dec.iter().map(|d| d.admitted).collect::<Vec<_>>(),
            vec![true, true, true, true, false, false]
        );
        // Widely spaced arrivals all admit (servers free in between).
        let arr2 = ArrivalConfig {
            model: ArrivalModel::Trace(vec![0, 10_000, 20_000]),
            max_inflight: 1,
            queue_cap: 0,
            est_service: SimNs::from_secs_f64(2.0),
            ..Default::default()
        };
        let sched2 = arr2.schedule();
        let (_, adm2) = decide_admissions(&sched2, &arr2, 1);
        assert_eq!(adm2.len(), 3);
    }

    #[test]
    fn serve_smoke_reports_open_loop() {
        let mut cfg = SystemConfig::marvel_igfs();
        cfg.map_workers = 2;
        cfg.reduce_workers = 2;
        cfg.arrivals = arrivals(1.0);
        let mut cluster = ClusterSpec::default().deploy(&cfg);
        cluster.stores.hdfs.block_size = 256 * 1024;
        let mut rt = RtEngine::load(None).unwrap();
        let wc = WordCount::new(800, 1.07, &rt);
        let res =
            OpenLoopServer::new(&wc, cfg, MIB).serve(&mut cluster, &mut rt);
        assert!(res.ok(), "{:?}", res.failed);
        let ol = res.open_loop.as_ref().expect("open-loop report");
        assert!(ol.offered > 0);
        assert_eq!(ol.offered, ol.admitted + ol.rejected);
        assert_eq!(ol.decisions.len(), ol.offered as usize);
        assert_eq!(res.jobs.len(), ol.admitted as usize);
        assert_eq!(res.tenants.len(), ol.admitted as usize);
        // Every admitted job produced bytes and a positive sojourn.
        assert!(res.jobs.iter().all(|j| j.ok()));
        assert!(ol.sojourn_ms.p50 > 0.0);
        assert!(ol.sojourn_ms.p99 >= ol.sojourn_ms.p50);
        assert!(ol.sojourn_ms.p999 >= ol.sojourn_ms.p99);
        // Class mix reached the report.
        assert!(!ol.classes.is_empty());
        let offered: u64 = ol.classes.iter().map(|c| c.offered).sum();
        assert_eq!(offered, ol.offered);
    }
}
