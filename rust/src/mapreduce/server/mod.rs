//! Multi-tenant job server: N independent jobs (or chained
//! submissions) admitted by weighted-share tenants and co-run over ONE
//! shared [`Cluster`] — the paper's shared serverless substrate
//! (OpenWhisk controller + warm container pools + in-memory caching
//! layer serving many functions at once) made end-to-end, in the
//! Cloudburst/Faasm tradition of many tenants sharing caches and warm
//! compute. See `ARCHITECTURE.md` (Multi-tenancy) for the full design.
//!
//! What "shared" means here, concretely:
//!
//! * **Compute.** All jobs' task procs enter the same DES engine and
//!   contend on the same invoker slot pools, which drain waiters in
//!   weighted-fair order by tenant class ([`crate::util::fairq`]) — so
//!   a 3-share tenant's container waves interleave 3:1 against a
//!   1-share tenant's, with preemption-free backfill when anyone idles.
//!   Virtual completion times therefore reflect real contention.
//! * **Warm containers.** The controller's per-node pools survive
//!   across jobs: every job runs the one shared Hadoop runtime image,
//!   so containers warmed (or pre-warmed) by an earlier job serve a
//!   later job's invocations warm. Cold starts happen only on first
//!   touch; per-job warm/cold splits land in each [`JobResult`], and
//!   the cross-job share in [`JobRun`]'s `cross_job_warm`.
//! * **State.** IGFS tiers, HDFS, and S3 are shared with key-prefix
//!   namespacing (every key starts with the job id), so co-tenants
//!   share DRAM/PMEM capacity and evict each other under pressure —
//!   measured per tenant via the `CacheStats` delta in each job's
//!   result.
//!
//! Determinism contract: per-tenant *outputs* are byte-identical to
//! the same jobs run solo, at any `{map,reduce}_workers` setting and
//! any admission order. The data planes run eagerly at admission
//! (fanned out through `pool_run`); only virtual *times* depend on
//! shares and co-location. Pinned by `rust/tests/multi_tenant.rs`.
//!
//! Beyond the closed-loop batch above, the server also runs *open
//! loop*: [`arrivals`] generates seed-driven arrival schedules
//! (Poisson / ramp / trace replay over tenant classes) and
//! [`open_loop::OpenLoopServer`] drives admission control, weighted-
//! fair job queueing, and elastic warm-pool autoscaling off them,
//! reporting p50/p99/p999 sojourn in [`ServerResult::open_loop`]. See
//! `ARCHITECTURE.md` (Open-loop serving & autoscaling).

pub mod arrivals;
pub mod open_loop;

pub use arrivals::{Arrival, ArrivalConfig, ArrivalModel, TenantClass};
pub use open_loop::{
    AdmissionDecision, ClassReport, OpenLoopReport, OpenLoopServer,
};

use crate::faas::HADOOP_RUNTIME;
use crate::igfs::CacheStats;
use crate::runtime::RtEngine;
use crate::sim::SimNs;

use super::driver::{
    finalize_stage, plan_stage, Cluster, PlannedStage, StageInput,
};
use super::shuffle::output_key;
use super::types::{JobResult, SystemConfig};
use super::workload::Workload;

/// One stage of a submission: a workload and the system config it runs
/// under (stores may differ per stage).
pub struct ChainStage<'a> {
    pub wl: &'a dyn Workload,
    pub cfg: SystemConfig,
}

/// A tenant's admission ticket: one job (single stage) or a chain of
/// stages where stage *k+1* reads stage *k*'s reducer outputs through
/// the IGFS handoff chain, gated on its completion barrier.
pub struct Submission<'a> {
    pub tenant: String,
    pub stages: Vec<ChainStage<'a>>,
    /// Staged input path feeding stage 0 (stage it with
    /// `stage_named_input` so co-tenants' inputs cannot collide).
    pub input: String,
    /// Data-plane seed — the same seed solo reproduces the same bytes.
    pub seed: u64,
}

/// Admission-and-execution layer over one shared cluster.
///
/// ```text
/// JobServer::new()
///     .tenant("alice", 3)
///     .tenant("bob", 1)
///     .job("alice", &wc, cfg.clone(), &input_a, seed)
///     .job("bob", &grep, cfg, &input_b, seed)
///     .run(&mut cluster, &mut rt)
/// ```
pub struct JobServer<'a> {
    tenants: Vec<(String, u64)>,
    subs: Vec<Submission<'a>>,
}

/// One submission's outcome: per-stage reports plus its virtual
/// completion instant on the shared clock.
#[derive(Clone, Debug)]
pub struct JobRun {
    pub tenant: String,
    /// Per-stage reports in chain order (single jobs have one).
    pub stages: Vec<JobResult>,
    /// Virtual time at which the last stage's reducers all finished.
    pub completion: SimNs,
    /// Cross-job warm reuse, measured as the warm-container stock
    /// that earlier jobs (or deployment prewarm) had left available at
    /// this submission's admission, capped by the warm starts it
    /// actually recorded. An upper bound on true cross-job reuse —
    /// containers carry no per-job provenance, so stock reused by
    /// later intra-job waves is not distinguished. Zero admission
    /// stock always reports zero.
    pub cross_job_warm: u64,
}

impl JobRun {
    pub fn ok(&self) -> bool {
        self.stages.iter().all(|s| s.ok())
    }

    pub fn final_stage(&self) -> Option<&JobResult> {
        self.stages.last()
    }
}

/// Per-tenant aggregate over all of the tenant's submissions.
#[derive(Clone, Debug)]
pub struct TenantReport {
    pub name: String,
    pub share: u64,
    /// Submissions this tenant ran.
    pub jobs: usize,
    /// Latest completion among the tenant's submissions.
    pub completion: SimNs,
    pub cold_starts: u64,
    pub warm_starts: u64,
    pub cross_job_warm: u64,
    /// Container attempts across the tenant's tasks (== tasks unless
    /// failure injection forced re-executions).
    pub task_attempts: u64,
    /// Bytes of task work this tenant lost to injected crashes and
    /// recomputed.
    pub recomputed_bytes: u64,
    /// Checkpoints the tenant's tasks wrote into the state store.
    pub checkpoints: u64,
    /// Virtual time the tenant's tasks spent writing them.
    pub checkpoint_overhead: SimNs,
    /// Speculative backup attempts launched for the tenant's tasks
    /// (charged to its own fair-share class).
    pub spec_backups: u64,
    /// Races those backups won (the original was cancelled).
    pub spec_backup_wins: u64,
    /// Flow deadlines this tenant's tasks blew through (each one a
    /// transport-level retry, not a task attempt).
    pub flow_timeouts: u64,
    /// Reads a lower storage tier served after a cache blackout.
    pub degraded_reads: u64,
    /// Tasks the placement strategy landed on a node named in their
    /// locality hints (replica holders / handoff-key owners), summed
    /// over the tenant's stages.
    pub affinity_hits: u64,
    /// Byte-weighted input locality across the tenant's stages: bytes
    /// read node-locally over all placed input bytes (0.0 when the
    /// tenant moved no input bytes).
    pub locality_ratio: f64,
    /// Worst shuffle imbalance among the tenant's stages (max of the
    /// per-stage p99/median partition-bytes coefficients; 1.0 = even).
    pub partition_skew: f64,
    /// Hot keys partition plans split across reducers, summed over the
    /// tenant's stages.
    pub hot_keys_split: u64,
    /// IGFS cache activity attributed to this tenant's planning —
    /// including evictions it inflicted on co-tenants under pressure.
    pub igfs: CacheStats,
}

/// Everything a co-run reports.
#[derive(Clone, Debug)]
pub struct ServerResult {
    /// One entry per submission, in admission order.
    pub jobs: Vec<JobRun>,
    /// One entry per registered tenant, in registration order.
    pub tenants: Vec<TenantReport>,
    /// Virtual time from first admission to last completion.
    pub makespan: SimNs,
    /// Engine-level failure (deadlock); per-job failures live in the
    /// individual [`JobResult`]s.
    pub failed: Option<String>,
    /// Open-loop serving report (admission log, tail percentiles,
    /// autoscaler activity). `None` for closed-loop co-runs; populated
    /// by [`OpenLoopServer::serve`].
    pub open_loop: Option<OpenLoopReport>,
}

impl ServerResult {
    pub fn ok(&self) -> bool {
        self.failed.is_none() && self.jobs.iter().all(|j| j.ok())
    }

    pub fn tenant(&self, name: &str) -> Option<&TenantReport> {
        self.tenants.iter().find(|t| t.name == name)
    }
}

impl<'a> Default for JobServer<'a> {
    fn default() -> Self {
        Self::new()
    }
}

impl<'a> JobServer<'a> {
    pub fn new() -> JobServer<'a> {
        JobServer { tenants: Vec::new(), subs: Vec::new() }
    }

    /// Register a tenant with a fair-share weight (builder style).
    /// Tenants referenced by [`JobServer::job`] without registration
    /// get share 1.
    pub fn tenant(mut self, name: &str, share: u64) -> Self {
        if let Some(t) = self.tenants.iter_mut().find(|t| t.0 == name) {
            t.1 = share.max(1);
        } else {
            self.tenants.push((name.to_string(), share.max(1)));
        }
        self
    }

    /// Admit a single-stage job for `tenant`.
    pub fn job(
        self,
        tenant: &str,
        wl: &'a dyn Workload,
        cfg: SystemConfig,
        input: &str,
        seed: u64,
    ) -> Self {
        self.chain(tenant, vec![ChainStage { wl, cfg }], input, seed)
    }

    /// Admit a multi-stage chain for `tenant`: stage *k+1* consumes
    /// stage *k*'s reducer outputs (IGFS-tier handoff) and its maps
    /// await stage *k*'s completion barrier on the shared clock.
    pub fn chain(
        mut self,
        tenant: &str,
        stages: Vec<ChainStage<'a>>,
        input: &str,
        seed: u64,
    ) -> Self {
        assert!(!stages.is_empty(), "submission needs at least one stage");
        if !self.tenants.iter().any(|t| t.0 == tenant) {
            self.tenants.push((tenant.to_string(), 1));
        }
        self.subs.push(Submission {
            tenant: tenant.to_string(),
            stages,
            input: input.to_string(),
            seed,
        });
        self
    }

    /// Number of admitted submissions.
    pub fn len(&self) -> usize {
        self.subs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.subs.is_empty()
    }

    /// Co-run every admitted submission over `cluster`.
    ///
    /// Phase 1 (admission order, serial): each stage's data plane runs
    /// eagerly and its task procs are spawned under the tenant's class.
    /// Phase 2: one `engine.run()` interleaves all jobs' time planes —
    /// slot pools arbitrate by share, flows fair-share bandwidth.
    /// Phase 3: per-job results are finalized off barrier timestamps.
    pub fn run(
        &self,
        cluster: &mut Cluster,
        rt: &mut RtEngine,
    ) -> ServerResult {
        // Tenant classes: yarn queue index == engine class (queue 0
        // stays the unscoped default). Flow-tag namespaces are assigned
        // per planned stage below, so per-job I/O stays separable.
        let mut classes: Vec<u32> = Vec::with_capacity(self.tenants.len());
        for (name, share) in &self.tenants {
            let id = cluster.rm.register_tenant(name, *share) as u32;
            cluster.engine.set_class_weight(id, *share);
            classes.push(id);
        }
        let class_of = |tenant: &str| -> u32 {
            self.tenants
                .iter()
                .position(|t| t.0 == tenant)
                .map(|i| classes[i])
                .unwrap_or(0)
        };

        let t0 = cluster.engine.now();
        // Phase 1 — plan (data planes + proc spawning), admission order.
        struct PlannedSub {
            tenant: String,
            warm_at_admission: u64,
            stages: Vec<Result<PlannedStage, JobResult>>,
        }
        let mut planned: Vec<PlannedSub> = Vec::with_capacity(self.subs.len());
        // Every planned stage gets its own flow-tag namespace so two
        // jobs of one tenant never conflate their I/O summaries; all
        // of a tenant's stages share one fair-share class.
        let mut stage_ns = 0u32;
        for (k, sub) in self.subs.iter().enumerate() {
            let class = class_of(&sub.tenant);
            let warm_at_admission =
                cluster.controller.warm_count(HADOOP_RUNTIME) as u64;
            let mut stages = Vec::with_capacity(sub.stages.len());
            let mut prev: Option<(String, usize, crate::sim::BarrierId)> =
                None;
            for (j, st) in sub.stages.iter().enumerate() {
                stage_ns += 1;
                cluster.set_scope(class, stage_ns);
                let job = format!(
                    "{}/j{k:02}/s{j:02}-{}",
                    sub.tenant,
                    st.wl.name()
                );
                let (stage_input, gate) = match &prev {
                    None => (StageInput::Path(sub.input.clone()), None),
                    Some((pjob, nr, done)) => (
                        StageInput::Handoff {
                            keys: (0..*nr)
                                .map(|i| output_key(pjob, i))
                                .collect(),
                        },
                        Some(*done),
                    ),
                };
                match plan_stage(
                    cluster, &st.cfg, st.wl, &job, stage_input, gate, rt,
                    sub.seed,
                ) {
                    Ok(p) => {
                        prev = Some((job, p.n_reduces(), p.job_done));
                        stages.push(Ok(p));
                    }
                    Err(e) => {
                        stages.push(Err(JobResult::failed(
                            &job,
                            &st.cfg.name,
                            0,
                            e,
                        )));
                        break; // downstream stages have no input
                    }
                }
            }
            planned.push(PlannedSub {
                tenant: sub.tenant.clone(),
                warm_at_admission,
                stages,
            });
        }
        cluster.set_scope(0, 0);

        // Phase 2 — one shared time plane.
        let (engine_end, failed) = match cluster.engine.run() {
            Ok(end) => (end, None),
            Err(e) => (cluster.engine.now(), Some(e)),
        };

        // Phase 3 — finalize per submission.
        let mut jobs: Vec<JobRun> = Vec::with_capacity(planned.len());
        for ps in planned {
            let mut stages = Vec::with_capacity(ps.stages.len());
            let mut completion = t0;
            let mut warm = 0u64;
            for st in ps.stages {
                let jr = match st {
                    Ok(p) => {
                        let done = cluster
                            .engine
                            .barrier_opened_at(p.job_done)
                            .unwrap_or(engine_end);
                        completion = completion.max(done);
                        let job = p.job.clone();
                        let cfg = p.cfg_name().to_string();
                        match finalize_stage(cluster, p, engine_end) {
                            Ok(jr) => jr,
                            Err(e) => JobResult::failed(&job, &cfg, 0, e),
                        }
                    }
                    Err(jr) => jr,
                };
                warm += jr.warm_starts;
                stages.push(jr);
            }
            jobs.push(JobRun {
                tenant: ps.tenant,
                stages,
                completion,
                cross_job_warm: warm.min(ps.warm_at_admission),
            });
        }

        // Per-tenant aggregates, registration order.
        let tenants = self
            .tenants
            .iter()
            .map(|(name, share)| {
                let mut rep = TenantReport {
                    name: name.clone(),
                    share: *share,
                    jobs: 0,
                    completion: t0,
                    cold_starts: 0,
                    warm_starts: 0,
                    cross_job_warm: 0,
                    task_attempts: 0,
                    recomputed_bytes: 0,
                    checkpoints: 0,
                    checkpoint_overhead: SimNs::ZERO,
                    spec_backups: 0,
                    spec_backup_wins: 0,
                    flow_timeouts: 0,
                    degraded_reads: 0,
                    affinity_hits: 0,
                    locality_ratio: 0.0,
                    partition_skew: 1.0,
                    hot_keys_split: 0,
                    igfs: CacheStats::default(),
                };
                // Byte-weighted locality across stages: a stage's ratio
                // is local/placed input bytes, and placed == the
                // stage's input bytes, so weighting by input recovers
                // the tenant-level byte ratio.
                let mut local_bytes = 0.0f64;
                let mut placed_bytes = 0.0f64;
                for run in jobs.iter().filter(|r| &r.tenant == name) {
                    rep.jobs += 1;
                    rep.completion = rep.completion.max(run.completion);
                    rep.cross_job_warm += run.cross_job_warm;
                    for s in &run.stages {
                        rep.cold_starts += s.cold_starts;
                        rep.warm_starts += s.warm_starts;
                        rep.task_attempts += s.task_attempts;
                        rep.recomputed_bytes += s.recomputed_bytes;
                        rep.checkpoints += s.checkpoints;
                        rep.checkpoint_overhead += s.checkpoint_overhead;
                        rep.spec_backups += s.spec_backups;
                        rep.spec_backup_wins += s.spec_backup_wins;
                        rep.flow_timeouts += s.flow_timeouts;
                        rep.degraded_reads += s.degraded_reads;
                        rep.affinity_hits += s.affinity_hits;
                        rep.partition_skew =
                            rep.partition_skew.max(s.partition_skew);
                        rep.hot_keys_split += s.hot_keys_split;
                        local_bytes +=
                            s.locality_ratio * s.input_bytes as f64;
                        placed_bytes += s.input_bytes as f64;
                        rep.igfs.add(&s.igfs);
                    }
                }
                if placed_bytes > 0.0 {
                    rep.locality_ratio = local_bytes / placed_bytes;
                }
                rep
            })
            .collect();

        ServerResult {
            jobs,
            tenants,
            makespan: engine_end.saturating_sub(t0),
            failed,
            open_loop: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ClusterSpec;
    use crate::mapreduce::stage_named_input;
    use crate::util::bytes::MIB;
    use crate::workloads::WordCount;

    fn cfg() -> SystemConfig {
        let mut c = SystemConfig::marvel_igfs();
        c.map_workers = 2;
        c.reduce_workers = 2;
        c
    }

    #[test]
    fn two_tenants_co_run_one_cluster() {
        let base = cfg();
        let mut cluster = ClusterSpec::default().deploy(&base);
        cluster.stores.hdfs.block_size = 256 * 1024;
        let mut rt = RtEngine::load(None).unwrap();
        let wc = WordCount::new(2000, 1.07, &rt);
        let in_a = stage_named_input(&mut cluster, &base, &wc, 2 * MIB, 7,
                                     "alice/in").unwrap();
        let in_b = stage_named_input(&mut cluster, &base, &wc, 2 * MIB, 7,
                                     "bob/in").unwrap();
        let res = JobServer::new()
            .tenant("alice", 3)
            .tenant("bob", 1)
            .job("alice", &wc, base.clone(), &in_a, 7)
            .job("bob", &wc, base.clone(), &in_b, 7)
            .run(&mut cluster, &mut rt);
        assert!(res.ok(), "{:?}", res.failed);
        assert_eq!(res.jobs.len(), 2);
        assert_eq!(res.tenants.len(), 2);
        for run in &res.jobs {
            let jr = run.final_stage().unwrap();
            assert!(jr.output_bytes > 0, "{}", jr.job);
            assert!(jr.igfs.hits_dram > 0, "per-tenant cache stats");
            assert!(run.completion > SimNs::ZERO);
        }
        // Shared warm pools: the second admission reuses containers the
        // first one (or deployment prewarm) left warm.
        assert!(res.jobs[1].cross_job_warm > 0);
        // Both tenants' completions are on one shared clock; the co-run
        // makespan covers the later one.
        let latest =
            res.jobs.iter().map(|r| r.completion).max().unwrap();
        assert_eq!(res.makespan, latest);
        assert_eq!(res.tenant("alice").unwrap().share, 3);
        assert!(res.tenant("alice").unwrap().completion > SimNs::ZERO);
    }

    #[test]
    fn chained_submission_hands_off_between_stages() {
        use crate::workloads::PageRank;
        let base = cfg();
        let mut cluster = ClusterSpec::default().deploy(&base);
        cluster.stores.hdfs.block_size = 256 * 1024;
        let mut rt = RtEngine::load(None).unwrap();
        let wc = WordCount::new(2000, 1.07, &rt);
        let pr = PageRank::new();
        let input = stage_named_input(&mut cluster, &base, &wc, 2 * MIB, 7,
                                      "carol/in").unwrap();
        let res = JobServer::new()
            .tenant("carol", 2)
            .chain(
                "carol",
                vec![
                    ChainStage { wl: &wc, cfg: base.clone() },
                    ChainStage { wl: &pr, cfg: base.clone() },
                ],
                &input,
                7,
            )
            .run(&mut cluster, &mut rt);
        assert!(res.ok(), "{:?}", res.failed);
        let run = &res.jobs[0];
        assert_eq!(run.stages.len(), 2);
        // Stage 1 resolved its input through the handoff chain.
        assert!(run.stages[1].handoff.resolved() > 0,
                "{:?}", run.stages[1].handoff);
        // Chain stages are serialized on the virtual clock.
        assert!(run.stages[1].job_time >= run.stages[0].job_time,
                "downstream stage waited on the gate");
    }

    #[test]
    fn unregistered_tenant_defaults_to_share_one() {
        let s = JobServer::new();
        assert!(s.is_empty());
        let base = cfg();
        let mut cluster = ClusterSpec::default().deploy(&base);
        let mut rt = RtEngine::load(None).unwrap();
        let wc = WordCount::new(500, 1.07, &rt);
        let input = stage_named_input(&mut cluster, &base, &wc, MIB, 3,
                                      "dave/in").unwrap();
        let res = JobServer::new()
            .job("dave", &wc, base.clone(), &input, 3)
            .run(&mut cluster, &mut rt);
        assert!(res.ok(), "{:?}", res.failed);
        assert_eq!(res.tenant("dave").unwrap().share, 1);
        assert_eq!(res.jobs.len(), 1);
    }
}
