//! Seed-driven deterministic arrival models for the open-loop
//! [`JobServer`](super::JobServer) service plane.
//!
//! A closed-loop co-run admits a fixed batch up front; production FaaS
//! traffic instead *arrives* — tenants appear, submit, and depart over
//! simulated hours. This module turns an [`ArrivalConfig`] into a
//! concrete [`Arrival`] schedule: interarrival gaps drawn from a
//! Poisson process, a linear ramp, or a replayed trace, with each
//! arrival assigned to a tenant class by a weighted mix draw and given
//! a fresh tenant-instance identity plus its own data-plane seed.
//!
//! Everything here is a pure function of `(config, seed)` through
//! [`crate::util::rng::Rng`]: the schedule — times, tenant names,
//! classes, and per-arrival seeds — is byte-identical across runs,
//! platforms, and `{map,reduce}_workers` settings. That is the root of
//! the open-loop determinism contract (`ARCHITECTURE.md`, Open-loop
//! serving & autoscaling).

use crate::sim::SimNs;
use crate::util::rng::Rng;

/// How interarrival gaps are drawn.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalModel {
    /// Memoryless arrivals at a constant `rate` (jobs per virtual
    /// second). `rate <= 0` disables the open-loop plane.
    Poisson {
        /// Mean arrival rate in jobs per virtual second.
        rate: f64,
    },
    /// Incremental ramp: the instantaneous rate moves linearly from
    /// `rate` at t=0 to `rate_end` at the horizon — the sweep shape
    /// that walks a server into (or out of) saturation within one run.
    Ramp {
        /// Rate at the start of the horizon (jobs per second).
        rate: f64,
        /// Rate at the end of the horizon (jobs per second).
        rate_end: f64,
    },
    /// Replay explicit arrival offsets (milliseconds since serve
    /// start). Offsets are used as given — not resorted — so a trace
    /// captured elsewhere replays verbatim.
    Trace(Vec<u64>),
}

/// One tenant class in the arrival mix: arrivals of this class get the
/// class's fair-share weight and count toward its admission totals.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantClass {
    /// Class name; tenant instances are named `{name}-{serial:03}`.
    pub name: String,
    /// Fair-share weight each instance runs under (yarn queue weight
    /// == engine class weight), floored at 1.
    pub share: u64,
    /// Relative arrival frequency of this class in the mix draw,
    /// floored at 1.
    pub mix: u64,
}

impl TenantClass {
    /// A class with equal share and mix weight 1.
    pub fn new(name: &str, share: u64, mix: u64) -> TenantClass {
        TenantClass {
            name: name.to_string(),
            share: share.max(1),
            mix: mix.max(1),
        }
    }
}

/// Open-loop arrival plane configuration (`[arrivals]` in TOML).
/// Disabled by default — `marvel serve` or an explicit config arms it.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrivalConfig {
    /// Interarrival model; `Poisson { rate: 0.0 }` means disabled.
    pub model: ArrivalModel,
    /// Schedule seed. Like the failure/straggler/netfault seeds it is
    /// inert until a serve loop arms it; `MARVEL_ARRIVAL_SEED`
    /// overrides the default via `SystemConfig::from_env`, and an
    /// explicit `[arrivals] seed` in a config file wins over both.
    pub seed: u64,
    /// Serve horizon: arrivals stop once the clock passes it.
    pub horizon: SimNs,
    /// Hard cap on offered jobs (backstop for high-rate sweeps).
    pub max_jobs: usize,
    /// Tenant-class mix; empty means one default class `t` with share
    /// and mix 1.
    pub classes: Vec<TenantClass>,
    /// In-flight job budget for admission control. 0 = auto-size from
    /// the cluster's aggregate invoker slots at serve time.
    pub max_inflight: usize,
    /// Waiting-room depth beyond the in-flight budget; an arrival that
    /// would push the backlog past this is rejected at admission.
    pub queue_cap: usize,
    /// Service-time estimate the admission estimator charges per job
    /// (virtual). Deliberately a config constant, never a measured
    /// time: admission decisions must not depend on worker counts.
    pub est_service: SimNs,
}

impl Default for ArrivalConfig {
    fn default() -> Self {
        ArrivalConfig {
            model: ArrivalModel::Poisson { rate: 0.0 },
            seed: 0xA221_7A1_5EED, // overridden by MARVEL_ARRIVAL_SEED
            horizon: SimNs::from_secs_f64(3600.0),
            max_jobs: 256,
            classes: Vec::new(),
            max_inflight: 0,
            queue_cap: 16,
            est_service: SimNs::from_secs_f64(2.0),
        }
    }
}

impl ArrivalConfig {
    /// Whether the open-loop plane is armed (a positive rate or a
    /// non-empty trace).
    pub fn enabled(&self) -> bool {
        match &self.model {
            ArrivalModel::Poisson { rate } => *rate > 0.0,
            ArrivalModel::Ramp { rate, rate_end } => {
                *rate > 0.0 || *rate_end > 0.0
            }
            ArrivalModel::Trace(t) => !t.is_empty(),
        }
    }

    /// Generate the arrival schedule — a pure function of this config
    /// and its seed. Arrival times are offsets from serve start.
    pub fn schedule(&self) -> Vec<Arrival> {
        let mut rng = Rng::new(self.seed);
        let default_class = [TenantClass::new("t", 1, 1)];
        let classes: &[TenantClass] = if self.classes.is_empty() {
            &default_class
        } else {
            &self.classes
        };
        let mix_total: u64 = classes.iter().map(|c| c.mix).sum();
        let mut serials = vec![0u64; classes.len()];
        let mut out = Vec::new();

        let mut push = |at: SimNs, rng: &mut Rng, out: &mut Vec<Arrival>,
                        serials: &mut [u64]| {
            // Weighted class draw, then a fresh instance identity and
            // an independent data-plane seed for the submission.
            let mut x = rng.below(mix_total);
            let mut ci = classes.len() - 1;
            for (i, c) in classes.iter().enumerate() {
                if x < c.mix {
                    ci = i;
                    break;
                }
                x -= c.mix;
            }
            let c = &classes[ci];
            serials[ci] += 1;
            out.push(Arrival {
                at,
                tenant: format!("{}-{:03}", c.name, serials[ci]),
                class: c.name.clone(),
                share: c.share,
                seed: rng.next_u64(),
            });
        };

        match &self.model {
            ArrivalModel::Trace(offsets) => {
                for &ms in offsets.iter().take(self.max_jobs) {
                    let at = SimNs::from_millis(ms);
                    if at > self.horizon {
                        break;
                    }
                    push(at, &mut rng, &mut out, &mut serials);
                }
            }
            model => {
                let mut t = SimNs::ZERO;
                while out.len() < self.max_jobs {
                    let rate = match model {
                        ArrivalModel::Poisson { rate } => *rate,
                        ArrivalModel::Ramp { rate, rate_end } => {
                            let f = if self.horizon > SimNs::ZERO {
                                (t.as_secs_f64()
                                    / self.horizon.as_secs_f64())
                                .min(1.0)
                            } else {
                                1.0
                            };
                            rate + (rate_end - rate) * f
                        }
                        ArrivalModel::Trace(_) => unreachable!(),
                    };
                    if rate <= 0.0 {
                        break;
                    }
                    let gap = rng.exp(1.0 / rate);
                    t += SimNs::from_secs_f64(gap);
                    if t > self.horizon {
                        break;
                    }
                    push(t, &mut rng, &mut out, &mut serials);
                }
            }
        }
        out
    }
}

/// One offered submission on the open-loop schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct Arrival {
    /// Offset from serve start on the virtual clock.
    pub at: SimNs,
    /// Fresh tenant-instance identity (`{class}-{serial:03}`) — each
    /// arrival is its own tenant; it departs when its job completes.
    pub tenant: String,
    /// Tenant-class name the instance was drawn from.
    pub class: String,
    /// Fair-share weight the instance runs under.
    pub share: u64,
    /// Data-plane seed for the submission (same seed solo reproduces
    /// the same bytes).
    pub seed: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson(rate: f64) -> ArrivalConfig {
        ArrivalConfig {
            model: ArrivalModel::Poisson { rate },
            seed: 7,
            horizon: SimNs::from_secs_f64(100.0),
            max_jobs: 10_000,
            ..Default::default()
        }
    }

    #[test]
    fn disabled_by_default() {
        let cfg = ArrivalConfig::default();
        assert!(!cfg.enabled());
        assert!(cfg.schedule().is_empty());
    }

    #[test]
    fn schedule_is_a_pure_function_of_the_seed() {
        let cfg = poisson(2.0);
        let a = cfg.schedule();
        let b = cfg.schedule();
        assert_eq!(a, b);
        assert!(!a.is_empty());
        let other = ArrivalConfig { seed: 8, ..poisson(2.0) };
        assert_ne!(a, other.schedule(), "seed must matter");
    }

    #[test]
    fn poisson_rate_is_roughly_honored() {
        // 100 s at 2 jobs/s → ~200 arrivals; Poisson sd ≈ 14, so a
        // ±35% band is loose enough to never flake on a fixed seed.
        let n = poisson(2.0).schedule().len();
        assert!((130..=270).contains(&n), "{n} arrivals at rate 2");
    }

    #[test]
    fn arrivals_are_time_ordered_and_capped() {
        let mut cfg = poisson(5.0);
        cfg.max_jobs = 37;
        let sched = cfg.schedule();
        assert_eq!(sched.len(), 37);
        for w in sched.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert!(sched.iter().all(|a| a.at <= cfg.horizon));
    }

    #[test]
    fn ramp_accelerates_toward_the_horizon() {
        let cfg = ArrivalConfig {
            model: ArrivalModel::Ramp { rate: 0.5, rate_end: 8.0 },
            seed: 11,
            horizon: SimNs::from_secs_f64(100.0),
            max_jobs: 10_000,
            ..Default::default()
        };
        let sched = cfg.schedule();
        let mid = SimNs::from_secs_f64(50.0);
        let first_half = sched.iter().filter(|a| a.at <= mid).count();
        let second_half = sched.len() - first_half;
        assert!(
            2 * second_half > 3 * first_half,
            "ramp should backload: {first_half} vs {second_half}"
        );
    }

    #[test]
    fn trace_replays_verbatim() {
        let cfg = ArrivalConfig {
            model: ArrivalModel::Trace(vec![10, 250, 4000]),
            ..Default::default()
        };
        assert!(cfg.enabled());
        let sched = cfg.schedule();
        assert_eq!(sched.len(), 3);
        assert_eq!(sched[0].at, SimNs::from_millis(10));
        assert_eq!(sched[2].at, SimNs::from_millis(4000));
    }

    #[test]
    fn class_mix_and_instance_identities() {
        let cfg = ArrivalConfig {
            classes: vec![
                TenantClass::new("analytics", 3, 3),
                TenantClass::new("batch", 1, 1),
            ],
            ..poisson(4.0)
        };
        let sched = cfg.schedule();
        let an = sched.iter().filter(|a| a.class == "analytics").count();
        let ba = sched.len() - an;
        assert!(an > ba, "3:1 mix skews to analytics: {an} vs {ba}");
        // Instance names are unique per arrival (fresh tenants), and
        // every analytics instance carries the class share.
        let mut names: Vec<&str> =
            sched.iter().map(|a| a.tenant.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), sched.len());
        assert!(sched
            .iter()
            .filter(|a| a.class == "analytics")
            .all(|a| a.share == 3 && a.tenant.starts_with("analytics-")));
    }
}
