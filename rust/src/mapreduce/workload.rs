//! The Workload abstraction: how a job's data plane behaves.
//!
//! `map_split` / `reduce_partition` operate on [`Payload`]s — real bytes
//! below the materialization cap, exact synthetic accounting above it.
//! Every workload must keep the two modes byte-consistent (cross-checked
//! by `tests/data_plane.rs`).

use crate::runtime::RtEngine;
use crate::storage::Payload;
use crate::util::rng::Rng;

use super::partition::{PartitionPlan, SplitMode};
use super::types::SystemConfig;

/// Output of one map task.
#[derive(Debug)]
pub struct MapOutput {
    /// Intermediate payload per reducer partition.
    pub partitions: Vec<Payload>,
    /// Records emitted (pre-combine tokens or combined aggregates).
    pub records: u64,
}

impl MapOutput {
    pub fn total_bytes(&self) -> u64 {
        self.partitions.iter().map(|p| p.len()).sum()
    }
}

/// Output of one reduce task.
#[derive(Debug)]
pub struct ReduceOutput {
    pub output: Payload,
    pub records: u64,
}

/// `Sync` is a supertrait so the driver's data-plane worker pool can
/// share one workload across map threads (the workloads are immutable
/// lookup tables + pure functions; all mutation lives in `RtEngine`,
/// which each worker owns privately).
pub trait Workload: Sync {
    fn name(&self) -> &str;

    /// Generate (or account for) the job's input and stage it as a
    /// payload of exactly `bytes`.
    fn generate_input(&self, bytes: u64, materialize: bool, rng: &mut Rng)
        -> Payload;

    /// Map one split into per-partition intermediate payloads,
    /// routing every emitted key through `plan` (`plan.parts()` is the
    /// reducer count; a [`PartitionPlan::hash`] plan reproduces the
    /// historical `key % parts` bit-for-bit).
    fn map_split(
        &self,
        split: &Payload,
        plan: &PartitionPlan,
        cfg: &SystemConfig,
        rt: &mut RtEngine,
        rng: &mut Rng,
    ) -> MapOutput;

    /// Reduce one partition from all mappers' payloads for it.
    /// `parts` is the total reducer count of the job.
    fn reduce_partition(
        &self,
        part: usize,
        parts: usize,
        inputs: &[Payload],
        cfg: &SystemConfig,
        rt: &mut RtEngine,
    ) -> ReduceOutput;

    /// Modeled map compute throughput (bytes of input per second per
    /// container) — calibrated constants recorded in EXPERIMENTS.md.
    fn map_rate(&self) -> f64;

    /// Modeled reduce compute throughput (bytes of intermediate/s).
    fn reduce_rate(&self) -> f64;

    /// Analytic key-weight distribution `(key, weight)` the planner
    /// feeds skew detection: deterministic, scale-free (only relative
    /// weights matter), and independent of materialization mode — e.g.
    /// the Zipf pmf a table generator samples fact keys from. The
    /// default (empty) means "no profile": skew-aware planning finds
    /// nothing hot and routes exactly like hash.
    fn key_profile(&self, _input_bytes: u64, _seed: u64) -> Vec<(u64, u64)> {
        Vec::new()
    }

    /// Size of the routed key space, for range planning (`0` =
    /// unknown/unbounded, which degrades `Range` to hash routing).
    fn key_domain(&self) -> u64 {
        0
    }

    /// Whether a skew-aware plan may spread one key's records across
    /// several reducers. Defaults to [`SplitMode::None`]: safe for any
    /// workload whose reduce needs all records of a key together.
    fn split_mode(&self) -> SplitMode {
        SplitMode::None
    }

    /// The merge workload that re-unifies partial aggregates after a
    /// [`SplitMode::Mergeable`] stage split hot keys. `JobPipeline`
    /// appends it as an extra stage when `hot_keys_split > 0`.
    fn unifier(&self) -> Option<&dyn Workload> {
        None
    }
}

/// Deterministic per-task RNG derivation.
pub fn task_rng(seed: u64, job: &str, task: u64) -> Rng {
    let jh = crate::util::hash::fnv1a64(job.as_bytes());
    Rng::new(seed ^ jh.rotate_left(17) ^ task.wrapping_mul(0x9E3779B97F4A7C15))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_rngs_independent() {
        let mut a = task_rng(1, "job", 0);
        let mut b = task_rng(1, "job", 1);
        let mut a2 = task_rng(1, "job", 0);
        assert_ne!(a.next_u64(), b.next_u64());
        let mut a = task_rng(1, "job", 0);
        assert_eq!(a.next_u64(), a2.next_u64());
    }
}
