//! Multi-stage stateful job pipelines — the paper's core claim made
//! end-to-end: chained MapReduce stages share intermediate results
//! through the in-memory caching layer instead of round-tripping
//! through remote storage (Cloudburst/Faasm-style stateful chaining).
//!
//! Stage *k+1*'s input is stage *k*'s reducer outputs, resolved through
//! the IGFS tiers at read time (DRAM hit → PMEM backing hit → HDFS →
//! S3 fallback — [`super::driver::StageInput::Handoff`]). After each
//! stage the pipeline checkpoints a completion record in the IGFS
//! state store (`crate::igfs::StateStore`); re-running on the same
//! cluster validates each checkpoint against the still-cached outputs
//! and skips every stage whose results survive — resumption from cached
//! state costs zero virtual time and zero recompute.
//!
//! Determinism: a pipeline's final output is byte-identical at any
//! `{map,reduce}_workers` setting, any IGFS capacity (eviction only
//! moves bytes between tiers), and any per-stage store choice — pinned
//! by `rust/tests/pipeline_stateful.rs`.

use crate::igfs::CacheStats;
use crate::runtime::RtEngine;
use crate::sim::SimNs;

use super::driver::{run_stage, Cluster, StageInput};
use super::shuffle::output_key;
use super::partition::Partitioner;
use super::types::{HandoffStats, JobResult, SystemConfig};
use super::workload::Workload;

/// One stage: a workload plus the system config it runs under (stores
/// may differ per stage — e.g. IGFS handoff mid-pipeline, durable HDFS
/// for the final output).
pub struct PipelineStage<'a> {
    pub wl: &'a dyn Workload,
    pub cfg: SystemConfig,
}

/// A named chain of MapReduce stages over one cluster.
pub struct JobPipeline<'a> {
    pub name: String,
    /// Attempt recorded on fresh checkpoints (a re-submitted pipeline
    /// bumps this; stale zombie checkpoints cannot clobber it).
    pub attempt: u32,
    pub stages: Vec<PipelineStage<'a>>,
}

/// Everything a pipeline run reports.
#[derive(Clone, Debug)]
pub struct PipelineResult {
    pub name: String,
    /// Per-stage reports, in stage order (checkpoint-skipped stages
    /// appear as empty reports carrying only `output_bytes`).
    pub stages: Vec<JobResult>,
    /// Per-stage merge reports: `Some` when a skew-split stage needed
    /// its unifier run as an appended merge stage (restored merge
    /// stages appear as empty reports carrying only `output_bytes`),
    /// `None` for the common unsplit case.
    pub merges: Vec<Option<JobResult>>,
    /// Whether each stage was restored from its checkpoint.
    pub restored: Vec<bool>,
    /// Stage-handoff tier resolution, summed over executed stages.
    pub handoff: HandoffStats,
    /// IGFS cache counters accumulated by this run.
    pub igfs: CacheStats,
    /// Virtual time the run added to the cluster's clock (restored
    /// stages are free — that is the point of cached state).
    pub job_time: SimNs,
    /// State-store checkpoints written / restores consumed by this run.
    pub checkpoints: u64,
    pub restores: u64,
    pub failed: Option<String>,
}

impl PipelineResult {
    pub fn ok(&self) -> bool {
        self.failed.is_none()
    }

    pub fn final_stage(&self) -> Option<&JobResult> {
        self.stages.last()
    }

    /// The report whose outputs a consumer of the pipeline would read:
    /// the last stage's merge when one ran, the stage itself otherwise.
    pub fn final_output(&self) -> Option<&JobResult> {
        match self.merges.last() {
            Some(Some(m)) => Some(m),
            _ => self.stages.last(),
        }
    }
}

const CP_MAGIC: &[u8; 4] = b"MPL2";

/// Checkpoint payload v2: magic, reducer count, total output bytes,
/// merge flag + the merge stage's reducer count and output bytes (all
/// zero when the stage's partition plan split nothing). v1 ("MPL1")
/// checkpoints fail the magic check and simply re-execute — the
/// determinism contract makes the rewrite byte-identical.
fn encode_checkpoint(
    n_reduces: usize,
    output_bytes: u64,
    merge: Option<(usize, u64)>,
) -> Vec<u8> {
    let mut v = Vec::with_capacity(29);
    v.extend_from_slice(CP_MAGIC);
    v.extend_from_slice(&(n_reduces as u32).to_le_bytes());
    v.extend_from_slice(&output_bytes.to_le_bytes());
    v.push(merge.is_some() as u8);
    let (mn, mb) = merge.unwrap_or((0, 0));
    v.extend_from_slice(&(mn as u32).to_le_bytes());
    v.extend_from_slice(&mb.to_le_bytes());
    v
}

type Checkpoint = (usize, u64, Option<(usize, u64)>);

fn decode_checkpoint(partial: &[u8]) -> Option<Checkpoint> {
    if partial.len() != 29 || &partial[..4] != CP_MAGIC {
        return None;
    }
    let n = u32::from_le_bytes(partial[4..8].try_into().unwrap()) as usize;
    let bytes = u64::from_le_bytes(partial[8..16].try_into().unwrap());
    let merge = match partial[16] {
        0 => None,
        _ => Some((
            u32::from_le_bytes(partial[17..21].try_into().unwrap())
                as usize,
            u64::from_le_bytes(partial[21..29].try_into().unwrap()),
        )),
    };
    Some((n, bytes, merge))
}

impl<'a> JobPipeline<'a> {
    pub fn new(name: &str) -> JobPipeline<'a> {
        JobPipeline {
            name: name.to_string(),
            attempt: 0,
            stages: Vec::new(),
        }
    }

    /// Append a stage (builder style).
    pub fn stage(mut self, wl: &'a dyn Workload, cfg: SystemConfig) -> Self {
        self.stages.push(PipelineStage { wl, cfg });
        self
    }

    /// The job name keying stage `k`'s shuffle and output data.
    pub fn stage_job(&self, k: usize) -> String {
        format!("{}/s{k:02}", self.name)
    }

    /// Bytes of stage output still resolvable through the handoff
    /// chain (`Stores::locate`) — must equal the committed total for
    /// the checkpoint to be trusted.
    fn available_output_bytes(
        cluster: &mut Cluster,
        job: &str,
        n_reduces: usize,
    ) -> u64 {
        (0..n_reduces)
            .map(|j| {
                cluster
                    .stores
                    .locate(&output_key(job, j))
                    .map_or(0, |(len, _)| len)
            })
            .sum()
    }

    /// Run (or resume) the pipeline. `input` is the staged path feeding
    /// stage 0; every later stage reads its predecessor's outputs
    /// through the IGFS tiers. `seed` drives all data-plane randomness.
    pub fn run(
        &self,
        cluster: &mut Cluster,
        rt: &mut RtEngine,
        seed: u64,
        input: &str,
    ) -> PipelineResult {
        let t0 = cluster.engine.now();
        let igfs0 = cluster.stores.igfs.stats();
        let cp0 = cluster.stores.igfs.state.checkpoints;
        let rs0 = cluster.stores.igfs.state.restores;
        let mut stages_out = Vec::new();
        let mut merges: Vec<Option<JobResult>> = Vec::new();
        let mut restored = Vec::new();
        let mut handoff = HandoffStats::default();
        let mut prev: Option<(String, usize)> = None;
        let mut failed = None;

        for (k, st) in self.stages.iter().enumerate() {
            let job = self.stage_job(k);
            // Resume: a decodable checkpoint whose outputs are still
            // fully resolvable lets the whole stage be skipped.
            let mjob = format!("{job}/m");
            let cp = cluster
                .stores
                .igfs
                .state
                .peek(&self.name, k as u32)
                .and_then(|ts| decode_checkpoint(&ts.partial));
            if let Some((nr, out_bytes, merge)) = cp {
                // Downstream consumers read the *final* outputs — the
                // merge stage's when one ran — so those are what must
                // still resolve for the checkpoint to be trusted.
                let (fjob, fnr, fbytes) = match merge {
                    Some((mn, mb)) => (mjob.clone(), mn, mb),
                    None => (job.clone(), nr, out_bytes),
                };
                let avail =
                    Self::available_output_bytes(cluster, &fjob, fnr);
                if avail == fbytes {
                    cluster.stores.igfs.state.restore(&self.name, k as u32);
                    let mut jr = JobResult::empty(&job, &st.cfg.name);
                    jr.output_bytes = out_bytes;
                    jr.reduce.tasks = nr;
                    stages_out.push(jr);
                    merges.push(merge.map(|(mn, mb)| {
                        let mut m = JobResult::empty(&mjob, &st.cfg.name);
                        m.output_bytes = mb;
                        m.reduce.tasks = mn;
                        m
                    }));
                    restored.push(true);
                    prev = Some((fjob, fnr));
                    continue;
                }
            }
            // Executing (or re-executing after an invalidated
            // checkpoint): scrub any stale shuffle/output keys first —
            // write-once backends (HDFS) reject colliding survivors,
            // and determinism makes the rewrite byte-identical anyway.
            cluster.stores.clear_prefix(&format!("{job}/"));
            let stage_input = match &prev {
                None => StageInput::Path(input.to_string()),
                Some((pjob, nr)) => StageInput::Handoff {
                    keys: (0..*nr).map(|j| output_key(pjob, j)).collect(),
                },
            };
            match run_stage(cluster, &st.cfg, st.wl, &job, stage_input, rt,
                            seed)
            {
                Ok(jr) => {
                    handoff.add(&jr.handoff);
                    // Skew-split stages owe a merge: the plan spread
                    // hot keys across reducers, so a key's partial
                    // aggregates sit on several of them — the
                    // workload's unifier re-unifies in one extra
                    // hash-partitioned stage over this stage's
                    // outputs. Unsplit runs skip this entirely.
                    let merge = match (jr.hot_keys_split, st.wl.unifier()) {
                        (n, Some(uw)) if n > 0 => {
                            let mut mcfg = st.cfg.clone();
                            mcfg.partition = Partitioner::Hash;
                            let m_in = StageInput::Handoff {
                                keys: (0..jr.reduce.tasks)
                                    .map(|j| output_key(&job, j))
                                    .collect(),
                            };
                            match run_stage(cluster, &mcfg, uw, &mjob,
                                            m_in, rt, seed)
                            {
                                Ok(mr) => {
                                    handoff.add(&mr.handoff);
                                    Some(mr)
                                }
                                Err(e) => {
                                    failed = Some(format!(
                                        "stage {k} merge ({}): {e}",
                                        uw.name()
                                    ));
                                    stages_out.push(jr);
                                    merges.push(None);
                                    restored.push(false);
                                    break;
                                }
                            }
                        }
                        _ => None,
                    };
                    // Record completion (covering the merge, which
                    // must re-run with the stage if either is lost);
                    // any prior (now-invalid) checkpoint is superseded
                    // by a higher attempt.
                    let att = cluster
                        .stores
                        .igfs
                        .state
                        .peek(&self.name, k as u32)
                        .map(|p| p.attempt + 1)
                        .unwrap_or(self.attempt);
                    let m_info = merge
                        .as_ref()
                        .map(|m| (m.reduce.tasks, m.output_bytes));
                    if let Err(e) = cluster.stores.igfs.state.checkpoint(
                        &self.name,
                        k as u32,
                        att,
                        jr.output_bytes,
                        encode_checkpoint(
                            jr.reduce.tasks,
                            jr.output_bytes,
                            m_info,
                        ),
                    ) {
                        failed = Some(format!("stage {k} checkpoint: {e}"));
                        stages_out.push(jr);
                        merges.push(merge);
                        restored.push(false);
                        break;
                    }
                    prev = Some(match &merge {
                        Some(m) => (mjob.clone(), m.reduce.tasks),
                        None => (job.clone(), jr.reduce.tasks),
                    });
                    stages_out.push(jr);
                    merges.push(merge);
                    restored.push(false);
                }
                Err(e) => {
                    failed =
                        Some(format!("stage {k} ({}): {e}", st.wl.name()));
                    break;
                }
            }
        }
        let now = cluster.stores.igfs.stats();
        PipelineResult {
            name: self.name.clone(),
            stages: stages_out,
            merges,
            restored,
            handoff,
            igfs: now.delta_since(&igfs0),
            job_time: cluster.engine.now() - t0,
            checkpoints: cluster.stores.igfs.state.checkpoints - cp0,
            restores: cluster.stores.igfs.state.restores - rs0,
            failed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let enc = encode_checkpoint(32, 123_456, None);
        assert_eq!(decode_checkpoint(&enc), Some((32, 123_456, None)));
        assert_eq!(decode_checkpoint(&enc[..8]), None);
        let mut bad = enc.clone();
        bad[0] = b'X';
        assert_eq!(decode_checkpoint(&bad), None);
        // Merged form carries the appended stage's shape too.
        let m = encode_checkpoint(8, 999, Some((4, 777)));
        assert_eq!(m.len(), enc.len(), "fixed 29-byte frame");
        assert_eq!(decode_checkpoint(&m), Some((8, 999, Some((4, 777)))));
        // A v1 (16-byte "MPL1") frame fails cleanly → stage re-runs.
        let mut v1 = b"MPL1".to_vec();
        v1.extend_from_slice(&32u32.to_le_bytes());
        v1.extend_from_slice(&123u64.to_le_bytes());
        assert_eq!(decode_checkpoint(&v1), None);
    }

    #[test]
    fn stage_jobs_are_disjoint() {
        let p = JobPipeline::new("pipe");
        assert_eq!(p.stage_job(0), "pipe/s00");
        assert_ne!(p.stage_job(1), p.stage_job(10));
    }
}
