//! MapReduce engine: job/system configuration, workload abstraction,
//! shuffle backends (S3 / HDFS / IGFS), and the driver that plans tasks,
//! runs the real data plane, and simulates the time plane.

pub mod driver;
pub mod shuffle;
pub mod types;
pub mod workload;

pub use driver::{map_splits_parallel, run_job, stage_input, Cluster};
pub use shuffle::{interm_key, output_key, Stores};
pub use types::{
    CombinerMode, JobResult, PhaseStats, Platform, SerFormat, StoreKind,
    SystemConfig,
};
pub use workload::{task_rng, MapOutput, ReduceOutput, Workload};
