//! MapReduce engine: job/system configuration, workload abstraction,
//! shuffle backends (S3 / HDFS / IGFS), the driver that plans tasks,
//! runs the real data plane, and simulates the time plane, and the
//! stateful multi-stage pipeline chaining jobs over cached state.

pub mod driver;
pub mod pipeline;
pub mod shuffle;
pub mod types;
pub mod workload;

pub use driver::{
    map_splits_parallel, reduce_partitions_parallel, run_job, run_stage,
    stage_input, Cluster, StageInput,
};
pub use pipeline::{JobPipeline, PipelineResult, PipelineStage};
pub use shuffle::{interm_key, output_key, KeyHome, Stores};
pub use types::{
    CombinerMode, HandoffStats, JobResult, PhaseStats, Platform, SerFormat,
    StoreKind, SystemConfig,
};
pub use workload::{task_rng, MapOutput, ReduceOutput, Workload};
