//! MapReduce engine: job/system configuration, workload abstraction,
//! shuffle backends (S3 / HDFS / IGFS), the driver that plans tasks,
//! runs the real data plane, and simulates the time plane, the
//! stateful multi-stage pipeline chaining jobs over cached state, and
//! the multi-tenant [`JobServer`] co-running N jobs over one shared
//! cluster — closed loop as a fixed batch, or open loop through
//! [`OpenLoopServer`] with seed-driven arrivals, admission control,
//! and elastic warm-pool autoscaling. See `ARCHITECTURE.md` (Layer 5,
//! and "Open-loop serving & autoscaling") for the execution model.

pub mod driver;
pub mod partition;
pub mod pipeline;
pub mod server;
pub mod shuffle;
pub mod types;
pub mod workload;

pub use driver::{
    finalize_stage, map_splits_parallel, plan_stage,
    reduce_partitions_parallel, run_job, run_stage, stage_input,
    stage_named_input, Cluster, PlannedStage, StageInput,
};
pub use partition::{
    record_salt, HotKey, PartitionPlan, Partitioner, SplitMode,
};
pub use pipeline::{JobPipeline, PipelineResult, PipelineStage};
pub use server::{
    AdmissionDecision, Arrival, ArrivalConfig, ArrivalModel, ChainStage,
    ClassReport, JobRun, JobServer, OpenLoopReport, OpenLoopServer,
    ServerResult, Submission, TenantClass, TenantReport,
};
pub use shuffle::{
    interm_key, interm_key_into, output_key, output_key_into, KeyHome,
    Stores,
};
pub use types::{
    CombinerMode, HandoffStats, JobResult, PhaseStats, Platform, SerFormat,
    SpeculationConfig, StoreKind, SystemConfig,
};
pub use workload::{task_rng, MapOutput, ReduceOutput, Workload};
// Placement lives in `yarn::placement`; re-exported here because it is
// configured through `SystemConfig` like every other job-level knob.
pub use crate::yarn::PlacementStrategy;
