//! Shuffle backends: where intermediate data lives and what it costs.
//!
//! This is the heart of the paper's comparison — the same MapReduce
//! data plane shuffled through (a) remote S3 objects (Corral), (b)
//! PMEM-backed HDFS files (Marvel-HDFS), or (c) the Ignite in-memory
//! cache (Marvel-IGFS).

use std::collections::HashMap;

use crate::hdfs::Hdfs;
use crate::igfs::Igfs;
use crate::metrics::tags;
use crate::net::{NodeId, Topology};
use crate::objstore::ObjectStore;
use crate::sim::{Engine, Stage};
use crate::storage::Payload;

use super::types::StoreKind;

/// All stores a cluster deployment provides; jobs borrow it.
///
/// Multi-tenancy: co-running jobs share these stores with *key-prefix
/// namespacing* — every shuffle/output key starts with the job id
/// ([`interm_key`]/[`output_key`]), so tenants share DRAM/PMEM
/// capacity (and evict each other under pressure) without ever
/// colliding on keys; `clear_prefix` scrubs one job's keys without
/// touching its co-tenants'. `tag_ns` stamps the tenant class on every
/// flow this struct emits so shared-cluster I/O stays attributable
/// (`crate::metrics::tags::scoped`).
pub struct Stores {
    pub hdfs: Hdfs,
    pub igfs: Igfs,
    pub s3: ObjectStore,
    /// Tenant class stamped on emitted flow tags (0 = unscoped).
    pub tag_ns: u32,
    /// Degraded-mode reads: a committed IGFS key the cache cannot
    /// serve (cache-node blackout) falls down the tiers — HDFS → S3 →
    /// checkpoint recompute — priced per serving tier and counted in
    /// `CacheStats::degraded_reads`, instead of erroring. Armed by the
    /// driver while a blackout plan with `degraded_tiers` is active;
    /// off (the default), such a read is the legacy "lost" error.
    pub degraded: bool,
    /// Write-through: IGFS intermediates also persist to HDFS (the
    /// paper's §4.3 "Ignite over PMEM" cache-over-store design) and a
    /// checkpoint copy is kept, so a blackout has somewhere to degrade
    /// *to*. Armed with a blackout plan; off keeps the legacy flow
    /// schedule bit-for-bit.
    pub write_through: bool,
    /// Integrity manifest: committed length per intermediate key.
    /// A read that comes back with a different length (or nothing at
    /// all for a committed key) is corruption and surfaces as `Err` —
    /// never as a silent miss.
    interm_len: HashMap<String, u64>,
    /// Checkpoint copies of written-through intermediates (zero-copy
    /// views) — the recompute source of last resort when *every*
    /// storage tier lost a sole-copy key.
    scratch: HashMap<String, Payload>,
    /// Per-partition shuffle-byte tallies for the stage currently
    /// being planned (reset by [`Stores::begin_partition_tally`]).
    /// The driver folds every intermediate write into this histogram
    /// and summarizes it as `JobResult::partition_skew`.
    partition_tally: Vec<u64>,
}

/// Key for one mapper's output for one partition.
pub fn interm_key(job: &str, map: usize, part: usize) -> String {
    let mut s = String::new();
    interm_key_into(&mut s, job, map, part);
    s
}

/// Format [`interm_key`] into a caller-owned buffer (cleared first).
/// The driver's shuffle loops run `n_maps × n_reduces` key formats per
/// stage; reusing one buffer keeps that hot path allocation-free
/// (regression lane: `key_format_reuse_ns` in the micro_hotpath bench).
pub fn interm_key_into(buf: &mut String, job: &str, map: usize, part: usize) {
    use std::fmt::Write as _;
    buf.clear();
    let _ = write!(buf, "{job}/shuffle/m{map:05}/p{part:03}");
}

/// Key for one reducer's final output.
pub fn output_key(job: &str, part: usize) -> String {
    let mut s = String::new();
    output_key_into(&mut s, job, part);
    s
}

/// Format [`output_key`] into a caller-owned buffer (cleared first).
pub fn output_key_into(buf: &mut String, job: &str, part: usize) {
    use std::fmt::Write as _;
    buf.clear();
    let _ = write!(buf, "{job}/out/p{part:03}");
}

/// Which store a key resolved in, probing the stage-handoff chain in
/// order: IGFS (either tier) → HDFS → S3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KeyHome {
    Igfs,
    Hdfs,
    S3,
}

impl Stores {
    pub fn new(hdfs: Hdfs, igfs: Igfs, s3: ObjectStore) -> Stores {
        Stores {
            hdfs,
            igfs,
            s3,
            tag_ns: 0,
            degraded: false,
            write_through: false,
            interm_len: HashMap::new(),
            scratch: HashMap::new(),
            partition_tally: Vec::new(),
        }
    }

    /// Reset the per-partition byte tallies for a stage with `parts`
    /// reduce partitions. Tallies are a pure planning statistic: they
    /// touch no store state and disturb no cache statistics.
    pub fn begin_partition_tally(&mut self, parts: usize) {
        self.partition_tally.clear();
        self.partition_tally.resize(parts, 0);
    }

    /// Fold one intermediate write of `len` bytes into partition `j`'s
    /// tally (out-of-range partitions are ignored defensively).
    pub fn tally_partition(&mut self, j: usize, len: u64) {
        if let Some(t) = self.partition_tally.get_mut(j) {
            *t += len;
        }
    }

    /// The per-partition shuffle-byte histogram of the current stage.
    pub fn partition_tallies(&self) -> &[u64] {
        &self.partition_tally
    }

    /// Probe the handoff resolution chain (IGFS tiers → HDFS → S3) for
    /// `key`: its stored length and which store holds it. The single
    /// source of truth for stage-handoff planning and checkpoint
    /// validation — keep any new tier here, not at the call sites.
    /// Disturbs no cache hit/miss statistics.
    pub fn locate(&mut self, key: &str) -> Option<(u64, KeyHome)> {
        if let Some(len) = self.igfs.len_of(key) {
            return Some((len, KeyHome::Igfs));
        }
        if let Some(inode) = self.hdfs.namenode.stat(key) {
            return Some((inode.len, KeyHome::Hdfs));
        }
        // Stat-free probe: `ObjectStore::get` would count a GET plus
        // the object's bytes against the store stats — a planning
        // probe must not (regression: `locate_disturbs_no_statistics`).
        self.s3.len_of(key).map(|len| (len, KeyHome::S3))
    }

    /// Delete every key under `prefix` from all three stores (and the
    /// intermediate-length manifest). A pipeline clears a stage's stale
    /// shuffle/output keys with this before re-executing it, so
    /// write-once backends (HDFS) cannot collide with survivors of an
    /// invalidated checkpoint. Returns the number of keys removed.
    pub fn clear_prefix(&mut self, prefix: &str) -> usize {
        let mut n = 0;
        let cached: Vec<String> = self
            .igfs
            .caches
            .values()
            .flat_map(|c| c.keys())
            .filter(|k| k.starts_with(prefix))
            .collect();
        for k in cached {
            if self.igfs.remove(&k) {
                n += 1;
            }
        }
        let files: Vec<String> = self
            .hdfs
            .namenode
            .list(prefix)
            .into_iter()
            .map(|inode| inode.path.clone())
            .collect();
        for p in files {
            if self.hdfs.delete(&p) {
                n += 1;
            }
        }
        for k in self.s3.list(prefix) {
            if self.s3.delete(&k) {
                n += 1;
            }
        }
        self.interm_len.retain(|k, _| !k.starts_with(prefix));
        self.scratch.retain(|k, _| !k.starts_with(prefix));
        n
    }

    /// Write an intermediate partition from `node`; returns stages.
    pub fn write_intermediate(
        &mut self,
        engine: &mut Engine,
        topo: &Topology,
        kind: StoreKind,
        node: NodeId,
        key: &str,
        data: Payload,
    ) -> Result<Vec<Stage>, String> {
        let tag = tags::scoped(tags::INTERMEDIATE_WRITE, self.tag_ns);
        self.interm_len.insert(key.to_string(), data.len());
        match kind {
            StoreKind::S3 => {
                let st =
                    self.s3.put_stages(engine, topo, node, data.len(), tag);
                self.s3.put(key, data);
                Ok(st)
            }
            StoreKind::Hdfs => self.hdfs.put(topo, node, key, data, tag),
            StoreKind::Igfs => {
                let mut st =
                    self.igfs.put(topo, node, key, data.clone(), tag);
                if self.write_through {
                    // Cache-over-store: persist the partition beneath
                    // the cache and keep a checkpoint view, so a later
                    // cache blackout has tiers to degrade to.
                    st.extend(self.hdfs.put(
                        topo,
                        node,
                        key,
                        data.clone(),
                        tag,
                    )?);
                    self.scratch.insert(key.to_string(), data);
                }
                Ok(st)
            }
        }
    }

    /// Degraded-mode fallback for a committed IGFS key the cache lost:
    /// HDFS → S3 → checkpoint recompute, in tier order. Each serving
    /// tier is priced with its own stages; the recompute leg restores
    /// the partition into the (surviving) cache so later readers hit
    /// it again. `None` means no tier holds the bytes — the caller's
    /// manifest check turns that into the "lost" error.
    fn degraded_read(
        &mut self,
        engine: &mut Engine,
        topo: &Topology,
        node: NodeId,
        key: &str,
        tag: u32,
    ) -> Option<(Payload, Vec<Stage>)> {
        if self.hdfs.namenode.stat(key).is_some() {
            // Blocks may be gone too (cache blackout composed with a
            // DataNode failure) — fall through rather than erroring.
            if let Ok((data, st, _, _)) = self.hdfs.read(topo, node, key, tag)
            {
                self.igfs.note_degraded(key);
                return Some((data, st));
            }
        }
        if let Some(data) = self.s3.get(key) {
            let st = self.s3.get_stages(engine, topo, node, data.len(), tag);
            self.igfs.note_degraded(key);
            return Some((data, st));
        }
        if let Some(data) = self.scratch.get(key).cloned() {
            let st = self.igfs.put(topo, node, key, data.clone(), tag);
            self.igfs.note_degraded(key);
            return Some((data, st));
        }
        None
    }

    /// Read an intermediate partition to `node`.
    ///
    /// `Ok(None)` means the key was never written — a mapper that
    /// emitted nothing for this partition, which the driver must treat
    /// as empty input. `Err` is a real store failure (e.g. an HDFS
    /// file whose blocks are gone from every DataNode) and must
    /// propagate; conflating the two silently drops corrupted data.
    pub fn read_intermediate(
        &mut self,
        engine: &mut Engine,
        topo: &Topology,
        kind: StoreKind,
        node: NodeId,
        key: &str,
    ) -> Result<Option<(Payload, Vec<Stage>)>, String> {
        let tag = tags::scoped(tags::INTERMEDIATE_READ, self.tag_ns);
        let got = match kind {
            StoreKind::S3 => match self.s3.get(key) {
                None => None,
                Some(data) => {
                    let st = self
                        .s3
                        .get_stages(engine, topo, node, data.len(), tag);
                    Some((data, st))
                }
            },
            StoreKind::Hdfs => {
                if self.hdfs.namenode.stat(key).is_none() {
                    None // never written in the namespace
                } else {
                    // Committed in the namespace: any read failure now
                    // is data loss/corruption and must surface.
                    let (data, st, _, _) =
                        self.hdfs.read(topo, node, key, tag)?;
                    Some((data, st))
                }
            }
            // IGFS demotes evicted entries to the backing tier instead
            // of dropping them, so a cache miss can only mean the key
            // was never stored (or lost — the manifest check below).
            StoreKind::Igfs => {
                let mut got = self.igfs.get(topo, node, key, tag);
                if got.is_none()
                    && self.degraded
                    && self.interm_len.contains_key(key)
                {
                    got = self.degraded_read(engine, topo, node, key, tag);
                }
                got
            }
        };
        // Integrity manifest: a committed key must come back with
        // exactly the committed length, whatever the backend.
        if let Some(&want) = self.interm_len.get(key) {
            match &got {
                None => {
                    return Err(format!(
                        "intermediate {key} lost: committed {want} \
                         bytes, store has none"
                    ));
                }
                Some((data, _)) if data.len() != want => {
                    return Err(format!(
                        "intermediate {key} corrupt: read {} bytes, \
                         committed {want}",
                        data.len()
                    ));
                }
                _ => {}
            }
        }
        Ok(got)
    }

    /// Write final output from `node`.
    pub fn write_output(
        &mut self,
        engine: &mut Engine,
        topo: &Topology,
        kind: StoreKind,
        node: NodeId,
        key: &str,
        data: Payload,
    ) -> Result<Vec<Stage>, String> {
        let tag = tags::scoped(tags::OUTPUT_WRITE, self.tag_ns);
        match kind {
            StoreKind::S3 => {
                let st =
                    self.s3.put_stages(engine, topo, node, data.len(), tag);
                self.s3.put(key, data);
                Ok(st)
            }
            StoreKind::Hdfs => self.hdfs.put(topo, node, key, data, tag),
            StoreKind::Igfs => Ok(self.igfs.put(topo, node, key, data, tag)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{DeviceRole, TopologyBuilder};
    use crate::objstore::ObjStoreConfig;
    use crate::sim::Engine;
    use crate::util::bytes::GIB;

    fn setup() -> (Engine, Topology, Stores) {
        let mut e = Engine::new();
        let t = TopologyBuilder { nodes: 2, ..Default::default() }
            .build(&mut e);
        let stores = Stores::new(
            Hdfs::new(&t, DeviceRole::Pmem, 1),
            Igfs::new(&t, GIB),
            ObjectStore::new(&mut e, &ObjStoreConfig::default()),
        );
        (e, t, stores)
    }

    #[test]
    fn roundtrip_every_backend() {
        let (mut e, t, mut s) = setup();
        for kind in [StoreKind::S3, StoreKind::Hdfs, StoreKind::Igfs] {
            let key = interm_key("wc", 0, 0);
            let key = format!("{kind:?}/{key}");
            let st = s
                .write_intermediate(&mut e, &t, kind, NodeId(0), &key,
                                    Payload::real(vec![7; 100]))
                .unwrap();
            e.spawn("w", st);
            let (data, st) = s
                .read_intermediate(&mut e, &t, kind, NodeId(1), &key)
                .unwrap()
                .expect("key just written");
            e.spawn("r", st);
            assert_eq!(data.len(), 100, "{kind:?}");
            assert_eq!(data.bytes().unwrap()[0], 7);
        }
        e.run().unwrap();
        // Flow log has both tags for all three backends.
        let tags_seen: std::collections::HashSet<u32> =
            e.flow_log.iter().map(|f| f.tag).collect();
        assert!(tags_seen.contains(&tags::INTERMEDIATE_WRITE));
        assert!(tags_seen.contains(&tags::INTERMEDIATE_READ));
    }

    #[test]
    fn missing_key_is_a_miss_not_an_error() {
        let (mut e, t, mut s) = setup();
        for kind in [StoreKind::S3, StoreKind::Hdfs, StoreKind::Igfs] {
            assert!(matches!(
                s.read_intermediate(&mut e, &t, kind, NodeId(0), "nope"),
                Ok(None)
            ), "{kind:?}");
        }
    }

    #[test]
    fn lost_hdfs_blocks_surface_as_error() {
        // A key committed in the namespace whose blocks vanished from
        // every DataNode is corruption, not an empty partition.
        let (mut e, t, mut s) = setup();
        s.write_intermediate(&mut e, &t, StoreKind::Hdfs, NodeId(0),
                             "doomed", Payload::real(vec![1; 64]))
            .unwrap();
        let blocks: Vec<_> = s.hdfs.namenode.stat("doomed").unwrap()
            .blocks.iter().map(|b| b.id).collect();
        for dn in s.hdfs.datanodes.values_mut() {
            for id in &blocks {
                dn.drop_block(*id);
            }
        }
        assert!(s
            .read_intermediate(&mut e, &t, StoreKind::Hdfs, NodeId(0),
                               "doomed")
            .is_err());
    }

    #[test]
    fn corrupted_intermediate_is_an_error_every_backend() {
        // A committed key whose stored bytes changed length behind the
        // manifest's back must read back as Err, not as data.
        let (mut e, t, mut s) = setup();
        for kind in [StoreKind::S3, StoreKind::Hdfs, StoreKind::Igfs] {
            let key = format!("{kind:?}/corrupt");
            s.write_intermediate(&mut e, &t, kind, NodeId(0), &key,
                                 Payload::real(vec![1; 64]))
                .unwrap();
            // Tamper through the raw store, bypassing the manifest.
            match kind {
                StoreKind::S3 => {
                    s.s3.put(&key, Payload::real(vec![9; 10]));
                }
                StoreKind::Hdfs => {
                    assert!(s.hdfs.delete(&key));
                    s.hdfs
                        .put(&t, NodeId(0), &key,
                             Payload::real(vec![9; 10]), 0)
                        .unwrap();
                }
                StoreKind::Igfs => {
                    s.igfs.put(&t, NodeId(0), &key,
                               Payload::real(vec![9; 10]), 0);
                }
            }
            let r = s.read_intermediate(&mut e, &t, kind, NodeId(1), &key);
            assert!(r.is_err(), "{kind:?} must surface corruption");
            assert!(r.unwrap_err().contains("corrupt"), "{kind:?}");
        }
    }

    #[test]
    fn lost_committed_intermediate_is_an_error_every_backend() {
        // Committed, then vanished entirely: Err, never Ok(None).
        let (mut e, t, mut s) = setup();
        for kind in [StoreKind::S3, StoreKind::Hdfs, StoreKind::Igfs] {
            let key = format!("{kind:?}/lost");
            s.write_intermediate(&mut e, &t, kind, NodeId(0), &key,
                                 Payload::real(vec![2; 32]))
                .unwrap();
            match kind {
                StoreKind::S3 => assert!(s.s3.delete(&key)),
                StoreKind::Hdfs => assert!(s.hdfs.delete(&key)),
                StoreKind::Igfs => assert!(s.igfs.remove(&key)),
            }
            let r = s.read_intermediate(&mut e, &t, kind, NodeId(0), &key);
            assert!(r.is_err(), "{kind:?} must surface loss");
            assert!(r.unwrap_err().contains("lost"), "{kind:?}");
        }
    }

    #[test]
    fn locate_probes_the_full_chain() {
        let (mut e, t, mut s) = setup();
        s.write_intermediate(&mut e, &t, StoreKind::Igfs, NodeId(0), "g/k",
                             Payload::real(vec![1; 11]))
            .unwrap();
        s.write_intermediate(&mut e, &t, StoreKind::Hdfs, NodeId(0), "h/k",
                             Payload::real(vec![1; 22]))
            .unwrap();
        s.write_intermediate(&mut e, &t, StoreKind::S3, NodeId(0), "s/k",
                             Payload::real(vec![1; 33]))
            .unwrap();
        assert_eq!(s.locate("g/k"), Some((11, KeyHome::Igfs)));
        assert_eq!(s.locate("h/k"), Some((22, KeyHome::Hdfs)));
        assert_eq!(s.locate("s/k"), Some((33, KeyHome::S3)));
        assert_eq!(s.locate("absent"), None);
    }

    #[test]
    fn locate_disturbs_no_statistics() {
        // Regression (mirrors igfs::cache's
        // len_of_probes_both_tiers_without_stats): locate's "disturbs
        // no statistics" contract used to be violated on the S3 leg —
        // `ObjectStore::get` counted a GET plus the object's bytes for
        // every planning probe.
        let (mut e, t, mut s) = setup();
        s.write_intermediate(&mut e, &t, StoreKind::Igfs, NodeId(0), "g/k",
                             Payload::real(vec![1; 11]))
            .unwrap();
        s.write_intermediate(&mut e, &t, StoreKind::S3, NodeId(0), "s/k",
                             Payload::real(vec![1; 33]))
            .unwrap();
        let igfs0 = s.igfs.stats();
        let (gets0, out0) = (s.s3.stats.gets, s.s3.stats.bytes_out);
        for _ in 0..3 {
            assert_eq!(s.locate("g/k"), Some((11, KeyHome::Igfs)));
            assert_eq!(s.locate("s/k"), Some((33, KeyHome::S3)));
            assert_eq!(s.locate("absent"), None);
        }
        assert_eq!(s.s3.stats.gets, gets0, "locate must not count GETs");
        assert_eq!(s.s3.stats.bytes_out, out0, "nor byte traffic");
        let d = s.igfs.stats().delta_since(&igfs0);
        assert_eq!(d.hits_dram + d.hits_backing + d.misses, 0);
        // The stat-free probe agrees with a real get's length.
        assert_eq!(s.s3.len_of("s/k"), Some(33));
        assert_eq!(s.s3.len_of("absent"), None);
        assert_eq!(s.s3.get("s/k").unwrap().len(), 33);
        assert_eq!(s.s3.stats.gets, gets0 + 1, "real gets still count");
    }

    #[test]
    fn clear_prefix_scrubs_every_backend_and_the_manifest() {
        let (mut e, t, mut s) = setup();
        for (kind, key) in [(StoreKind::Igfs, "job/s01/shuffle/a"),
                            (StoreKind::Hdfs, "job/s01/out/b"),
                            (StoreKind::S3, "job/s01/out/c")] {
            s.write_intermediate(&mut e, &t, kind, NodeId(0), key,
                                 Payload::real(vec![5; 16]))
                .unwrap();
        }
        s.write_intermediate(&mut e, &t, StoreKind::Igfs, NodeId(0),
                             "job/s02/keep", Payload::real(vec![5; 16]))
            .unwrap();
        assert_eq!(s.clear_prefix("job/s01/"), 3);
        // Cleared keys read back as a plain miss — the manifest entry
        // is gone too, so this is Ok(None), not Err("lost").
        for (kind, key) in [(StoreKind::Igfs, "job/s01/shuffle/a"),
                            (StoreKind::Hdfs, "job/s01/out/b"),
                            (StoreKind::S3, "job/s01/out/c")] {
            assert!(matches!(
                s.read_intermediate(&mut e, &t, kind, NodeId(0), key),
                Ok(None)
            ), "{kind:?}");
        }
        // Other prefixes untouched.
        assert!(s.locate("job/s02/keep").is_some());
    }

    #[test]
    fn degraded_reads_fall_down_the_tiers() {
        // Write-through armed: the IGFS intermediate also lands in
        // HDFS and a checkpoint copy is kept. After a cache blackout
        // the read degrades HDFS → checkpoint instead of erroring,
        // counting each degraded serve.
        let (mut e, t, mut s) = setup();
        s.write_through = true;
        s.degraded = true;
        let key = "job/shuffle/m00000/p000";
        s.write_intermediate(&mut e, &t, StoreKind::Igfs, NodeId(0), key,
                             Payload::real(vec![3; 48]))
            .unwrap();
        assert!(s.hdfs.namenode.stat(key).is_some(), "write-through copy");
        // Blackout: the cache copy is gone from both tiers.
        assert!(s.igfs.remove(key));
        let (data, st) = s
            .read_intermediate(&mut e, &t, StoreKind::Igfs, NodeId(1), key)
            .unwrap()
            .expect("degraded read serves from HDFS");
        assert_eq!(data.len(), 48);
        assert_eq!(data.gather().unwrap()[0], 3);
        assert!(!st.is_empty(), "degraded serve is priced");
        assert_eq!(s.igfs.stats().degraded_reads, 1);
        // HDFS gone too: sole-copy key recomputes from the checkpoint
        // and is restored into the surviving cache.
        assert!(s.hdfs.delete(key));
        let (data, _) = s
            .read_intermediate(&mut e, &t, StoreKind::Igfs, NodeId(1), key)
            .unwrap()
            .expect("checkpoint recompute serves");
        assert_eq!(data.len(), 48);
        assert_eq!(s.igfs.stats().degraded_reads, 2);
        // Restored: the next read is a plain cache hit, not degraded.
        assert!(s
            .read_intermediate(&mut e, &t, StoreKind::Igfs, NodeId(1), key)
            .unwrap()
            .is_some());
        assert_eq!(s.igfs.stats().degraded_reads, 2);
    }

    #[test]
    fn degraded_read_errors_only_when_no_tier_holds_the_bytes() {
        // Degraded mode without write-through: the cache held the sole
        // copy, so once it is gone no tier can serve — still an error,
        // graceful degradation never invents bytes.
        let (mut e, t, mut s) = setup();
        s.degraded = true;
        let key = "job/shuffle/sole";
        s.write_intermediate(&mut e, &t, StoreKind::Igfs, NodeId(0), key,
                             Payload::real(vec![5; 32]))
            .unwrap();
        assert!(s.igfs.remove(key));
        let r = s.read_intermediate(&mut e, &t, StoreKind::Igfs, NodeId(0),
                                    key);
        assert!(r.is_err());
        assert!(r.unwrap_err().contains("lost"));
    }

    #[test]
    fn keys_are_unique_per_task() {
        let a = interm_key("j", 1, 2);
        let b = interm_key("j", 2, 1);
        assert_ne!(a, b);
        assert_ne!(output_key("j", 0), output_key("j", 1));
    }

    #[test]
    fn key_into_matches_alloc_form_and_reuses_buffer() {
        let mut buf = String::with_capacity(64);
        for (map, part) in [(0usize, 0usize), (2, 3), (99999, 999)] {
            interm_key_into(&mut buf, "j", map, part);
            assert_eq!(buf, interm_key("j", map, part));
        }
        // The buffer is cleared per call, never appended to.
        output_key_into(&mut buf, "job", 7);
        assert_eq!(buf, output_key("job", 7));
        assert_eq!(buf, "job/out/p007");
    }

    #[test]
    fn partition_tallies_accumulate_and_reset() {
        let (_e, _t, mut s) = setup();
        assert!(s.partition_tallies().is_empty());
        s.begin_partition_tally(3);
        s.tally_partition(0, 10);
        s.tally_partition(2, 5);
        s.tally_partition(2, 5);
        s.tally_partition(99, 1_000_000); // out of range: ignored
        assert_eq!(s.partition_tallies(), &[10, 0, 10]);
        s.begin_partition_tally(2);
        assert_eq!(s.partition_tallies(), &[0, 0]);
    }
}
