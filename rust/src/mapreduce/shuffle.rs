//! Shuffle backends: where intermediate data lives and what it costs.
//!
//! This is the heart of the paper's comparison — the same MapReduce
//! data plane shuffled through (a) remote S3 objects (Corral), (b)
//! PMEM-backed HDFS files (Marvel-HDFS), or (c) the Ignite in-memory
//! cache (Marvel-IGFS).

use crate::hdfs::Hdfs;
use crate::igfs::Igfs;
use crate::metrics::tags;
use crate::net::{NodeId, Topology};
use crate::objstore::ObjectStore;
use crate::sim::{Engine, Stage};
use crate::storage::Payload;

use super::types::StoreKind;

/// All stores a cluster deployment provides; jobs borrow it.
pub struct Stores {
    pub hdfs: Hdfs,
    pub igfs: Igfs,
    pub s3: ObjectStore,
}

/// Key for one mapper's output for one partition.
pub fn interm_key(job: &str, map: usize, part: usize) -> String {
    format!("{job}/shuffle/m{map:05}/p{part:03}")
}

/// Key for one reducer's final output.
pub fn output_key(job: &str, part: usize) -> String {
    format!("{job}/out/p{part:03}")
}

impl Stores {
    /// Write an intermediate partition from `node`; returns stages.
    pub fn write_intermediate(
        &mut self,
        engine: &mut Engine,
        topo: &Topology,
        kind: StoreKind,
        node: NodeId,
        key: &str,
        data: Payload,
    ) -> Result<Vec<Stage>, String> {
        let tag = tags::INTERMEDIATE_WRITE;
        match kind {
            StoreKind::S3 => {
                let st =
                    self.s3.put_stages(engine, topo, node, data.len(), tag);
                self.s3.put(key, data);
                Ok(st)
            }
            StoreKind::Hdfs => self.hdfs.put(topo, node, key, data, tag),
            StoreKind::Igfs => Ok(self.igfs.put(topo, node, key, data, tag)),
        }
    }

    /// Read an intermediate partition to `node`; returns (data, stages).
    pub fn read_intermediate(
        &mut self,
        engine: &mut Engine,
        topo: &Topology,
        kind: StoreKind,
        node: NodeId,
        key: &str,
    ) -> Result<(Payload, Vec<Stage>), String> {
        let tag = tags::INTERMEDIATE_READ;
        match kind {
            StoreKind::S3 => {
                let data = self
                    .s3
                    .get(key)
                    .ok_or_else(|| format!("s3 miss {key}"))?;
                let st =
                    self.s3.get_stages(engine, topo, node, data.len(), tag);
                Ok((data, st))
            }
            StoreKind::Hdfs => {
                let (data, st, _, _) = self.hdfs.read(topo, node, key, tag)?;
                Ok((data, st))
            }
            StoreKind::Igfs => self
                .igfs
                .get(topo, node, key, tag)
                .ok_or_else(|| format!("igfs miss {key}")),
        }
    }

    /// Write final output from `node`.
    pub fn write_output(
        &mut self,
        engine: &mut Engine,
        topo: &Topology,
        kind: StoreKind,
        node: NodeId,
        key: &str,
        data: Payload,
    ) -> Result<Vec<Stage>, String> {
        let tag = tags::OUTPUT_WRITE;
        match kind {
            StoreKind::S3 => {
                let st =
                    self.s3.put_stages(engine, topo, node, data.len(), tag);
                self.s3.put(key, data);
                Ok(st)
            }
            StoreKind::Hdfs => self.hdfs.put(topo, node, key, data, tag),
            StoreKind::Igfs => Ok(self.igfs.put(topo, node, key, data, tag)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{DeviceRole, TopologyBuilder};
    use crate::objstore::ObjStoreConfig;
    use crate::sim::Engine;
    use crate::util::bytes::GIB;

    fn setup() -> (Engine, Topology, Stores) {
        let mut e = Engine::new();
        let t = TopologyBuilder { nodes: 2, ..Default::default() }
            .build(&mut e);
        let stores = Stores {
            hdfs: Hdfs::new(&t, DeviceRole::Pmem, 1),
            igfs: Igfs::new(&t, GIB),
            s3: ObjectStore::new(&mut e, &ObjStoreConfig::default()),
        };
        (e, t, stores)
    }

    #[test]
    fn roundtrip_every_backend() {
        let (mut e, t, mut s) = setup();
        for kind in [StoreKind::S3, StoreKind::Hdfs, StoreKind::Igfs] {
            let key = interm_key("wc", 0, 0);
            let key = format!("{kind:?}/{key}");
            let st = s
                .write_intermediate(&mut e, &t, kind, NodeId(0), &key,
                                    Payload::real(vec![7; 100]))
                .unwrap();
            e.spawn("w", st);
            let (data, st) = s
                .read_intermediate(&mut e, &t, kind, NodeId(1), &key)
                .unwrap();
            e.spawn("r", st);
            assert_eq!(data.len(), 100, "{kind:?}");
            assert_eq!(data.bytes().unwrap()[0], 7);
        }
        e.run().unwrap();
        // Flow log has both tags for all three backends.
        let tags_seen: std::collections::HashSet<u32> =
            e.flow_log.iter().map(|f| f.tag).collect();
        assert!(tags_seen.contains(&tags::INTERMEDIATE_WRITE));
        assert!(tags_seen.contains(&tags::INTERMEDIATE_READ));
    }

    #[test]
    fn missing_key_errors() {
        let (mut e, t, mut s) = setup();
        for kind in [StoreKind::S3, StoreKind::Hdfs, StoreKind::Igfs] {
            assert!(s
                .read_intermediate(&mut e, &t, kind, NodeId(0), "nope")
                .is_err());
        }
    }

    #[test]
    fn keys_are_unique_per_task() {
        let a = interm_key("j", 1, 2);
        let b = interm_key("j", 2, 1);
        assert_ne!(a, b);
        assert_ne!(output_key("j", 0), output_key("j", 1));
    }
}
