//! Shuffle backends: where intermediate data lives and what it costs.
//!
//! This is the heart of the paper's comparison — the same MapReduce
//! data plane shuffled through (a) remote S3 objects (Corral), (b)
//! PMEM-backed HDFS files (Marvel-HDFS), or (c) the Ignite in-memory
//! cache (Marvel-IGFS).

use crate::hdfs::Hdfs;
use crate::igfs::Igfs;
use crate::metrics::tags;
use crate::net::{NodeId, Topology};
use crate::objstore::ObjectStore;
use crate::sim::{Engine, Stage};
use crate::storage::Payload;

use super::types::StoreKind;

/// All stores a cluster deployment provides; jobs borrow it.
pub struct Stores {
    pub hdfs: Hdfs,
    pub igfs: Igfs,
    pub s3: ObjectStore,
}

/// Key for one mapper's output for one partition.
pub fn interm_key(job: &str, map: usize, part: usize) -> String {
    format!("{job}/shuffle/m{map:05}/p{part:03}")
}

/// Key for one reducer's final output.
pub fn output_key(job: &str, part: usize) -> String {
    format!("{job}/out/p{part:03}")
}

impl Stores {
    /// Write an intermediate partition from `node`; returns stages.
    pub fn write_intermediate(
        &mut self,
        engine: &mut Engine,
        topo: &Topology,
        kind: StoreKind,
        node: NodeId,
        key: &str,
        data: Payload,
    ) -> Result<Vec<Stage>, String> {
        let tag = tags::INTERMEDIATE_WRITE;
        match kind {
            StoreKind::S3 => {
                let st =
                    self.s3.put_stages(engine, topo, node, data.len(), tag);
                self.s3.put(key, data);
                Ok(st)
            }
            StoreKind::Hdfs => self.hdfs.put(topo, node, key, data, tag),
            StoreKind::Igfs => Ok(self.igfs.put(topo, node, key, data, tag)),
        }
    }

    /// Read an intermediate partition to `node`.
    ///
    /// `Ok(None)` means the key was never written — a mapper that
    /// emitted nothing for this partition, which the driver must treat
    /// as empty input. `Err` is a real store failure (e.g. an HDFS
    /// file whose blocks are gone from every DataNode) and must
    /// propagate; conflating the two silently drops corrupted data.
    pub fn read_intermediate(
        &mut self,
        engine: &mut Engine,
        topo: &Topology,
        kind: StoreKind,
        node: NodeId,
        key: &str,
    ) -> Result<Option<(Payload, Vec<Stage>)>, String> {
        let tag = tags::INTERMEDIATE_READ;
        match kind {
            StoreKind::S3 => match self.s3.get(key) {
                None => Ok(None),
                Some(data) => {
                    let st = self
                        .s3
                        .get_stages(engine, topo, node, data.len(), tag);
                    Ok(Some((data, st)))
                }
            },
            StoreKind::Hdfs => {
                if self.hdfs.namenode.stat(key).is_none() {
                    return Ok(None); // never written: a miss, not a fault
                }
                // Committed in the namespace: any read failure now is
                // data loss/corruption and must surface.
                let (data, st, _, _) = self.hdfs.read(topo, node, key, tag)?;
                Ok(Some((data, st)))
            }
            // IGFS demotes evicted entries to the backing tier instead
            // of dropping them, so a cache miss can only mean the key
            // was never stored.
            StoreKind::Igfs => Ok(self.igfs.get(topo, node, key, tag)),
        }
    }

    /// Write final output from `node`.
    pub fn write_output(
        &mut self,
        engine: &mut Engine,
        topo: &Topology,
        kind: StoreKind,
        node: NodeId,
        key: &str,
        data: Payload,
    ) -> Result<Vec<Stage>, String> {
        let tag = tags::OUTPUT_WRITE;
        match kind {
            StoreKind::S3 => {
                let st =
                    self.s3.put_stages(engine, topo, node, data.len(), tag);
                self.s3.put(key, data);
                Ok(st)
            }
            StoreKind::Hdfs => self.hdfs.put(topo, node, key, data, tag),
            StoreKind::Igfs => Ok(self.igfs.put(topo, node, key, data, tag)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{DeviceRole, TopologyBuilder};
    use crate::objstore::ObjStoreConfig;
    use crate::sim::Engine;
    use crate::util::bytes::GIB;

    fn setup() -> (Engine, Topology, Stores) {
        let mut e = Engine::new();
        let t = TopologyBuilder { nodes: 2, ..Default::default() }
            .build(&mut e);
        let stores = Stores {
            hdfs: Hdfs::new(&t, DeviceRole::Pmem, 1),
            igfs: Igfs::new(&t, GIB),
            s3: ObjectStore::new(&mut e, &ObjStoreConfig::default()),
        };
        (e, t, stores)
    }

    #[test]
    fn roundtrip_every_backend() {
        let (mut e, t, mut s) = setup();
        for kind in [StoreKind::S3, StoreKind::Hdfs, StoreKind::Igfs] {
            let key = interm_key("wc", 0, 0);
            let key = format!("{kind:?}/{key}");
            let st = s
                .write_intermediate(&mut e, &t, kind, NodeId(0), &key,
                                    Payload::real(vec![7; 100]))
                .unwrap();
            e.spawn("w", st);
            let (data, st) = s
                .read_intermediate(&mut e, &t, kind, NodeId(1), &key)
                .unwrap()
                .expect("key just written");
            e.spawn("r", st);
            assert_eq!(data.len(), 100, "{kind:?}");
            assert_eq!(data.bytes().unwrap()[0], 7);
        }
        e.run().unwrap();
        // Flow log has both tags for all three backends.
        let tags_seen: std::collections::HashSet<u32> =
            e.flow_log.iter().map(|f| f.tag).collect();
        assert!(tags_seen.contains(&tags::INTERMEDIATE_WRITE));
        assert!(tags_seen.contains(&tags::INTERMEDIATE_READ));
    }

    #[test]
    fn missing_key_is_a_miss_not_an_error() {
        let (mut e, t, mut s) = setup();
        for kind in [StoreKind::S3, StoreKind::Hdfs, StoreKind::Igfs] {
            assert!(matches!(
                s.read_intermediate(&mut e, &t, kind, NodeId(0), "nope"),
                Ok(None)
            ), "{kind:?}");
        }
    }

    #[test]
    fn lost_hdfs_blocks_surface_as_error() {
        // A key committed in the namespace whose blocks vanished from
        // every DataNode is corruption, not an empty partition.
        let (mut e, t, mut s) = setup();
        s.write_intermediate(&mut e, &t, StoreKind::Hdfs, NodeId(0),
                             "doomed", Payload::real(vec![1; 64]))
            .unwrap();
        let blocks: Vec<_> = s.hdfs.namenode.stat("doomed").unwrap()
            .blocks.iter().map(|b| b.id).collect();
        for dn in s.hdfs.datanodes.values_mut() {
            for id in &blocks {
                dn.drop_block(*id);
            }
        }
        assert!(s
            .read_intermediate(&mut e, &t, StoreKind::Hdfs, NodeId(0),
                               "doomed")
            .is_err());
    }

    #[test]
    fn keys_are_unique_per_task() {
        let a = interm_key("j", 1, 2);
        let b = interm_key("j", 2, 1);
        assert_ne!(a, b);
        assert_ne!(output_key("j", 0), output_key("j", 1));
    }
}
