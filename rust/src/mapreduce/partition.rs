//! Partition plan layer: pluggable key→partition routing.
//!
//! Historically the key→reducer mapping was a `hash(key) % n_reduces`
//! smeared across every workload's `map_split`. This module lifts it
//! into a first-class [`PartitionPlan`] the driver builds once per
//! stage and hands down to the data plane:
//!
//! | partitioner  | routing                                          |
//! |--------------|--------------------------------------------------|
//! | `Hash`       | `key % parts` — the legacy mapping bit-for-bit   |
//! | `Range`      | binary search over ascending cut points (derived |
//! |              | uniformly from `Workload::key_domain` when none  |
//! |              | are given; an unknown domain degrades to hash)   |
//! | `SkewAware`  | hash base routing + hot keys split across        |
//! |              | `split_ways` consecutive reducers                |
//!
//! Hot keys are detected *at plan time* from the workload's analytic
//! [`Workload::key_profile`] — a deterministic, materialization-free
//! key-weight distribution (e.g. the Zipf pmf a table generator
//! samples from), so real and synthetic modes route identically and
//! the plan never needs a statistics pass over map outputs.
//!
//! Determinism contract: within one partitioner choice, job outputs
//! are byte-identical at any worker count, placement, and fault plan
//! (the plan is a pure function of `(partitioner, workload, parts)`).
//! Across partitioners the *canonical* output — the multiset of
//! records over all partitions — is identical; routing moves records
//! between partitions, never invents or drops them. `SkewAware` on a
//! workload whose [`SplitMode`] is `None` detects and reports hot
//! keys but does not move them, so it is bit-for-bit `Hash` — which
//! is what makes CI's global `MARVEL_PARTITIONER=skew-aware` sweep
//! safe for every legacy workload.

use super::workload::Workload;

/// Can a workload's records for one key be safely spread across
/// several reducers by a skew-aware plan?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitMode {
    /// No: the reduce function needs every record of a key in one
    /// partition (the default). `SkewAware` then only *reports* hot
    /// keys and routes exactly like `Hash`.
    None,
    /// Yes, and the split outputs are independent rows needing no
    /// re-unification (e.g. a repartition join: the build side is
    /// replicated to every way, probe rows join wherever they land).
    Independent,
    /// Yes, but the split partitions hold *partial* aggregates that a
    /// final merge stage (the workload's [`Workload::unifier`]) must
    /// re-unify — `JobPipeline` appends that stage automatically.
    Mergeable,
}

/// The configured partitioning strategy (`[partition]` in TOML,
/// `--partitioner` on the CLI, `MARVEL_PARTITIONER` in CI).
#[derive(Clone, Debug, PartialEq)]
pub enum Partitioner {
    /// Legacy `key % parts`, bit-for-bit.
    Hash,
    /// Ascending cut points; partition `j` holds keys in
    /// `[bounds[j-1], bounds[j])`. Empty bounds derive uniformly from
    /// the workload's `key_domain()` (domain 0 = unknown → hash).
    Range { bounds: Vec<u64> },
    /// Hash base routing, with keys whose profile weight exceeds
    /// `hot_threshold × (total / parts)` split across `split_ways`
    /// consecutive reducers (on workloads that allow it).
    SkewAware { hot_threshold: f64, split_ways: usize },
}

impl Default for Partitioner {
    fn default() -> Self {
        Partitioner::Hash
    }
}

impl Partitioner {
    /// Default hot-key threshold: a key is hot when its profile weight
    /// exceeds this multiple of the mean per-partition weight.
    pub const DEFAULT_HOT_THRESHOLD: f64 = 2.0;
    /// Default number of reducers a hot key is split across.
    pub const DEFAULT_SPLIT_WAYS: usize = 4;

    /// Parse a strategy name (the CLI/TOML/env surface).
    pub fn parse(s: &str) -> Result<Partitioner, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "hash" => Ok(Partitioner::Hash),
            "range" => Ok(Partitioner::Range { bounds: Vec::new() }),
            "skew-aware" | "skewaware" | "skew" => {
                Ok(Partitioner::SkewAware {
                    hot_threshold: Self::DEFAULT_HOT_THRESHOLD,
                    split_ways: Self::DEFAULT_SPLIT_WAYS,
                })
            }
            other => Err(format!(
                "unknown partitioner '{other}' \
                 (expected hash | range | skew-aware)"
            )),
        }
    }

    /// Canonical strategy name (round-trips through [`parse`]).
    ///
    /// [`parse`]: Partitioner::parse
    pub fn name(&self) -> &'static str {
        match self {
            Partitioner::Hash => "hash",
            Partitioner::Range { .. } => "range",
            Partitioner::SkewAware { .. } => "skew-aware",
        }
    }
}

/// One plan-time-detected hot key and how many ways it is spread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HotKey {
    pub key: u64,
    pub ways: u32,
}

/// Base routing of the plan (before hot-key spreading).
#[derive(Clone, Debug, PartialEq)]
enum PlanKind {
    Hash,
    Range { bounds: Vec<u64> },
}

/// A stage's frozen key→partition mapping, built by the driver after
/// reducer sizing and handed to every `map_split` call. Pure data — a
/// deterministic function of `(partitioner, workload, parts)`.
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionPlan {
    parts: usize,
    kind: PlanKind,
    /// Plan-time hot keys, sorted by key for binary search. Empty
    /// unless the partitioner is `SkewAware` and the profile flagged
    /// keys past the threshold.
    hot: Vec<HotKey>,
    /// Whether hot keys are actually spread (the workload's
    /// `SplitMode` allows it and `parts > 1`). When false the plan
    /// routes bit-for-bit like its base kind and only *reports* hot.
    split: bool,
}

impl PartitionPlan {
    /// The legacy plan: `key % parts`, no hot keys. What every test
    /// helper wants when partitioning is not the thing under test.
    pub fn hash(parts: usize) -> PartitionPlan {
        PartitionPlan {
            parts: parts.max(1),
            kind: PlanKind::Hash,
            hot: Vec::new(),
            split: false,
        }
    }

    /// Build the plan for a stage: profile + domain + split mode come
    /// from the workload, `parts` from reducer sizing. The workload's
    /// profile is analytic and scale-free, so the same plan can be
    /// rebuilt anywhere (e.g. by a synthetic reduce path) from
    /// `(cfg.partition, workload, parts)` alone.
    pub fn build(
        partitioner: &Partitioner,
        wl: &dyn Workload,
        input_bytes: u64,
        parts: usize,
        seed: u64,
    ) -> PartitionPlan {
        Self::from_profile(
            partitioner,
            &wl.key_profile(input_bytes, seed),
            wl.key_domain(),
            wl.split_mode(),
            parts,
        )
    }

    /// The pure core of [`build`](PartitionPlan::build), unit-testable
    /// without a workload.
    pub fn from_profile(
        partitioner: &Partitioner,
        profile: &[(u64, u64)],
        key_domain: u64,
        split_mode: SplitMode,
        parts: usize,
    ) -> PartitionPlan {
        let parts = parts.max(1);
        match partitioner {
            Partitioner::Hash => PartitionPlan::hash(parts),
            Partitioner::Range { bounds } => {
                let bounds = if !bounds.is_empty() {
                    let mut b = bounds.clone();
                    b.sort_unstable();
                    b.truncate(parts.saturating_sub(1));
                    b
                } else if key_domain as u128 >= parts as u128 {
                    // Uniform cut points over the declared key domain.
                    let width = key_domain / parts as u64;
                    (1..parts).map(|i| i as u64 * width).collect()
                } else {
                    // Unknown (or degenerate) domain: degrade to hash
                    // routing rather than piling every key on p0.
                    return PartitionPlan::hash(parts);
                };
                PartitionPlan {
                    parts,
                    kind: PlanKind::Range { bounds },
                    hot: Vec::new(),
                    split: false,
                }
            }
            Partitioner::SkewAware { hot_threshold, split_ways } => {
                let total: u128 =
                    profile.iter().map(|(_, w)| *w as u128).sum();
                let ways = (*split_ways).clamp(2, parts) as u32;
                let mut hot: Vec<HotKey> = Vec::new();
                if total > 0 && parts > 1 {
                    let mean = total as f64 / parts as f64;
                    let cut = hot_threshold.max(0.0) * mean;
                    for &(key, w) in profile {
                        if w as f64 > cut {
                            hot.push(HotKey { key, ways });
                        }
                    }
                    hot.sort_unstable_by_key(|h| h.key);
                }
                PartitionPlan {
                    parts,
                    kind: PlanKind::Hash,
                    split: split_mode != SplitMode::None
                        && parts > 1
                        && !hot.is_empty(),
                    hot,
                }
            }
        }
    }

    /// Reducer count this plan routes into.
    pub fn parts(&self) -> usize {
        self.parts
    }

    /// Base route for `key` — ignores hot-key spreading. `Hash` plans
    /// reproduce the legacy `key % parts` bit-for-bit.
    pub fn route(&self, key: u64) -> usize {
        match &self.kind {
            PlanKind::Hash => (key % self.parts as u64) as usize,
            PlanKind::Range { bounds } => {
                bounds.partition_point(|b| *b <= key).min(self.parts - 1)
            }
        }
    }

    /// Route with hot-key spreading: a split hot key lands on one of
    /// its `ways` consecutive partitions, chosen by `salt`. Callers
    /// must derive `salt` from record *content* (or a per-task RNG) so
    /// routing is independent of split boundaries and worker counts.
    /// Non-hot keys (and non-splitting plans) route like [`route`].
    ///
    /// [`route`]: PartitionPlan::route
    pub fn route_salted(&self, key: u64, salt: u64) -> usize {
        let w = self.ways(key);
        if w <= 1 {
            return self.route(key);
        }
        (self.route(key) + (salt % w as u64) as usize) % self.parts
    }

    /// How many partitions `key` is spread across (1 unless the plan
    /// splits and the key is hot).
    pub fn ways(&self, key: u64) -> usize {
        if !self.split {
            return 1;
        }
        match self.hot.binary_search_by_key(&key, |h| h.key) {
            Ok(i) => self.hot[i].ways as usize,
            Err(_) => 1,
        }
    }

    /// The `i`-th partition of `key`'s spread (`i < ways(key)`). A
    /// build side replicating a hot key emits one copy per way.
    pub fn route_way(&self, key: u64, i: usize) -> usize {
        (self.route(key) + i) % self.parts
    }

    /// Hot keys the plan actually spreads (reported as
    /// `JobResult::hot_keys_split`). Zero when the workload cannot
    /// split or the partitioner is not skew-aware.
    pub fn hot_keys_split(&self) -> u64 {
        if self.split {
            self.hot.len() as u64
        } else {
            0
        }
    }

    /// Hot keys detected at plan time, split or not.
    pub fn hot_keys_detected(&self) -> u64 {
        self.hot.len() as u64
    }
}

/// Content-derived routing salt for hot-key spreading: FNV-1a over the
/// record bytes, so a record routes identically wherever (and by
/// whichever worker) it is mapped.
pub fn record_salt(record: &[u8]) -> u64 {
    crate::util::hash::fnv1a64(record)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        for name in ["hash", "range", "skew-aware"] {
            let p = Partitioner::parse(name).unwrap();
            assert_eq!(p.name(), name);
        }
        assert_eq!(
            Partitioner::parse("SKEW").unwrap().name(),
            "skew-aware"
        );
        assert!(Partitioner::parse("modulo").is_err());
        assert_eq!(Partitioner::default(), Partitioner::Hash);
    }

    #[test]
    fn hash_plan_is_legacy_modulo() {
        let plan = PartitionPlan::hash(7);
        for key in 0..200u64 {
            assert_eq!(plan.route(key), (key % 7) as usize);
            assert_eq!(plan.route_salted(key, 0xDEAD), plan.route(key));
            assert_eq!(plan.ways(key), 1);
        }
        assert_eq!(plan.hot_keys_split(), 0);
        // parts 0 clamps to 1 instead of dividing by zero.
        assert_eq!(PartitionPlan::hash(0).parts(), 1);
    }

    #[test]
    fn range_routes_by_cut_points() {
        let p = Partitioner::Range { bounds: vec![10, 20] };
        let plan = PartitionPlan::from_profile(
            &p, &[], 0, SplitMode::None, 3,
        );
        assert_eq!(plan.route(0), 0);
        assert_eq!(plan.route(9), 0);
        assert_eq!(plan.route(10), 1);
        assert_eq!(plan.route(19), 1);
        assert_eq!(plan.route(20), 2);
        assert_eq!(plan.route(u64::MAX), 2);
    }

    #[test]
    fn range_derives_uniform_bounds_from_domain() {
        let p = Partitioner::Range { bounds: vec![] };
        let plan = PartitionPlan::from_profile(
            &p, &[], 100, SplitMode::None, 4,
        );
        assert_eq!(plan.route(0), 0);
        assert_eq!(plan.route(24), 0);
        assert_eq!(plan.route(25), 1);
        assert_eq!(plan.route(99), 3);
        // Every partition is reachable.
        let mut seen = vec![false; 4];
        for k in 0..100 {
            seen[plan.route(k)] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn range_with_unknown_domain_degrades_to_hash() {
        let p = Partitioner::Range { bounds: vec![] };
        let plan = PartitionPlan::from_profile(
            &p, &[], 0, SplitMode::None, 5,
        );
        for key in 0..100u64 {
            assert_eq!(plan.route(key), (key % 5) as usize);
        }
    }

    #[test]
    fn skew_detects_and_spreads_hot_keys() {
        let p = Partitioner::SkewAware { hot_threshold: 2.0, split_ways: 3 };
        // total 100 over 4 parts → mean 25, cut 50: only key 0 is hot.
        let profile = [(0u64, 80u64), (1, 10), (2, 5), (3, 5)];
        let plan = PartitionPlan::from_profile(
            &p, &profile, 0, SplitMode::Independent, 4,
        );
        assert_eq!(plan.hot_keys_split(), 1);
        assert_eq!(plan.hot_keys_detected(), 1);
        assert_eq!(plan.ways(0), 3);
        assert_eq!(plan.ways(1), 1);
        // The spread stays inside the 3 consecutive ways off route(0).
        let base = plan.route(0);
        for salt in 0..64u64 {
            let j = plan.route_salted(0, salt);
            let off = (j + 4 - base) % 4;
            assert!(off < 3, "salt {salt} landed {off} ways out");
        }
        // All 3 ways are actually used.
        let used: std::collections::HashSet<usize> =
            (0..64).map(|s| plan.route_salted(0, s)).collect();
        assert_eq!(used.len(), 3);
        // route_way enumerates exactly the spread.
        for i in 0..3 {
            assert_eq!(plan.route_way(0, i), (base + i) % 4);
        }
        // Cold keys still route like hash.
        assert_eq!(plan.route_salted(3, 99), plan.route(3));
    }

    #[test]
    fn skew_on_unsplittable_workload_is_hash_bit_for_bit() {
        let p = Partitioner::SkewAware { hot_threshold: 2.0, split_ways: 4 };
        let profile = [(0u64, 90u64), (1, 10)];
        let plan = PartitionPlan::from_profile(
            &p, &profile, 0, SplitMode::None, 4,
        );
        let hash = PartitionPlan::hash(4);
        for key in 0..64u64 {
            for salt in 0..8u64 {
                assert_eq!(
                    plan.route_salted(key, salt),
                    hash.route_salted(key, salt)
                );
            }
            assert_eq!(plan.ways(key), 1);
        }
        // Detected but not split: the report still sees the hot key.
        assert_eq!(plan.hot_keys_detected(), 1);
        assert_eq!(plan.hot_keys_split(), 0);
    }

    #[test]
    fn skew_edge_cases_are_inert() {
        let p = Partitioner::SkewAware { hot_threshold: 2.0, split_ways: 4 };
        // Empty profile, single partition, uniform profile: no hot.
        for (profile, parts) in [
            (vec![], 8usize),
            (vec![(0u64, 100u64)], 1),
            (vec![(0, 25), (1, 25), (2, 25), (3, 25)], 4),
        ] {
            let plan = PartitionPlan::from_profile(
                &p, &profile, 0, SplitMode::Independent, parts,
            );
            assert_eq!(plan.hot_keys_split(), 0, "{profile:?}");
        }
    }

    #[test]
    fn record_salt_is_content_deterministic() {
        assert_eq!(record_salt(b"row-a"), record_salt(b"row-a"));
        assert_ne!(record_salt(b"row-a"), record_salt(b"row-b"));
    }
}
