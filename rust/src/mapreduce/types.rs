//! Job/system configuration and result types.
//!
//! A `SystemConfig` captures one column of the paper's evaluation:
//! which platform runs the functions, where input/intermediate/output
//! live, whether the map-side combiner (the L1 kernel) is enabled, and
//! the serialization format (Corral ships JSON records; Marvel's Hadoop
//! runtime uses compact binary — this drives the Table 1 intermediate
//! expansion factors).

use crate::coordinator::recovery::{FailurePlan, RecoveryConfig};
use crate::faas::AutoscaleConfig;
use crate::igfs::CacheStats;
use crate::net::{DeviceRole, NetFaultPlan, StragglerProfile};
use crate::sim::SimNs;
use crate::util::bytes::{GIB, MIB};
use crate::yarn::PlacementStrategy;

use super::partition::Partitioner;
use super::server::arrivals::ArrivalConfig;

/// Speculative-execution policy (Hadoop-style backup attempts): when a
/// task's plan-time projected duration exceeds `lag_factor` × the
/// stage median, a backup copy is compiled on the fastest other node.
/// The backup launches once the median task would have finished,
/// re-acquires a slot through the same weighted fair queue (charged to
/// the same tenant class), and races the original — the first finisher
/// cancels the loser (`sim::Stage::Cancel`), whose container returns
/// warm. Off by default: the compiled plan is then bit-for-bit the
/// legacy one.
///
/// Determinism contract: speculation moves only virtual time and
/// attempt counts — outputs are byte-identical to the speculation-off
/// run at any worker count, straggler seed, and under co-runs, because
/// the data plane runs once at plan time and both racers replay the
/// same byte volumes.
#[derive(Clone, Debug, PartialEq)]
pub struct SpeculationConfig {
    /// Master switch; off keeps the compiled plan bit-for-bit legacy.
    pub enabled: bool,
    /// Back a task up when its projected duration exceeds this
    /// multiple of the stage median (values below 1 behave as 1).
    pub lag_factor: f64,
}

impl Default for SpeculationConfig {
    fn default() -> Self {
        SpeculationConfig { enabled: false, lag_factor: 1.5 }
    }
}

impl SpeculationConfig {
    /// Speculation off (the default for every preset).
    pub fn disabled() -> SpeculationConfig {
        SpeculationConfig::default()
    }

    /// Speculation on with the default lag threshold.
    pub fn on() -> SpeculationConfig {
        SpeculationConfig { enabled: true, ..Default::default() }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Which FaaS substrate runs the functions.
pub enum Platform {
    /// OpenWhisk with the Marvel Hadoop runtime (stateful).
    OpenWhisk,
    /// AWS Lambda under Corral (stateless baseline).
    Lambda,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Which store backs a data path (input/intermediate/output).
pub enum StoreKind {
    S3,
    Hdfs,
    Igfs,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Intermediate record serialization format.
pub enum SerFormat {
    /// Corral-style JSON records: {"key":"...","value":N}.
    Json,
    /// Hadoop-style binary KV framing.
    Binary,
}

impl SerFormat {
    /// Fixed per-record overhead on top of the key bytes
    /// (Json: `{"key":"...","value":...}` framing ≈ 31 B — calibrated so
    /// the Table 1 expansion factors land on the paper's).
    pub fn record_overhead(self) -> u64 {
        match self {
            SerFormat::Json => 31,
            SerFormat::Binary => 6,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Whether the map-side combiner (the L1 kernel) runs.
pub enum CombinerMode {
    /// Ship raw <key,1> records (Corral has no combiner).
    None,
    /// Map-side combine through the AOT kernel (Marvel).
    Kernel,
}

#[derive(Clone, Debug)]
/// One evaluated system configuration (a column of the paper's
/// comparison grid).
pub struct SystemConfig {
    pub name: String,
    pub platform: Platform,
    pub input_store: StoreKind,
    pub intermediate_store: StoreKind,
    pub output_store: StoreKind,
    /// Device role backing HDFS DataNodes (Figure 1 sweeps this).
    pub hdfs_role: DeviceRole,
    pub combiner: CombinerMode,
    pub ser: SerFormat,
    pub split_bytes: u64,
    pub replication: usize,
    /// DRAM budget per node for IGFS.
    pub igfs_capacity: u64,
    /// Pre-warm the Hadoop runtime containers at deployment.
    pub prewarm: bool,
    /// Materialize real intermediate payloads only below this total
    /// input size (exact byte accounting always happens).
    pub materialize_cap: u64,
    /// Data-plane map workers (host threads running `map_split`):
    /// 0 = auto (available parallelism). Any value produces output
    /// byte-identical to serial — see the determinism contract in
    /// `driver::map_splits_parallel`.
    pub map_workers: usize,
    /// Data-plane reduce workers (host threads running
    /// `reduce_partition` across partitions): 0 = auto. Same
    /// determinism contract as `map_workers` — each partition is
    /// reduced by exactly one worker over inputs gathered in mapper
    /// order, so worker count is invisible in every output bit.
    pub reduce_workers: usize,
    /// Checkpoint/recovery policy for map/reduce tasks. Active in the
    /// time plane only while `failures` is armed; the stateless
    /// baseline (`recovery.stateful == false`) restarts failed tasks
    /// from byte zero.
    pub recovery: RecoveryConfig,
    /// Deterministic fault injection (container crashes, DataNode
    /// loss). Disabled by default; with any plan, job *outputs* stay
    /// byte-identical to the failure-free run — failures move only
    /// virtual time and attempt counts.
    pub failures: FailurePlan,
    /// Heterogeneous node speeds (stragglers). Disabled by default;
    /// arming it slows the sampled nodes' compute and devices in the
    /// time plane only — outputs never move.
    pub stragglers: StragglerProfile,
    /// Speculative backup attempts racing projected laggards. Off by
    /// default; like `stragglers`, a time-plane-only knob.
    pub speculation: SpeculationConfig,
    /// Network fault injection + degraded-mode I/O (link fault
    /// windows, flow deadlines with backoff retries, cache-node
    /// blackouts). Disabled by default; arming it moves only virtual
    /// time and the `flow_timeouts`/`degraded_reads` counters —
    /// outputs stay byte-identical.
    pub netfaults: NetFaultPlan,
    /// Open-loop arrival plane (`marvel serve`): seed-driven arrival
    /// model, tenant-class mix, and admission-control budget. Disabled
    /// by default — closed-loop runs never consult it.
    pub arrivals: ArrivalConfig,
    /// Elastic warm-pool autoscaling policy the open-loop serve loop
    /// drives against observed arrival rate. Disabled by default (the
    /// static `prewarm` flag keeps its closed-loop meaning).
    pub autoscale: AutoscaleConfig,
    /// Pluggable task-placement strategy (`yarn::placement`). FairOrder
    /// by default — the legacy scheduler bit-for-bit. Placement steers
    /// only *which node* a task lands on; outputs are byte-identical
    /// under any strategy (pinned by the placement property test).
    pub placement: PlacementStrategy,
    /// Key→partition routing policy (`mapreduce::partition`). `Hash`
    /// by default — the legacy `key % parts` modulo bit-for-bit.
    /// Partitioners steer only *which reducer* a key's bytes land on;
    /// job outputs stay canonically identical under any of them
    /// (pinned by the partitioner property test), and per-partition
    /// bytes are pinned within a fixed partitioner.
    pub partition: Partitioner,
}

/// Parse one worker-count override value (the pure half of `from_env`,
/// unit-testable without touching the process environment — writing
/// env vars from tests races other threads' `getenv`).
fn parse_workers(val: Option<&str>) -> Option<usize> {
    val?.trim().parse().ok()
}

impl SystemConfig {
    /// Apply environment overrides: `MARVEL_MAP_WORKERS` /
    /// `MARVEL_REDUCE_WORKERS` force the data-plane worker counts, and
    /// `MARVEL_FAILURE_SEED` re-seeds the failure plan (inert until a
    /// plan arms `crash_prob`/`lose_datanodes`, so the plain test
    /// suite is unaffected; the recovery tests build their plans on
    /// top of it, which is how CI sweeps fault schedules). Every
    /// preset constructor applies this, so CI's determinism matrix can
    /// sweep knobs across the whole test suite — the byte-identical
    /// contract means outputs cannot change, only wall-clock can.
    /// Explicit field assignment after construction still wins (the
    /// pinned determinism tests rely on that).
    pub fn from_env(self) -> SystemConfig {
        let map = std::env::var("MARVEL_MAP_WORKERS").ok();
        let reduce = std::env::var("MARVEL_REDUCE_WORKERS").ok();
        let fseed = std::env::var("MARVEL_FAILURE_SEED").ok();
        let sseed = std::env::var("MARVEL_STRAGGLER_SEED").ok();
        let nseed = std::env::var("MARVEL_NETFAULT_SEED").ok();
        let aseed = std::env::var("MARVEL_ARRIVAL_SEED").ok();
        let mut cfg = self.with_worker_overrides(
            parse_workers(map.as_deref()),
            parse_workers(reduce.as_deref()),
        );
        if let Some(seed) =
            fseed.as_deref().and_then(|s| s.trim().parse::<u64>().ok())
        {
            cfg.failures.seed = seed;
        }
        // Like the failure seed: inert until a profile arms `prob`,
        // so the plain suite is unaffected; the straggler tests build
        // their profiles on top of it, which is how CI sweeps
        // straggler draws through the determinism matrix.
        if let Some(seed) =
            sseed.as_deref().and_then(|s| s.trim().parse::<u64>().ok())
        {
            cfg.stragglers.seed = seed;
        }
        // Third fault axis, same pattern: inert until a plan arms
        // `prob`, so only the netfault tests (and CI's seed column)
        // feel it.
        if let Some(seed) =
            nseed.as_deref().and_then(|s| s.trim().parse::<u64>().ok())
        {
            cfg.netfaults.seed = seed;
        }
        // Fourth seeded axis, same pattern: inert until a serve loop
        // arms the arrival model, so only the open-loop tests (and
        // CI's MARVEL_ARRIVAL_SEED column) feel it. An explicit
        // `[arrivals] seed` in a config file still wins (parsed after
        // the preset constructs).
        if let Some(seed) =
            aseed.as_deref().and_then(|s| s.trim().parse::<u64>().ok())
        {
            cfg.arrivals.seed = seed;
        }
        // Placement sweep axis (CI's determinism matrix): any strategy
        // is safe to force globally because placement cannot move
        // output bytes — only virtual time and locality counters.
        // Unset (or unparseable) leaves the preset's FairOrder default.
        let pseed = std::env::var("MARVEL_PLACEMENT_SEED")
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .unwrap_or(1);
        if let Some(strategy) = std::env::var("MARVEL_PLACEMENT")
            .ok()
            .and_then(|s| PlacementStrategy::parse(&s, pseed).ok())
        {
            cfg.placement = strategy;
        }
        // Partitioner sweep axis, same rationale: any partitioner is
        // safe to force globally because routing moves bytes only
        // *between reducers* — canonical job outputs cannot change
        // (and `SkewAware` is hash-identical on workloads that declare
        // no splittable profile, i.e. the whole legacy suite).
        if let Some(p) = std::env::var("MARVEL_PARTITIONER")
            .ok()
            .and_then(|s| Partitioner::parse(&s).ok())
        {
            cfg.partition = p;
        }
        cfg
    }

    /// Apply already-parsed worker overrides (`None` = leave as-is).
    pub fn with_worker_overrides(
        mut self,
        map: Option<usize>,
        reduce: Option<usize>,
    ) -> SystemConfig {
        if let Some(w) = map {
            self.map_workers = w;
        }
        if let Some(w) = reduce {
            self.reduce_workers = w;
        }
        self
    }

    /// Corral on AWS Lambda with S3 for everything — the baseline of
    /// Figures 4/5 ("Lambda" series).
    pub fn corral_lambda() -> SystemConfig {
        SystemConfig {
            name: "lambda-s3".into(),
            platform: Platform::Lambda,
            input_store: StoreKind::S3,
            intermediate_store: StoreKind::S3,
            output_store: StoreKind::S3,
            hdfs_role: DeviceRole::Ssd, // unused on Lambda
            combiner: CombinerMode::None,
            ser: SerFormat::Json,
            split_bytes: 64 * MIB,
            replication: 1,
            igfs_capacity: 0,
            prewarm: false,
            materialize_cap: 32 * MIB,
            map_workers: 0,
            reduce_workers: 0,
            // Corral has no state store to checkpoint into: failed
            // functions restart from zero (the paper's observation).
            recovery: RecoveryConfig { stateful: false, ..Default::default() },
            failures: FailurePlan::disabled(),
            stragglers: StragglerProfile::disabled(),
            speculation: SpeculationConfig::disabled(),
            netfaults: NetFaultPlan::disabled(),
            arrivals: ArrivalConfig::default(),
            autoscale: AutoscaleConfig::default(),
            placement: PlacementStrategy::default(),
            partition: Partitioner::Hash,
        }
        .from_env()
    }

    /// Marvel with PMEM-backed HDFS for intermediate data
    /// ("Marvel-HDFS" series).
    pub fn marvel_hdfs() -> SystemConfig {
        SystemConfig {
            name: "marvel-hdfs".into(),
            platform: Platform::OpenWhisk,
            input_store: StoreKind::Hdfs,
            intermediate_store: StoreKind::Hdfs,
            output_store: StoreKind::Hdfs,
            hdfs_role: DeviceRole::Pmem,
            combiner: CombinerMode::Kernel,
            ser: SerFormat::Binary,
            split_bytes: 128 * MIB,
            replication: 1,
            igfs_capacity: 64 * GIB,
            prewarm: true,
            materialize_cap: 32 * MIB,
            map_workers: 0,
            reduce_workers: 0,
            recovery: RecoveryConfig::default(),
            failures: FailurePlan::disabled(),
            stragglers: StragglerProfile::disabled(),
            speculation: SpeculationConfig::disabled(),
            netfaults: NetFaultPlan::disabled(),
            arrivals: ArrivalConfig::default(),
            autoscale: AutoscaleConfig::default(),
            placement: PlacementStrategy::default(),
            partition: Partitioner::Hash,
        }
        .from_env()
    }

    /// Marvel with intermediate data in the Ignite in-memory cache
    /// ("Marvel-IGFS" series — the paper's best configuration).
    pub fn marvel_igfs() -> SystemConfig {
        SystemConfig {
            name: "marvel-igfs".into(),
            intermediate_store: StoreKind::Igfs,
            ..SystemConfig::marvel_hdfs()
        }
    }

    /// Paper-faithful Marvel variants: the published system ships *raw*
    /// intermediate records (Table 1's 5.5x expansion is measured
    /// pre-combine); the kernel combiner is this repo's first-class
    /// extension, ablated in `benches/ablation_combiner.rs`.
    pub fn marvel_hdfs_paper() -> SystemConfig {
        SystemConfig {
            name: "marvel-hdfs".into(),
            combiner: CombinerMode::None,
            ser: SerFormat::Json,
            ..SystemConfig::marvel_hdfs()
        }
    }

    pub fn marvel_igfs_paper() -> SystemConfig {
        SystemConfig {
            name: "marvel-igfs".into(),
            intermediate_store: StoreKind::Igfs,
            ..SystemConfig::marvel_hdfs_paper()
        }
    }

    /// Figure 1 motivation variants: on-prem serverless wordcount with
    /// a given HDFS backing device, optionally durably writing input +
    /// output through S3 ("SSD & S3", "PMEM & S3" bars).
    pub fn onprem(role: DeviceRole, with_s3: bool) -> SystemConfig {
        let store = if with_s3 { StoreKind::S3 } else { StoreKind::Hdfs };
        let suffix = if with_s3 { "+s3" } else { "" };
        SystemConfig {
            name: format!(
                "onprem-{}{suffix}",
                format!("{role:?}").to_lowercase()
            ),
            platform: Platform::OpenWhisk,
            input_store: store,
            intermediate_store: StoreKind::Hdfs,
            output_store: store,
            hdfs_role: role,
            // Figure 1 runs the *Corral library* on-prem: no combiner.
            combiner: CombinerMode::None,
            ser: SerFormat::Json,
            split_bytes: 128 * MIB,
            replication: 1,
            igfs_capacity: 0,
            prewarm: true,
            materialize_cap: 32 * MIB,
            map_workers: 0,
            reduce_workers: 0,
            // Corral library on-prem: no checkpointing either.
            recovery: RecoveryConfig { stateful: false, ..Default::default() },
            failures: FailurePlan::disabled(),
            stragglers: StragglerProfile::disabled(),
            speculation: SpeculationConfig::disabled(),
            netfaults: NetFaultPlan::disabled(),
            arrivals: ArrivalConfig::default(),
            autoscale: AutoscaleConfig::default(),
            placement: PlacementStrategy::default(),
            partition: Partitioner::Hash,
        }
        .from_env()
    }
}

/// One phase of a finished job.
#[derive(Clone, Debug, Default)]
pub struct PhaseStats {
    pub tasks: usize,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub duration: SimNs,
}

/// How a pipeline stage's input splits resolved through the driver's
/// DRAM → PMEM-backing → HDFS → S3 fallback chain. `empty` counts
/// upstream reducers that emitted nothing. All-zero for path-staged
/// inputs.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HandoffStats {
    pub dram: u64,
    pub backing: u64,
    pub hdfs: u64,
    pub s3: u64,
    pub empty: u64,
}

impl HandoffStats {
    pub fn add(&mut self, other: &HandoffStats) {
        self.dram += other.dram;
        self.backing += other.backing;
        self.hdfs += other.hdfs;
        self.s3 += other.s3;
        self.empty += other.empty;
    }

    /// Splits that resolved to actual bytes (any tier).
    pub fn resolved(&self) -> u64 {
        self.dram + self.backing + self.hdfs + self.s3
    }
}

/// Everything a job run reports (feeds every table/figure bench).
#[derive(Clone, Debug)]
pub struct JobResult {
    pub job: String,
    pub config: String,
    pub input_bytes: u64,
    pub intermediate_bytes: u64,
    pub output_bytes: u64,
    pub map: PhaseStats,
    pub reduce: PhaseStats,
    pub job_time: SimNs,
    pub failed: Option<String>,
    pub cold_starts: u64,
    /// Invocations served by an already-warm container — on a shared
    /// cluster this includes containers warmed by *earlier jobs*
    /// (cross-job reuse; `super::JobServer` reports the split).
    pub warm_starts: u64,
    pub locality_ratio: f64,
    pub io: crate::metrics::IoSummary,
    /// Real wall-clock spent in the PJRT/oracle combine path.
    pub rt_batches: u64,
    pub rt_compute_ns: u64,
    /// IGFS cache activity attributable to this job: stage-handoff
    /// reads plus intermediate shuffle traffic through the cache.
    pub igfs: CacheStats,
    /// How the job's input splits resolved when they came from an
    /// upstream pipeline stage (all-zero for path-staged inputs).
    pub handoff: HandoffStats,
    /// Container attempts across all tasks (== tasks when no failures
    /// were injected; each injected crash adds a re-execution).
    pub task_attempts: u64,
    /// Bytes of split/partition work lost to crashes and redone —
    /// the fig8 stateful-vs-stateless comparison metric.
    pub recomputed_bytes: u64,
    /// Checkpoints written by this job's tasks under an armed stateful
    /// failure plan: IGFS state-store checkpoints plus speculative
    /// backups' scratch checkpoints.
    pub checkpoints: u64,
    /// Virtual time this job's tasks spent writing checkpoints — the
    /// price of stateful recovery on the failure-free path.
    pub checkpoint_overhead: SimNs,
    /// Speculative backup attempts launched for this job's tasks
    /// (0 unless `SystemConfig::speculation` is enabled and some task
    /// projected past the lag threshold).
    pub spec_backups: u64,
    /// Races the backup won (the original was cancelled). The rest of
    /// the backups lost and were cancelled themselves — either way
    /// exactly one copy of each speculated task completed.
    pub spec_backup_wins: u64,
    /// Flow deadlines this job's tasks blew (each one reaped the
    /// stalled transfer and retried it with backoff — not counted in
    /// `task_attempts`, which tracks container invocations).
    pub flow_timeouts: u64,
    /// Reads the cache tier could not serve (cache-node blackout) and
    /// a lower storage tier (HDFS/S3) served instead of erroring.
    pub degraded_reads: u64,
    /// Tasks (maps + reduces) the scheduler landed on a node named in
    /// their locality hints — an HDFS replica holder or an IGFS
    /// handoff-key owner. Together with `locality_ratio` (byte-
    /// weighted), this is the placement plane's report card: affinity
    /// strategies drive it toward the task count, Random reads as the
    /// luck baseline.
    pub affinity_hits: u64,
    /// Shuffle balance: p99/median of per-partition intermediate bytes
    /// (`util::stats::skew_coefficient`). 1.0 = perfectly even (also
    /// the degenerate no-shuffle report); `SkewAware` plans exist to
    /// pull this toward 1 on skewed workloads.
    pub partition_skew: f64,
    /// Hot keys the stage's partition plan spread across reducers
    /// (0 under `Hash`/`Range`, or when nothing crossed the skew
    /// threshold). Nonzero on a `Mergeable` workload is what makes a
    /// pipeline append the merge stage.
    pub hot_keys_split: u64,
}

impl JobResult {
    /// An all-zero successful report — the base for `failed` and the
    /// placeholder a pipeline records for a checkpoint-skipped stage.
    pub fn empty(job: &str, config: &str) -> JobResult {
        JobResult {
            job: job.into(),
            config: config.into(),
            input_bytes: 0,
            intermediate_bytes: 0,
            output_bytes: 0,
            map: PhaseStats::default(),
            reduce: PhaseStats::default(),
            job_time: SimNs::ZERO,
            failed: None,
            cold_starts: 0,
            warm_starts: 0,
            locality_ratio: 0.0,
            io: Default::default(),
            rt_batches: 0,
            rt_compute_ns: 0,
            igfs: CacheStats::default(),
            handoff: HandoffStats::default(),
            task_attempts: 0,
            recomputed_bytes: 0,
            checkpoints: 0,
            checkpoint_overhead: SimNs::ZERO,
            spec_backups: 0,
            spec_backup_wins: 0,
            flow_timeouts: 0,
            degraded_reads: 0,
            affinity_hits: 0,
            partition_skew: 1.0,
            hot_keys_split: 0,
        }
    }

    pub fn failed(job: &str, config: &str, input_bytes: u64, msg: String)
        -> JobResult
    {
        let mut r = JobResult::empty(job, config);
        r.input_bytes = input_bytes;
        r.failed = Some(msg);
        r
    }

    pub fn ok(&self) -> bool {
        self.failed.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_differ_where_it_matters() {
        let l = SystemConfig::corral_lambda();
        let h = SystemConfig::marvel_hdfs();
        let g = SystemConfig::marvel_igfs();
        assert_eq!(l.platform, Platform::Lambda);
        assert_eq!(l.combiner, CombinerMode::None);
        assert_eq!(h.intermediate_store, StoreKind::Hdfs);
        assert_eq!(g.intermediate_store, StoreKind::Igfs);
        assert_eq!(g.hdfs_role, DeviceRole::Pmem);
        assert!(g.name != h.name);
    }

    #[test]
    fn fig1_variants() {
        let a = SystemConfig::onprem(DeviceRole::Ssd, true);
        assert_eq!(a.input_store, StoreKind::S3);
        assert_eq!(a.hdfs_role, DeviceRole::Ssd);
        assert!(a.name.contains("ssd+s3"));
        let b = SystemConfig::onprem(DeviceRole::Pmem, false);
        assert_eq!(b.input_store, StoreKind::Hdfs);
    }

    #[test]
    fn worker_overrides_parse_and_apply() {
        // The pure halves of from_env — tested without env mutation
        // (set_var would race concurrent getenv in other test threads).
        assert_eq!(parse_workers(Some("3")), Some(3));
        assert_eq!(parse_workers(Some(" 8 ")), Some(8));
        assert_eq!(parse_workers(Some("auto")), None);
        assert_eq!(parse_workers(None), None);
        let c = SystemConfig::marvel_igfs()
            .with_worker_overrides(Some(3), Some(5));
        assert_eq!(c.map_workers, 3);
        assert_eq!(c.reduce_workers, 5);
        let d = c.clone().with_worker_overrides(None, None);
        assert_eq!(d.map_workers, 3);
        assert_eq!(d.reduce_workers, 5);
        // When CI's determinism matrix sets the env vars, every preset
        // picks them up; both fields agree under the matrix.
        let e = SystemConfig::marvel_igfs();
        let want_map = parse_workers(
            std::env::var("MARVEL_MAP_WORKERS").ok().as_deref(),
        )
        .unwrap_or(0);
        assert_eq!(e.map_workers, want_map);
    }

    #[test]
    fn handoff_stats_accumulate() {
        let mut a = HandoffStats {
            dram: 1,
            backing: 2,
            hdfs: 3,
            s3: 4,
            empty: 5,
        };
        a.add(&HandoffStats { dram: 10, ..Default::default() });
        assert_eq!(a.dram, 11);
        assert_eq!(a.resolved(), 11 + 2 + 3 + 4);
    }

    #[test]
    fn recovery_defaults_match_platform_story() {
        // Marvel checkpoints into the state store; Corral (Lambda and
        // the on-prem library) restarts from zero. No preset arms
        // failure injection by itself.
        assert!(SystemConfig::marvel_igfs().recovery.stateful);
        assert!(SystemConfig::marvel_hdfs().recovery.stateful);
        assert!(!SystemConfig::corral_lambda().recovery.stateful);
        assert!(!SystemConfig::onprem(DeviceRole::Ssd, false)
            .recovery
            .stateful);
        for cfg in [
            SystemConfig::marvel_igfs(),
            SystemConfig::corral_lambda(),
            SystemConfig::onprem(DeviceRole::Pmem, true),
        ] {
            assert!(!cfg.failures.enabled(), "{}", cfg.name);
        }
    }

    #[test]
    fn straggler_and_speculation_defaults_are_inert() {
        for cfg in [
            SystemConfig::corral_lambda(),
            SystemConfig::marvel_hdfs(),
            SystemConfig::marvel_igfs(),
            SystemConfig::onprem(DeviceRole::Ssd, false),
        ] {
            assert!(!cfg.stragglers.enabled(), "{}", cfg.name);
            assert!(!cfg.speculation.enabled, "{}", cfg.name);
            assert!(!cfg.netfaults.enabled(), "{}", cfg.name);
            assert!(!cfg.netfaults.blackout_armed(), "{}", cfg.name);
            // The open-loop plane and its autoscaler are equally inert
            // by default — closed-loop runs never consult them.
            assert!(!cfg.arrivals.enabled(), "{}", cfg.name);
            assert!(!cfg.autoscale.enabled, "{}", cfg.name);
            // Placement defaults to the legacy FairOrder path unless
            // CI's MARVEL_PLACEMENT column (or a config) overrides it.
            if std::env::var("MARVEL_PLACEMENT").is_err() {
                assert_eq!(
                    cfg.placement,
                    PlacementStrategy::FairOrder,
                    "{}",
                    cfg.name
                );
            }
            // Same for partitioning: legacy hash modulo unless CI's
            // MARVEL_PARTITIONER column (or a config) overrides it.
            if std::env::var("MARVEL_PARTITIONER").is_err() {
                assert_eq!(cfg.partition, Partitioner::Hash, "{}", cfg.name);
            }
        }
        assert!(SpeculationConfig::on().enabled);
        // Explicit field assignment after construction wins over the
        // MARVEL_STRAGGLER_SEED env default, like the failure seed.
        let mut c = SystemConfig::marvel_igfs();
        c.stragglers.seed = 99;
        assert_eq!(c.stragglers.seed, 99);
    }

    #[test]
    fn ser_overheads_ordered() {
        assert!(SerFormat::Json.record_overhead()
                > SerFormat::Binary.record_overhead());
    }
}
