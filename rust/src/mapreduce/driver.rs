//! Job driver: plans a MapReduce stage against a deployed cluster, runs
//! the *data plane* eagerly (real bytes through the real combine path),
//! compiles every task into a DES proc, and runs the *time plane* to a
//! deterministic completion time. Implements the paper's Figure 3
//! workflow steps 1–10.
//!
//! A stage's input comes either from a staged path ([`StageInput::Path`],
//! the classic single job) or from an upstream pipeline stage's reducer
//! outputs ([`StageInput::Handoff`]) resolved through the IGFS tiers:
//! DRAM hit → PMEM backing hit → HDFS → S3 fallback. Both the map and the
//! reduce data planes fan out over scoped host-thread pools under the
//! byte-identical determinism contract (see `pool_run`).
//!
//! Fault tolerance: with a `FailurePlan` armed in the `SystemConfig`,
//! each task's time-plane proc is compiled from its sampled attempt
//! schedule (`coordinator::recovery`) instead of a single invocation —
//! crashed attempts release their slot through the fair queue and lose
//! their container's warm state, stateful retries resume from the last
//! IGFS checkpoint, stateless ones restart from zero, and an exhausted
//! retry budget surfaces as a job error. Outputs stay byte-identical
//! to the failure-free run; see `ARCHITECTURE.md` (Fault tolerance).
//!
//! Stragglers & speculation: nodes carry speed factors
//! (`net::StragglerProfile` → `Topology::speed_of`), task procs spawn
//! speed-scaled, and with `SystemConfig::speculation` enabled the
//! planner backs up projected laggards with racing copies — first
//! finisher wins, the loser is cancelled and its container returns
//! warm. See `ARCHITECTURE.md` (Stragglers & speculation).

use crate::coordinator::recovery::{self, TaskRecovery};
use crate::faas::{ActionSpec, Controller, Lambda, HADOOP_RUNTIME};
use crate::igfs::{CacheStats, Tier};
use crate::metrics::{tags, IoSummary};
use crate::net::{NodeId, Topology, MAX_FLOW_RETRIES};
use crate::runtime::RtEngine;
use crate::sim::{BarrierId, Engine, PoolId, ProcId, SimNs, Stage};
use crate::storage::Payload;
use crate::yarn::{Allocation, ContainerRequest, ResourceManager};

use super::partition::PartitionPlan;
use super::shuffle::{interm_key_into, output_key_into, KeyHome, Stores};
use super::types::{
    HandoffStats, JobResult, PhaseStats, Platform, SpeculationConfig,
    StoreKind, SystemConfig,
};
use super::workload::{task_rng, MapOutput, ReduceOutput, Workload};

/// A deployed cluster jobs run against. A pipeline chains several
/// stages over one instance so virtual time and cache state carry
/// across stages; a [`super::JobServer`] co-runs many tenants' jobs
/// over one instance so warm container pools, cache capacity, and the
/// virtual clock are genuinely shared.
pub struct Cluster {
    pub engine: Engine,
    pub topo: Topology,
    pub stores: Stores,
    pub controller: Controller,
    pub lambda: Lambda,
    pub rm: ResourceManager,
    /// Fair-share class currently planning against this cluster (0 =
    /// unscoped single job). Stamped on spawned procs; the flow-tag
    /// namespace lives in `stores.tag_ns`. Set both via
    /// [`Cluster::set_tenant`] / [`Cluster::set_scope`].
    pub tenant: u32,
}

impl Cluster {
    /// Switch the tenant class subsequent planning runs under, keeping
    /// the stores' flow-tag namespace in lockstep (solo / one-job-per-
    /// tenant paths).
    pub fn set_tenant(&mut self, class: u32) {
        self.set_scope(class, class);
    }

    /// Set the fair-share class and the flow-tag namespace separately.
    /// A `JobServer` gives every planned *stage* its own tag namespace
    /// (so per-job I/O summaries never conflate two jobs of the same
    /// tenant) while all of a tenant's stages share one class.
    pub fn set_scope(&mut self, class: u32, tag_ns: u32) {
        self.tenant = class;
        self.stores.tag_ns = tag_ns;
    }
}

/// Stage the job input into the configured input store (deployment-time;
/// not billed to job execution, matching the paper's methodology).
/// Stages at the workload's default path — co-running the same
/// workload for several tenants needs [`stage_named_input`] instead.
pub fn stage_input(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    wl: &dyn Workload,
    bytes: u64,
    seed: u64,
) -> Result<String, String> {
    let path = format!("{}/input", wl.name());
    stage_named_input(cluster, cfg, wl, bytes, seed, &path)
}

/// [`stage_input`] at a caller-chosen path. Input *content* depends
/// only on `(seed, workload)` — never on the path — so a tenant's
/// staged copy is byte-identical to a solo run's.
pub fn stage_named_input(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    wl: &dyn Workload,
    bytes: u64,
    seed: u64,
    path: &str,
) -> Result<String, String> {
    let materialize = bytes <= cfg.materialize_cap;
    let mut rng = task_rng(seed, wl.name(), u64::MAX);
    let data = wl.generate_input(bytes, materialize, &mut rng);
    assert_eq!(data.len(), bytes, "workload generated wrong input size");
    let path = path.to_string();
    match cfg.input_store {
        StoreKind::S3 => {
            cluster.stores.s3.put(&path, data);
        }
        StoreKind::Hdfs | StoreKind::Igfs => {
            // Ingest from node 0; staging stages are dropped (untimed).
            cluster
                .stores
                .hdfs
                .put(&cluster.topo, NodeId(0), &path, data, tags::INPUT_READ)?;
        }
    }
    Ok(path)
}

/// Where a stage's input splits come from.
pub enum StageInput {
    /// A staged path in `cfg.input_store`, split by block locations
    /// (HDFS) or `split_bytes` (S3).
    Path(String),
    /// Handoff from an upstream pipeline stage: one split per upstream
    /// reducer output key, resolved at read time through the IGFS
    /// tiers (DRAM → PMEM backing → HDFS → S3 fallback).
    Handoff { keys: Vec<String> },
}

enum SplitSource {
    Range { offset: u64 },
    Key(String),
}

struct SplitPlan {
    source: SplitSource,
    len: u64,
    locality: Vec<NodeId>,
}

fn plan_splits(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    input: &str,
) -> Result<(u64, Vec<SplitPlan>), String> {
    match cfg.input_store {
        StoreKind::Hdfs | StoreKind::Igfs => {
            let locs = cluster.stores.hdfs.block_locations(input);
            if locs.is_empty() {
                return Err(format!("input {input} not in HDFS"));
            }
            let total = locs.iter().map(|(b, _)| b.len).sum();
            Ok((
                total,
                locs.into_iter()
                    .map(|(b, nodes)| SplitPlan {
                        source: SplitSource::Range { offset: b.offset },
                        len: b.len,
                        locality: nodes,
                    })
                    .collect(),
            ))
        }
        StoreKind::S3 => {
            let total = cluster
                .stores
                .s3
                .get(input)
                .ok_or_else(|| format!("input {input} not in S3"))?
                .len();
            let mut splits = Vec::new();
            let mut off = 0;
            while off < total {
                let len = cfg.split_bytes.min(total - off);
                splits.push(SplitPlan {
                    source: SplitSource::Range { offset: off },
                    len,
                    locality: vec![],
                });
                off += len;
            }
            if splits.is_empty() {
                splits.push(SplitPlan {
                    source: SplitSource::Range { offset: 0 },
                    len: 0,
                    locality: vec![],
                });
            }
            Ok((total, splits))
        }
    }
}

/// Plan handoff splits: one per upstream output key, located through
/// `Stores::locate` (the shared IGFS → HDFS → S3 chain; disturbs no
/// cache statistics). Locality hints: the IGFS owner, the first HDFS
/// replica set, or none for remote S3; a key absent everywhere is an
/// upstream reducer that emitted nothing.
fn plan_handoff(
    cluster: &mut Cluster,
    keys: Vec<String>,
) -> (u64, Vec<SplitPlan>) {
    let mut total = 0u64;
    let mut plans = Vec::with_capacity(keys.len());
    for key in keys {
        let (len, locality) = match cluster.stores.locate(&key) {
            Some((len, KeyHome::Igfs)) => {
                (len, vec![cluster.stores.igfs.owner(&key)])
            }
            Some((len, KeyHome::Hdfs)) => {
                let locs = cluster.stores.hdfs.block_locations(&key);
                let first = locs
                    .first()
                    .map(|(_, nodes)| nodes.clone())
                    .unwrap_or_default();
                (len, first)
            }
            Some((len, KeyHome::S3)) => (len, Vec::new()),
            None => (0, Vec::new()),
        };
        total += len;
        plans.push(SplitPlan {
            source: SplitSource::Key(key),
            len,
            locality,
        });
    }
    (total, plans)
}

/// Count allocations that landed on a node named in their request's
/// locality hints — HDFS replica holders or IGFS handoff-key owners.
/// Any `LocalityLevel` counts: a strict strategy's queued-on-holder
/// placement still routes the task's reads to local bytes.
fn count_affinity_hits(
    reqs: &[ContainerRequest],
    allocs: &[Allocation],
) -> u64 {
    allocs
        .iter()
        .filter(|a| reqs[a.request_idx].locality.contains(&a.node))
        .count() as u64
}

/// CacheAffinity reducer hints: the nodes holding partition `j`'s
/// intermediate keys, heaviest byte share first (node-id tie-break).
/// Resolved through the stat-free `Stores::locate` chain, so computing
/// hints disturbs no cache statistics — and only the scheduler reads
/// them, so hints can move a reducer's node but never its bytes.
fn reduce_affinity_hints(
    stores: &mut Stores,
    job: &str,
    n_maps: usize,
    j: usize,
) -> Vec<NodeId> {
    let mut by_node: Vec<(NodeId, u64)> = Vec::new();
    let mut key = String::new();
    for i in 0..n_maps {
        interm_key_into(&mut key, job, i, j);
        let holder = match stores.locate(&key) {
            Some((len, KeyHome::Igfs)) => {
                Some((stores.igfs.owner(&key), len))
            }
            Some((len, KeyHome::Hdfs)) => stores
                .hdfs
                .block_locations(&key)
                .first()
                .and_then(|(_, nodes)| nodes.first().copied())
                .map(|n| (n, len)),
            _ => None, // S3 (no node) or an empty mapper output
        };
        if let Some((n, len)) = holder {
            // A mapper that emitted nothing for this partition wrote no
            // key; len.max(1) keeps zero-length-but-present keys votable.
            match by_node.iter_mut().find(|(m, _)| *m == n) {
                Some((_, b)) => *b += len.max(1),
                None => by_node.push((n, len.max(1))),
            }
        }
    }
    by_node.sort_by(|a, b| b.1.cmp(&a.1).then(a.0 .0.cmp(&b.0 .0)));
    by_node.into_iter().map(|(n, _)| n).collect()
}

/// Which tier served a handoff split.
enum HandoffTier {
    Dram,
    Backing,
    Hdfs,
    S3,
    Empty,
}

/// Resolve one handoff key on `node`: IGFS first (the tier the hit came
/// from prices the read), then HDFS, then S3, else an empty split. The
/// payload is a zero-copy view over the serving store's buffers in
/// every case.
fn read_handoff(
    stores: &mut Stores,
    engine: &mut Engine,
    topo: &Topology,
    node: NodeId,
    key: &str,
    tag: u32,
) -> Result<(Payload, Vec<Stage>, HandoffTier, bool), String> {
    if let Some((data, st, tier)) =
        stores.igfs.get_tiered(topo, node, key, tag)
    {
        let local = stores.igfs.owner(key) == node;
        let tier = match tier {
            Tier::Dram => HandoffTier::Dram,
            Tier::Backing => HandoffTier::Backing,
        };
        return Ok((data, st, tier, local));
    }
    if stores.hdfs.namenode.stat(key).is_some() {
        let (data, st, _, remote) =
            stores.hdfs.read(topo, node, key, tag)?;
        return Ok((data, st, HandoffTier::Hdfs, remote == 0));
    }
    if let Some(data) = stores.s3.get(key) {
        let st = stores.s3.get_stages(engine, topo, node, data.len(), tag);
        return Ok((data, st, HandoffTier::S3, false));
    }
    Ok((Payload::real(Vec::new()), Vec::new(), HandoffTier::Empty, true))
}

/// Replay input-read `stages` covering only `num` of `den` bytes: flow
/// volumes scale proportionally — an attempt that crashed at byte *f*
/// of its split only fetched ~*f* input bytes, and a stateful resume
/// re-reads only the tail it recomputes. Per-request latency delays
/// are unchanged; a zero-span (startup crash) reads nothing.
fn scale_flows(stages: &[Stage], num: u64, den: u64) -> Vec<Stage> {
    if num == 0 {
        return Vec::new();
    }
    if den == 0 || num >= den {
        return stages.to_vec();
    }
    let frac = num as f64 / den as f64;
    stages
        .iter()
        .map(|s| match s {
            Stage::Flow { bytes, path, tag, timeout } => Stage::Flow {
                bytes: bytes * frac,
                path: path.clone(),
                tag: *tag,
                timeout: *timeout,
            },
            other => other.clone(),
        })
        .collect()
}

/// Arm a flow deadline on every transfer stage of a task proc. Only
/// called with a live fault plan — legacy runs keep their
/// `timeout: None` stages bit-for-bit.
fn arm_flow_timeouts(stages: &mut [Stage], deadline: SimNs) {
    for s in stages.iter_mut() {
        if let Stage::Flow { timeout, .. } = s {
            *timeout = Some(deadline);
        }
    }
}

/// Base delay for a timed-out flow's backoff ladder: the recovery
/// policy's knob when set, else one deadline — the retry cadence then
/// tracks the timeout itself, which rides out any fault window well
/// within `MAX_FLOW_RETRIES` attempts.
fn flow_backoff_base(cfg: &SystemConfig) -> SimNs {
    if cfg.recovery.backoff_base > SimNs::ZERO {
        cfg.recovery.backoff_base
    } else {
        cfg.netfaults.flow_timeout
    }
}

/// Compile a task's failure-injected attempt schedule into time-plane
/// stages. Every attempt is a fresh container invocation: it
/// re-acquires a slot *through the fair queue* (a crashed attempt's
/// Release hands the slot to whoever is next — possibly a co-tenant),
/// replays the input span it covers, pays compute plus checkpoint
/// latency, and a crashed attempt emits a [`Stage::Crash`] event and
/// loses its container (warm state destroyed, so retries may
/// cold-start). Returns the final attempt's slot (which the caller's
/// success tail releases) and the total checkpoint overhead charged.
#[allow(clippy::too_many_arguments)] // mirrors the task-compilation actors
fn compile_attempts(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    spec: &ActionSpec,
    node: NodeId,
    in_stages: &[Stage],
    work: u64,
    rate: f64,
    tr: &TaskRecovery,
    stages: &mut Vec<Stage>,
) -> (PoolId, SimNs) {
    let per_ckpt = cfg.recovery.per_checkpoint;
    // The reported overhead is *virtual time spent*: the engine
    // stretches this proc's Delay stages by 1/node-speed, so the tally
    // must stretch identically or a straggler's checkpoint cost would
    // be under-reported.
    let speed = cluster.topo.speed_of(node);
    let mut overhead = SimNs::ZERO;
    let mut slot = PoolId(0);
    for (a, seg) in tr.segments.iter().enumerate() {
        let (s, startup) = invoke_once(cluster, cfg, spec, node);
        slot = s;
        stages.push(Stage::Acquire(slot));
        stages.push(Stage::Delay(startup));
        let span = seg.end - seg.start;
        stages.extend(scale_flows(in_stages, span, work));
        if span > 0 && rate > 0.0 {
            stages.push(Stage::Delay(SimNs::from_secs_f64(
                span as f64 / rate,
            )));
        }
        if seg.checkpoints > 0 {
            let d = SimNs::from_nanos(
                per_ckpt.as_nanos() * seg.checkpoints as u64,
            );
            overhead += d.div_speed(speed);
            stages.push(Stage::Delay(d));
        }
        if seg.crashed {
            let (n, at) = (a + 1, seg.end);
            stages.push(Stage::Release(slot));
            stages.push(Stage::Crash(format!(
                "attempt {n} crashed at byte {at} of {work}"
            )));
            match cfg.platform {
                Platform::OpenWhisk => cluster.controller.crash(spec, node),
                Platform::Lambda => cluster.lambda.crash(),
            }
            // Capped exponential backoff before the next attempt
            // re-enters the fair queue (inert with the ZERO default —
            // legacy recovery timings are pinned).
            let wait = cfg.recovery.backoff_for((a + 1) as u32);
            if wait > SimNs::ZERO {
                stages.push(Stage::Delay(wait));
            }
        }
    }
    (slot, overhead)
}

/// Plan-time speculation decisions for one phase's tasks: which tasks
/// get a backup attempt, on which node, and when backups launch.
///
/// A task is backed up when its *projected* duration (`work / rate /
/// node speed` — the driver knows every node's speed factor, the DES
/// analog of observing task progress) exceeds the configured lag
/// factor × the phase median. Backups go to the fastest nodes,
/// rotating across equally-fast hosts and avoiding the original's
/// node when the cluster has more than one; they launch at the phase
/// median — the instant Hadoop's speculative scheduler would notice
/// the task running long past its peers.
fn plan_backups(
    topo: &Topology,
    sc: &SpeculationConfig,
    nodes: &[NodeId],
    ests: &[f64],
) -> (Vec<Option<NodeId>>, SimNs) {
    let none = (vec![None; ests.len()], SimNs::ZERO);
    if !sc.enabled || ests.is_empty() {
        return none;
    }
    let mut sorted = ests.to_vec();
    sorted.sort_by(f64::total_cmp);
    let median = sorted[sorted.len() / 2];
    if !median.is_finite() || median <= 0.0 {
        return none;
    }
    let lag = if sc.lag_factor.is_finite() {
        sc.lag_factor.max(1.0)
    } else {
        return none;
    };
    let mut by_speed: Vec<NodeId> =
        (0..topo.n_nodes()).map(NodeId).collect();
    by_speed.sort_by(|a, b| {
        topo.speed_of(*b)
            .total_cmp(&topo.speed_of(*a))
            .then(a.0.cmp(&b.0))
    });
    let backups = ests
        .iter()
        .enumerate()
        .map(|(i, est)| {
            if *est <= lag * median {
                return None;
            }
            // Fastest node that is NOT the original's host, rotating
            // across equally-fast candidates for spread. Even when the
            // original already sits on the unique fastest node (a
            // skewed split, not a slow host), the backup goes to the
            // best *other* host — racing on queueing alone against
            // yourself is pointless. Only a single-node cluster falls
            // back to sharing the original's host.
            let others: Vec<NodeId> = by_speed
                .iter()
                .copied()
                .filter(|n| *n != nodes[i])
                .collect();
            if others.is_empty() {
                return Some(nodes[i]);
            }
            let top = topo.speed_of(others[0]);
            let fast: Vec<NodeId> = others
                .iter()
                .copied()
                .filter(|n| topo.speed_of(*n) >= top)
                .collect();
            Some(fast[i % fast.len()])
        })
        .collect();
    (backups, SimNs::from_secs_f64(median))
}

/// Compile and spawn one speculative backup attempt: after the phase
/// gate it idles until `launch` (the lag-detection instant), then
/// re-acquires a slot on `node` *through the fair queue* under the
/// same tenant class, replays the original's input volumes, pays the
/// compute at its own node's speed, optionally stages its in-flight
/// partial checkpoint under the task's speculative scratch key, replays
/// the output-write volumes, and closes the race: `Cancel` the
/// original, `Arrive` at the phase barrier. Returns the backup's proc
/// id so the caller can append the mirror-image `Cancel` + `Arrive`
/// tail to the original — first finisher wins, loser is reaped with
/// its container returned warm.
///
/// Input/output replays reuse the original's stage volumes (the bytes
/// are identical by construction); only the compute delay is
/// re-derived, since the engine scales it by the backup node's speed.
#[allow(clippy::too_many_arguments)] // one per racer coordinate
fn compile_backup(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    spec: &ActionSpec,
    node: NodeId,
    gate: Option<BarrierId>,
    launch: SimNs,
    replay: &[Stage],
    work: u64,
    rate: f64,
    out_stages: &[Stage],
    arrive: BarrierId,
    cancel: ProcId,
    label: &str,
    scratch: Option<(String, Vec<u8>)>,
) -> Result<ProcId, String> {
    let class = cluster.tenant;
    let mut stages = Vec::new();
    if let Some(g) = gate {
        stages.push(Stage::Await(g));
    }
    if launch > SimNs::ZERO {
        stages.push(Stage::Delay(launch));
    }
    let (slot, startup) = invoke_once(cluster, cfg, spec, node);
    stages.push(Stage::Acquire(slot));
    stages.push(Stage::Delay(startup));
    stages.extend(replay.iter().cloned());
    if work > 0 && rate > 0.0 {
        stages.push(Stage::Delay(SimNs::from_secs_f64(
            work as f64 / rate,
        )));
    }
    if let Some((key, partial)) = scratch {
        // The backup's in-flight partial checkpoint, staged under the
        // task's speculative scratch prefix. The caller scrubs that
        // prefix with `Stores::clear_prefix` once the race is
        // compiled, so a write-once backend (HDFS) can never collide
        // with a survivor of a cancelled attempt on re-execution.
        let st = cluster.stores.write_intermediate(
            &mut cluster.engine,
            &cluster.topo,
            cfg.intermediate_store,
            node,
            &key,
            Payload::real(partial),
        )?;
        stages.extend(st);
        stages.push(Stage::Delay(cfg.recovery.per_checkpoint));
    }
    stages.extend(out_stages.iter().cloned());
    stages.push(Stage::Release(slot));
    stages.push(Stage::Cancel(cancel));
    stages.push(Stage::Arrive(arrive));
    let speed = cluster.topo.speed_of(node);
    let pid = cluster.engine.spawn_scaled(label, class, speed, stages);
    if cfg.platform == Platform::OpenWhisk {
        cluster.controller.complete(spec, node);
    } else {
        cluster.lambda.finish();
    }
    Ok(pid)
}

/// Stage-level recovery bookkeeping accumulated across map and reduce
/// tasks (lands in the [`JobResult`] counters).
#[derive(Default)]
struct RecoveryTally {
    task_attempts: u64,
    recomputed_bytes: u64,
    checkpoints: u64,
    overhead: SimNs,
    /// First task that exhausted its retry budget: the job is doomed,
    /// and `plan_stage` must error before any further output bytes
    /// land under the job's shared keys.
    doomed: Option<String>,
}

impl RecoveryTally {
    /// Account a speculative backup's scratch checkpoint — written
    /// only while a stateful failure plan is armed, mirroring the
    /// stage `compile_backup` compiles. The overhead is *virtual time
    /// spent*: the engine stretches the backup's Delay by
    /// 1/node-speed, so the tally stretches identically.
    fn tally_scratch_ckpt(
        &mut self,
        cluster: &Cluster,
        cfg: &SystemConfig,
        node: NodeId,
    ) {
        if !cfg.failures.enabled() || !cfg.recovery.stateful {
            return;
        }
        let speed = cluster.topo.speed_of(node);
        self.checkpoints += 1;
        self.overhead += cfg.recovery.per_checkpoint.div_speed(speed);
    }
}

/// One container invocation on the configured platform: the slot pool
/// the task body must hold and the startup latency it pays. The single
/// source of invocation accounting for the failure-free map/reduce
/// branches and every injected attempt in [`compile_attempts`].
fn invoke_once(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    spec: &ActionSpec,
    node: NodeId,
) -> (PoolId, SimNs) {
    match cfg.platform {
        Platform::OpenWhisk => {
            let inv = cluster.controller.invoke(spec, node);
            (cluster.controller.slots_of(node), inv.startup)
        }
        Platform::Lambda => {
            let (lat, _) = cluster.lambda.startup();
            (cluster.lambda.concurrency, lat)
        }
    }
}

/// Sample one task's crash schedule from the armed plan, run the
/// shared recovery policy against the cluster's real state store
/// (checkpoints land under `("{job}/{kind}", idx)` and the record is
/// dropped once the segments are extracted, so a long-lived server's
/// state store stays bounded), and fold the outcome into the stage
/// tally. The returned schedule feeds [`compile_attempts`].
#[allow(clippy::too_many_arguments)] // one per task coordinate, like run_stage
fn plan_task_recovery(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    job: &str,
    kind: &str,
    idx: u64,
    work: u64,
    partial: &[u8],
    tally: &mut RecoveryTally,
) -> TaskRecovery {
    let fails = cfg.failures.failures_for(job, kind, idx, work);
    let state_job = format!("{job}/{kind}");
    let tr = recovery::run_with_failures(
        &mut cluster.stores.igfs.state,
        &cfg.recovery,
        &state_job,
        idx as u32,
        work,
        &fails,
        cfg.recovery.stateful,
        partial,
    );
    cluster.stores.igfs.state.remove(&state_job, idx as u32);
    tally.task_attempts += tr.attempts as u64;
    tally.recomputed_bytes += tr.bytes_recomputed;
    tally.checkpoints += tr.checkpoints();
    tr
}

/// Resolve a data-plane worker count: explicit, or the host's available
/// parallelism when `requested` is 0; never more workers than items.
fn effective_workers(requested: usize, n_items: usize) -> usize {
    let w = if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    };
    w.clamp(1, n_items.max(1))
}

/// Run `f(i, rt)` for every `i in 0..n`, fanning out across `workers`
/// host threads (via `util::pool::run_indexed`).
///
/// DESIGN — determinism contract: output is byte-identical to the
/// serial path at ANY worker count because (a) each item's work is
/// derived independently (no shared mutable state between items), (b)
/// each worker owns a private `RtEngine` oracle instance aliasing the
/// job engine's frozen `Arc<Manifest>` (same constants, zero re-derive
/// per spawn; combine counts are integer-valued f32s, so oracle and
/// PJRT agree bitwise), and (c) results land in a per-item slot and are
/// consumed in item order — scheduling order affects nothing but
/// wall-clock. Only the data plane parallelizes; the DES time plane
/// stays single-threaded and deterministic. Worker `RtStats` are folded
/// back into the job-level engine.
fn pool_run<T, F>(rt: &mut RtEngine, workers: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut RtEngine) -> T + Sync,
{
    if workers <= 1 || n <= 1 {
        // Serial path runs on the job engine itself (PJRT when built).
        return (0..n).map(|i| f(i, rt)).collect();
    }
    let manifest = rt.manifest.clone(); // Arc bump, not a deep copy
    let (out, worker_rts) = crate::util::pool::run_indexed(
        workers,
        n,
        || RtEngine::oracle_shared(manifest.clone()),
        f,
    );
    for wrt in &worker_rts {
        rt.absorb_stats(&wrt.stats);
    }
    out
}

/// Run `map_split` over every fetched split across `workers` host
/// threads, routing emissions through one shared [`PartitionPlan`].
/// Per-split RNG streams derive from the *workload name*
/// (`task_rng(seed, wl.name(), i)`), so the split schedule cannot
/// influence data — see the `pool_run` determinism contract.
pub fn map_splits_parallel(
    wl: &dyn Workload,
    datas: &[Payload],
    plan: &PartitionPlan,
    cfg: &SystemConfig,
    rt: &mut RtEngine,
    seed: u64,
    workers: usize,
) -> Vec<MapOutput> {
    let job = wl.name();
    pool_run(rt, workers, datas.len(), |i, wrt| {
        let mut rng = task_rng(seed, job, i as u64);
        wl.map_split(&datas[i], plan, cfg, wrt, &mut rng)
    })
}

/// Run `reduce_partition` over every partition's gathered inputs across
/// `workers` host threads. Each partition is reduced by exactly one
/// worker over inputs pre-gathered in mapper order, so worker count is
/// invisible in every output bit (`pool_run` contract).
pub fn reduce_partitions_parallel(
    wl: &dyn Workload,
    inputs: &[Vec<Payload>],
    n_reduces: usize,
    cfg: &SystemConfig,
    rt: &mut RtEngine,
    workers: usize,
) -> Vec<ReduceOutput> {
    pool_run(rt, workers, inputs.len(), |j, wrt| {
        wl.reduce_partition(j, n_reduces, &inputs[j], cfg, wrt)
    })
}

/// Run one job end-to-end. `seed` drives all data-plane randomness.
pub fn run_job(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    wl: &dyn Workload,
    input: &str,
    rt: &mut RtEngine,
    seed: u64,
) -> JobResult {
    let stage_in = StageInput::Path(input.to_string());
    match run_stage(cluster, cfg, wl, wl.name(), stage_in, rt, seed) {
        Ok(r) => r,
        Err(e) => {
            let input_bytes = match cfg.input_store {
                // Stat-free probe: sizing an error report must not
                // count a phantom GET (same contract as
                // `Stores::locate`).
                StoreKind::S3 => cluster
                    .stores
                    .s3
                    .len_of(input)
                    .unwrap_or(0),
                _ => cluster
                    .stores
                    .hdfs
                    .namenode
                    .stat(input)
                    .map(|i| i.len)
                    .unwrap_or(0),
            };
            JobResult::failed(wl.name(), &cfg.name, input_bytes, e)
        }
    }
}

/// Plan bookkeeping for one reducer between the gather and time planes.
struct ReducePlan {
    node: NodeId,
    /// Failure-free invocation, made at gather time (slot + startup);
    /// `None` under an armed failure plan — the attempt schedule then
    /// invokes per attempt at compile time.
    invoked: Option<(PoolId, SimNs)>,
    /// Shuffle-read stages, replayed per attempt on retries.
    in_stages: Vec<Stage>,
}

/// Run one MapReduce stage to completion. `job` names the stage (it
/// prefixes every shuffle/output key, so pipeline stages sharing a
/// workload stay disjoint); single jobs pass `wl.name()`.
///
/// Equivalent to [`plan_stage`] + `engine.run()` + [`finalize_stage`]
/// — the split a [`super::JobServer`] uses to overlap many jobs' time
/// planes on one engine.
pub fn run_stage(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    wl: &dyn Workload,
    job: &str,
    input: StageInput,
    rt: &mut RtEngine,
    seed: u64,
) -> Result<JobResult, String> {
    let planned = plan_stage(cluster, cfg, wl, job, input, None, rt, seed)?;
    let end = cluster.engine.run()?;
    finalize_stage(cluster, planned, end)
}

/// A stage whose *data plane* has fully run (real bytes through the
/// stores) and whose task procs are spawned, but whose *time plane*
/// has not: the caller still owes an `engine.run()`. Produced by
/// [`plan_stage`]; turned into a [`JobResult`] by [`finalize_stage`].
pub struct PlannedStage {
    /// Job id — prefixes every key and every proc label.
    pub job: String,
    /// Opens when the last reducer arrives: the job's completion
    /// instant, and the gate a chained downstream stage awaits.
    pub job_done: BarrierId,
    maps_done: BarrierId,
    cfg_name: String,
    tag_ns: u32,
    t_start: SimNs,
    flows0: usize,
    input_bytes: u64,
    intermediate_bytes: u64,
    output_bytes: u64,
    reduce_in_bytes: u64,
    n_maps: usize,
    n_reduces: usize,
    map_in_local: u64,
    map_in_remote: u64,
    handoff: HandoffStats,
    igfs: CacheStats,
    cold_starts: u64,
    warm_starts: u64,
    rt_batches: u64,
    rt_compute_ns: u64,
    task_attempts: u64,
    recomputed_bytes: u64,
    checkpoints: u64,
    checkpoint_overhead: SimNs,
    spec_backups: u64,
    affinity_hits: u64,
    partition_skew: f64,
    hot_keys_split: u64,
}

impl PlannedStage {
    /// Reducer count — how many output keys (`output_key(job, 0..n)`)
    /// this stage will leave behind; a chained next stage's handoff
    /// key set.
    pub fn n_reduces(&self) -> usize {
        self.n_reduces
    }

    /// Name of the system config this stage was planned under.
    pub fn cfg_name(&self) -> &str {
        &self.cfg_name
    }
}

/// Assemble the [`JobResult`] for a planned stage once the shared
/// engine has run. `engine_end` (the `engine.run()` return) backstops
/// barrier timestamps that never opened. Fails if any of *this job's*
/// procs failed; co-tenants' failures are theirs to report.
pub fn finalize_stage(
    cluster: &Cluster,
    p: PlannedStage,
    engine_end: SimNs,
) -> Result<JobResult, String> {
    let prefix = format!("{}/", p.job);
    if let Some(msg) = cluster.engine.failure_with_prefix(&prefix) {
        return Err(format!("task failed: {msg}"));
    }
    // Speculation census: every resolved race cancelled exactly one
    // racer — a cancelled backup lost, a cancelled original means the
    // backup won.
    let cancelled = cluster.engine.cancelled_with_prefix(&prefix);
    let spec_backup_wins = cancelled
        .iter()
        .filter(|l| !l.ends_with("/bak"))
        .count() as u64;
    let maps_end = cluster
        .engine
        .barrier_opened_at(p.maps_done)
        .unwrap_or(engine_end);
    let end = cluster
        .engine
        .barrier_opened_at(p.job_done)
        .unwrap_or(engine_end);
    let job_time = end.saturating_sub(p.t_start);
    let io = IoSummary::for_tenant(
        &cluster.engine.flow_log[p.flows0..],
        p.tag_ns,
        job_time,
    );
    let placed = p.map_in_local + p.map_in_remote;
    Ok(JobResult {
        job: p.job,
        config: p.cfg_name,
        input_bytes: p.input_bytes,
        intermediate_bytes: p.intermediate_bytes,
        output_bytes: p.output_bytes,
        map: PhaseStats {
            tasks: p.n_maps,
            bytes_in: p.input_bytes,
            bytes_out: p.intermediate_bytes,
            duration: maps_end.saturating_sub(p.t_start),
        },
        reduce: PhaseStats {
            tasks: p.n_reduces,
            bytes_in: p.reduce_in_bytes,
            bytes_out: p.output_bytes,
            duration: end.saturating_sub(maps_end),
        },
        job_time,
        failed: None,
        cold_starts: p.cold_starts,
        warm_starts: p.warm_starts,
        locality_ratio: if placed > 0 {
            p.map_in_local as f64 / placed as f64
        } else {
            0.0
        },
        io,
        rt_batches: p.rt_batches,
        rt_compute_ns: p.rt_compute_ns,
        igfs: p.igfs,
        handoff: p.handoff,
        task_attempts: p.task_attempts,
        recomputed_bytes: p.recomputed_bytes,
        checkpoints: p.checkpoints,
        checkpoint_overhead: p.checkpoint_overhead,
        spec_backups: p.spec_backups,
        spec_backup_wins,
        // Engine-level flow deadline expiries are transport retries,
        // not task attempts — reported separately from task_attempts.
        flow_timeouts: cluster.engine.timeouts_with_prefix(&prefix) as u64,
        degraded_reads: p.igfs.degraded_reads,
        affinity_hits: p.affinity_hits,
        partition_skew: p.partition_skew,
        hot_keys_split: p.hot_keys_split,
    })
}

/// Plan one MapReduce stage: run its data plane eagerly and spawn its
/// time-plane procs under the cluster's current tenant class — without
/// running the engine. `after` gates every map task on an upstream
/// barrier (chained submissions); `None` for independent jobs.
///
/// The data plane executes *here*, in admission order, under the
/// byte-identical determinism contract (`pool_run`): planning jobs in
/// any order yields the same bytes in every store because job keys are
/// prefix-disjoint, task RNGs derive from `(seed, workload, task)`
/// only, and cache eviction merely moves entries between tiers.
#[allow(clippy::too_many_arguments)] // one per Figure-3 actor, like run_stage
pub fn plan_stage(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    wl: &dyn Workload,
    job: &str,
    input: StageInput,
    after: Option<BarrierId>,
    rt: &mut RtEngine,
    seed: u64,
) -> Result<PlannedStage, String> {
    let job = job.to_string();
    // Fair-share class (spawned procs, yarn queue) and flow-tag
    // namespace (I/O attribution) — identical on solo paths, distinct
    // under a JobServer (class per tenant, namespace per stage).
    let class = cluster.tenant;
    let ns = cluster.stores.tag_ns;
    let in_tag = tags::scoped(tags::INPUT_READ, ns);
    let t_start = cluster.engine.now();
    let rt_batches0 = rt.stats.batches;
    let rt_ns0 = rt.stats.pjrt_ns + rt.stats.oracle_ns;
    let igfs0 = cluster.stores.igfs.stats();
    // Flow-log / container-start offsets: a pipeline or a co-run plans
    // many stages on one engine, and this stage's report must cover
    // only its own activity.
    let flows0 = cluster.engine.flow_log.len();
    let cold0 =
        cluster.controller.cold_starts() + cluster.lambda.cold_starts;
    let warm0 =
        cluster.controller.warm_starts() + cluster.lambda.warm_starts;
    let mut handoff = HandoffStats::default();

    // Failure injection (inert by default). DataNode losses land
    // before split planning so stale NameNode locality hints and
    // surviving-replica fallback both get exercised; container-crash
    // schedules are sampled per task below. Recovery bookkeeping
    // accumulates across both phases.
    let inject = cfg.failures.enabled();
    // Degraded-mode I/O (inert by default). A blackout plan arms
    // write-through — IGFS intermediates also persist beneath the
    // cache, so a mid-job cache loss has tiers to degrade *to* — and,
    // when the plan allows it, tier-degraded reads. Flow deadlines arm
    // per task proc below whenever the fault plan is live.
    let faulty = cfg.netfaults.enabled();
    cluster.stores.write_through = cfg.netfaults.blackout_armed();
    cluster.stores.degraded =
        cfg.netfaults.blackout_armed() && cfg.netfaults.degraded_tiers;
    if inject {
        for &n in &cfg.failures.lose_datanodes {
            // A typo'd node id must not silently degrade the plan to a
            // failure-free baseline run.
            if n >= cluster.topo.n_nodes() {
                return Err(format!(
                    "failure plan names DataNode {n}, cluster has {}",
                    cluster.topo.n_nodes()
                ));
            }
            cluster.stores.hdfs.fail_datanode(NodeId(n));
        }
    }
    let mut tally = RecoveryTally::default();

    // (1–3) Client → controller → YARN: size the job.
    let (path, (input_bytes, splits)) = match input {
        StageInput::Path(p) => {
            let planned = plan_splits(cluster, cfg, &p)?;
            (Some(p), planned)
        }
        StageInput::Handoff { keys } => (None, plan_handoff(cluster, keys)),
    };
    let n_splits = splits.len();
    let (n_maps, n_reduces) =
        cluster.rm.size_job(n_splits, rt.manifest.parts);

    // Partition plan: key→partition routing for the whole stage,
    // decided before any data moves. Hot-key detection reads the
    // workload's analytic profile (stat-free, deterministic per seed);
    // `Partitioner::Hash` plans reproduce the legacy `key % parts`
    // routing bit-for-bit.
    let plan =
        PartitionPlan::build(&cfg.partition, wl, input_bytes, n_reduces, seed);

    // Lambda admission: the Corral baseline dies past the transfer
    // quota (paper §4.2.1 observation 1).
    if cfg.platform == Platform::Lambda {
        cluster.lambda.admit_job(input_bytes, n_maps + n_reduces)?;
    }

    // (4) Placement for map tasks (locality from the NameNode for
    // ranges, from the IGFS owner / HDFS replicas for handoff keys).
    let map_reqs: Vec<ContainerRequest> = splits
        .iter()
        .map(|s| ContainerRequest {
            vcores: 1,
            memory_mb: 2048,
            locality: s.locality.clone(),
        })
        .collect();
    // Placement runs under the tenant's fair queue when one is
    // registered (JobServer co-runs); the default queue otherwise.
    let qid = if (class as usize) < cluster.rm.scheduler.queues.len() {
        class as usize
    } else {
        0
    };
    let map_allocs = cluster.rm.allocate_for(qid, &map_reqs);
    let mut affinity_hits = count_affinity_hits(&map_reqs, &map_allocs);
    if cfg.prewarm && cfg.platform == Platform::OpenWhisk {
        cluster.controller.prewarm(HADOOP_RUNTIME, 64);
    }

    let maps_done = cluster.engine.add_barrier(n_maps);
    let job_done = cluster.engine.add_barrier(n_reduces);
    let map_spec = ActionSpec::map(&job, 2048);
    let reduce_spec = ActionSpec::reduce(&job, 2048);

    // (5–7) Map phase: data plane now, time plane as procs.
    //
    // Three sub-phases. Fetch is serial (it touches the stores and the
    // DES engine) but zero-copy: an HDFS split read is a view assembly
    // over the DataNodes' block buffers, an S3 split is an O(1) slice
    // of the object, and a handoff key is a view over the IGFS owner's
    // cache entry. Map compute — the actually expensive part — fans
    // out across host threads. Time-plane spawning is serial again, in
    // split order, so the DES stays deterministic.
    let mut intermediate_bytes = 0u64;
    let mut map_in_local = 0u64;
    let mut map_in_remote = 0u64;
    let mut datas = Vec::with_capacity(splits.len());
    let mut in_stages_per_split = Vec::with_capacity(splits.len());
    for (i, split) in splits.iter().enumerate() {
        let node = map_allocs[i].node;
        let (data, in_stages) = match &split.source {
            SplitSource::Range { offset } => {
                let path = path.as_deref().expect("range split without path");
                match cfg.input_store {
                    StoreKind::Hdfs | StoreKind::Igfs => {
                        let (d, st, local) = cluster.stores.hdfs.read_range(
                            &cluster.topo,
                            node,
                            path,
                            *offset,
                            split.len,
                            in_tag,
                        )?;
                        if local {
                            map_in_local += split.len;
                        } else {
                            map_in_remote += split.len;
                        }
                        (d, st)
                    }
                    StoreKind::S3 => {
                        let whole = cluster
                            .stores
                            .s3
                            .get(path)
                            .ok_or("input vanished")?;
                        let d = whole.slice(*offset, split.len);
                        let st = cluster.stores.s3.get_stages(
                            &mut cluster.engine,
                            &cluster.topo,
                            node,
                            split.len,
                            in_tag,
                        );
                        map_in_remote += split.len;
                        (d, st)
                    }
                }
            }
            SplitSource::Key(key) => {
                let (d, st, tier, local) = read_handoff(
                    &mut cluster.stores,
                    &mut cluster.engine,
                    &cluster.topo,
                    node,
                    key,
                    in_tag,
                )?;
                match tier {
                    HandoffTier::Dram => handoff.dram += 1,
                    HandoffTier::Backing => handoff.backing += 1,
                    HandoffTier::Hdfs => handoff.hdfs += 1,
                    HandoffTier::S3 => handoff.s3 += 1,
                    HandoffTier::Empty => handoff.empty += 1,
                }
                if local {
                    map_in_local += split.len;
                } else {
                    map_in_remote += split.len;
                }
                (d, st)
            }
        };
        datas.push(data);
        in_stages_per_split.push(in_stages);
    }

    // -- data plane: map + combine (the hot path), parallel
    let workers = effective_workers(cfg.map_workers, splits.len());
    let map_outs =
        map_splits_parallel(wl, &datas, &plan, cfg, rt, seed, workers);
    drop(datas); // split views released before the shuffle writes

    // -- time plane, split order. With a failure plan armed, a task's
    // single invocation becomes its sampled attempt schedule: the
    // recovery policy (`coordinator::recovery`) runs against the real
    // IGFS state store and `compile_attempts` turns its segments into
    // stages. The data plane above already ran — failures move only
    // virtual time and attempt counts, never bytes.
    //
    // Speculation (when enabled): tasks projected to lag the phase
    // median get a backup attempt racing the original — see
    // `plan_backups` / `compile_backup`. Decisions derive only from
    // split sizes and node speeds, never from data.
    let map_rate = wl.map_rate();
    let map_nodes: Vec<NodeId> =
        (0..splits.len()).map(|i| map_allocs[i].node).collect();
    let map_ests: Vec<f64> = splits
        .iter()
        .enumerate()
        .map(|(i, s)| {
            if map_rate > 0.0 {
                s.len as f64 / map_rate / cluster.topo.speed_of(map_nodes[i])
            } else {
                0.0
            }
        })
        .collect();
    let (map_backups, map_launch) =
        plan_backups(&cluster.topo, &cfg.speculation, &map_nodes, &map_ests);
    let mut spec_backups = 0u64;
    let mut keybuf = String::new();
    cluster.stores.begin_partition_tally(n_reduces);
    for ((i, mo), in_stages) in
        map_outs.into_iter().enumerate().zip(in_stages_per_split)
    {
        let node = map_allocs[i].node;
        let split = &splits[i];
        let partial = mo.total_bytes().to_le_bytes();
        // Clone the input-read volumes only when a backup will replay
        // them; the common path keeps its zero-clone shape.
        let replay: Vec<Stage> = if map_backups[i].is_some() {
            in_stages.clone()
        } else {
            Vec::new()
        };
        let mut stages = Vec::new();
        if let Some(gate) = after {
            // Chained submission: maps start only once the upstream
            // stage's reducers have all arrived.
            stages.push(Stage::Await(gate));
        }
        let rec = if inject {
            Some(plan_task_recovery(
                cluster,
                cfg,
                &job,
                "map",
                i as u64,
                split.len,
                &partial,
                &mut tally,
            ))
        } else {
            tally.task_attempts += 1;
            None
        };
        let (slot, ok) = match &rec {
            None => {
                let (slot, startup) =
                    invoke_once(cluster, cfg, &map_spec, node);
                stages.push(Stage::Acquire(slot));
                stages.push(Stage::Delay(startup));
                stages.extend(in_stages);
                stages.push(Stage::Delay(SimNs::from_secs_f64(
                    split.len as f64 / wl.map_rate(),
                )));
                (slot, true)
            }
            Some(tr) => {
                let (slot, ck) = compile_attempts(
                    cluster,
                    cfg,
                    &map_spec,
                    node,
                    &in_stages,
                    split.len,
                    wl.map_rate(),
                    tr,
                    &mut stages,
                );
                tally.overhead += ck;
                (slot, tr.recovered)
            }
        };
        let mut out_st: Vec<Stage> = Vec::new();
        if ok {
            for (j, part) in mo.partitions.into_iter().enumerate() {
                if part.is_empty() {
                    continue;
                }
                intermediate_bytes += part.len();
                cluster.stores.tally_partition(j, part.len());
                interm_key_into(&mut keybuf, &job, i, j);
                let st = cluster.stores.write_intermediate(
                    &mut cluster.engine,
                    &cluster.topo,
                    cfg.intermediate_store,
                    node,
                    &keybuf,
                    part,
                )?;
                out_st.extend(st);
            }
            if map_backups[i].is_none() {
                // No race: move the write stages in (clone only for
                // the speculated minority, which replays them).
                stages.append(&mut out_st);
                stages.push(Stage::Release(slot));
                stages.push(Stage::Arrive(maps_done));
            } else {
                stages.extend(out_st.iter().cloned());
                stages.push(Stage::Release(slot));
                // The Cancel + Arrive tail is appended below, once the
                // backup's proc id exists — the race's closing move.
            }
        } else {
            // Retry budget exhausted: the task produced nothing. Still
            // open the barrier (co-tenants must not deadlock) and
            // record the failure on the proc; the job itself is doomed
            // — plan_stage errors after this loop, before any reduce
            // output could land under the job's shared keys.
            stages.push(Stage::Arrive(maps_done));
            let msg = format!(
                "map{i}: retry budget exhausted after {} attempts",
                cfg.recovery.max_attempts.max(1)
            );
            stages.push(Stage::Fail(msg.clone()));
            tally.doomed.get_or_insert(msg);
        }
        if faulty {
            arm_flow_timeouts(&mut stages, cfg.netfaults.flow_timeout);
        }
        let speed = cluster.topo.speed_of(node);
        let orig = cluster.engine.spawn_scaled(
            &format!("{job}/map{i}"),
            class,
            speed,
            stages,
        );
        if faulty {
            cluster.engine.set_flow_retry(
                orig,
                flow_backoff_base(cfg),
                cfg.recovery.backoff_cap,
                MAX_FLOW_RETRIES,
            );
        }
        if ok {
            if cfg.platform == Platform::OpenWhisk {
                cluster.controller.complete(&map_spec, node);
            } else {
                cluster.lambda.finish();
            }
        }
        if let (Some(bnode), true) = (map_backups[i], ok) {
            let scratch_prefix = format!("{job}/spec/map{i}/");
            let scratch = if inject && cfg.recovery.stateful {
                Some((format!("{scratch_prefix}ckpt"), partial.to_vec()))
            } else {
                None
            };
            let bak = compile_backup(
                cluster,
                cfg,
                &map_spec,
                bnode,
                after,
                map_launch,
                &replay,
                split.len,
                wl.map_rate(),
                &out_st,
                maps_done,
                orig,
                &format!("{job}/map{i}/bak"),
                scratch,
            )?;
            cluster.engine.append_stages(
                orig,
                vec![Stage::Cancel(bak), Stage::Arrive(maps_done)],
            );
            // Scrub the task's speculative scratch keys: whichever
            // racer loses, its partial checkpoint is garbage, and a
            // re-planned stage must never collide with it on a
            // write-once backend.
            cluster.stores.clear_prefix(&scratch_prefix);
            tally.tally_scratch_ckpt(cluster, cfg, bnode);
            tally.task_attempts += 1;
            spec_backups += 1;
        }
    }
    // A doomed map means the shuffle is incomplete: running the reduce
    // phase anyway would persist plausible-but-wrong aggregates under
    // the job's real output keys — which a chained stage planned
    // before finalize could then consume. Fail the plan instead.
    if let Some(msg) = tally.doomed.take() {
        return Err(msg);
    }

    // Shuffle-balance census: p99/median of the per-partition
    // intermediate byte tallies the map writes just produced — the
    // number fig13 plots and `SkewAware` exists to pull toward 1.
    let partition_skew = crate::util::stats::skew_coefficient(
        cluster.stores.partition_tallies(),
    );
    let hot_keys_split = plan.hot_keys_split() as u64;

    // Cache-node blackout (inert by default): between the phases —
    // after every intermediate landed, before any reducer gathers —
    // the named nodes lose both cache tiers and leave the partition
    // map, so their keys reroute and their bytes are gone from the
    // cache. Gathers then degrade down the storage chain (or fail the
    // job, when degradation is off). Idempotent per node, so repeated
    // plans over one shared cluster re-apply harmlessly.
    if cfg.netfaults.blackout_armed() {
        for &n in &cfg.netfaults.lose_cachenodes {
            if n >= cluster.topo.n_nodes() {
                return Err(format!(
                    "netfault plan names cache node {n}, cluster has {}",
                    cluster.topo.n_nodes()
                ));
            }
            cluster.stores.igfs.fail_cache_node(NodeId(n))?;
        }
    }

    // (8–10) Reduce phase — the same three-sub-phase shape as map.
    // Gather is serial (stores + DES engine): for every partition,
    // invoke the container and collect each mapper's payload for it as
    // zero-copy views. A miss (Ok(None)) is a mapper that emitted
    // nothing; a store error is data loss and fails the job instead of
    // silently reducing over a hole.
    // Reducer placement: legacy strategies request with no hints (the
    // scheduler's spill order is then bit-for-bit the pre-placement
    // code); CacheAffinity hints each reducer at the nodes holding its
    // partition's intermediate bytes, so the shuffle gather below reads
    // DRAM/PMEM-local instead of crossing the LAN.
    let reduce_reqs: Vec<ContainerRequest> = (0..n_reduces)
        .map(|j| ContainerRequest {
            vcores: 1,
            memory_mb: 2048,
            locality: if cfg.placement.wants_reduce_affinity() {
                reduce_affinity_hints(&mut cluster.stores, &job, n_maps, j)
            } else {
                vec![]
            },
        })
        .collect();
    let reduce_allocs = cluster.rm.allocate_for(qid, &reduce_reqs);
    affinity_hits += count_affinity_hits(&reduce_reqs, &reduce_allocs);
    let mut reduce_in_bytes = 0u64;
    let mut plans: Vec<ReducePlan> = Vec::with_capacity(n_reduces);
    let mut inputs_per_part: Vec<Vec<Payload>> =
        Vec::with_capacity(n_reduces);
    for j in 0..n_reduces {
        let node = reduce_allocs[j].node;
        // Failure-free runs invoke here (gather order), preserving the
        // legacy warm-pool accounting; under injection each attempt
        // invokes for itself in the time-plane loop below.
        let invoked = if inject {
            None
        } else {
            Some(invoke_once(cluster, cfg, &reduce_spec, node))
        };
        let mut in_stages = Vec::new();
        let mut inputs = Vec::new();
        for i in 0..n_maps {
            interm_key_into(&mut keybuf, &job, i, j);
            match cluster.stores.read_intermediate(
                &mut cluster.engine,
                &cluster.topo,
                cfg.intermediate_store,
                node,
                &keybuf,
            )? {
                Some((d, st)) => {
                    reduce_in_bytes += d.len();
                    inputs.push(d);
                    in_stages.extend(st);
                }
                None => {} // mapper emitted nothing for this partition
            }
        }
        plans.push(ReducePlan { node, invoked, in_stages });
        inputs_per_part.push(inputs);
    }

    // -- data plane: reduce merge across partitions, parallel
    let r_workers = effective_workers(cfg.reduce_workers, n_reduces);
    let reduce_outs = reduce_partitions_parallel(
        wl,
        &inputs_per_part,
        n_reduces,
        cfg,
        rt,
        r_workers,
    );

    // -- time plane, partition order (attempt schedules mirror map's;
    // speculation, when enabled, races laggard reducers exactly like
    // laggard maps — gated on the same `maps_done` barrier).
    let reduce_rate = wl.reduce_rate();
    let red_nodes: Vec<NodeId> = plans.iter().map(|p| p.node).collect();
    let red_ests: Vec<f64> = inputs_per_part
        .iter()
        .enumerate()
        .map(|(j, inputs)| {
            let b: u64 = inputs.iter().map(|p| p.len()).sum();
            if reduce_rate > 0.0 {
                b as f64 / reduce_rate / cluster.topo.speed_of(red_nodes[j])
            } else {
                0.0
            }
        })
        .collect();
    let (red_backups, red_launch) =
        plan_backups(&cluster.topo, &cfg.speculation, &red_nodes, &red_ests);
    let mut output_bytes = 0u64;
    for (j, (rplan, ro)) in
        plans.into_iter().zip(reduce_outs).enumerate()
    {
        let in_bytes: u64 =
            inputs_per_part[j].iter().map(|p| p.len()).sum();
        let partial = ro.output.len().to_le_bytes();
        let replay: Vec<Stage> = if red_backups[j].is_some() {
            rplan.in_stages.clone()
        } else {
            Vec::new()
        };
        let mut stages = vec![Stage::Await(maps_done)];
        let (slot, ok) = match rplan.invoked {
            Some((slot, startup)) => {
                tally.task_attempts += 1;
                stages.push(Stage::Acquire(slot));
                stages.push(Stage::Delay(startup));
                stages.extend(rplan.in_stages);
                stages.push(Stage::Delay(SimNs::from_secs_f64(
                    in_bytes as f64 / wl.reduce_rate(),
                )));
                (slot, true)
            }
            None => {
                let tr = plan_task_recovery(
                    cluster,
                    cfg,
                    &job,
                    "red",
                    j as u64,
                    in_bytes,
                    &partial,
                    &mut tally,
                );
                let (slot, ck) = compile_attempts(
                    cluster,
                    cfg,
                    &reduce_spec,
                    rplan.node,
                    &rplan.in_stages,
                    in_bytes,
                    wl.reduce_rate(),
                    &tr,
                    &mut stages,
                );
                tally.overhead += ck;
                (slot, tr.recovered)
            }
        };
        let mut out_st: Vec<Stage> = Vec::new();
        if ok {
            if !ro.output.is_empty() {
                output_bytes += ro.output.len();
                output_key_into(&mut keybuf, &job, j);
                let st = cluster.stores.write_output(
                    &mut cluster.engine,
                    &cluster.topo,
                    cfg.output_store,
                    rplan.node,
                    &keybuf,
                    ro.output,
                )?;
                out_st.extend(st);
            }
            if red_backups[j].is_none() {
                stages.append(&mut out_st);
                stages.push(Stage::Release(slot));
                stages.push(Stage::Arrive(job_done));
            } else {
                stages.extend(out_st.iter().cloned());
                stages.push(Stage::Release(slot));
            }
        } else {
            stages.push(Stage::Arrive(job_done));
            let msg = format!(
                "red{j}: retry budget exhausted after {} attempts",
                cfg.recovery.max_attempts.max(1)
            );
            stages.push(Stage::Fail(msg.clone()));
            tally.doomed.get_or_insert(msg);
        }
        if faulty {
            arm_flow_timeouts(&mut stages, cfg.netfaults.flow_timeout);
        }
        let speed = cluster.topo.speed_of(rplan.node);
        let orig = cluster.engine.spawn_scaled(
            &format!("{job}/red{j}"),
            class,
            speed,
            stages,
        );
        if faulty {
            cluster.engine.set_flow_retry(
                orig,
                flow_backoff_base(cfg),
                cfg.recovery.backoff_cap,
                MAX_FLOW_RETRIES,
            );
        }
        if ok {
            if cfg.platform == Platform::OpenWhisk {
                cluster.controller.complete(&reduce_spec, rplan.node);
            } else {
                cluster.lambda.finish();
            }
        }
        if let (Some(bnode), true) = (red_backups[j], ok) {
            let scratch_prefix = format!("{job}/spec/red{j}/");
            let scratch = if inject && cfg.recovery.stateful {
                Some((format!("{scratch_prefix}ckpt"), partial.to_vec()))
            } else {
                None
            };
            let bak = compile_backup(
                cluster,
                cfg,
                &reduce_spec,
                bnode,
                Some(maps_done),
                red_launch,
                &replay,
                in_bytes,
                wl.reduce_rate(),
                &out_st,
                job_done,
                orig,
                &format!("{job}/red{j}/bak"),
                scratch,
            )?;
            cluster.engine.append_stages(
                orig,
                vec![Stage::Cancel(bak), Stage::Arrive(job_done)],
            );
            cluster.stores.clear_prefix(&scratch_prefix);
            tally.tally_scratch_ckpt(cluster, cfg, bnode);
            tally.task_attempts += 1;
            spec_backups += 1;
        }
    }
    // Same protection as the map phase: a reducer out of attempts has
    // no output, so the job's result set is incomplete — error at plan
    // time so no chained stage can consume it as if it were whole.
    // (Completed sibling reducers did write correct bytes; a pipeline
    // re-run scrubs them via `clear_prefix` before re-executing.)
    if let Some(msg) = tally.doomed.take() {
        return Err(msg);
    }

    // Data plane complete; capture this stage's share of every
    // plan-time counter. The time plane (and with it the barrier
    // timestamps finalize_stage reads) runs when the caller runs the
    // engine — together with however many co-planned jobs share it.
    Ok(PlannedStage {
        job,
        job_done,
        maps_done,
        cfg_name: cfg.name.clone(),
        tag_ns: ns,
        t_start,
        flows0,
        input_bytes,
        intermediate_bytes,
        output_bytes,
        reduce_in_bytes,
        n_maps,
        n_reduces,
        map_in_local,
        map_in_remote,
        handoff,
        igfs: cluster.stores.igfs.stats().delta_since(&igfs0),
        cold_starts: cluster.controller.cold_starts()
            + cluster.lambda.cold_starts
            - cold0,
        warm_starts: cluster.controller.warm_starts()
            + cluster.lambda.warm_starts
            - warm0,
        rt_batches: rt.stats.batches - rt_batches0,
        rt_compute_ns: rt.stats.pjrt_ns + rt.stats.oracle_ns - rt_ns0,
        task_attempts: tally.task_attempts,
        recomputed_bytes: tally.recomputed_bytes,
        checkpoints: tally.checkpoints,
        checkpoint_overhead: tally.overhead,
        spec_backups,
        affinity_hits,
        partition_skew,
        hot_keys_split,
    })
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end via coordinator tests + rust/tests/.
    #[test]
    fn interm_key_stable() {
        let k = crate::mapreduce::shuffle::interm_key("j", 2, 3);
        assert_eq!(k, "j/shuffle/m00002/p003");
    }

    #[test]
    fn scale_flows_scales_volumes_not_latencies() {
        use crate::sim::{SimNs, Stage};
        let st = vec![
            Stage::Delay(SimNs::from_micros(3)),
            Stage::Flow { bytes: 1000.0, path: vec![], tag: 9, timeout: None },
        ];
        let half = super::scale_flows(&st, 50, 100);
        match (&half[0], &half[1]) {
            (Stage::Delay(d), Stage::Flow { bytes, tag, .. }) => {
                assert_eq!(*d, SimNs::from_micros(3));
                assert!((bytes - 500.0).abs() < 1e-9);
                assert_eq!(*tag, 9);
            }
            other => panic!("unexpected stages {other:?}"),
        }
        // Zero span reads nothing; full (or over-full) span replays
        // verbatim; a zero-byte task replays verbatim too.
        assert!(super::scale_flows(&st, 0, 100).is_empty());
        assert_eq!(super::scale_flows(&st, 100, 100).len(), 2);
        assert_eq!(super::scale_flows(&st, 7, 0).len(), 2);
    }

    #[test]
    fn plan_backups_targets_laggards_on_fast_nodes() {
        use crate::net::{NodeId, TopologyBuilder};
        use crate::sim::Engine;
        let mut e = Engine::new();
        let topo = TopologyBuilder {
            nodes: 4,
            node_speeds: vec![1.0, 0.25, 1.0, 1.0],
            ..Default::default()
        }
        .build(&mut e);
        let sc = crate::mapreduce::SpeculationConfig::on();
        // Equal work everywhere; node 1 is a 4× straggler, so only its
        // task projects past 1.5× the median.
        let nodes = vec![NodeId(0), NodeId(1), NodeId(2), NodeId(3)];
        let ests = vec![1.0, 4.0, 1.0, 1.0];
        let (backups, launch) =
            super::plan_backups(&topo, &sc, &nodes, &ests);
        assert_eq!(backups.iter().filter(|b| b.is_some()).count(), 1);
        let bnode = backups[1].expect("straggler task backed up");
        assert_ne!(bnode, NodeId(1), "backup avoids the slow node");
        assert_eq!(topo.speed_of(bnode), 1.0, "backup goes to a fast node");
        assert_eq!(launch, crate::sim::SimNs::from_secs_f64(1.0),
                   "backups launch at the phase median");
        // Disabled policy or uniform projections: no backups.
        let off = crate::mapreduce::SpeculationConfig::disabled();
        let (none, _) = super::plan_backups(&topo, &off, &nodes, &ests);
        assert!(none.iter().all(|b| b.is_none()));
        let (none, _) = super::plan_backups(
            &topo, &sc, &nodes, &[2.0, 2.0, 2.0, 2.0],
        );
        assert!(none.iter().all(|b| b.is_none()));
        // Zero-work phases never speculate.
        let (none, _) =
            super::plan_backups(&topo, &sc, &nodes, &[0.0; 4]);
        assert!(none.iter().all(|b| b.is_none()));
    }

    #[test]
    fn effective_workers_clamps() {
        assert_eq!(super::effective_workers(4, 16), 4);
        assert_eq!(super::effective_workers(16, 4), 4);
        assert_eq!(super::effective_workers(3, 0), 1);
        assert!(super::effective_workers(0, 64) >= 1);
    }
}
