//! Job driver: plans a MapReduce stage against a deployed cluster, runs
//! the *data plane* eagerly (real bytes through the real combine path),
//! compiles every task into a DES proc, and runs the *time plane* to a
//! deterministic completion time. Implements the paper's Figure 3
//! workflow steps 1–10.
//!
//! A stage's input comes either from a staged path ([`StageInput::Path`],
//! the classic single job) or from an upstream pipeline stage's reducer
//! outputs ([`StageInput::Handoff`]) resolved through the IGFS tiers:
//! DRAM hit → PMEM backing hit → HDFS → S3 fallback. Both the map and the
//! reduce data planes fan out over scoped host-thread pools under the
//! byte-identical determinism contract (see `pool_run`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::faas::{ActionSpec, Controller, Lambda};
use crate::igfs::Tier;
use crate::metrics::{tags, IoSummary};
use crate::net::{NodeId, Topology};
use crate::runtime::{RtEngine, RtStats};
use crate::sim::{Engine, PoolId, SimNs, Stage};
use crate::storage::Payload;
use crate::yarn::{ContainerRequest, ResourceManager};

use super::shuffle::{interm_key, output_key, KeyHome, Stores};
use super::types::{
    HandoffStats, JobResult, PhaseStats, Platform, StoreKind, SystemConfig,
};
use super::workload::{task_rng, MapOutput, ReduceOutput, Workload};

/// A deployed cluster a job runs against. A pipeline chains several
/// stages over one instance so virtual time and cache state carry
/// across stages; independent jobs use one instance each.
pub struct Cluster {
    pub engine: Engine,
    pub topo: Topology,
    pub stores: Stores,
    pub controller: Controller,
    pub lambda: Lambda,
    pub rm: ResourceManager,
}

/// Stage the job input into the configured input store (deployment-time;
/// not billed to job execution, matching the paper's methodology).
pub fn stage_input(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    wl: &dyn Workload,
    bytes: u64,
    seed: u64,
) -> Result<String, String> {
    let materialize = bytes <= cfg.materialize_cap;
    let mut rng = task_rng(seed, wl.name(), u64::MAX);
    let data = wl.generate_input(bytes, materialize, &mut rng);
    assert_eq!(data.len(), bytes, "workload generated wrong input size");
    let path = format!("{}/input", wl.name());
    match cfg.input_store {
        StoreKind::S3 => {
            cluster.stores.s3.put(&path, data);
        }
        StoreKind::Hdfs | StoreKind::Igfs => {
            // Ingest from node 0; staging stages are dropped (untimed).
            cluster
                .stores
                .hdfs
                .put(&cluster.topo, NodeId(0), &path, data, tags::INPUT_READ)?;
        }
    }
    Ok(path)
}

/// Where a stage's input splits come from.
pub enum StageInput {
    /// A staged path in `cfg.input_store`, split by block locations
    /// (HDFS) or `split_bytes` (S3).
    Path(String),
    /// Handoff from an upstream pipeline stage: one split per upstream
    /// reducer output key, resolved at read time through the IGFS
    /// tiers (DRAM → PMEM backing → HDFS → S3 fallback).
    Handoff { keys: Vec<String> },
}

enum SplitSource {
    Range { offset: u64 },
    Key(String),
}

struct SplitPlan {
    source: SplitSource,
    len: u64,
    locality: Vec<NodeId>,
}

fn plan_splits(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    input: &str,
) -> Result<(u64, Vec<SplitPlan>), String> {
    match cfg.input_store {
        StoreKind::Hdfs | StoreKind::Igfs => {
            let locs = cluster.stores.hdfs.block_locations(input);
            if locs.is_empty() {
                return Err(format!("input {input} not in HDFS"));
            }
            let total = locs.iter().map(|(b, _)| b.len).sum();
            Ok((
                total,
                locs.into_iter()
                    .map(|(b, nodes)| SplitPlan {
                        source: SplitSource::Range { offset: b.offset },
                        len: b.len,
                        locality: nodes,
                    })
                    .collect(),
            ))
        }
        StoreKind::S3 => {
            let total = cluster
                .stores
                .s3
                .get(input)
                .ok_or_else(|| format!("input {input} not in S3"))?
                .len();
            let mut splits = Vec::new();
            let mut off = 0;
            while off < total {
                let len = cfg.split_bytes.min(total - off);
                splits.push(SplitPlan {
                    source: SplitSource::Range { offset: off },
                    len,
                    locality: vec![],
                });
                off += len;
            }
            if splits.is_empty() {
                splits.push(SplitPlan {
                    source: SplitSource::Range { offset: 0 },
                    len: 0,
                    locality: vec![],
                });
            }
            Ok((total, splits))
        }
    }
}

/// Plan handoff splits: one per upstream output key, located through
/// `Stores::locate` (the shared IGFS → HDFS → S3 chain; disturbs no
/// cache statistics). Locality hints: the IGFS owner, the first HDFS
/// replica set, or none for remote S3; a key absent everywhere is an
/// upstream reducer that emitted nothing.
fn plan_handoff(
    cluster: &mut Cluster,
    keys: Vec<String>,
) -> (u64, Vec<SplitPlan>) {
    let mut total = 0u64;
    let mut plans = Vec::with_capacity(keys.len());
    for key in keys {
        let (len, locality) = match cluster.stores.locate(&key) {
            Some((len, KeyHome::Igfs)) => {
                (len, vec![cluster.stores.igfs.owner(&key)])
            }
            Some((len, KeyHome::Hdfs)) => {
                let locs = cluster.stores.hdfs.block_locations(&key);
                let first = locs
                    .first()
                    .map(|(_, nodes)| nodes.clone())
                    .unwrap_or_default();
                (len, first)
            }
            Some((len, KeyHome::S3)) => (len, Vec::new()),
            None => (0, Vec::new()),
        };
        total += len;
        plans.push(SplitPlan {
            source: SplitSource::Key(key),
            len,
            locality,
        });
    }
    (total, plans)
}

/// Which tier served a handoff split.
enum HandoffTier {
    Dram,
    Backing,
    Hdfs,
    S3,
    Empty,
}

/// Resolve one handoff key on `node`: IGFS first (the tier the hit came
/// from prices the read), then HDFS, then S3, else an empty split. The
/// payload is a zero-copy view over the serving store's buffers in
/// every case.
fn read_handoff(
    stores: &mut Stores,
    engine: &mut Engine,
    topo: &Topology,
    node: NodeId,
    key: &str,
) -> Result<(Payload, Vec<Stage>, HandoffTier, bool), String> {
    if let Some((data, st, tier)) =
        stores.igfs.get_tiered(topo, node, key, tags::INPUT_READ)
    {
        let local = stores.igfs.owner(key) == node;
        let tier = match tier {
            Tier::Dram => HandoffTier::Dram,
            Tier::Backing => HandoffTier::Backing,
        };
        return Ok((data, st, tier, local));
    }
    if stores.hdfs.namenode.stat(key).is_some() {
        let (data, st, _, remote) =
            stores.hdfs.read(topo, node, key, tags::INPUT_READ)?;
        return Ok((data, st, HandoffTier::Hdfs, remote == 0));
    }
    if let Some(data) = stores.s3.get(key) {
        let st = stores.s3.get_stages(engine, topo, node, data.len(),
                                      tags::INPUT_READ);
        return Ok((data, st, HandoffTier::S3, false));
    }
    Ok((Payload::real(Vec::new()), Vec::new(), HandoffTier::Empty, true))
}

/// Resolve a data-plane worker count: explicit, or the host's available
/// parallelism when `requested` is 0; never more workers than items.
fn effective_workers(requested: usize, n_items: usize) -> usize {
    let w = if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    };
    w.clamp(1, n_items.max(1))
}

/// Run `f(i, rt)` for every `i in 0..n`, fanning out across `workers`
/// host threads.
///
/// DESIGN — determinism contract: output is byte-identical to the
/// serial path at ANY worker count because (a) each item's work is
/// derived independently (no shared mutable state between items), (b)
/// each worker owns a private `RtEngine` oracle instance (same manifest
/// constants; combine counts are integer-valued f32s, so oracle and
/// PJRT agree bitwise), and (c) results land in a per-item slot and are
/// consumed in item order — scheduling order affects nothing but
/// wall-clock. Only the data plane parallelizes; the DES time plane
/// stays single-threaded and deterministic. Worker `RtStats` are folded
/// back into the job-level engine.
fn pool_run<T, F>(rt: &mut RtEngine, workers: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut RtEngine) -> T + Sync,
{
    if workers <= 1 || n <= 1 {
        return (0..n).map(|i| f(i, rt)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let stats = Mutex::new(RtStats::default());
    let manifest = rt.manifest.clone();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                let mut wrt = RtEngine::oracle_from(manifest.clone());
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = f(i, &mut wrt);
                    *slots[i].lock().unwrap() = Some(out);
                }
                let mut st = stats.lock().unwrap();
                st.batches += wrt.stats.batches;
                st.pjrt_ns += wrt.stats.pjrt_ns;
                st.oracle_ns += wrt.stats.oracle_ns;
            });
        }
    });
    rt.absorb_stats(&stats.into_inner().unwrap());
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("pool worker died"))
        .collect()
}

/// Run `map_split` over every fetched split across `workers` host
/// threads. Per-split RNG streams derive from the *workload name*
/// (`task_rng(seed, wl.name(), i)`), so the split schedule cannot
/// influence data — see the `pool_run` determinism contract.
pub fn map_splits_parallel(
    wl: &dyn Workload,
    datas: &[Payload],
    n_reduces: usize,
    cfg: &SystemConfig,
    rt: &mut RtEngine,
    seed: u64,
    workers: usize,
) -> Vec<MapOutput> {
    let job = wl.name();
    pool_run(rt, workers, datas.len(), |i, wrt| {
        let mut rng = task_rng(seed, job, i as u64);
        wl.map_split(&datas[i], n_reduces, cfg, wrt, &mut rng)
    })
}

/// Run `reduce_partition` over every partition's gathered inputs across
/// `workers` host threads. Each partition is reduced by exactly one
/// worker over inputs pre-gathered in mapper order, so worker count is
/// invisible in every output bit (`pool_run` contract).
pub fn reduce_partitions_parallel(
    wl: &dyn Workload,
    inputs: &[Vec<Payload>],
    n_reduces: usize,
    cfg: &SystemConfig,
    rt: &mut RtEngine,
    workers: usize,
) -> Vec<ReduceOutput> {
    pool_run(rt, workers, inputs.len(), |j, wrt| {
        wl.reduce_partition(j, n_reduces, &inputs[j], cfg, wrt)
    })
}

/// Run one job end-to-end. `seed` drives all data-plane randomness.
pub fn run_job(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    wl: &dyn Workload,
    input: &str,
    rt: &mut RtEngine,
    seed: u64,
) -> JobResult {
    let stage_in = StageInput::Path(input.to_string());
    match run_stage(cluster, cfg, wl, wl.name(), stage_in, rt, seed) {
        Ok(r) => r,
        Err(e) => {
            let input_bytes = match cfg.input_store {
                StoreKind::S3 => cluster
                    .stores
                    .s3
                    .get(input)
                    .map(|p| p.len())
                    .unwrap_or(0),
                _ => cluster
                    .stores
                    .hdfs
                    .namenode
                    .stat(input)
                    .map(|i| i.len)
                    .unwrap_or(0),
            };
            JobResult::failed(wl.name(), &cfg.name, input_bytes, e)
        }
    }
}

/// Plan bookkeeping for one reducer between the gather and time planes.
struct ReducePlan {
    node: NodeId,
    slot: PoolId,
    stages: Vec<Stage>,
}

/// Run one MapReduce stage. `job` names the stage (it prefixes every
/// shuffle/output key, so pipeline stages sharing a workload stay
/// disjoint); single jobs pass `wl.name()`.
pub fn run_stage(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    wl: &dyn Workload,
    job: &str,
    input: StageInput,
    rt: &mut RtEngine,
    seed: u64,
) -> Result<JobResult, String> {
    let job = job.to_string();
    let t_start = cluster.engine.now();
    let rt_batches0 = rt.stats.batches;
    let rt_ns0 = rt.stats.pjrt_ns + rt.stats.oracle_ns;
    let igfs0 = cluster.stores.igfs.stats();
    // Flow-log / cold-start offsets: a pipeline runs many stages on one
    // engine, and this stage's report must cover only its own activity.
    let flows0 = cluster.engine.flow_log.len();
    let cold0 =
        cluster.controller.cold_starts() + cluster.lambda.cold_starts;
    let mut handoff = HandoffStats::default();

    // (1–3) Client → controller → YARN: size the job.
    let (path, (input_bytes, splits)) = match input {
        StageInput::Path(p) => {
            let planned = plan_splits(cluster, cfg, &p)?;
            (Some(p), planned)
        }
        StageInput::Handoff { keys } => (None, plan_handoff(cluster, keys)),
    };
    let n_splits = splits.len();
    let (n_maps, n_reduces) =
        cluster.rm.size_job(n_splits, rt.manifest.parts);

    // Lambda admission: the Corral baseline dies past the transfer
    // quota (paper §4.2.1 observation 1).
    if cfg.platform == Platform::Lambda {
        cluster.lambda.admit_job(input_bytes, n_maps + n_reduces)?;
    }

    // (4) Placement for map tasks (locality from the NameNode for
    // ranges, from the IGFS owner / HDFS replicas for handoff keys).
    let map_reqs: Vec<ContainerRequest> = splits
        .iter()
        .map(|s| ContainerRequest {
            vcores: 1,
            memory_mb: 2048,
            locality: s.locality.clone(),
        })
        .collect();
    let map_allocs = cluster.rm.allocate(&map_reqs);
    if cfg.prewarm && cfg.platform == Platform::OpenWhisk {
        cluster.controller.prewarm("marvel-hadoop:latest", 64);
    }

    let maps_done = cluster.engine.add_barrier(n_maps);
    let job_done = cluster.engine.add_barrier(n_reduces);
    let map_spec = ActionSpec::map(&job, 2048);
    let reduce_spec = ActionSpec::reduce(&job, 2048);

    // (5–7) Map phase: data plane now, time plane as procs.
    //
    // Three sub-phases. Fetch is serial (it touches the stores and the
    // DES engine) but zero-copy: an HDFS split read is a view assembly
    // over the DataNodes' block buffers, an S3 split is an O(1) slice
    // of the object, and a handoff key is a view over the IGFS owner's
    // cache entry. Map compute — the actually expensive part — fans
    // out across host threads. Time-plane spawning is serial again, in
    // split order, so the DES stays deterministic.
    let mut intermediate_bytes = 0u64;
    let mut map_in_local = 0u64;
    let mut map_in_remote = 0u64;
    let mut datas = Vec::with_capacity(splits.len());
    let mut in_stages_per_split = Vec::with_capacity(splits.len());
    for (i, split) in splits.iter().enumerate() {
        let node = map_allocs[i].node;
        let (data, in_stages) = match &split.source {
            SplitSource::Range { offset } => {
                let path = path.as_deref().expect("range split without path");
                match cfg.input_store {
                    StoreKind::Hdfs | StoreKind::Igfs => {
                        let (d, st, local) = cluster.stores.hdfs.read_range(
                            &cluster.topo,
                            node,
                            path,
                            *offset,
                            split.len,
                            tags::INPUT_READ,
                        )?;
                        if local {
                            map_in_local += split.len;
                        } else {
                            map_in_remote += split.len;
                        }
                        (d, st)
                    }
                    StoreKind::S3 => {
                        let whole = cluster
                            .stores
                            .s3
                            .get(path)
                            .ok_or("input vanished")?;
                        let d = whole.slice(*offset, split.len);
                        let st = cluster.stores.s3.get_stages(
                            &mut cluster.engine,
                            &cluster.topo,
                            node,
                            split.len,
                            tags::INPUT_READ,
                        );
                        map_in_remote += split.len;
                        (d, st)
                    }
                }
            }
            SplitSource::Key(key) => {
                let (d, st, tier, local) = read_handoff(
                    &mut cluster.stores,
                    &mut cluster.engine,
                    &cluster.topo,
                    node,
                    key,
                )?;
                match tier {
                    HandoffTier::Dram => handoff.dram += 1,
                    HandoffTier::Backing => handoff.backing += 1,
                    HandoffTier::Hdfs => handoff.hdfs += 1,
                    HandoffTier::S3 => handoff.s3 += 1,
                    HandoffTier::Empty => handoff.empty += 1,
                }
                if local {
                    map_in_local += split.len;
                } else {
                    map_in_remote += split.len;
                }
                (d, st)
            }
        };
        datas.push(data);
        in_stages_per_split.push(in_stages);
    }

    // -- data plane: map + combine (the hot path), parallel
    let workers = effective_workers(cfg.map_workers, splits.len());
    let map_outs =
        map_splits_parallel(wl, &datas, n_reduces, cfg, rt, seed, workers);
    drop(datas); // split views released before the shuffle writes

    // -- time plane, split order
    for ((i, mo), in_stages) in
        map_outs.into_iter().enumerate().zip(in_stages_per_split)
    {
        let node = map_allocs[i].node;
        let split = &splits[i];
        let (slot, startup) = match cfg.platform {
            Platform::OpenWhisk => {
                let inv = cluster.controller.invoke(&map_spec, node);
                (cluster.controller.slots_of(node), inv.startup)
            }
            Platform::Lambda => {
                let (lat, _) = cluster.lambda.startup();
                (cluster.lambda.concurrency, lat)
            }
        };
        let mut stages = vec![Stage::Acquire(slot), Stage::Delay(startup)];
        stages.extend(in_stages);
        stages.push(Stage::Delay(SimNs::from_secs_f64(
            split.len as f64 / wl.map_rate(),
        )));
        for (j, part) in mo.partitions.into_iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            intermediate_bytes += part.len();
            let key = interm_key(&job, i, j);
            let st = cluster.stores.write_intermediate(
                &mut cluster.engine,
                &cluster.topo,
                cfg.intermediate_store,
                node,
                &key,
                part,
            )?;
            stages.extend(st);
        }
        stages.push(Stage::Release(slot));
        stages.push(Stage::Arrive(maps_done));
        cluster.engine.spawn(&format!("{job}/map{i}"), stages);
        if cfg.platform == Platform::OpenWhisk {
            cluster.controller.complete(&map_spec, node);
        } else {
            cluster.lambda.finish();
        }
    }

    // (8–10) Reduce phase — the same three-sub-phase shape as map.
    // Gather is serial (stores + DES engine): for every partition,
    // invoke the container and collect each mapper's payload for it as
    // zero-copy views. A miss (Ok(None)) is a mapper that emitted
    // nothing; a store error is data loss and fails the job instead of
    // silently reducing over a hole.
    let reduce_reqs: Vec<ContainerRequest> = (0..n_reduces)
        .map(|_| ContainerRequest {
            vcores: 1,
            memory_mb: 2048,
            locality: vec![],
        })
        .collect();
    let reduce_allocs = cluster.rm.allocate(&reduce_reqs);
    let mut reduce_in_bytes = 0u64;
    let mut plans: Vec<ReducePlan> = Vec::with_capacity(n_reduces);
    let mut inputs_per_part: Vec<Vec<Payload>> =
        Vec::with_capacity(n_reduces);
    for j in 0..n_reduces {
        let node = reduce_allocs[j].node;
        let mut stages = vec![Stage::Await(maps_done)];
        let (slot, startup) = match cfg.platform {
            Platform::OpenWhisk => {
                let inv = cluster.controller.invoke(&reduce_spec, node);
                (cluster.controller.slots_of(node), inv.startup)
            }
            Platform::Lambda => {
                let (lat, _) = cluster.lambda.startup();
                (cluster.lambda.concurrency, lat)
            }
        };
        stages.push(Stage::Acquire(slot));
        stages.push(Stage::Delay(startup));
        let mut inputs = Vec::new();
        for i in 0..n_maps {
            let key = interm_key(&job, i, j);
            match cluster.stores.read_intermediate(
                &mut cluster.engine,
                &cluster.topo,
                cfg.intermediate_store,
                node,
                &key,
            )? {
                Some((d, st)) => {
                    reduce_in_bytes += d.len();
                    inputs.push(d);
                    stages.extend(st);
                }
                None => {} // mapper emitted nothing for this partition
            }
        }
        plans.push(ReducePlan { node, slot, stages });
        inputs_per_part.push(inputs);
    }

    // -- data plane: reduce merge across partitions, parallel
    let r_workers = effective_workers(cfg.reduce_workers, n_reduces);
    let reduce_outs = reduce_partitions_parallel(
        wl,
        &inputs_per_part,
        n_reduces,
        cfg,
        rt,
        r_workers,
    );

    // -- time plane, partition order
    let mut output_bytes = 0u64;
    for (j, (plan, ro)) in
        plans.into_iter().zip(reduce_outs).enumerate()
    {
        let in_bytes: u64 =
            inputs_per_part[j].iter().map(|p| p.len()).sum();
        let mut stages = plan.stages;
        stages.push(Stage::Delay(SimNs::from_secs_f64(
            in_bytes as f64 / wl.reduce_rate(),
        )));
        if !ro.output.is_empty() {
            output_bytes += ro.output.len();
            let st = cluster.stores.write_output(
                &mut cluster.engine,
                &cluster.topo,
                cfg.output_store,
                plan.node,
                &output_key(&job, j),
                ro.output,
            )?;
            stages.extend(st);
        }
        stages.push(Stage::Release(plan.slot));
        stages.push(Stage::Arrive(job_done));
        cluster.engine.spawn(&format!("{job}/red{j}"), stages);
        if cfg.platform == Platform::OpenWhisk {
            cluster.controller.complete(&reduce_spec, plan.node);
        } else {
            cluster.lambda.finish();
        }
    }

    // Run the time plane.
    let end = cluster.engine.run()?;
    if let Some((_, msg)) = cluster.engine.failures().first() {
        return Err(format!("task failed: {msg}"));
    }
    let maps_end = cluster
        .engine
        .barrier_opened_at(maps_done)
        .unwrap_or(end);
    let job_time = end - t_start;
    let io = IoSummary::from_flow_log(&cluster.engine.flow_log[flows0..],
                                      job_time);

    let placed = map_in_local + map_in_remote;
    Ok(JobResult {
        job,
        config: cfg.name.clone(),
        input_bytes,
        intermediate_bytes,
        output_bytes,
        map: PhaseStats {
            tasks: n_maps,
            bytes_in: input_bytes,
            bytes_out: intermediate_bytes,
            duration: maps_end - t_start,
        },
        reduce: PhaseStats {
            tasks: n_reduces,
            bytes_in: reduce_in_bytes,
            bytes_out: output_bytes,
            duration: end.saturating_sub(maps_end),
        },
        job_time,
        failed: None,
        cold_starts: cluster.controller.cold_starts()
            + cluster.lambda.cold_starts
            - cold0,
        locality_ratio: if placed > 0 {
            map_in_local as f64 / placed as f64
        } else {
            0.0
        },
        io,
        rt_batches: rt.stats.batches - rt_batches0,
        rt_compute_ns: rt.stats.pjrt_ns + rt.stats.oracle_ns - rt_ns0,
        igfs: cluster.stores.igfs.stats().delta_since(&igfs0),
        handoff,
    })
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end via coordinator tests + rust/tests/.
    #[test]
    fn interm_key_stable() {
        assert_eq!(super::interm_key("j", 2, 3), "j/shuffle/m00002/p003");
    }

    #[test]
    fn effective_workers_clamps() {
        assert_eq!(super::effective_workers(4, 16), 4);
        assert_eq!(super::effective_workers(16, 4), 4);
        assert_eq!(super::effective_workers(3, 0), 1);
        assert!(super::effective_workers(0, 64) >= 1);
    }
}
