//! Job driver: plans a MapReduce job against a deployed cluster, runs
//! the *data plane* eagerly (real bytes through the real combine path),
//! compiles every task into a DES proc, and runs the *time plane* to a
//! deterministic completion time. Implements the paper's Figure 3
//! workflow steps 1–10.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::faas::{ActionSpec, Controller, Lambda};
use crate::metrics::{tags, IoSummary};
use crate::net::{NodeId, Topology};
use crate::runtime::{RtEngine, RtStats};
use crate::sim::{Engine, SimNs, Stage};
use crate::storage::Payload;
use crate::yarn::{ContainerRequest, ResourceManager};

use super::shuffle::{interm_key, output_key, Stores};
use super::types::{
    JobResult, PhaseStats, Platform, StoreKind, SystemConfig,
};
use super::workload::{task_rng, MapOutput, Workload};

/// A deployed cluster a job runs against. One job per instance keeps
/// virtual time and flow logs cleanly attributable.
pub struct Cluster {
    pub engine: Engine,
    pub topo: Topology,
    pub stores: Stores,
    pub controller: Controller,
    pub lambda: Lambda,
    pub rm: ResourceManager,
}

/// Stage the job input into the configured input store (deployment-time;
/// not billed to job execution, matching the paper's methodology).
pub fn stage_input(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    wl: &dyn Workload,
    bytes: u64,
    seed: u64,
) -> Result<String, String> {
    let materialize = bytes <= cfg.materialize_cap;
    let mut rng = task_rng(seed, wl.name(), u64::MAX);
    let data = wl.generate_input(bytes, materialize, &mut rng);
    assert_eq!(data.len(), bytes, "workload generated wrong input size");
    let path = format!("{}/input", wl.name());
    match cfg.input_store {
        StoreKind::S3 => {
            cluster.stores.s3.put(&path, data);
        }
        StoreKind::Hdfs | StoreKind::Igfs => {
            // Ingest from node 0; staging stages are dropped (untimed).
            cluster
                .stores
                .hdfs
                .put(&cluster.topo, NodeId(0), &path, data, tags::INPUT_READ)?;
        }
    }
    Ok(path)
}

struct SplitPlan {
    offset: u64,
    len: u64,
    locality: Vec<NodeId>,
}

fn plan_splits(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    input: &str,
) -> Result<(u64, Vec<SplitPlan>), String> {
    match cfg.input_store {
        StoreKind::Hdfs | StoreKind::Igfs => {
            let locs = cluster.stores.hdfs.block_locations(input);
            if locs.is_empty() {
                return Err(format!("input {input} not in HDFS"));
            }
            let total = locs.iter().map(|(b, _)| b.len).sum();
            Ok((
                total,
                locs.into_iter()
                    .map(|(b, nodes)| SplitPlan {
                        offset: b.offset,
                        len: b.len,
                        locality: nodes,
                    })
                    .collect(),
            ))
        }
        StoreKind::S3 => {
            let total = cluster
                .stores
                .s3
                .get(input)
                .ok_or_else(|| format!("input {input} not in S3"))?
                .len();
            let mut splits = Vec::new();
            let mut off = 0;
            while off < total {
                let len = cfg.split_bytes.min(total - off);
                splits.push(SplitPlan { offset: off, len, locality: vec![] });
                off += len;
            }
            if splits.is_empty() {
                splits.push(SplitPlan { offset: 0, len: 0, locality: vec![] });
            }
            Ok((total, splits))
        }
    }
}

/// Resolve the data-plane worker count: explicit from the config, or
/// the host's available parallelism; never more workers than splits.
fn effective_map_workers(cfg: &SystemConfig, n_splits: usize) -> usize {
    let w = if cfg.map_workers == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        cfg.map_workers
    };
    w.clamp(1, n_splits.max(1))
}

/// Run `map_split` over every fetched split, fanning out across
/// `workers` host threads.
///
/// DESIGN — determinism contract: output is byte-identical to the
/// serial path at ANY worker count because (a) each split's RNG is
/// derived independently (`task_rng(seed, job, i)` — no shared stream
/// to race on), (b) each worker owns a private `RtEngine` oracle
/// instance (same manifest constants; combine counts are
/// integer-valued f32s, so oracle and PJRT agree bitwise), and (c)
/// results land in a per-split slot and are consumed in split order —
/// scheduling order affects nothing but wall-clock. Only the map data
/// plane parallelizes; the DES time plane stays single-threaded and
/// deterministic.
pub fn map_splits_parallel(
    wl: &dyn Workload,
    datas: &[Payload],
    n_reduces: usize,
    cfg: &SystemConfig,
    rt: &mut RtEngine,
    seed: u64,
    workers: usize,
) -> Vec<MapOutput> {
    let job = wl.name();
    if workers <= 1 || datas.len() <= 1 {
        return datas
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let mut rng = task_rng(seed, job, i as u64);
                wl.map_split(d, n_reduces, cfg, rt, &mut rng)
            })
            .collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<MapOutput>>> =
        (0..datas.len()).map(|_| Mutex::new(None)).collect();
    let stats = Mutex::new(RtStats::default());
    let manifest = rt.manifest.clone();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                let mut wrt = RtEngine::oracle_from(manifest.clone());
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= datas.len() {
                        break;
                    }
                    let mut rng = task_rng(seed, job, i as u64);
                    let mo =
                        wl.map_split(&datas[i], n_reduces, cfg, &mut wrt,
                                     &mut rng);
                    *slots[i].lock().unwrap() = Some(mo);
                }
                let mut st = stats.lock().unwrap();
                st.batches += wrt.stats.batches;
                st.pjrt_ns += wrt.stats.pjrt_ns;
                st.oracle_ns += wrt.stats.oracle_ns;
            });
        }
    });
    rt.absorb_stats(&stats.into_inner().unwrap());
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("map worker died"))
        .collect()
}

/// Run one job end-to-end. `seed` drives all data-plane randomness.
pub fn run_job(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    wl: &dyn Workload,
    input: &str,
    rt: &mut RtEngine,
    seed: u64,
) -> JobResult {
    match run_job_inner(cluster, cfg, wl, input, rt, seed) {
        Ok(r) => r,
        Err(e) => {
            let input_bytes = match cfg.input_store {
                StoreKind::S3 => cluster
                    .stores
                    .s3
                    .get(input)
                    .map(|p| p.len())
                    .unwrap_or(0),
                _ => cluster
                    .stores
                    .hdfs
                    .namenode
                    .stat(input)
                    .map(|i| i.len)
                    .unwrap_or(0),
            };
            JobResult::failed(wl.name(), &cfg.name, input_bytes, e)
        }
    }
}

fn run_job_inner(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    wl: &dyn Workload,
    input: &str,
    rt: &mut RtEngine,
    seed: u64,
) -> Result<JobResult, String> {
    let job = wl.name().to_string();
    let t_start = cluster.engine.now();
    let rt_batches0 = rt.stats.batches;
    let rt_ns0 = rt.stats.pjrt_ns + rt.stats.oracle_ns;

    // (1–3) Client → controller → YARN: size the job.
    let (input_bytes, splits) = plan_splits(cluster, cfg, input)?;
    let n_splits = splits.len();
    let (n_maps, n_reduces) =
        cluster.rm.size_job(n_splits, rt.manifest.parts);

    // Lambda admission: the Corral baseline dies past the transfer
    // quota (paper §4.2.1 observation 1).
    if cfg.platform == Platform::Lambda {
        cluster.lambda.admit_job(input_bytes, n_maps + n_reduces)?;
    }

    // (4) Placement for map tasks (locality from the NameNode).
    let map_reqs: Vec<ContainerRequest> = splits
        .iter()
        .map(|s| ContainerRequest {
            vcores: 1,
            memory_mb: 2048,
            locality: s.locality.clone(),
        })
        .collect();
    let map_allocs = cluster.rm.allocate(&map_reqs);
    if cfg.prewarm && cfg.platform == Platform::OpenWhisk {
        cluster.controller.prewarm("marvel-hadoop:latest", 64);
    }

    let maps_done = cluster.engine.add_barrier(n_maps);
    let job_done = cluster.engine.add_barrier(n_reduces);
    let map_spec = ActionSpec::map(&job, 2048);
    let reduce_spec = ActionSpec::reduce(&job, 2048);

    // (5–7) Map phase: data plane now, time plane as procs.
    //
    // Three sub-phases. Fetch is serial (it touches the stores and the
    // DES engine) but zero-copy: an HDFS split read is a view assembly
    // over the DataNodes' block buffers, an S3 split is an O(1) slice
    // of the object. Map compute — the actually expensive part — fans
    // out across host threads. Time-plane spawning is serial again, in
    // split order, so the DES stays deterministic.
    let mut intermediate_bytes = 0u64;
    let mut map_in_local = 0u64;
    let mut map_in_remote = 0u64;
    let mut datas = Vec::with_capacity(splits.len());
    let mut in_stages_per_split = Vec::with_capacity(splits.len());
    for (i, split) in splits.iter().enumerate() {
        let node = map_allocs[i].node;
        let (data, in_stages) = match cfg.input_store {
            StoreKind::Hdfs | StoreKind::Igfs => {
                let (d, st, local) = cluster.stores.hdfs.read_range(
                    &cluster.topo,
                    node,
                    input,
                    split.offset,
                    split.len,
                    tags::INPUT_READ,
                )?;
                if local {
                    map_in_local += split.len;
                } else {
                    map_in_remote += split.len;
                }
                (d, st)
            }
            StoreKind::S3 => {
                let whole = cluster
                    .stores
                    .s3
                    .get(input)
                    .ok_or("input vanished")?;
                let d = whole.slice(split.offset, split.len);
                let st = cluster.stores.s3.get_stages(
                    &mut cluster.engine,
                    &cluster.topo,
                    node,
                    split.len,
                    tags::INPUT_READ,
                );
                map_in_remote += split.len;
                (d, st)
            }
        };
        datas.push(data);
        in_stages_per_split.push(in_stages);
    }

    // -- data plane: map + combine (the hot path), parallel
    let workers = effective_map_workers(cfg, splits.len());
    let map_outs =
        map_splits_parallel(wl, &datas, n_reduces, cfg, rt, seed, workers);
    drop(datas); // split views released before the shuffle writes

    // -- time plane, split order
    for ((i, mo), in_stages) in
        map_outs.into_iter().enumerate().zip(in_stages_per_split)
    {
        let node = map_allocs[i].node;
        let split = &splits[i];
        let (slot, startup) = match cfg.platform {
            Platform::OpenWhisk => {
                let inv = cluster.controller.invoke(&map_spec, node);
                (cluster.controller.slots_of(node), inv.startup)
            }
            Platform::Lambda => {
                let (lat, _) = cluster.lambda.startup();
                (cluster.lambda.concurrency, lat)
            }
        };
        let mut stages = vec![Stage::Acquire(slot), Stage::Delay(startup)];
        stages.extend(in_stages);
        stages.push(Stage::Delay(SimNs::from_secs_f64(
            split.len as f64 / wl.map_rate(),
        )));
        for (j, part) in mo.partitions.into_iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            intermediate_bytes += part.len();
            let key = interm_key(&job, i, j);
            let st = cluster.stores.write_intermediate(
                &mut cluster.engine,
                &cluster.topo,
                cfg.intermediate_store,
                node,
                &key,
                part,
            )?;
            stages.extend(st);
        }
        stages.push(Stage::Release(slot));
        stages.push(Stage::Arrive(maps_done));
        cluster.engine.spawn(&format!("{job}/map{i}"), stages);
        if cfg.platform == Platform::OpenWhisk {
            cluster.controller.complete(&map_spec, node);
        } else {
            cluster.lambda.finish();
        }
    }

    // (8–10) Reduce phase.
    let reduce_reqs: Vec<ContainerRequest> = (0..n_reduces)
        .map(|_| ContainerRequest {
            vcores: 1,
            memory_mb: 2048,
            locality: vec![],
        })
        .collect();
    let reduce_allocs = cluster.rm.allocate(&reduce_reqs);
    let mut output_bytes = 0u64;
    let mut reduce_in_bytes = 0u64;
    for j in 0..n_reduces {
        let node = reduce_allocs[j].node;
        let mut stages = vec![Stage::Await(maps_done)];
        let (slot, startup) = match cfg.platform {
            Platform::OpenWhisk => {
                let inv = cluster.controller.invoke(&reduce_spec, node);
                (cluster.controller.slots_of(node), inv.startup)
            }
            Platform::Lambda => {
                let (lat, _) = cluster.lambda.startup();
                (cluster.lambda.concurrency, lat)
            }
        };
        stages.push(Stage::Acquire(slot));
        stages.push(Stage::Delay(startup));
        // -- data plane: gather this partition from every mapper.
        // A miss (Ok(None)) is a mapper that emitted nothing; a store
        // error is data loss and fails the job instead of silently
        // reducing over a hole.
        let mut inputs = Vec::new();
        for i in 0..n_maps {
            let key = interm_key(&job, i, j);
            match cluster.stores.read_intermediate(
                &mut cluster.engine,
                &cluster.topo,
                cfg.intermediate_store,
                node,
                &key,
            )? {
                Some((d, st)) => {
                    reduce_in_bytes += d.len();
                    inputs.push(d);
                    stages.extend(st);
                }
                None => {} // mapper emitted nothing for this partition
            }
        }
        let ro = wl.reduce_partition(j, n_reduces, &inputs, cfg, rt);
        let in_bytes: u64 = inputs.iter().map(|p| p.len()).sum();
        stages.push(Stage::Delay(SimNs::from_secs_f64(
            in_bytes as f64 / wl.reduce_rate(),
        )));
        if !ro.output.is_empty() {
            output_bytes += ro.output.len();
            let st = cluster.stores.write_output(
                &mut cluster.engine,
                &cluster.topo,
                cfg.output_store,
                node,
                &output_key(&job, j),
                ro.output,
            )?;
            stages.extend(st);
        }
        stages.push(Stage::Release(slot));
        stages.push(Stage::Arrive(job_done));
        cluster.engine.spawn(&format!("{job}/red{j}"), stages);
        if cfg.platform == Platform::OpenWhisk {
            cluster.controller.complete(&reduce_spec, node);
        } else {
            cluster.lambda.finish();
        }
    }

    // Run the time plane.
    let end = cluster.engine.run()?;
    if let Some((_, msg)) = cluster.engine.failures().first() {
        return Err(format!("task failed: {msg}"));
    }
    let maps_end = cluster
        .engine
        .barrier_opened_at(maps_done)
        .unwrap_or(end);
    let job_time = end - t_start;
    let io = IoSummary::from_flow_log(&cluster.engine.flow_log, job_time);

    let placed = map_in_local + map_in_remote;
    Ok(JobResult {
        job,
        config: cfg.name.clone(),
        input_bytes,
        intermediate_bytes,
        output_bytes,
        map: PhaseStats {
            tasks: n_maps,
            bytes_in: input_bytes,
            bytes_out: intermediate_bytes,
            duration: maps_end - t_start,
        },
        reduce: PhaseStats {
            tasks: n_reduces,
            bytes_in: reduce_in_bytes,
            bytes_out: output_bytes,
            duration: end.saturating_sub(maps_end),
        },
        job_time,
        failed: None,
        cold_starts: cluster.controller.cold_starts()
            + cluster.lambda.cold_starts,
        locality_ratio: if placed > 0 {
            map_in_local as f64 / placed as f64
        } else {
            0.0
        },
        io,
        rt_batches: rt.stats.batches - rt_batches0,
        rt_compute_ns: rt.stats.pjrt_ns + rt.stats.oracle_ns - rt_ns0,
    })
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end via coordinator tests + rust/tests/.
    #[test]
    fn interm_key_stable() {
        assert_eq!(super::interm_key("j", 2, 3), "j/shuffle/m00002/p003");
    }
}
