//! Typed experiment configuration, parsed from mini-TOML files.
//!
//! Example (`examples/configs/marvel.toml`):
//! ```toml
//! [cluster]
//! nodes = 1
//! slots_per_node = 32
//! nic_gbps = 10.0
//! [experiment]
//! system = "marvel-igfs"   # lambda-s3 | marvel-hdfs | marvel-igfs |
//!                          # onprem-pmem | onprem-ssd | ...
//! workload = "wordcount"
//! input = "1GiB"
//! seed = 42
//! ```
//!
//! See `ARCHITECTURE.md` for what each knob configures.

use crate::coordinator::ClusterSpec;
use crate::mapreduce::{
    ArrivalModel, Partitioner, PlacementStrategy, SystemConfig, TenantClass,
};
use crate::net::DeviceRole;
use crate::sim::SimNs;
use crate::util::bytes::GIB;
use crate::util::toml_mini::Doc;

#[derive(Clone, Debug)]
/// A fully-resolved experiment: cluster shape, system config,
/// workload, input size, and the optional co-run roster.
pub struct ExperimentConfig {
    pub cluster: ClusterSpec,
    pub system: SystemConfig,
    pub workload: String,
    pub input_bytes: u64,
    pub seed: u64,
    pub vocab: usize,
    pub zipf_s: f64,
    /// Multi-tenant co-run roster (`[server] tenants = "alice:3,bob:1"`)
    /// consumed by `marvel corun`; empty when unconfigured.
    pub tenants: Vec<(String, u64)>,
    /// Workloads the co-run admits round-robin across `tenants`
    /// (`[server] workloads = "wordcount,grep"`).
    pub corun_workloads: Vec<String>,
}

/// Parse a `name:share,name:share` tenant roster (share defaults to 1).
pub fn parse_tenant_spec(spec: &str) -> Result<Vec<(String, u64)>, String> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let mut it = part.trim().splitn(2, ':');
        let name = it.next().unwrap_or("").trim();
        if name.is_empty() {
            return Err(format!("empty tenant name in {spec:?}"));
        }
        let share = match it.next() {
            None => 1,
            Some(s) => s
                .trim()
                .parse::<u64>()
                .map_err(|_| format!("bad share in {part:?}"))?,
        };
        if out.iter().any(|t: &(String, u64)| t.0 == name) {
            return Err(format!("duplicate tenant {name:?}"));
        }
        out.push((name.to_string(), share.max(1)));
    }
    Ok(out)
}

/// Parse a `name:share:mix` tenant-class roster for the open-loop
/// arrival mix (share and mix both default to 1).
pub fn parse_class_spec(spec: &str) -> Result<Vec<TenantClass>, String> {
    let mut out: Vec<TenantClass> = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let mut it = part.trim().splitn(3, ':');
        let name = it.next().unwrap_or("").trim();
        if name.is_empty() {
            return Err(format!("empty class name in {spec:?}"));
        }
        let mut num = |what: &str| -> Result<u64, String> {
            match it.next() {
                None => Ok(1),
                Some(s) => s
                    .trim()
                    .parse::<u64>()
                    .map_err(|_| format!("bad {what} in {part:?}")),
            }
        };
        let share = num("share")?;
        let mix = num("mix")?;
        if out.iter().any(|c| c.name == name) {
            return Err(format!("duplicate class {name:?}"));
        }
        out.push(TenantClass::new(name, share, mix));
    }
    Ok(out)
}

/// Parse a comma-separated list of trace offsets in milliseconds.
fn parse_trace_ms(spec: &str) -> Result<Vec<u64>, String> {
    spec.split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| {
            p.trim()
                .parse::<u64>()
                .map_err(|_| format!("bad trace offset {p:?}"))
        })
        .collect()
}

/// Resolve a system-config preset by name.
pub fn system_by_name(name: &str) -> Result<SystemConfig, String> {
    Ok(match name {
        "lambda-s3" | "lambda" | "corral" => SystemConfig::corral_lambda(),
        "marvel-hdfs" => SystemConfig::marvel_hdfs(),
        "marvel-igfs" | "marvel" => SystemConfig::marvel_igfs(),
        "onprem-pmem" => SystemConfig::onprem(DeviceRole::Pmem, false),
        "onprem-pmem+s3" => SystemConfig::onprem(DeviceRole::Pmem, true),
        "onprem-ssd" => SystemConfig::onprem(DeviceRole::Ssd, false),
        "onprem-ssd+s3" => SystemConfig::onprem(DeviceRole::Ssd, true),
        "onprem-hdd" => SystemConfig::onprem(DeviceRole::Hdd, false),
        other => return Err(format!("unknown system config {other:?}")),
    })
}

impl ExperimentConfig {
    pub fn parse(text: &str) -> Result<ExperimentConfig, String> {
        let doc = Doc::parse(text)?;
        let mut cluster = ClusterSpec::default();
        cluster.nodes = doc.i64_or("cluster", "nodes", 1).max(1) as usize;
        cluster.slots_per_node =
            doc.i64_or("cluster", "slots_per_node", 32).max(1) as usize;
        cluster.nic_gbps = doc.f64_or("cluster", "nic_gbps", 10.0);
        cluster.wan_gbps = doc.f64_or("cluster", "wan_gbps", 5.0);
        cluster.pmem_capacity =
            doc.size_or("cluster", "pmem_capacity", 700 * GIB);
        cluster.ssd_capacity =
            doc.size_or("cluster", "ssd_capacity", 960 * GIB);
        cluster.dram_capacity =
            doc.size_or("cluster", "dram_capacity", 360 * GIB);

        let sys_name = doc.str_or("experiment", "system", "marvel-igfs");
        let mut system = system_by_name(sys_name)?;
        if let Some(v) = doc.get("experiment", "replication") {
            system.replication = v.as_i64().unwrap_or(1).max(1) as usize;
        }
        if let Some(v) = doc.get("experiment", "igfs_capacity") {
            if let Some(s) = v.as_str() {
                system.igfs_capacity =
                    crate::util::bytes::parse_size(s)?;
            } else if let Some(i) = v.as_i64() {
                system.igfs_capacity = i.max(0) as u64;
            }
        }
        // Data-plane map/reduce threads; 0 = auto. Output is byte-
        // identical at any setting (driver determinism contract).
        if let Some(v) = doc.get("experiment", "map_workers") {
            system.map_workers = v.as_i64().unwrap_or(0).max(0) as usize;
        }
        if let Some(v) = doc.get("experiment", "reduce_workers") {
            system.reduce_workers =
                v.as_i64().unwrap_or(0).max(0) as usize;
        }
        // [recovery] — checkpoint/resume policy (active in the time
        // plane only while [failures] is armed).
        if let Some(v) = doc.get("recovery", "interval") {
            if let Some(s) = v.as_str() {
                system.recovery.interval_bytes =
                    crate::util::bytes::parse_size(s)?;
            } else if let Some(i) = v.as_i64() {
                system.recovery.interval_bytes = i.max(1) as u64;
            }
        }
        if let Some(v) = doc.get("recovery", "max_attempts") {
            system.recovery.max_attempts =
                v.as_i64().unwrap_or(3).max(1) as u32;
        }
        system.recovery.stateful =
            doc.bool_or("recovery", "stateful", system.recovery.stateful);
        // [failures] — deterministic fault injection. Outputs stay
        // byte-identical to the failure-free run under any plan.
        system.failures.crash_prob = doc
            .f64_or("failures", "crash_prob", system.failures.crash_prob)
            .clamp(0.0, 1.0);
        if let Some(v) = doc.get("failures", "seed") {
            system.failures.seed = v.as_i64().unwrap_or(0) as u64;
        }
        if let Some(v) = doc.get("failures", "max_per_task") {
            system.failures.max_failures_per_task =
                v.as_i64().unwrap_or(2).max(0) as u32;
        }
        if let Some(s) =
            doc.get("failures", "lose_datanodes").and_then(|v| v.as_str())
        {
            system.failures.lose_datanodes =
                crate::coordinator::FailurePlan::parse_datanode_list(s)?;
        }
        // [stragglers] — heterogeneous node speeds. Time plane only:
        // outputs stay byte-identical under any profile.
        system.stragglers.prob = doc
            .f64_or("stragglers", "prob", system.stragglers.prob)
            .clamp(0.0, 1.0);
        system.stragglers.slowdown = doc
            .f64_or("stragglers", "slowdown", system.stragglers.slowdown)
            .max(1.0);
        if let Some(v) = doc.get("stragglers", "seed") {
            system.stragglers.seed = v.as_i64().unwrap_or(0) as u64;
        }
        // [netfaults] — seed-driven link fault windows, flow deadlines,
        // and the degraded-mode I/O knobs that ride with them. Time
        // plane + counters only: outputs stay byte-identical.
        system.netfaults.prob = doc
            .f64_or("netfaults", "link_fault_prob", system.netfaults.prob)
            .clamp(0.0, 1.0);
        system.netfaults.slowdown = doc
            .f64_or("netfaults", "link_slowdown", system.netfaults.slowdown)
            .max(1.0);
        if let Some(v) = doc.get("netfaults", "seed") {
            system.netfaults.seed = v.as_i64().unwrap_or(0) as u64;
        }
        if let Some(v) = doc.get("netfaults", "flow_timeout_ms") {
            system.netfaults.flow_timeout =
                SimNs::from_millis(v.as_i64().unwrap_or(250).max(1) as u64);
        }
        system.netfaults.degraded_tiers = doc.bool_or(
            "netfaults",
            "degraded_tiers",
            system.netfaults.degraded_tiers,
        );
        if let Some(s) = doc
            .get("netfaults", "lose_cachenodes")
            .and_then(|v| v.as_str())
        {
            system.netfaults.lose_cachenodes =
                crate::coordinator::FailurePlan::parse_datanode_list(s)?;
        }
        // [speculation] — backup attempts racing projected laggards.
        system.speculation.enabled = doc.bool_or(
            "speculation",
            "enabled",
            system.speculation.enabled,
        );
        system.speculation.lag_factor = doc
            .f64_or(
                "speculation",
                "lag_factor",
                system.speculation.lag_factor,
            )
            .max(1.0);
        // [arrivals] — open-loop arrival plane (`marvel serve`).
        // Inert unless a model is armed (positive rate / non-empty
        // trace). An explicit seed here wins over MARVEL_ARRIVAL_SEED
        // (parse order: preset/env first, then the file).
        let rate = doc.f64_or("arrivals", "rate", 0.0).max(0.0);
        system.arrivals.model = match doc.str_or("arrivals", "model", "poisson")
        {
            "poisson" => ArrivalModel::Poisson { rate },
            "ramp" => ArrivalModel::Ramp {
                rate,
                rate_end: doc.f64_or("arrivals", "rate_end", rate).max(0.0),
            },
            "trace" => ArrivalModel::Trace(parse_trace_ms(
                doc.str_or("arrivals", "trace_ms", ""),
            )?),
            other => {
                return Err(format!("unknown arrival model {other:?}"))
            }
        };
        if let Some(v) = doc.get("arrivals", "seed") {
            system.arrivals.seed = v.as_i64().unwrap_or(0) as u64;
        }
        if let Some(v) = doc.get("arrivals", "horizon_s") {
            system.arrivals.horizon = SimNs::from_secs_f64(
                v.as_f64().unwrap_or(3600.0).max(0.0),
            );
        }
        if let Some(v) = doc.get("arrivals", "max_jobs") {
            system.arrivals.max_jobs =
                v.as_i64().unwrap_or(256).max(1) as usize;
        }
        system.arrivals.classes =
            parse_class_spec(doc.str_or("arrivals", "classes", ""))?;
        if let Some(v) = doc.get("arrivals", "max_inflight") {
            system.arrivals.max_inflight =
                v.as_i64().unwrap_or(0).max(0) as usize;
        }
        if let Some(v) = doc.get("arrivals", "queue_cap") {
            system.arrivals.queue_cap =
                v.as_i64().unwrap_or(16).max(0) as usize;
        }
        if let Some(v) = doc.get("arrivals", "est_service_ms") {
            system.arrivals.est_service = SimNs::from_millis(
                v.as_i64().unwrap_or(2000).max(1) as u64,
            );
        }
        // [autoscale] — elastic warm-pool policy the serve loop drives.
        system.autoscale.enabled =
            doc.bool_or("autoscale", "enabled", system.autoscale.enabled);
        system.autoscale.warm_per_rate = doc
            .f64_or("autoscale", "warm_per_rate", system.autoscale.warm_per_rate)
            .max(0.0);
        system.autoscale.up_threshold = doc
            .f64_or("autoscale", "up_threshold", system.autoscale.up_threshold)
            .max(1.0);
        system.autoscale.down_threshold = doc
            .f64_or(
                "autoscale",
                "down_threshold",
                system.autoscale.down_threshold,
            )
            .clamp(0.0, 1.0);
        if let Some(v) = doc.get("autoscale", "min_warm") {
            system.autoscale.min_warm =
                v.as_i64().unwrap_or(0).max(0) as usize;
        }
        if let Some(v) = doc.get("autoscale", "max_warm") {
            system.autoscale.max_warm =
                v.as_i64().unwrap_or(256).max(1) as usize;
        }
        if let Some(v) = doc.get("autoscale", "window_s") {
            system.autoscale.window = SimNs::from_secs_f64(
                v.as_f64().unwrap_or(30.0).max(0.001),
            );
        }
        // [placement] — pluggable task-placement strategy. `seed` only
        // matters to `random`; an explicit strategy here overrides the
        // preset's default (and any MARVEL_PLACEMENT env value, which
        // `from_env` applied at preset construction).
        let pseed = doc.i64_or("placement", "seed", 1).max(0) as u64;
        if let Some(v) = doc.get("placement", "strategy") {
            let name = v.as_str().unwrap_or_default();
            system.placement = PlacementStrategy::parse(name, pseed)
                .map_err(|e| format!("[placement] strategy: {e}"))?;
        } else if doc.get("placement", "seed").is_some() {
            // Seed-only section: re-seed an env-selected random strategy.
            if let PlacementStrategy::Random { seed } =
                &mut system.placement
            {
                *seed = pseed;
            }
        }
        // [partition] — key→partition routing policy. An explicit
        // strategy here overrides the preset's default (and any
        // MARVEL_PARTITIONER env value, which `from_env` applied at
        // preset construction); `hot_threshold` / `split_ways` refine
        // an explicit or env-selected skew-aware partitioner.
        if let Some(v) = doc.get("partition", "strategy") {
            let name = v.as_str().unwrap_or_default();
            system.partition = Partitioner::parse(name)
                .map_err(|e| format!("[partition] strategy: {e}"))?;
        }
        if let Partitioner::SkewAware { hot_threshold, split_ways } =
            &mut system.partition
        {
            *hot_threshold = doc
                .f64_or("partition", "hot_threshold", *hot_threshold)
                .max(0.0);
            if let Some(v) = doc.get("partition", "split_ways") {
                *split_ways = v.as_i64().unwrap_or(0).max(2) as usize;
            }
        }
        let tenants =
            parse_tenant_spec(doc.str_or("server", "tenants", ""))?;
        let corun_workloads: Vec<String> = doc
            .str_or("server", "workloads", "")
            .split(',')
            .map(|s| s.trim())
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect();
        Ok(ExperimentConfig {
            cluster,
            system,
            workload: doc
                .str_or("experiment", "workload", "wordcount")
                .to_string(),
            input_bytes: doc.size_or("experiment", "input", GIB),
            seed: doc.i64_or("experiment", "seed", 42) as u64,
            vocab: doc.i64_or("experiment", "vocab", 10_000).max(2) as usize,
            zipf_s: doc.f64_or("experiment", "zipf_s", 1.07),
            tenants,
            corun_workloads,
        })
    }

    pub fn load(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_config() {
        let cfg = ExperimentConfig::parse(
            r#"
[cluster]
nodes = 4
slots_per_node = 16
[experiment]
system = "marvel-hdfs"
workload = "grep"
input = "2GiB"
seed = 7
replication = 3
map_workers = 4
reduce_workers = 2
"#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.nodes, 4);
        assert_eq!(cfg.system.name, "marvel-hdfs");
        assert_eq!(cfg.system.replication, 3);
        assert_eq!(cfg.system.map_workers, 4);
        assert_eq!(cfg.system.reduce_workers, 2);
        assert_eq!(cfg.workload, "grep");
        assert_eq!(cfg.input_bytes, 2 * GIB);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn defaults_are_sane() {
        let cfg = ExperimentConfig::parse("").unwrap();
        assert_eq!(cfg.cluster.nodes, 1);
        assert_eq!(cfg.system.name, "marvel-igfs");
        assert_eq!(cfg.input_bytes, GIB);
    }

    #[test]
    fn tenant_spec_parses() {
        assert_eq!(
            parse_tenant_spec("alice:3,bob:1").unwrap(),
            vec![("alice".into(), 3), ("bob".into(), 1)]
        );
        assert_eq!(
            parse_tenant_spec("solo").unwrap(),
            vec![("solo".into(), 1)]
        );
        assert_eq!(parse_tenant_spec("").unwrap(), vec![]);
        assert!(parse_tenant_spec("a:x").is_err());
        assert!(parse_tenant_spec(":3").is_err());
        assert!(parse_tenant_spec("a:1,a:2").is_err());
        // share 0 is clamped to 1 (a zero-weight queue would starve).
        assert_eq!(parse_tenant_spec("z:0").unwrap()[0].1, 1);
    }

    #[test]
    fn server_section_parses() {
        let cfg = ExperimentConfig::parse(
            r#"
[server]
tenants = "alice:3,bob:1"
workloads = "wordcount, grep"
"#,
        )
        .unwrap();
        assert_eq!(cfg.tenants.len(), 2);
        assert_eq!(cfg.tenants[0], ("alice".to_string(), 3));
        assert_eq!(cfg.corun_workloads, vec!["wordcount", "grep"]);
        let empty = ExperimentConfig::parse("").unwrap();
        assert!(empty.tenants.is_empty());
        assert!(empty.corun_workloads.is_empty());
    }

    #[test]
    fn placement_section_parses() {
        let cfg = ExperimentConfig::parse(
            r#"
[placement]
strategy = "cache-affinity"
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.system.placement,
            PlacementStrategy::CacheAffinity
        );
        let cfg = ExperimentConfig::parse(
            r#"
[placement]
strategy = "random"
seed = 99
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.system.placement,
            PlacementStrategy::Random { seed: 99 }
        );
        assert!(ExperimentConfig::parse(
            "[placement]\nstrategy = \"nearest\"\n"
        )
        .is_err());
        // No section: the preset default survives (guard the env knob
        // so a sweep harness exporting MARVEL_PLACEMENT can't flake us).
        if std::env::var("MARVEL_PLACEMENT").is_err() {
            let cfg = ExperimentConfig::parse("").unwrap();
            assert_eq!(
                cfg.system.placement,
                PlacementStrategy::FairOrder
            );
        }
    }

    #[test]
    fn partition_section_parses() {
        let cfg = ExperimentConfig::parse(
            r#"
[partition]
strategy = "skew-aware"
hot_threshold = 1.25
split_ways = 3
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.system.partition,
            Partitioner::SkewAware { hot_threshold: 1.25, split_ways: 3 }
        );
        // Defaults fill in when the knobs are omitted.
        let cfg = ExperimentConfig::parse(
            "[partition]\nstrategy = \"skew-aware\"\n",
        )
        .unwrap();
        assert_eq!(
            cfg.system.partition,
            Partitioner::SkewAware {
                hot_threshold: Partitioner::DEFAULT_HOT_THRESHOLD,
                split_ways: Partitioner::DEFAULT_SPLIT_WAYS,
            }
        );
        let cfg = ExperimentConfig::parse(
            "[partition]\nstrategy = \"range\"\n",
        )
        .unwrap();
        assert_eq!(
            cfg.system.partition,
            Partitioner::Range { bounds: vec![] }
        );
        assert!(ExperimentConfig::parse(
            "[partition]\nstrategy = \"modulo\"\n"
        )
        .is_err());
        // No section: legacy hash unless CI's env column overrides.
        if std::env::var("MARVEL_PARTITIONER").is_err() {
            let cfg = ExperimentConfig::parse("").unwrap();
            assert_eq!(cfg.system.partition, Partitioner::Hash);
        }
    }

    #[test]
    fn failure_and_recovery_sections_parse() {
        let cfg = ExperimentConfig::parse(
            r#"
[recovery]
interval = "4MiB"
max_attempts = 5
stateful = false
[failures]
crash_prob = 0.4
seed = 77
max_per_task = 3
lose_datanodes = "0, 2"
"#,
        )
        .unwrap();
        assert_eq!(cfg.system.recovery.interval_bytes, 4 * 1024 * 1024);
        assert_eq!(cfg.system.recovery.max_attempts, 5);
        assert!(!cfg.system.recovery.stateful);
        assert!(cfg.system.failures.enabled());
        assert!((cfg.system.failures.crash_prob - 0.4).abs() < 1e-12);
        // An explicit [failures] seed wins over the MARVEL_FAILURE_SEED
        // env default (parse order: preset/env first, then the file).
        assert_eq!(cfg.system.failures.seed, 77);
        assert_eq!(cfg.system.failures.max_failures_per_task, 3);
        assert_eq!(cfg.system.failures.lose_datanodes, vec![0, 2]);
        assert!(ExperimentConfig::parse(
            "[failures]\nlose_datanodes = \"zero\"\n"
        )
        .is_err());
        // Absent sections leave the plan disabled.
        let plain = ExperimentConfig::parse("").unwrap();
        assert!(!plain.system.failures.enabled());
    }

    #[test]
    fn straggler_and_speculation_sections_parse() {
        let cfg = ExperimentConfig::parse(
            r#"
[stragglers]
prob = 0.25
slowdown = 8.0
seed = 21
[speculation]
enabled = true
lag_factor = 2.0
"#,
        )
        .unwrap();
        assert!(cfg.system.stragglers.enabled());
        assert!((cfg.system.stragglers.prob - 0.25).abs() < 1e-12);
        assert!((cfg.system.stragglers.slowdown - 8.0).abs() < 1e-12);
        // An explicit [stragglers] seed wins over MARVEL_STRAGGLER_SEED
        // (parse order: preset/env first, then the file).
        assert_eq!(cfg.system.stragglers.seed, 21);
        assert!(cfg.system.speculation.enabled);
        assert!((cfg.system.speculation.lag_factor - 2.0).abs() < 1e-12);
        // Degenerate values are clamped to sane policy.
        let clamped = ExperimentConfig::parse(
            "[stragglers]\nprob = 7.0\nslowdown = 0.5\n\
             [speculation]\nlag_factor = 0.2\n",
        )
        .unwrap();
        assert!((clamped.system.stragglers.prob - 1.0).abs() < 1e-12);
        assert!((clamped.system.stragglers.slowdown - 1.0).abs() < 1e-12);
        assert!((clamped.system.speculation.lag_factor - 1.0).abs() < 1e-12);
        // Absent sections leave both knobs inert.
        let plain = ExperimentConfig::parse("").unwrap();
        assert!(!plain.system.stragglers.enabled());
        assert!(!plain.system.speculation.enabled);
    }

    #[test]
    fn netfault_section_parses() {
        let cfg = ExperimentConfig::parse(
            r#"
[netfaults]
link_fault_prob = 0.5
link_slowdown = 16.0
seed = 99
flow_timeout_ms = 400
degraded_tiers = false
lose_cachenodes = "1, 2"
"#,
        )
        .unwrap();
        let nf = &cfg.system.netfaults;
        assert!(nf.enabled());
        assert!((nf.prob - 0.5).abs() < 1e-12);
        assert!((nf.slowdown - 16.0).abs() < 1e-12);
        // An explicit [netfaults] seed wins over MARVEL_NETFAULT_SEED
        // (parse order: preset/env first, then the file).
        assert_eq!(nf.seed, 99);
        assert_eq!(nf.flow_timeout, SimNs::from_millis(400));
        assert!(!nf.degraded_tiers);
        assert!(nf.blackout_armed());
        assert_eq!(nf.lose_cachenodes, vec![1, 2]);
        assert!(ExperimentConfig::parse(
            "[netfaults]\nlose_cachenodes = \"one\"\n"
        )
        .is_err());
        // Degenerate values clamp; an absent section stays inert.
        let clamped = ExperimentConfig::parse(
            "[netfaults]\nlink_fault_prob = 9.0\nlink_slowdown = 0.1\n",
        )
        .unwrap();
        assert!((clamped.system.netfaults.prob - 1.0).abs() < 1e-12);
        assert!((clamped.system.netfaults.slowdown - 1.0).abs() < 1e-12);
        let plain = ExperimentConfig::parse("").unwrap();
        assert!(!plain.system.netfaults.enabled());
        assert!(!plain.system.netfaults.blackout_armed());
    }

    #[test]
    fn arrivals_and_autoscale_sections_parse() {
        let cfg = ExperimentConfig::parse(
            r#"
[arrivals]
model = "ramp"
rate = 0.5
rate_end = 4.0
seed = 13
horizon_s = 120.0
max_jobs = 40
classes = "an:3:2,batch:1"
max_inflight = 6
queue_cap = 3
est_service_ms = 1500

[autoscale]
enabled = true
warm_per_rate = 4.0
up_threshold = 1.5
down_threshold = 0.25
min_warm = 2
max_warm = 24
window_s = 15
"#,
        )
        .unwrap();
        let arr = &cfg.system.arrivals;
        assert!(arr.enabled());
        match arr.model {
            crate::mapreduce::ArrivalModel::Ramp { rate, rate_end } => {
                assert!((rate - 0.5).abs() < 1e-12);
                assert!((rate_end - 4.0).abs() < 1e-12);
            }
            ref m => panic!("expected ramp, got {m:?}"),
        }
        // An explicit [arrivals] seed wins over MARVEL_ARRIVAL_SEED
        // (parse order: preset/env first, then the file).
        assert_eq!(arr.seed, 13);
        assert_eq!(arr.horizon, SimNs::from_secs_f64(120.0));
        assert_eq!(arr.max_jobs, 40);
        assert_eq!(arr.classes.len(), 2);
        assert_eq!(arr.classes[0].name, "an");
        assert_eq!(arr.classes[0].share, 3);
        assert_eq!(arr.classes[0].mix, 2);
        // Omitted mix defaults to 1.
        assert_eq!(arr.classes[1].name, "batch");
        assert_eq!(arr.classes[1].share, 1);
        assert_eq!(arr.classes[1].mix, 1);
        assert_eq!(arr.max_inflight, 6);
        assert_eq!(arr.queue_cap, 3);
        assert_eq!(arr.est_service, SimNs::from_millis(1500));
        let auto = &cfg.system.autoscale;
        assert!(auto.enabled);
        assert!((auto.warm_per_rate - 4.0).abs() < 1e-12);
        assert!((auto.up_threshold - 1.5).abs() < 1e-12);
        assert!((auto.down_threshold - 0.25).abs() < 1e-12);
        assert_eq!(auto.min_warm, 2);
        assert_eq!(auto.max_warm, 24);
        assert_eq!(auto.window, SimNs::from_secs_f64(15.0));

        // Trace replay: offsets in ms, verbatim.
        let traced = ExperimentConfig::parse(
            "[arrivals]\nmodel = \"trace\"\ntrace_ms = \"0, 250, 900\"\n",
        )
        .unwrap();
        match traced.system.arrivals.model {
            crate::mapreduce::ArrivalModel::Trace(ref ms) => {
                assert_eq!(ms, &vec![0, 250, 900]);
            }
            ref m => panic!("expected trace, got {m:?}"),
        }
        assert!(traced.system.arrivals.enabled());

        // Malformed specs surface as errors, not silent defaults.
        assert!(ExperimentConfig::parse("[arrivals]\nmodel = \"burst\"\n")
            .is_err());
        assert!(ExperimentConfig::parse(
            "[arrivals]\nmodel = \"trace\"\ntrace_ms = \"0, soon\"\n"
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            "[arrivals]\nclasses = \"an:3,an:1\"\n"
        )
        .is_err());
        assert!(
            ExperimentConfig::parse("[arrivals]\nclasses = \":2\"\n").is_err()
        );
        assert!(ExperimentConfig::parse(
            "[arrivals]\nclasses = \"an:lots\"\n"
        )
        .is_err());

        // Degenerate values clamp; absent sections stay inert.
        let clamped = ExperimentConfig::parse(
            "[autoscale]\nup_threshold = 0.2\ndown_threshold = 7.0\n",
        )
        .unwrap();
        assert!((clamped.system.autoscale.up_threshold - 1.0).abs() < 1e-12);
        assert!((clamped.system.autoscale.down_threshold - 1.0).abs() < 1e-12);
        let plain = ExperimentConfig::parse("").unwrap();
        assert!(!plain.system.arrivals.enabled());
        assert!(!plain.system.autoscale.enabled);
    }

    #[test]
    fn every_preset_resolves() {
        for name in ["lambda-s3", "marvel-hdfs", "marvel-igfs",
                     "onprem-pmem", "onprem-pmem+s3", "onprem-ssd",
                     "onprem-ssd+s3", "onprem-hdd"] {
            assert!(system_by_name(name).is_ok(), "{name}");
        }
        assert!(system_by_name("bogus").is_err());
    }
}
