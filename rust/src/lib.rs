//! Marvel: persistent-memory-backed stateful serverless computing for
//! big-data applications — a full reproduction of Li et al. (CS.DC'23)
//! as a three-layer Rust + JAX + Pallas system. See ARCHITECTURE.md.
//!
//! Layer map:
//! * L1/L2 (build time): `python/compile/` — Pallas combine kernels +
//!   jax models, AOT-lowered to `artifacts/*.hlo.txt`.
//! * Runtime bridge: [`runtime`] loads the artifacts via PJRT.
//! * L3 (this crate): everything else — the serverless platform, the
//!   storage substrates, the MapReduce engine, and the coordinator.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod faas;
pub mod hdfs;
pub mod igfs;
pub mod mapreduce;
pub mod metrics;
pub mod net;
pub mod objstore;
pub mod runtime;
pub mod sim;
pub mod storage;
pub mod util;
pub mod workloads;
pub mod yarn;
