//! Parse `artifacts/manifest.json` written by `python/compile/aot.py`.
//! The manifest pins the shapes/constants the AOT artifacts were lowered
//! with; the Rust side must build literals that match exactly.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Clone, Debug)]
/// Shape/dtype of one artifact parameter.
pub struct ParamSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
/// One AOT-lowered artifact: file, entry, batch geometry.
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub params: Vec<ParamSpec>,
    pub outputs: Vec<Vec<usize>>,
    pub n: usize,
    pub sha256: String,
}

#[derive(Clone, Debug)]
/// The artifact manifest produced by `python/compile/aot.py`.
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub tokens_per_batch: usize,
    pub small_batch: usize,
    pub word_width: usize,
    pub buckets: usize,
    pub parts: usize,
    pub segments: usize,
    pub part_shift: u32,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("read manifest: {e}"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let j = Json::parse(text)?;
        let consts = j
            .get("constants")
            .ok_or("manifest missing constants")?;
        let c = |k: &str| -> Result<usize, String> {
            consts
                .get(k)
                .and_then(|v| v.as_u64())
                .map(|v| v as usize)
                .ok_or_else(|| format!("manifest missing constant {k}"))
        };
        let mut artifacts = BTreeMap::new();
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or("manifest missing artifacts")?;
        for (name, meta) in arts {
            let file = meta
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| format!("artifact {name} missing file"))?;
            let params = meta
                .get("params")
                .and_then(|p| p.as_arr())
                .ok_or_else(|| format!("artifact {name} missing params"))?
                .iter()
                .map(|p| {
                    let shape = p
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .map(|a| {
                            a.iter()
                                .filter_map(|v| v.as_u64())
                                .map(|v| v as usize)
                                .collect()
                        })
                        .unwrap_or_default();
                    let dtype = p
                        .get("dtype")
                        .and_then(|d| d.as_str())
                        .unwrap_or("?")
                        .to_string();
                    ParamSpec { shape, dtype }
                })
                .collect();
            let outputs = meta
                .get("outputs")
                .and_then(|o| o.as_arr())
                .map(|a| {
                    a.iter()
                        .map(|o| {
                            o.as_arr()
                                .map(|d| {
                                    d.iter()
                                        .filter_map(|v| v.as_u64())
                                        .map(|v| v as usize)
                                        .collect()
                                })
                                .unwrap_or_default()
                        })
                        .collect()
                })
                .unwrap_or_default();
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: dir.join(file),
                    params,
                    outputs,
                    n: meta.get("n").and_then(|v| v.as_u64()).unwrap_or(0)
                        as usize,
                    sha256: meta
                        .get("sha256")
                        .and_then(|v| v.as_str())
                        .unwrap_or("")
                        .to_string(),
                },
            );
        }
        Ok(Manifest {
            artifacts,
            tokens_per_batch: c("tokens_per_batch")?,
            small_batch: c("small_batch")?,
            word_width: c("word_width")?,
            buckets: c("buckets")?,
            parts: c("parts")?,
            segments: c("segments")?,
            part_shift: c("part_shift")? as u32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text/return-tuple",
      "constants": {"tokens_per_batch": 8192, "small_batch": 1024,
                    "word_width": 16, "buckets": 1024, "parts": 32,
                    "segments": 1024, "part_shift": 10},
      "artifacts": {
        "wordcount_combine": {
          "file": "wordcount_combine.hlo.txt", "n": 8192,
          "sha256": "ab", "outputs": [[32, 1024]],
          "params": [{"shape": [8192], "dtype": "int32"},
                     {"shape": [8192], "dtype": "float32"}]
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.tokens_per_batch, 8192);
        assert_eq!(m.parts, 32);
        let a = &m.artifacts["wordcount_combine"];
        assert_eq!(a.file, PathBuf::from("/art/wordcount_combine.hlo.txt"));
        assert_eq!(a.params.len(), 2);
        assert_eq!(a.params[0].shape, vec![8192]);
        assert_eq!(a.params[0].dtype, "int32");
        assert_eq!(a.outputs, vec![vec![32, 1024]]);
    }

    #[test]
    fn missing_constant_errors() {
        assert!(Manifest::parse(r#"{"constants": {}, "artifacts": {}}"#,
                                Path::new("/")).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // When `make artifacts` has run, validate the real file.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.contains_key("wordcount_combine"));
            assert!(m.artifacts.contains_key("grep_combine"));
            assert!(m.artifacts.contains_key("agg_combine"));
            assert_eq!(m.buckets, 1024);
        }
    }
}
