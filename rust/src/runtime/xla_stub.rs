//! Compile-time stand-in for the `xla` crate, used when the `pjrt`
//! feature is off (the offline build has no PJRT plugin). The API
//! surface mirrors exactly what `engine.rs` touches; every runtime
//! entry point fails, so `RtEngine::load` falls back to the oracle —
//! same behavior the engine already has when `artifacts/` is absent.
//!
//! To run compiled HLO through PJRT, add the real `xla` crate under
//! `[dependencies]` in rust/Cargo.toml (it is intentionally not
//! declared — see the manifest header) and build with
//! `--features pjrt`.

#![allow(dead_code)]

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} (built without the `pjrt` feature)", self.0)
    }
}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!("{what}: PJRT unavailable")))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(
        &self,
        _inputs: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}
