//! PJRT runtime: loads the AOT artifacts (HLO text) once at startup,
//! compiles them on the CPU PJRT client, and executes combine batches on
//! the request path. Python is never involved at runtime — this module
//! plus `artifacts/` is the entire compute stack (ARCHITECTURE.md,
//! Runtime & artifacts).
//!
//! Falls back to `oracle` when artifacts are absent so the library works
//! pre-`make artifacts`; integration tests assert PJRT-vs-oracle
//! equality whenever the artifacts exist.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use super::manifest::Manifest;
use super::oracle::{self, CombineScheme};

// Without the `pjrt` feature the real `xla` crate is replaced by a
// same-shape stub whose entry points fail at runtime, so `load` falls
// back to the oracle exactly as it does when artifacts are missing.
#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

/// Execution statistics for §Perf.
#[derive(Clone, Debug, Default)]
pub struct RtStats {
    pub batches: u64,
    pub pjrt_ns: u64,
    pub oracle_ns: u64,
}

enum Exec {
    Pjrt { exe: xla::PjRtLoadedExecutable },
    Oracle,
}

/// Reusable batch-staging buffers for the combine hot path. The mask
/// invariant is "all ones": callers zero only the tail of a partial
/// final chunk and restore it before handing the scratch back, so the
/// per-batch mask rewrite disappears from full chunks entirely.
pub struct BatchScratch {
    pub batch: Vec<i32>,
    pub mask: Vec<f32>,
}

/// The runtime engine. One compiled executable per artifact.
///
/// The manifest is frozen behind an `Arc`: a job-level engine and every
/// per-worker oracle spawned off it ([`RtEngine::oracle_shared`]) read
/// the same interned constants instead of re-deriving a deep copy per
/// worker per stage.
pub struct RtEngine {
    pub manifest: Arc<Manifest>,
    client: Option<xla::PjRtClient>,
    execs: HashMap<String, Exec>,
    pub stats: RtStats,
    scratch: Option<BatchScratch>,
}

impl RtEngine {
    /// Load + compile everything in `dir`; `None` dir → oracle mode.
    /// Without the `pjrt` feature the manifest constants still load
    /// (shapes must match the artifacts) but compute stays on the
    /// oracle — the stub client is never constructed.
    pub fn load(dir: Option<&Path>) -> Result<RtEngine, String> {
        let (manifest, use_pjrt) = match dir {
            Some(d) if d.join("manifest.json").exists() => {
                (Manifest::load(d)?, cfg!(feature = "pjrt"))
            }
            _ => (default_manifest(), false),
        };
        let mut execs = HashMap::new();
        let client = if use_pjrt {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| format!("pjrt client: {e}"))?;
            for (name, meta) in &manifest.artifacts {
                let proto = xla::HloModuleProto::from_text_file(
                    meta.file.to_str().ok_or("bad path")?,
                )
                .map_err(|e| format!("load {name}: {e}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| format!("compile {name}: {e}"))?;
                execs.insert(name.clone(), Exec::Pjrt { exe });
            }
            Some(client)
        } else {
            execs = oracle_execs();
            None
        };
        Ok(RtEngine {
            manifest: Arc::new(manifest),
            client,
            execs,
            stats: RtStats::default(),
            scratch: None,
        })
    }

    /// A fresh oracle-mode engine taking ownership of `manifest` —
    /// kept for callers that build a manifest from scratch. Fan-out
    /// paths should prefer [`RtEngine::oracle_shared`].
    pub fn oracle_from(manifest: Manifest) -> RtEngine {
        RtEngine::oracle_shared(Arc::new(manifest))
    }

    /// A fresh oracle-mode engine sharing an already-interned manifest
    /// — the per-worker compute instance of the parallel map/reduce
    /// data planes (see DESIGN note in `mapreduce::driver`): `pool_run`
    /// hands every worker the same frozen `Arc` instead of deep-copying
    /// the artifact table per spawn. Oracle and PJRT produce identical
    /// integer-valued counts, so outputs stay bit-identical to the
    /// serial path.
    pub fn oracle_shared(manifest: Arc<Manifest>) -> RtEngine {
        RtEngine {
            manifest,
            client: None,
            execs: oracle_execs(),
            stats: RtStats::default(),
            scratch: None,
        }
    }

    /// Fold a worker engine's stats into this (job-level) engine.
    pub fn absorb_stats(&mut self, other: &RtStats) {
        self.stats.batches += other.batches;
        self.stats.pjrt_ns += other.pjrt_ns;
        self.stats.oracle_ns += other.oracle_ns;
    }

    /// Take the reusable batch scratch (sized to `batch_size`, mask all
    /// ones). Pair with `put_batch_scratch` so the buffers survive
    /// across `combine_hashes` calls instead of being reallocated per
    /// split.
    pub fn take_batch_scratch(&mut self) -> BatchScratch {
        let n = self.batch_size();
        match self.scratch.take() {
            Some(s) if s.batch.len() == n => s,
            _ => BatchScratch { batch: vec![0; n], mask: vec![1.0; n] },
        }
    }

    pub fn put_batch_scratch(&mut self, s: BatchScratch) {
        self.scratch = Some(s);
    }

    pub fn is_pjrt(&self) -> bool {
        self.client.is_some()
    }

    pub fn scheme(&self) -> CombineScheme {
        CombineScheme {
            parts: self.manifest.parts,
            buckets: self.manifest.buckets,
            part_shift: self.manifest.part_shift,
        }
    }

    pub fn batch_size(&self) -> usize {
        self.manifest.tokens_per_batch
    }

    /// Resolve a logical artifact name, preferring the CPU-specialized
    /// lowering when present (EXPERIMENTS.md §Perf: the interpret-mode
    /// Pallas grid costs ~40 ms/batch on CPU-PJRT; the scatter-add
    /// lowering of the same math runs in microseconds).
    fn resolve(&self, name: &str) -> String {
        let cpu = format!("{name}_cpu");
        if self.execs.contains_key(&cpu) {
            cpu
        } else {
            name.to_string()
        }
    }

    fn run_pjrt(
        &mut self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<Vec<f32>>, String> {
        let name = &self.resolve(name);
        let exe = match self.execs.get(name.as_str()) {
            Some(Exec::Pjrt { exe }) => exe,
            _ => return Err(format!("artifact {name} not loaded as PJRT")),
        };
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| format!("execute {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("sync {name}: {e}"))?;
        // Artifacts are lowered with return_tuple=True.
        let parts = result
            .to_tuple()
            .map_err(|e| format!("tuple {name}: {e}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(
                p.to_vec::<f32>()
                    .map_err(|e| format!("to_vec {name}: {e}"))?,
            );
        }
        self.stats.batches += 1;
        self.stats.pjrt_ns += t0.elapsed().as_nanos() as u64;
        Ok(out)
    }

    /// WordCount combine over exactly one batch (N tokens, padded).
    /// Returns flattened (R*B) counts.
    pub fn wordcount_batch(
        &mut self,
        hashes: &[i32],
        mask: &[f32],
    ) -> Result<Vec<f32>, String> {
        let n = self.manifest.tokens_per_batch;
        assert_eq!(hashes.len(), n, "batch must be padded to N={n}");
        if self.is_pjrt() {
            let h = xla::Literal::vec1(hashes);
            let m = xla::Literal::vec1(mask);
            Ok(self.run_pjrt("wordcount_combine", &[h, m])?.remove(0))
        } else {
            let t0 = Instant::now();
            let out = oracle::wordcount_combine(&self.scheme(), hashes, mask);
            self.stats.batches += 1;
            self.stats.oracle_ns += t0.elapsed().as_nanos() as u64;
            Ok(out)
        }
    }

    /// Grep combine over one batch: (R*B counts, total matches).
    pub fn grep_batch(
        &mut self,
        tokens: &[i32],
        hashes: &[i32],
        mask: &[f32],
        pattern: &[i32],
    ) -> Result<(Vec<f32>, f32), String> {
        let n = self.manifest.tokens_per_batch;
        let w = self.manifest.word_width;
        assert_eq!(tokens.len(), n * w);
        assert_eq!(pattern.len(), w);
        if self.is_pjrt() {
            let t = xla::Literal::vec1(tokens)
                .reshape(&[n as i64, w as i64])
                .map_err(|e| format!("reshape: {e}"))?;
            let h = xla::Literal::vec1(hashes);
            let m = xla::Literal::vec1(mask);
            let p = xla::Literal::vec1(pattern);
            let mut out = self.run_pjrt("grep_combine", &[t, h, m, p])?;
            let total = out.pop().ok_or("missing total")?;
            let counts = out.pop().ok_or("missing counts")?;
            Ok((counts, total[0]))
        } else {
            let t0 = Instant::now();
            let r = oracle::grep_combine(&self.scheme(), tokens, hashes,
                                         mask, pattern, w);
            self.stats.batches += 1;
            self.stats.oracle_ns += t0.elapsed().as_nanos() as u64;
            Ok(r)
        }
    }

    /// Aggregation combine over one small batch: (sums, counts).
    pub fn agg_batch(
        &mut self,
        seg_ids: &[i32],
        values: &[f32],
        mask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>), String> {
        let n = self.manifest.small_batch;
        assert_eq!(seg_ids.len(), n);
        if self.is_pjrt() {
            let s = xla::Literal::vec1(seg_ids);
            let v = xla::Literal::vec1(values);
            let m = xla::Literal::vec1(mask);
            let mut out = self.run_pjrt("agg_combine", &[s, v, m])?;
            let counts = out.pop().ok_or("missing counts")?;
            let sums = out.pop().ok_or("missing sums")?;
            Ok((sums, counts))
        } else {
            let t0 = Instant::now();
            let r = oracle::agg_combine(self.manifest.segments, seg_ids,
                                        values, mask);
            self.stats.batches += 1;
            self.stats.oracle_ns += t0.elapsed().as_nanos() as u64;
            Ok(r)
        }
    }

    /// Mean measured latency per batch, ns (0 before first batch).
    pub fn mean_batch_ns(&self) -> u64 {
        if self.stats.batches == 0 {
            0
        } else {
            (self.stats.pjrt_ns + self.stats.oracle_ns) / self.stats.batches
        }
    }
}

/// The oracle-mode executable registry (single source for `load` and
/// `oracle_from` — add new kernel names here only).
fn oracle_execs() -> HashMap<String, Exec> {
    ["wordcount_combine", "wordcount_combine_small", "grep_combine",
     "agg_combine"]
        .into_iter()
        .map(|name| (name.to_string(), Exec::Oracle))
        .collect()
}

/// Manifest used in oracle mode (same constants as model.py).
fn default_manifest() -> Manifest {
    Manifest {
        artifacts: std::collections::BTreeMap::new(),
        tokens_per_batch: 8192,
        small_batch: 1024,
        word_width: 16,
        buckets: 1024,
        parts: 32,
        segments: 1024,
        part_shift: 10,
    }
}

/// Locate `artifacts/` relative to the crate root, if built.
pub fn default_artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_mode_works_without_artifacts() {
        let mut rt = RtEngine::load(None).unwrap();
        assert!(!rt.is_pjrt());
        let n = rt.batch_size();
        let hashes: Vec<i32> = (0..n as i32).collect();
        let mask = vec![1f32; n];
        let out = rt.wordcount_batch(&hashes, &mask).unwrap();
        assert_eq!(out.len(), 32 * 1024);
        assert_eq!(out.iter().sum::<f32>(), n as f32);
        assert_eq!(rt.stats.batches, 1);
    }

    #[test]
    fn grep_oracle_batch() {
        let mut rt = RtEngine::load(None).unwrap();
        let n = rt.batch_size();
        let w = rt.manifest.word_width;
        let mut tokens = vec![0i32; n * w];
        for i in 0..n / 2 {
            tokens[i * w] = 42; // half the tokens start with 42
        }
        let hashes = vec![1i32; n];
        let mask = vec![1f32; n];
        let mut pattern = vec![oracle::WILD_REST; w];
        pattern[0] = 42;
        let (_, total) = rt
            .grep_batch(&tokens, &hashes, &mask, &pattern)
            .unwrap();
        assert_eq!(total, (n / 2) as f32);
    }

    #[test]
    fn agg_oracle_batch() {
        let mut rt = RtEngine::load(None).unwrap();
        let n = rt.manifest.small_batch;
        let ids: Vec<i32> = (0..n as i32).map(|i| i % 7).collect();
        let vals = vec![2f32; n];
        let mask = vec![1f32; n];
        let (sums, cnts) = rt.agg_batch(&ids, &vals, &mask).unwrap();
        assert_eq!(sums.iter().sum::<f32>(), 2.0 * n as f32);
        assert_eq!(cnts.iter().sum::<f32>(), n as f32);
    }

    #[test]
    fn oracle_shared_interns_the_manifest() {
        // Worker oracles must alias the job engine's manifest, not
        // deep-copy it: one frozen constant table per job.
        let rt = RtEngine::load(None).unwrap();
        let w1 = RtEngine::oracle_shared(rt.manifest.clone());
        let w2 = RtEngine::oracle_shared(rt.manifest.clone());
        assert!(Arc::ptr_eq(&rt.manifest, &w1.manifest));
        assert!(Arc::ptr_eq(&w1.manifest, &w2.manifest));
        assert_eq!(w1.batch_size(), rt.batch_size());
        assert!(!w1.is_pjrt());
    }

    // PJRT-vs-oracle equivalence lives in rust/tests/pjrt_runtime.rs
    // (needs `make artifacts` first).
}
