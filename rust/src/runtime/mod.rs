//! Runtime layer: PJRT client wrapping the `xla` crate — loads
//! `artifacts/*.hlo.txt` (AOT-lowered by python/compile/aot.py), compiles
//! once, executes combine batches from the L3 hot path.
//!
//! See `ARCHITECTURE.md` (Runtime & artifacts).

pub mod engine;
pub mod manifest;
pub mod oracle;
#[cfg(not(feature = "pjrt"))]
mod xla_stub;

pub use engine::{default_artifacts_dir, BatchScratch, RtEngine, RtStats};
pub use manifest::{ArtifactMeta, Manifest};
pub use oracle::CombineScheme;
