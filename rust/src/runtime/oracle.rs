//! Pure-Rust reference of the AOT combine computations. Two roles:
//! (1) the ground truth the integration tests hold the PJRT path to,
//! and (2) the fallback compute path when `artifacts/` has not been
//! built (keeps unit tests and examples runnable pre-`make artifacts`).
//!
//! Must mirror python/compile/model.py exactly (same bit-level
//! partition/bucket scheme).

/// Partition/bucket scheme shared with the kernels. B and R must match
/// the manifest; the shift is log2(B).
#[derive(Clone, Copy, Debug)]
pub struct CombineScheme {
    pub parts: usize,
    pub buckets: usize,
    pub part_shift: u32,
}

impl CombineScheme {
    pub fn bucket(&self, hash: i32) -> usize {
        (hash as usize) & (self.buckets - 1)
    }

    pub fn part(&self, hash: i32) -> usize {
        ((hash as usize) >> self.part_shift) & (self.parts - 1)
    }

    pub fn flat(&self, hash: i32) -> usize {
        self.part(hash) * self.buckets + self.bucket(hash)
    }
}

/// wordcount_combine: masked counts per (part, bucket), flattened R*B.
pub fn wordcount_combine(
    scheme: &CombineScheme,
    hashes: &[i32],
    mask: &[f32],
) -> Vec<f32> {
    assert_eq!(hashes.len(), mask.len());
    let mut out = vec![0f32; scheme.parts * scheme.buckets];
    for (h, m) in hashes.iter().zip(mask) {
        out[scheme.flat(*h)] += m;
    }
    out
}

/// grep pattern sentinels (mirror kernels/grep_match.py).
pub const WILD_ONE: i32 = -1;
pub const WILD_REST: i32 = -2;

/// grep_match: 0/1 per padded token row.
pub fn grep_match(tokens: &[i32], pattern: &[i32], width: usize) -> Vec<f32> {
    assert_eq!(tokens.len() % width, 0);
    let n = tokens.len() / width;
    let mut out = vec![0f32; n];
    for (i, row) in tokens.chunks(width).enumerate() {
        let mut ok = true;
        let mut rest = false;
        for (t, p) in row.iter().zip(pattern) {
            rest |= *p == WILD_REST;
            if rest || *p == WILD_ONE || t == p {
                continue;
            }
            ok = false;
            break;
        }
        out[i] = if ok { 1.0 } else { 0.0 };
    }
    out
}

/// grep_combine: counts of matching tokens per (part, bucket) + total.
pub fn grep_combine(
    scheme: &CombineScheme,
    tokens: &[i32],
    hashes: &[i32],
    mask: &[f32],
    pattern: &[i32],
    width: usize,
) -> (Vec<f32>, f32) {
    let m = grep_match(tokens, pattern, width);
    let weights: Vec<f32> =
        m.iter().zip(mask).map(|(a, b)| a * b).collect();
    let counts = wordcount_combine(scheme, hashes, &weights);
    let total = weights.iter().sum();
    (counts, total)
}

/// agg_combine: masked (sums, counts) per segment.
pub fn agg_combine(
    segments: usize,
    seg_ids: &[i32],
    values: &[f32],
    mask: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut sums = vec![0f32; segments];
    let mut cnts = vec![0f32; segments];
    for ((s, v), m) in seg_ids.iter().zip(values).zip(mask) {
        let idx = *s as i64;
        if idx >= 0 && (idx as usize) < segments {
            sums[idx as usize] += v * m;
            cnts[idx as usize] += m;
        }
    }
    (sums, cnts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scheme() -> CombineScheme {
        CombineScheme { parts: 32, buckets: 1024, part_shift: 10 }
    }

    #[test]
    fn bit_scheme_matches_python() {
        // bucket = h & 1023, part = (h >> 10) & 31 — spot values.
        let s = scheme();
        let h = 123456789i32;
        assert_eq!(s.bucket(h), (123456789usize) & 1023);
        assert_eq!(s.part(h), (123456789usize >> 10) & 31);
        assert_eq!(s.flat(h), s.part(h) * 1024 + s.bucket(h));
    }

    #[test]
    fn wordcount_mass_conserved() {
        let s = scheme();
        let hashes: Vec<i32> = (0..1000u32)
            .map(|i| (i.wrapping_mul(2654435761) & 0x7fffffff) as i32)
            .collect();
        let mask = vec![1f32; 1000];
        let out = wordcount_combine(&s, &hashes, &mask);
        assert_eq!(out.iter().sum::<f32>(), 1000.0);
    }

    #[test]
    fn masked_tokens_skipped() {
        let s = scheme();
        let out = wordcount_combine(&s, &[5, 5, 5], &[1.0, 0.0, 1.0]);
        assert_eq!(out[s.flat(5)], 2.0);
    }

    #[test]
    fn grep_wildcards() {
        let pat = vec![7, WILD_ONE, 9, 0];
        let toks = vec![
            7, 8, 9, 0, // match
            7, 8, 8, 0, // no
            7, 1, 9, 0, // match
        ];
        assert_eq!(grep_match(&toks, &pat, 4), vec![1.0, 0.0, 1.0]);
        let pat_rest = vec![7, WILD_REST, 0, 0];
        assert_eq!(grep_match(&toks, &pat_rest, 4), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn grep_combine_totals() {
        let s = scheme();
        let toks = vec![1, 0, 2, 0]; // two tokens, width 2
        let pat = vec![1, 0];
        let (counts, total) = grep_combine(&s, &toks, &[100, 200], &[1.0, 1.0],
                                           &pat, 2);
        assert_eq!(total, 1.0);
        assert_eq!(counts[s.flat(100)], 1.0);
        assert_eq!(counts[s.flat(200)], 0.0);
    }

    #[test]
    fn agg_sums_and_counts() {
        let (sums, cnts) = agg_combine(
            4,
            &[0, 1, 1, 3, 9],
            &[1.0, 2.0, 3.0, 4.0, 100.0],
            &[1.0, 1.0, 1.0, 1.0, 1.0],
        );
        assert_eq!(sums, vec![1.0, 5.0, 0.0, 4.0]); // id 9 out of range
        assert_eq!(cnts, vec![1.0, 2.0, 0.0, 1.0]);
    }
}
