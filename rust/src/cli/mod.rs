//! Command-line interface for the `marvel` binary (no clap offline —
//! a small strict arg parser + subcommands).
//!
//! ```text
//! marvel run   [--config FILE] [--system NAME] [--workload NAME]
//!              [--input SIZE] [--seed N] [--nodes N]
//! marvel corun [--tenants a:3,b:1] [--workloads wc,grep] [--input SIZE]
//! marvel serve [--rate 2.0] [--classes an:3:3,batch:1] [--horizon-s 60]
//!              [--autoscale on]                   # open loop, Fig. 11
//! marvel fio   [--streams N] [--ops N]            # Table 2
//! marvel sweep [--workload NAME] [--sizes a,b,c] [--systems x,y]
//! marvel info                                     # artifacts + cluster
//! ```
//!
//! See `ARCHITECTURE.md` for the system the commands drive.

use std::collections::BTreeMap;

use crate::config::{parse_class_spec, parse_tenant_spec, system_by_name,
                    ExperimentConfig};
use crate::coordinator::{ClusterSpec, Marvel};
use crate::mapreduce::{
    stage_named_input, ArrivalModel, JobResult, JobServer, OpenLoopReport,
    OpenLoopServer, ServerResult, SystemConfig, Workload,
};
use crate::metrics::tags;
use crate::storage::fio;
use crate::util::bytes::{self, parse_size};
use crate::util::table::{fmt_secs, Table};
use crate::workloads::{AggregationQuery, Grep, JoinQuery, ScanQuery,
                       WordCount};

/// Parsed `--key value` flags + positional args.
pub struct Args {
    pub cmd: String,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
        let mut flags = BTreeMap::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got {a:?}"))?;
            let val = argv
                .get(i + 1)
                .ok_or_else(|| format!("--{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
            i += 2;
        }
        Ok(Args { cmd, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
}

/// Build a workload by name.
pub fn workload_by_name(
    name: &str,
    vocab: usize,
    zipf_s: f64,
    rt: &crate::runtime::RtEngine,
) -> Result<Box<dyn Workload>, String> {
    Ok(match name {
        "wordcount" | "wc" => Box::new(WordCount::new(vocab, zipf_s, rt)),
        "grep" => {
            let prefix = crate::workloads::Corpus::new(vocab, zipf_s)
                .prefix_of_rank(5, 2);
            Box::new(Grep::new(vocab, zipf_s, &prefix, rt))
        }
        "scan_query" | "scan" => Box::new(ScanQuery::new()),
        "aggregation_query" | "agg" => Box::new(AggregationQuery::new(rt)),
        "join_query" | "join" => Box::new(JoinQuery::new()),
        "pagerank" | "pr" => {
            Box::new(crate::workloads::PageRank::new())
        }
        // Star-schema suite: `vocab` sizes the dimension key space,
        // `zipf_s` the fact-side key skew (0 = uniform).
        "starjoin" | "repartition_join" => {
            Box::new(crate::workloads::RepartitionJoin::new(
                crate::workloads::StarSchema::new(vocab as u64, zipf_s),
            ))
        }
        "groupby" | "group_by" => Box::new(crate::workloads::GroupBy::new(
            crate::workloads::StarSchema::new(vocab as u64, zipf_s),
        )),
        other => return Err(format!("unknown workload {other:?}")),
    })
}

pub fn print_job_result(r: &JobResult) {
    let mut t = Table::new(
        &format!("{} on {}", r.job, r.config),
        &["metric", "value"],
    );
    match &r.failed {
        Some(msg) => {
            t.row_strs(&["status", &format!("FAILED: {msg}")]);
        }
        None => {
            t.row_strs(&["status", "ok"]);
        }
    }
    t.row_strs(&["input", &bytes::human(r.input_bytes)]);
    t.row_strs(&["intermediate", &bytes::human(r.intermediate_bytes)]);
    t.row_strs(&["output", &bytes::human(r.output_bytes)]);
    t.row_strs(&["job time", &format!("{}", r.job_time)]);
    t.row_strs(&["map phase", &format!("{} tasks, {}", r.map.tasks,
                                       r.map.duration)]);
    t.row_strs(&["reduce phase", &format!("{} tasks, {}", r.reduce.tasks,
                                          r.reduce.duration)]);
    t.row_strs(&["cold starts", &r.cold_starts.to_string()]);
    t.row_strs(&["warm starts", &r.warm_starts.to_string()]);
    if r.task_attempts > (r.map.tasks + r.reduce.tasks) as u64
        || r.recomputed_bytes > 0
        || r.checkpoints > 0
    {
        t.row_strs(&["task attempts", &r.task_attempts.to_string()]);
        t.row_strs(&["recomputed", &bytes::human(r.recomputed_bytes)]);
        t.row_strs(&["checkpoints", &format!(
            "{} ({} overhead)",
            r.checkpoints, r.checkpoint_overhead
        )]);
    }
    if r.spec_backups > 0 {
        t.row_strs(&["speculative backups", &format!(
            "{} ({} won the race)",
            r.spec_backups, r.spec_backup_wins
        )]);
    }
    t.row_strs(&["locality", &format!("{:.0} %", r.locality_ratio * 100.0)]);
    if r.affinity_hits > 0 {
        t.row_strs(&["affinity hits", &r.affinity_hits.to_string()]);
    }
    t.row_strs(&["partition skew", &format!(
        "{:.2} p99/median", r.partition_skew
    )]);
    if r.hot_keys_split > 0 {
        t.row_strs(&["hot keys split", &r.hot_keys_split.to_string()]);
    }
    t.row_strs(&["shuffle I/O", &format!(
        "{:.2} Gbps",
        r.io.gbps_over_makespan(&[tags::INTERMEDIATE_WRITE,
                                  tags::INTERMEDIATE_READ])
    )]);
    t.row_strs(&["combine batches", &r.rt_batches.to_string()]);
    t.print();
}

/// Load the experiment config and apply the flag overrides `run` and
/// `corun` share (--config/--system/--input/--seed/--nodes).
fn load_experiment(args: &Args) -> Result<ExperimentConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(path)?,
        None => ExperimentConfig::parse("")?,
    };
    if let Some(s) = args.get("system") {
        cfg.system = system_by_name(s)?;
    }
    if let Some(i) = args.get("input") {
        cfg.input_bytes = parse_size(i)?;
    }
    if let Some(s) = args.get("seed") {
        cfg.seed = s.parse().map_err(|_| "bad --seed")?;
    }
    if let Some(n) = args.get("nodes") {
        cfg.cluster.nodes = n.parse().map_err(|_| "bad --nodes")?;
    }
    if let Some(v) = args.get("vocab") {
        cfg.vocab = v.parse().map_err(|_| "bad --vocab")?;
    }
    if let Some(z) = args.get("zipf") {
        cfg.zipf_s = z.parse::<f64>().map_err(|_| "bad --zipf")?.max(0.0);
    }
    // Failure-injection / recovery overrides (see `marvel help`).
    if let Some(p) = args.get("crash-prob") {
        cfg.system.failures.crash_prob =
            p.parse::<f64>().map_err(|_| "bad --crash-prob")?.clamp(0.0, 1.0);
    }
    if let Some(s) = args.get("failure-seed") {
        cfg.system.failures.seed =
            s.parse().map_err(|_| "bad --failure-seed")?;
    }
    if let Some(s) = args.get("lose-datanodes") {
        cfg.system.failures.lose_datanodes =
            crate::coordinator::FailurePlan::parse_datanode_list(s)
                .map_err(|e| format!("--lose-datanodes: {e}"))?;
    }
    if let Some(s) = args.get("ckpt-interval") {
        cfg.system.recovery.interval_bytes = parse_size(s)?.max(1);
    }
    if let Some(s) = args.get("max-attempts") {
        cfg.system.recovery.max_attempts =
            s.parse::<u32>().map_err(|_| "bad --max-attempts")?.max(1);
    }
    match args.get("recovery") {
        None => {}
        Some("stateful") => cfg.system.recovery.stateful = true,
        Some("stateless") => cfg.system.recovery.stateful = false,
        Some(other) => {
            return Err(format!(
                "--recovery must be stateful|stateless, got {other:?}"
            ))
        }
    }
    // Straggler / speculation overrides (see `marvel help`). Time
    // plane only: outputs never move under any of these.
    if let Some(p) = args.get("straggler-prob") {
        cfg.system.stragglers.prob = p
            .parse::<f64>()
            .map_err(|_| "bad --straggler-prob")?
            .clamp(0.0, 1.0);
    }
    if let Some(s) = args.get("slowdown") {
        cfg.system.stragglers.slowdown =
            s.parse::<f64>().map_err(|_| "bad --slowdown")?.max(1.0);
    }
    if let Some(s) = args.get("straggler-seed") {
        cfg.system.stragglers.seed =
            s.parse().map_err(|_| "bad --straggler-seed")?;
    }
    match args.get("speculation") {
        None => {}
        Some("on") => cfg.system.speculation.enabled = true,
        Some("off") => cfg.system.speculation.enabled = false,
        Some(other) => {
            return Err(format!(
                "--speculation must be on|off, got {other:?}"
            ))
        }
    }
    if let Some(f) = args.get("lag-factor") {
        cfg.system.speculation.lag_factor =
            f.parse::<f64>().map_err(|_| "bad --lag-factor")?.max(1.0);
    }
    // Network fault / degraded-mode I/O overrides (see `marvel help`).
    // Time plane + counters only: outputs never move under any of these.
    if let Some(p) = args.get("link-fault-prob") {
        cfg.system.netfaults.prob = p
            .parse::<f64>()
            .map_err(|_| "bad --link-fault-prob")?
            .clamp(0.0, 1.0);
    }
    if let Some(s) = args.get("link-slowdown") {
        cfg.system.netfaults.slowdown =
            s.parse::<f64>().map_err(|_| "bad --link-slowdown")?.max(1.0);
    }
    if let Some(s) = args.get("netfault-seed") {
        cfg.system.netfaults.seed =
            s.parse().map_err(|_| "bad --netfault-seed")?;
    }
    if let Some(ms) = args.get("flow-timeout-ms") {
        cfg.system.netfaults.flow_timeout = crate::sim::SimNs::from_millis(
            ms.parse::<u64>().map_err(|_| "bad --flow-timeout-ms")?.max(1),
        );
    }
    if let Some(s) = args.get("lose-cachenodes") {
        cfg.system.netfaults.lose_cachenodes =
            crate::coordinator::FailurePlan::parse_datanode_list(s)
                .map_err(|e| format!("--lose-cachenodes: {e}"))?;
    }
    match args.get("degraded-tiers") {
        None => {}
        Some("on") => cfg.system.netfaults.degraded_tiers = true,
        Some("off") => cfg.system.netfaults.degraded_tiers = false,
        Some(other) => {
            return Err(format!(
                "--degraded-tiers must be on|off, got {other:?}"
            ))
        }
    }
    // Placement overrides (see `marvel help`). Placement moves tasks
    // between nodes — never bytes: outputs are strategy-invariant.
    let pseed = match args.get("placement-seed") {
        Some(s) => s.parse().map_err(|_| "bad --placement-seed")?,
        None => match cfg.system.placement {
            crate::mapreduce::PlacementStrategy::Random { seed } => seed,
            _ => 1,
        },
    };
    if let Some(name) = args.get("placement") {
        cfg.system.placement =
            crate::mapreduce::PlacementStrategy::parse(name, pseed)
                .map_err(|e| format!("--placement: {e}"))?;
    } else if args.get("placement-seed").is_some() {
        if let crate::mapreduce::PlacementStrategy::Random { seed } =
            &mut cfg.system.placement
        {
            *seed = pseed;
        }
    }
    // Partitioner overrides (see `marvel help`). Routing moves bytes
    // between reducers — canonical outputs are partitioner-invariant.
    if let Some(name) = args.get("partitioner") {
        cfg.system.partition = crate::mapreduce::Partitioner::parse(name)
            .map_err(|e| format!("--partitioner: {e}"))?;
    }
    if let crate::mapreduce::Partitioner::SkewAware {
        hot_threshold,
        split_ways,
    } = &mut cfg.system.partition
    {
        if let Some(v) = args.get("hot-threshold") {
            *hot_threshold = v
                .parse::<f64>()
                .map_err(|_| "bad --hot-threshold")?
                .max(0.0);
        }
        if let Some(v) = args.get("split-ways") {
            *split_ways =
                v.parse::<usize>().map_err(|_| "bad --split-ways")?.max(2);
        }
    }
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let mut cfg = load_experiment(args)?;
    if let Some(w) = args.get("workload") {
        cfg.workload = w.to_string();
    }
    let mut m = Marvel::new(cfg.cluster.clone(), cfg.seed)?;
    println!(
        "runtime: {} ({} artifacts)",
        if m.rt.is_pjrt() { "PJRT" } else { "oracle (run `make artifacts`)" },
        m.rt.manifest.artifacts.len()
    );
    let wl = workload_by_name(&cfg.workload, cfg.vocab, cfg.zipf_s, &m.rt)?;
    let r = m.run(&cfg.system, wl.as_ref(), cfg.input_bytes);
    print_job_result(&r);
    Ok(())
}

/// Print a co-run report: one row per job, then the tenant summary.
pub fn print_server_result(res: &ServerResult) {
    let mut t = Table::new(
        "co-run jobs (shared cluster)",
        &["tenant", "job", "status", "output", "job time", "cold", "warm",
          "x-job warm"],
    );
    for run in &res.jobs {
        for (i, jr) in run.stages.iter().enumerate() {
            t.row(&[
                run.tenant.clone(),
                jr.job.clone(),
                match &jr.failed {
                    Some(m) => format!("FAILED: {m}"),
                    None => "ok".into(),
                },
                bytes::human(jr.output_bytes),
                format!("{}", jr.job_time),
                jr.cold_starts.to_string(),
                jr.warm_starts.to_string(),
                // Per submission, not per stage: once per chain.
                if i == 0 {
                    run.cross_job_warm.to_string()
                } else {
                    String::new()
                },
            ]);
        }
    }
    t.print();
    let mut t = Table::new(
        &format!("tenants (virtual makespan {})", res.makespan),
        &["tenant", "share", "jobs", "completion", "cold", "warm",
          "dram hits", "evictions"],
    );
    for rep in &res.tenants {
        t.row(&[
            rep.name.clone(),
            rep.share.to_string(),
            rep.jobs.to_string(),
            format!("{}", rep.completion),
            rep.cold_starts.to_string(),
            rep.warm_starts.to_string(),
            rep.igfs.hits_dram.to_string(),
            rep.igfs.evictions.to_string(),
        ]);
    }
    t.print();
}

/// `marvel corun`: admit one job per workload, round-robin across the
/// tenant roster, and co-run them over one shared cluster.
fn cmd_corun(args: &Args) -> Result<(), String> {
    let mut cfg = load_experiment(args)?;
    if let Some(t) = args.get("tenants") {
        cfg.tenants = parse_tenant_spec(t)?;
    }
    if let Some(w) = args.get("workloads") {
        cfg.corun_workloads =
            w.split(',').map(|s| s.trim().to_string()).collect();
    }
    if cfg.tenants.is_empty() {
        cfg.tenants = parse_tenant_spec("alice:3,bob:1")?;
    }
    if cfg.corun_workloads.is_empty() {
        cfg.corun_workloads =
            vec!["wordcount".into(), "grep".into(), "pagerank".into(),
                 "agg".into()];
    }

    let mut m = Marvel::new(cfg.cluster.clone(), cfg.seed)?;
    let mut cluster = cfg.cluster.deploy(&cfg.system);
    let wls: Vec<Box<dyn Workload>> = cfg
        .corun_workloads
        .iter()
        .map(|n| workload_by_name(n, cfg.vocab, cfg.zipf_s, &m.rt))
        .collect::<Result<_, _>>()?;
    // Stage every job's input under its own namespace first, then
    // admit: tenant k%T runs workload k.
    let mut inputs = Vec::with_capacity(wls.len());
    for (k, wl) in wls.iter().enumerate() {
        let tenant = &cfg.tenants[k % cfg.tenants.len()].0;
        let path = format!("{tenant}/j{k:02}/input");
        inputs.push(stage_named_input(
            &mut cluster,
            &cfg.system,
            wl.as_ref(),
            cfg.input_bytes,
            cfg.seed,
            &path,
        )?);
    }
    let mut server = JobServer::new();
    for (name, share) in &cfg.tenants {
        server = server.tenant(name, *share);
    }
    for (k, wl) in wls.iter().enumerate() {
        let tenant = cfg.tenants[k % cfg.tenants.len()].0.clone();
        server = server.job(
            &tenant,
            wl.as_ref(),
            cfg.system.clone(),
            &inputs[k],
            cfg.seed,
        );
    }
    let res = server.run(&mut cluster, &mut m.rt);
    print_server_result(&res);
    if let Some(e) = &res.failed {
        return Err(format!("co-run failed: {e}"));
    }
    let failed_jobs = res.jobs.iter().filter(|r| !r.ok()).count();
    if failed_jobs > 0 {
        return Err(format!("{failed_jobs} job(s) failed (see table)"));
    }
    Ok(())
}

/// Print the open-loop serving report: admission + tail-latency
/// summary, then the per-class breakdown (never per-job rows — a serve
/// can admit hundreds).
pub fn print_open_loop(ol: &OpenLoopReport) {
    let mut t = Table::new(
        &format!("open-loop serve (arrival seed {})", ol.arrival_seed),
        &["metric", "value"],
    );
    t.row_strs(&["offered", &ol.offered.to_string()]);
    t.row_strs(&["admitted", &ol.admitted.to_string()]);
    t.row_strs(&["rejected", &ol.rejected.to_string()]);
    t.row_strs(&["max in-flight", &ol.max_inflight.to_string()]);
    t.row_strs(&["sojourn p50/p99/p999", &format!(
        "{:.0} / {:.0} / {:.0} ms",
        ol.sojourn_ms.p50, ol.sojourn_ms.p99, ol.sojourn_ms.p999
    )]);
    t.row_strs(&["queue wait p50/p99", &format!(
        "{:.0} / {:.0} ms",
        ol.queue_wait_ms.p50, ol.queue_wait_ms.p99
    )]);
    t.row_strs(&["scale ups/downs", &format!(
        "{} / {}", ol.scale_ups, ol.scale_downs
    )]);
    t.row_strs(&["cold starts", &ol.cold_starts.to_string()]);
    t.row_strs(&["warm starts", &ol.warm_starts.to_string()]);
    t.print();
    let mut t = Table::new(
        "tenant classes",
        &["class", "offered", "admitted", "rejected", "sojourn p50",
          "sojourn p99"],
    );
    for c in &ol.classes {
        t.row(&[
            c.name.clone(),
            c.offered.to_string(),
            c.admitted.to_string(),
            c.rejected.to_string(),
            format!("{:.0} ms", c.sojourn_ms.p50),
            format!("{:.0} ms", c.sojourn_ms.p99),
        ]);
    }
    t.print();
}

/// `marvel serve`: open-loop arrival-driven serving — seed-driven
/// arrivals, admission control, weighted-fair queueing for job tokens,
/// and (optionally) elastic warm-pool autoscaling.
fn cmd_serve(args: &Args) -> Result<(), String> {
    let mut cfg = load_experiment(args)?;
    // Arrival-plane overrides (see `marvel help`).
    let arr = &mut cfg.system.arrivals;
    if let Some(r) = args.get("rate") {
        let rate = r.parse::<f64>().map_err(|_| "bad --rate")?.max(0.0);
        let model = match arr.model {
            ArrivalModel::Ramp { rate_end, .. } => {
                ArrivalModel::Ramp { rate, rate_end }
            }
            _ => ArrivalModel::Poisson { rate },
        };
        arr.model = model;
    }
    if let Some(r) = args.get("rate-end") {
        let rate_end =
            r.parse::<f64>().map_err(|_| "bad --rate-end")?.max(0.0);
        let rate = match arr.model {
            ArrivalModel::Poisson { rate } => rate,
            ArrivalModel::Ramp { rate, .. } => rate,
            ArrivalModel::Trace(_) => {
                return Err("--rate-end needs a rate model, not a trace"
                    .into())
            }
        };
        arr.model = ArrivalModel::Ramp { rate, rate_end };
    }
    if let Some(s) = args.get("arrival-seed") {
        arr.seed = s.parse().map_err(|_| "bad --arrival-seed")?;
    }
    if let Some(h) = args.get("horizon-s") {
        arr.horizon = crate::sim::SimNs::from_secs_f64(
            h.parse::<f64>().map_err(|_| "bad --horizon-s")?.max(0.0),
        );
    }
    if let Some(n) = args.get("max-jobs") {
        arr.max_jobs = n.parse().map_err(|_| "bad --max-jobs")?;
    }
    if let Some(c) = args.get("classes") {
        arr.classes = parse_class_spec(c)?;
    }
    if let Some(n) = args.get("max-inflight") {
        arr.max_inflight = n.parse().map_err(|_| "bad --max-inflight")?;
    }
    if let Some(n) = args.get("queue-cap") {
        arr.queue_cap = n.parse().map_err(|_| "bad --queue-cap")?;
    }
    match args.get("autoscale") {
        None => {}
        Some("on") => cfg.system.autoscale.enabled = true,
        Some("off") => cfg.system.autoscale.enabled = false,
        Some(other) => {
            return Err(format!(
                "--autoscale must be on|off, got {other:?}"
            ))
        }
    }
    if let Some(w) = args.get("warm-per-rate") {
        cfg.system.autoscale.warm_per_rate =
            w.parse::<f64>().map_err(|_| "bad --warm-per-rate")?.max(0.0);
    }
    if !cfg.system.arrivals.enabled() {
        return Err("no arrivals: set --rate (or [arrivals] in --config)"
            .into());
    }
    if let Some(w) = args.get("workload") {
        cfg.workload = w.to_string();
    }

    let mut m = Marvel::new(cfg.cluster.clone(), cfg.seed)?;
    let mut cluster = cfg.cluster.deploy(&cfg.system);
    let wl = workload_by_name(&cfg.workload, cfg.vocab, cfg.zipf_s, &m.rt)?;
    let server =
        OpenLoopServer::new(wl.as_ref(), cfg.system, cfg.input_bytes);
    let res = server.serve(&mut cluster, &mut m.rt);
    if let Some(ol) = &res.open_loop {
        print_open_loop(ol);
    }
    if let Some(e) = &res.failed {
        return Err(format!("serve failed: {e}"));
    }
    let failed_jobs = res.jobs.iter().filter(|r| !r.ok()).count();
    if failed_jobs > 0 {
        return Err(format!("{failed_jobs} job(s) failed"));
    }
    Ok(())
}

fn cmd_fio(args: &Args) -> Result<(), String> {
    let streams: u32 = args
        .get("streams")
        .unwrap_or("8")
        .parse()
        .map_err(|_| "bad --streams")?;
    let ops: u64 = args
        .get("ops")
        .unwrap_or("100000")
        .parse()
        .map_err(|_| "bad --ops")?;
    let rows = fio::table2(streams, ops);
    let mut t = Table::new(
        "Table 2 — IOPS, Bandwidth, Latency for PMEM vs. SSD (4 KiB)",
        &["benchmark", "media", "IOPS (K)", "Bandwidth (GiB/s)", "Latency"],
    );
    for r in rows {
        t.row(&[
            format!("{:?} {:?}", r.access, r.dir),
            r.media.to_string(),
            format!("{:.1}", r.kiops),
            format!("{:.2}", r.bandwidth_gib_s),
            format!("{}", r.latency),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let sizes: Vec<u64> = args
        .get("sizes")
        .unwrap_or("256MiB,512MiB,1GiB")
        .split(',')
        .map(parse_size)
        .collect::<Result<_, _>>()?;
    let systems: Vec<SystemConfig> = args
        .get("systems")
        .unwrap_or("lambda-s3,marvel-hdfs,marvel-igfs")
        .split(',')
        .map(system_by_name)
        .collect::<Result<_, _>>()?;
    let wl_name = args.get("workload").unwrap_or("wordcount");
    let seed = args
        .get("seed")
        .unwrap_or("42")
        .parse()
        .map_err(|_| "bad --seed")?;
    let mut m = Marvel::new(ClusterSpec::default(), seed)?;
    let wl = workload_by_name(wl_name, 10_000, 1.07, &m.rt)?;
    let mut headers = vec!["input".to_string()];
    headers.extend(systems.iter().map(|s| s.name.clone()));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("{wl_name} execution time (s) by system"),
        &hdr_refs,
    );
    for size in sizes {
        let mut row = vec![bytes::human(size)];
        for sys in &systems {
            let r = m.run(sys, wl.as_ref(), size);
            row.push(match r.failed {
                Some(_) => "FAIL".into(),
                None => fmt_secs(r.job_time.as_secs_f64()),
            });
        }
        t.row(&row);
    }
    t.print();
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    let m = Marvel::new(ClusterSpec::default(), 0)?;
    println!("marvel — stateful serverless MapReduce (CS.DC'23 repro)");
    println!("runtime mode : {}",
             if m.rt.is_pjrt() { "PJRT (AOT artifacts loaded)" }
             else { "oracle fallback (run `make artifacts`)" });
    println!("artifacts    : {}", m.rt.manifest.artifacts.len());
    for (name, meta) in &m.rt.manifest.artifacts {
        println!("  {name}: n={} file={}", meta.n, meta.file.display());
    }
    println!("batch size   : {}", m.rt.manifest.tokens_per_batch);
    println!("partitions R : {}", m.rt.manifest.parts);
    println!("buckets B    : {}", m.rt.manifest.buckets);
    Ok(())
}

const HELP: &str = "\
marvel — PMEM-backed stateful serverless MapReduce (paper reproduction)

USAGE: marvel <run|corun|serve|fio|sweep|info|help> [--flag value]...
  run    one job:   --system marvel-igfs --workload wordcount --input 1GiB
  corun  multi-tenant co-run over ONE shared cluster:
         --tenants alice:3,bob:1 --workloads wordcount,grep --input 64MiB
  serve  open-loop arrival-driven serving (Fig. 11):
         --rate 2.0 --classes an:3:3,batch:1 --horizon-s 60 --autoscale on
  fio    Table 2 microbenchmark: --streams 8 --ops 100000
  sweep  Figure 4/5 style sweep: --sizes 1GiB,5GiB --systems a,b,c
  info   show runtime/artifact status

failure injection (run/corun; outputs stay byte-identical, only times
and attempt counts move):
  --crash-prob 0.5        per-attempt container crash probability
  --failure-seed 7        fault-schedule seed (MARVEL_FAILURE_SEED)
  --lose-datanodes 0,2    kill DataNodes before the job runs
  --ckpt-interval 16MiB   checkpoint every N split bytes
  --max-attempts 3        retry budget per task
  --recovery stateful     stateful (resume) | stateless (restart)

stragglers & speculation (run/corun; outputs stay byte-identical, only
times and attempt counts move):
  --straggler-prob 0.25   per-node probability of being a straggler
  --slowdown 4.0          straggler slowdown factor (compute + devices)
  --straggler-seed 17     straggler-draw seed (MARVEL_STRAGGLER_SEED)
  --speculation on        race projected laggards with backup attempts
  --lag-factor 1.5        back up tasks projected past N x the median

degraded-mode I/O (run/corun; outputs stay byte-identical, only times
and timeout/degradation counters move):
  --link-fault-prob 0.5   per-link probability of a fault window
  --link-slowdown 8.0     faulted link serves at 1/N capacity
  --netfault-seed 29      link-fault-draw seed (MARVEL_NETFAULT_SEED)
  --flow-timeout-ms 250   flow deadline while faults are armed
  --lose-cachenodes 1,2   black out cache nodes between map and reduce
  --degraded-tiers on     degrade reads IGFS->HDFS->S3 | off = hard fail

task placement (run/corun/serve; outputs stay byte-identical, only
node choices, times, and locality/affinity counters move):
  --placement fair        fair|random|round-robin|hdfs-local|
                          cache-affinity|straggler-aware (MARVEL_PLACEMENT)
  --placement-seed 7      scan-start seed for random (MARVEL_PLACEMENT_SEED)

partitioning (run/corun/serve; canonical outputs stay identical, only
which reducer a key's bytes land on moves):
  --partitioner hash      hash|range|skew-aware (MARVEL_PARTITIONER)
  --hot-threshold 1.3     flag keys above N x the mean partition share
  --split-ways 4          spread a hot key across N reducers
  workloads starjoin/groupby exercise the skew path end to end
  (--workload starjoin --vocab 1024 --zipf 1.5; vocab = dimension
  key-space size, zipf = fact-key skew exponent, 0 = uniform)

open-loop serving (serve; same seeds => identical admission log and
byte-identical per-tenant outputs at any worker count):
  --rate 2.0              mean arrival rate, jobs/s (Poisson)
  --rate-end 8.0          ramp the rate toward this by the horizon
  --arrival-seed 7        schedule seed (MARVEL_ARRIVAL_SEED)
  --horizon-s 60          stop generating arrivals past this offset
  --max-jobs 64           hard cap on generated arrivals
  --classes an:3:3,b:1    tenant classes as name:share:mix
  --max-inflight 4        admission budget (0 = auto from cluster slots)
  --queue-cap 16          waiting-room depth before rejections engage
  --autoscale on          elastic warm pool tracking the arrival rate
  --warm-per-rate 8.0     warm-container target per unit arrival rate
";

/// CLI entrypoint; returns process exit code.
pub fn main_with_args(argv: &[String]) -> i32 {
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{HELP}");
            return 2;
        }
    };
    let res = match args.cmd.as_str() {
        "run" => cmd_run(&args),
        "corun" => cmd_corun(&args),
        "serve" => cmd_serve(&args),
        "fio" => cmd_fio(&args),
        "sweep" => cmd_sweep(&args),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n{HELP}")),
    };
    match res {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags() {
        let a = Args::parse(&sv(&["run", "--input", "1GiB", "--seed", "7"]))
            .unwrap();
        assert_eq!(a.cmd, "run");
        assert_eq!(a.get("input"), Some("1GiB"));
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get("nope"), None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Args::parse(&sv(&["run", "positional"])).is_err());
        assert!(Args::parse(&sv(&["run", "--key"])).is_err());
    }

    #[test]
    fn workloads_resolve() {
        let rt = crate::runtime::RtEngine::load(None).unwrap();
        for n in [
            "wordcount", "grep", "scan", "agg", "join", "starjoin",
            "groupby",
        ] {
            assert!(workload_by_name(n, 100, 1.07, &rt).is_ok(), "{n}");
        }
        assert!(workload_by_name("nope", 100, 1.07, &rt).is_err());
    }

    #[test]
    fn help_and_unknown_exit_codes() {
        assert_eq!(main_with_args(&sv(&["help"])), 0);
        assert_eq!(main_with_args(&sv(&["bogus"])), 1);
    }

    #[test]
    fn corun_command_runs_small() {
        assert_eq!(
            main_with_args(&sv(&[
                "corun",
                "--tenants", "a:3,b:1",
                "--workloads", "wordcount,grep",
                "--input", "1MiB",
                "--seed", "5",
            ])),
            0
        );
    }

    #[test]
    fn run_with_failure_injection_succeeds() {
        // Byte-identity under injection is pinned by
        // rust/tests/recovery_e2e.rs; here: the CLI path wires the
        // plan through and the job still completes.
        assert_eq!(
            main_with_args(&sv(&[
                "run",
                "--workload", "wordcount",
                "--input", "1MiB",
                "--crash-prob", "0.6",
                "--failure-seed", "9",
                "--ckpt-interval", "64KiB",
                "--max-attempts", "4",
                "--recovery", "stateful",
            ])),
            0
        );
        assert_eq!(
            main_with_args(&sv(&["run", "--recovery", "bogus"])),
            1
        );
        assert_eq!(
            main_with_args(&sv(&["run", "--crash-prob", "x"])),
            1
        );
    }

    #[test]
    fn run_with_stragglers_and_speculation_succeeds() {
        // Byte-identity under stragglers/speculation is pinned by
        // rust/tests/stragglers_e2e.rs; here: the CLI wires the
        // profile through and the job still completes.
        assert_eq!(
            main_with_args(&sv(&[
                "run",
                "--workload", "wordcount",
                "--input", "1MiB",
                "--nodes", "4",
                "--straggler-prob", "0.5",
                "--slowdown", "4.0",
                "--straggler-seed", "3",
                "--speculation", "on",
                "--lag-factor", "1.5",
            ])),
            0
        );
        assert_eq!(
            main_with_args(&sv(&["run", "--speculation", "maybe"])),
            1
        );
        assert_eq!(
            main_with_args(&sv(&["run", "--straggler-prob", "x"])),
            1
        );
        assert_eq!(
            main_with_args(&sv(&["run", "--slowdown", "x"])),
            1
        );
    }

    #[test]
    fn run_with_placement_strategy_succeeds() {
        // Byte-identity across strategies is pinned by
        // rust/tests/props.rs and placement_e2e.rs; here: the CLI
        // wires each strategy through and the job still completes.
        for name in ["cache-affinity", "hdfs-local", "straggler-aware"] {
            assert_eq!(
                main_with_args(&sv(&[
                    "run",
                    "--workload", "wordcount",
                    "--input", "1MiB",
                    "--nodes", "4",
                    "--placement", name,
                ])),
                0,
                "{name}"
            );
        }
        assert_eq!(
            main_with_args(&sv(&[
                "run",
                "--input", "1MiB",
                "--placement", "random",
                "--placement-seed", "9",
            ])),
            0
        );
        assert_eq!(
            main_with_args(&sv(&["run", "--placement", "nearest"])),
            1
        );
        assert_eq!(
            main_with_args(&sv(&["run", "--placement-seed", "x"])),
            1
        );
    }

    #[test]
    fn run_with_partitioner_succeeds() {
        // Canonical-identity across partitioners is pinned by
        // rust/tests/props.rs and join_skew_e2e.rs; here: the CLI
        // wires each strategy (and the skew workloads) through and
        // the job still completes.
        for name in ["hash", "range", "skew-aware"] {
            assert_eq!(
                main_with_args(&sv(&[
                    "run",
                    "--workload", "wordcount",
                    "--input", "1MiB",
                    "--partitioner", name,
                ])),
                0,
                "{name}"
            );
        }
        assert_eq!(
            main_with_args(&sv(&[
                "run",
                "--workload", "starjoin",
                "--input", "1MiB",
                "--partitioner", "skew-aware",
                "--hot-threshold", "1.3",
                "--split-ways", "3",
            ])),
            0
        );
        assert_eq!(
            main_with_args(&sv(&[
                "run",
                "--workload", "groupby",
                "--input", "1MiB",
            ])),
            0
        );
        assert_eq!(
            main_with_args(&sv(&["run", "--partitioner", "modulo"])),
            1
        );
        assert_eq!(
            main_with_args(&sv(&[
                "run", "--input", "1MiB", "--split-ways", "x",
            ])),
            0,
            "--split-ways is inert without a skew-aware partitioner"
        );
    }

    #[test]
    fn run_with_netfaults_and_degradation_succeeds() {
        // Byte-identity under netfaults + blackout is pinned by
        // rust/tests/netfaults_e2e.rs; here: the CLI wires the plan
        // through and the degraded job still completes.
        assert_eq!(
            main_with_args(&sv(&[
                "run",
                "--workload", "wordcount",
                "--input", "1MiB",
                "--nodes", "4",
                "--link-fault-prob", "0.5",
                "--link-slowdown", "8.0",
                "--netfault-seed", "11",
                "--flow-timeout-ms", "250",
                "--lose-cachenodes", "1",
                "--degraded-tiers", "on",
            ])),
            0
        );
        assert_eq!(
            main_with_args(&sv(&["run", "--degraded-tiers", "maybe"])),
            1
        );
        assert_eq!(
            main_with_args(&sv(&["run", "--link-fault-prob", "x"])),
            1
        );
        assert_eq!(
            main_with_args(&sv(&["run", "--lose-cachenodes", "one"])),
            1
        );
    }

    #[test]
    fn serve_command_runs_small() {
        // Determinism across worker counts is pinned by
        // rust/tests/openloop_e2e.rs; here: the CLI wires the arrival
        // plane through and the serve completes.
        assert_eq!(
            main_with_args(&sv(&[
                "serve",
                "--workload", "wordcount",
                "--input", "1MiB",
                "--rate", "1.0",
                "--arrival-seed", "7",
                "--horizon-s", "30",
                "--max-jobs", "6",
                "--classes", "an:3:3,batch:1",
                "--max-inflight", "2",
                "--queue-cap", "2",
                "--autoscale", "on",
            ])),
            0
        );
        // No arrival model armed → a clear error, not a silent no-op.
        assert_eq!(main_with_args(&sv(&["serve"])), 1);
        assert_eq!(
            main_with_args(&sv(&["serve", "--rate", "fast"])),
            1
        );
        assert_eq!(
            main_with_args(&sv(&["serve", "--rate", "1", "--autoscale",
                                 "maybe"])),
            1
        );
    }

    #[test]
    fn fio_command_runs() {
        assert_eq!(
            main_with_args(&sv(&["fio", "--streams", "2", "--ops", "500"])),
            0
        );
    }
}
