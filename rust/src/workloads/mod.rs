//! The paper's benchmark workloads: WordCount, Grep (Figures 4/5/6),
//! and the Scan / Aggregation / Join queries (Table 1).

pub mod corpus;
pub mod grep;
pub mod queries;
pub mod wordcount;

pub use corpus::Corpus;
pub use grep::Grep;
pub use queries::{AggregationQuery, JoinQuery, ScanQuery};
pub use wordcount::WordCount;
