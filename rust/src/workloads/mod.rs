//! The paper's benchmark workloads: WordCount, Grep (Figures 4/5/6),
//! the Scan / Aggregation / Join queries (Table 1), and the iterative
//! PageRank used by the multi-stage stateful pipeline.
//!
//! See `ARCHITECTURE.md` (Layer 6) for the data-derivation contract.

pub mod corpus;
pub mod grep;
pub mod pagerank;
pub mod queries;
pub mod tables;
pub mod wordcount;

pub use corpus::Corpus;
pub use grep::Grep;
pub use pagerank::PageRank;
pub use queries::{AggregationQuery, JoinQuery, ScanQuery};
pub use tables::{GroupBy, RepartitionJoin, StarSchema};
pub use wordcount::WordCount;

use std::borrow::Cow;
use std::collections::{BTreeMap, HashMap};

use crate::storage::Payload;

/// Reduce-side merge of kernel aggregates: `(cell: u32, count: u32)`
/// 8-byte records from every mapper payload, element-wise summed and
/// re-serialized as sorted `(cell: u32, count: u64)` 12-byte rows.
/// Walks each payload's chunk sequence in place — no concatenated
/// staging buffer. Returns (output bytes, distinct cells).
pub(crate) fn reduce_aggregates(inputs: &[Payload]) -> (Vec<u8>, u64) {
    let mut merged = BTreeMap::<u32, u64>::new();
    for p in inputs {
        let mut cur = p.cursor();
        while cur.remaining() >= 8 {
            let cell = cur.read_u32_le().unwrap();
            let count = cur.read_u32_le().unwrap();
            *merged.entry(cell).or_default() += count as u64;
        }
    }
    let mut out = Vec::with_capacity(merged.len() * 12);
    for (cell, count) in &merged {
        out.extend_from_slice(&cell.to_le_bytes());
        out.extend_from_slice(&count.to_le_bytes());
    }
    let records = merged.len() as u64;
    (out, records)
}

/// Reduce-side count of raw `<u16 len><word><pad>` shuffle records
/// across mapper payloads, serialized as sorted `word\tcount\n`
/// lines. Keys are borrowed slices into the payloads; only records
/// straddling a chunk boundary are copied (into the `owned` side
/// map, merged before serialization). `pad` is the record overhead
/// beyond the 2-byte length (already clamped by callers). Returns
/// (output bytes, distinct words).
pub(crate) fn reduce_raw_word_counts(
    inputs: &[Payload],
    pad: usize,
) -> (Vec<u8>, u64) {
    let mut borrowed = HashMap::<&[u8], u64>::new();
    let mut owned = HashMap::<Vec<u8>, u64>::new();
    for p in inputs {
        let mut cur = p.cursor();
        while let Some(len) = cur.read_u16_le() {
            let Some(w) = cur.read(len as usize) else {
                break; // truncated trailing record
            };
            match w {
                Cow::Borrowed(w) => *borrowed.entry(w).or_default() += 1,
                Cow::Owned(v) => *owned.entry(v).or_default() += 1,
            }
            if !cur.skip(pad) {
                break;
            }
        }
    }
    let mut merged: Vec<(&[u8], u64)> =
        Vec::with_capacity(borrowed.len() + owned.len());
    for (w, c) in &borrowed {
        let extra = owned.get(*w).copied().unwrap_or(0);
        merged.push((*w, c + extra));
    }
    for (w, c) in &owned {
        if !borrowed.contains_key(w.as_slice()) {
            merged.push((w.as_slice(), *c));
        }
    }
    merged.sort_unstable_by(|a, b| a.0.cmp(b.0));
    let cap: usize = merged.iter().map(|(w, _)| w.len() + 8).sum();
    let mut out = Vec::with_capacity(cap);
    for (w, c) in &merged {
        out.extend_from_slice(w);
        out.push(b'\t');
        out.extend_from_slice(c.to_string().as_bytes());
        out.push(b'\n');
    }
    let records = merged.len() as u64;
    (out, records)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(words: &[&[u8]], pad: usize) -> Vec<u8> {
        let mut out = Vec::new();
        for w in words {
            out.extend_from_slice(&(w.len() as u16).to_le_bytes());
            out.extend_from_slice(w);
            out.resize(out.len() + pad, b'x');
        }
        out
    }

    #[test]
    fn raw_counts_merge_borrowed_and_straddling_records() {
        let pad = 3;
        let a = frame(&[b"cat", b"dog", b"cat"], pad);
        // Split `b` mid-record so "dog" straddles a chunk boundary and
        // takes the owned path — it must still merge with the
        // borrowed "dog" from `a`.
        let b = frame(&[b"dog", b"emu"], pad);
        let chunked = Payload::concat(&[
            Payload::real(b[..3].to_vec()),
            Payload::real(b[3..].to_vec()),
        ]);
        assert!(chunked.n_chunks() > 1);
        let (out, records) =
            reduce_raw_word_counts(&[Payload::real(a), chunked], pad);
        assert_eq!(records, 3);
        assert_eq!(out, b"cat\t2\ndog\t2\nemu\t1\n".to_vec());
    }

    #[test]
    fn aggregates_merge_across_chunked_inputs() {
        let rec = |cell: u32, count: u32| {
            let mut v = cell.to_le_bytes().to_vec();
            v.extend_from_slice(&count.to_le_bytes());
            v
        };
        let a = Payload::real([rec(5, 2), rec(1, 1)].concat());
        // Chunk boundary through the middle of a record.
        let b_bytes = [rec(5, 3), rec(9, 7)].concat();
        let b = Payload::concat(&[
            Payload::real(b_bytes[..6].to_vec()),
            Payload::real(b_bytes[6..].to_vec()),
        ]);
        let (out, records) = reduce_aggregates(&[a, b]);
        assert_eq!(records, 3);
        let rows: Vec<(u32, u64)> = out
            .chunks_exact(12)
            .map(|r| {
                (u32::from_le_bytes(r[0..4].try_into().unwrap()),
                 u64::from_le_bytes(r[4..12].try_into().unwrap()))
            })
            .collect();
        assert_eq!(rows, vec![(1, 1), (5, 5), (9, 7)]);
    }
}
