//! Zipfian text corpus generator + its analytic expectations.
//!
//! Real mode emits actual text (space-separated words drawn from a
//! fixed vocabulary with Zipf frequencies — the distribution that makes
//! map-side combining effective). Synthetic mode reuses the *same*
//! vocabulary and probabilities to compute exact expected byte counts,
//! so real and synthetic job runs agree (cross-checked in tests).

use crate::runtime::CombineScheme;
use crate::util::hash::token_hash;
use crate::util::rng::{Rng, Zipf};

#[derive(Clone, Debug)]
/// Zipf-distributed synthetic text corpus shared by the text
/// workloads; deterministic per (vocab, s, seed).
pub struct Corpus {
    pub vocab: Vec<Vec<u8>>,
    pub hashes: Vec<i32>,
    pub probs: Vec<f64>,
    zipf: Zipf,
    /// E[word length] under the rank distribution.
    pub mean_word_len: f64,
}

/// Synthesize the rank-th vocabulary word: compact, letters only,
/// shorter words for frequent ranks (like natural language).
pub fn rank_word(rank: u64) -> Vec<u8> {
    let len = 3 + (64 - (rank + 1).leading_zeros() as u64) / 2;
    let mut w = Vec::with_capacity(len as usize);
    let mut x = rank.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    for _ in 0..len {
        w.push(b'a' + (x % 26) as u8);
        x /= 26;
        if x == 0 {
            x = rank + 7;
        }
    }
    w
}

impl Corpus {
    pub fn new(vocab_size: usize, s: f64) -> Corpus {
        assert!(vocab_size > 1);
        let vocab: Vec<Vec<u8>> =
            (0..vocab_size as u64).map(rank_word).collect();
        let hashes: Vec<i32> =
            vocab.iter().map(|w| token_hash(w)).collect();
        let mut probs: Vec<f64> = (0..vocab_size)
            .map(|k| 1.0 / ((k + 1) as f64).powf(s))
            .collect();
        let z: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= z;
        }
        let mean_word_len = vocab
            .iter()
            .zip(&probs)
            .map(|(w, p)| w.len() as f64 * p)
            .sum();
        Corpus {
            vocab,
            hashes,
            probs,
            zipf: Zipf::new(vocab_size as u64, s),
            mean_word_len,
        }
    }

    /// Expected bytes per token in the text ("word " incl. separator).
    pub fn mean_token_bytes(&self) -> f64 {
        self.mean_word_len + 1.0
    }

    /// Expected tokens in `bytes` of generated text.
    pub fn expected_tokens(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.mean_token_bytes()).round() as u64
    }

    /// Generate exactly `bytes` of real text.
    pub fn generate(&self, bytes: u64, rng: &mut Rng) -> Vec<u8> {
        let mut out = Vec::with_capacity(bytes as usize);
        while (out.len() as u64) < bytes {
            let w = &self.vocab[self.zipf.sample(rng) as usize];
            out.extend_from_slice(w);
            out.push(b' ');
        }
        out.truncate(bytes as usize);
        // Blank out any truncated tail word so every token is in-vocab.
        if let Some(p) = out.iter().rposition(|b| *b == b' ') {
            for b in &mut out[p + 1..] {
                *b = b' ';
            }
        }
        out
    }

    /// A grep prefix guaranteed to exist in this vocabulary: the first
    /// `len` bytes of the rank-th word.
    pub fn prefix_of_rank(&self, rank: usize, len: usize) -> Vec<u8> {
        let w = &self.vocab[rank.min(self.vocab.len() - 1)];
        w[..len.min(w.len())].to_vec()
    }

    /// Probability-weighted share of intermediate bytes per reducer
    /// partition when emitting `<word,1>` records of
    /// `len(word) + overhead` bytes (the no-combiner data path).
    pub fn partition_record_fractions(
        &self,
        scheme: &CombineScheme,
        overhead: u64,
    ) -> Vec<f64> {
        let mut frac = vec![0.0; scheme.parts];
        let mut total = 0.0;
        for ((w, h), p) in self.vocab.iter().zip(&self.hashes).zip(&self.probs)
        {
            let bytes = (w.len() as u64 + overhead) as f64 * p;
            frac[scheme.part(*h)] += bytes;
            total += bytes;
        }
        for f in frac.iter_mut() {
            *f /= total;
        }
        frac
    }

    /// Expected `<word,1>` record bytes per token.
    pub fn mean_record_bytes(&self, overhead: u64) -> f64 {
        self.mean_word_len + overhead as f64
    }

    /// Distinct (part, bucket) cells the vocabulary occupies, per part —
    /// the size of a combined partition once the whole vocab has been
    /// seen (true for any input ≥ ~100 MiB at these vocab sizes).
    pub fn occupied_buckets_per_part(&self, scheme: &CombineScheme)
        -> Vec<u64>
    {
        let mut seen =
            vec![false; scheme.parts * scheme.buckets];
        let mut counts = vec![0u64; scheme.parts];
        for h in &self.hashes {
            let flat = scheme.flat(*h);
            if !seen[flat] {
                seen[flat] = true;
                counts[scheme.part(*h)] += 1;
            }
        }
        counts
    }

    /// Distinct vocabulary words per partition (reduce output sizing).
    pub fn vocab_per_part(&self, scheme: &CombineScheme) -> Vec<u64> {
        let mut counts = vec![0u64; scheme.parts];
        for h in &self.hashes {
            counts[scheme.part(*h)] += 1;
        }
        counts
    }

    /// Expected output bytes per partition for exact wordcount
    /// (`word<sep>count\n` ≈ len + `overhead`).
    pub fn output_bytes_per_part(
        &self,
        scheme: &CombineScheme,
        overhead: u64,
    ) -> Vec<u64> {
        let mut out = vec![0u64; scheme.parts];
        for (w, h) in self.vocab.iter().zip(&self.hashes) {
            out[scheme.part(*h)] += w.len() as u64 + overhead;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scheme() -> CombineScheme {
        CombineScheme { parts: 32, buckets: 1024, part_shift: 10 }
    }

    #[test]
    fn vocab_words_distinct() {
        let c = Corpus::new(5000, 1.07);
        let mut v = c.vocab.clone();
        v.sort();
        v.dedup();
        assert_eq!(v.len(), 5000, "vocabulary collision");
    }

    #[test]
    fn generates_exact_bytes_and_tokenizable() {
        let c = Corpus::new(1000, 1.07);
        let mut rng = Rng::new(42);
        let text = c.generate(10_000, &mut rng);
        assert_eq!(text.len(), 10_000);
        assert_eq!(*text.last().unwrap(), b' ');
        // Every word tokenized is in-vocab.
        for w in text.split(|b| *b == b' ').filter(|w| !w.is_empty()) {
            assert!(c.vocab.iter().any(|v| v == w),
                    "unknown word {:?}", String::from_utf8_lossy(w));
        }
    }

    #[test]
    fn token_count_matches_expectation() {
        let c = Corpus::new(2000, 1.07);
        let mut rng = Rng::new(7);
        let text = c.generate(200_000, &mut rng);
        let actual = text
            .split(|b| *b == b' ')
            .filter(|w| !w.is_empty())
            .count() as f64;
        let expected = c.expected_tokens(200_000) as f64;
        assert!((actual - expected).abs() / expected < 0.03,
                "actual {actual} vs expected {expected}");
    }

    #[test]
    fn zipf_head_dominates() {
        let c = Corpus::new(1000, 1.07);
        let mut rng = Rng::new(9);
        let text = c.generate(100_000, &mut rng);
        let top = &c.vocab[0];
        let count = text
            .split(|b| *b == b' ')
            .filter(|w| w == top)
            .count();
        // p_0 ≈ 1/H ≈ 0.11 at s=1.07, n=1000 → thousands of hits.
        assert!(count > 500, "head word count {count}");
    }

    #[test]
    fn partition_fractions_sum_to_one() {
        let c = Corpus::new(5000, 1.07);
        let f = c.partition_record_fractions(&scheme(), 28);
        let sum: f64 = f.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert!(f.iter().all(|x| *x > 0.0), "empty partition");
    }

    #[test]
    fn occupied_buckets_bounded_by_vocab() {
        let c = Corpus::new(5000, 1.07);
        let occ = c.occupied_buckets_per_part(&scheme());
        let total: u64 = occ.iter().sum();
        assert!(total <= 5000);
        assert!(total > 4000, "implausible collision rate: {total}");
        let vp = c.vocab_per_part(&scheme());
        assert_eq!(vp.iter().sum::<u64>(), 5000);
    }
}
