//! WordCount — the paper's primary benchmark (Figures 1, 4, 6; Table 1).
//!
//! Kernel path (Marvel): tokenize → hash → PJRT `wordcount_combine`
//! batches → per-partition bucket aggregates (tiny intermediate).
//! Raw path (Corral): emit one `<word,1>` record per token (intermediate
//! ≈ 5× input with JSON framing — Table 1's expansion).

use crate::mapreduce::{
    CombinerMode, MapOutput, PartitionPlan, ReduceOutput, SystemConfig,
    Workload,
};
use crate::runtime::{CombineScheme, RtEngine};
use crate::storage::Payload;
use crate::util::rng::Rng;

use super::corpus::Corpus;

/// The paper's flagship workload: tokenize, hash, and count words
/// of a Zipf-distributed corpus (Figures 4/6, Table 1).
pub struct WordCount {
    pub corpus: Corpus,
    scheme: CombineScheme,
}

impl WordCount {
    pub fn new(vocab: usize, zipf_s: f64, rt: &RtEngine) -> WordCount {
        WordCount { corpus: Corpus::new(vocab, zipf_s), scheme: rt.scheme() }
    }

    /// Tokenize a real chunk into (hash, len) pairs.
    fn tokenize<'a>(
        &self,
        text: &'a [u8],
    ) -> impl Iterator<Item = &'a [u8]> + 'a {
        text.split(|b| *b == b' ').filter(|w| !w.is_empty())
    }

    /// Run the PJRT combine over a hash stream; returns flattened R*B
    /// counts (padding masked out). Batch/mask staging buffers are
    /// borrowed from the engine and survive across calls; only the
    /// tail chunk ever rewrites the mask (full chunks use the all-ones
    /// invariant untouched).
    pub fn combine_hashes(
        &self,
        hashes: &[i32],
        rt: &mut RtEngine,
    ) -> Vec<f32> {
        let n = rt.batch_size();
        let mut acc = vec![0f32; self.scheme.parts * self.scheme.buckets];
        let mut scratch = rt.take_batch_scratch();
        for chunk in hashes.chunks(n) {
            scratch.batch[..chunk.len()].copy_from_slice(chunk);
            let partial = chunk.len() < n;
            if partial {
                scratch.mask[chunk.len()..].fill(0.0);
            }
            let out = rt
                .wordcount_batch(&scratch.batch, &scratch.mask)
                .expect("combine batch failed");
            if partial {
                scratch.mask[chunk.len()..].fill(1.0);
            }
            for (a, o) in acc.iter_mut().zip(&out) {
                *a += o;
            }
        }
        rt.put_batch_scratch(scratch);
        acc
    }

    /// Serialize reducer partition `part`'s slice of the combined
    /// counts as (flat cell: u32, count: u32) records. Scheme
    /// partitions fold onto reducer partitions through the plan's
    /// route (a hash plan reproduces the historical `p % parts`,
    /// exactly like the raw path's `part(h) % parts`), in ascending
    /// scheme-partition order either way.
    fn ser_aggregates(
        &self,
        counts: &[f32],
        part: usize,
        plan: &PartitionPlan,
    ) -> Vec<u8> {
        let b = self.scheme.buckets;
        // Upper bound: every bucket of every folded scheme partition
        // occupied — sized once, no growth reallocs on the hot path.
        let folded = (0..self.scheme.parts)
            .filter(|p| plan.route(*p as u64) == part)
            .count();
        let mut out = Vec::with_capacity(folded * b * 8);
        for p in (0..self.scheme.parts)
            .filter(|p| plan.route(*p as u64) == part)
        {
            for (bucket, c) in counts[p * b..(p + 1) * b].iter().enumerate() {
                if *c > 0.0 {
                    let flat = (p * b + bucket) as u32;
                    out.extend_from_slice(&flat.to_le_bytes());
                    out.extend_from_slice(&(*c as u32).to_le_bytes());
                }
            }
        }
        out
    }

    fn raw_record_overhead(&self, cfg: &SystemConfig) -> u64 {
        cfg.ser.record_overhead()
    }
}

/// Fold per-scheme-partition values onto `parts` reducer partitions
/// (index p contributes to p % parts) — the legacy hash folding rule,
/// equal to [`fold_parts_plan`] with a hash plan.
pub fn fold_parts<T: Copy + std::ops::AddAssign + Default>(
    vals: &[T],
    parts: usize,
) -> Vec<T> {
    fold_parts_plan(vals, &PartitionPlan::hash(parts))
}

/// Fold per-scheme-partition values onto reducer partitions through a
/// partition plan (index p contributes to `plan.route(p)`) — the
/// single folding rule every real and synthetic path must share, so
/// both modes stay byte-consistent under *any* partitioner.
pub fn fold_parts_plan<T: Copy + std::ops::AddAssign + Default>(
    vals: &[T],
    plan: &PartitionPlan,
) -> Vec<T> {
    let mut out = vec![T::default(); plan.parts()];
    for (p, v) in vals.iter().enumerate() {
        out[plan.route(p as u64)] += *v;
    }
    out
}

impl Workload for WordCount {
    fn name(&self) -> &str {
        "wordcount"
    }

    fn generate_input(&self, bytes: u64, materialize: bool, rng: &mut Rng)
        -> Payload
    {
        if materialize {
            Payload::real(self.corpus.generate(bytes, rng))
        } else {
            Payload::synthetic(bytes)
        }
    }

    fn map_split(
        &self,
        split: &Payload,
        plan: &PartitionPlan,
        cfg: &SystemConfig,
        rt: &mut RtEngine,
        _rng: &mut Rng,
    ) -> MapOutput {
        let parts = plan.parts();
        assert!(parts <= self.scheme.parts);
        match split.contiguous() {
            Some(text) => {
                let text: &[u8] = &text;
                let hashes: Vec<i32> = self
                    .tokenize(text)
                    .map(crate::util::hash::token_hash)
                    .collect();
                match cfg.combiner {
                    CombinerMode::Kernel => {
                        let counts = self.combine_hashes(&hashes, rt);
                        let partitions = (0..parts)
                            .map(|j| {
                                Payload::real(
                                    self.ser_aggregates(&counts, j, plan),
                                )
                            })
                            .collect();
                        MapOutput {
                            partitions,
                            records: hashes.len() as u64,
                        }
                    }
                    CombinerMode::None => {
                        // Framing: u16 len + word + pad. The pad is the
                        // record overhead minus the 2-byte length we
                        // already wrote — clamped so compact formats
                        // (overhead < 2) can't underflow.
                        let ov = self.raw_record_overhead(cfg) as usize;
                        let pad = ov.saturating_sub(2);
                        let mut parts_bytes: Vec<Vec<u8>> =
                            vec![Vec::new(); parts];
                        for w in self.tokenize(text) {
                            let h = crate::util::hash::token_hash(w);
                            let j = plan.route(self.scheme.part(h) as u64);
                            let buf = &mut parts_bytes[j];
                            buf.extend_from_slice(
                                &(w.len() as u16).to_le_bytes(),
                            );
                            buf.extend_from_slice(w);
                            buf.resize(buf.len() + pad, b'x');
                        }
                        MapOutput {
                            partitions: parts_bytes
                                .into_iter()
                                .map(Payload::real)
                                .collect(),
                            records: hashes.len() as u64,
                        }
                    }
                }
            }
            None => {
                // Synthetic: exact expectations from the corpus model.
                let tokens = self.corpus.expected_tokens(split.len());
                match cfg.combiner {
                    CombinerMode::Kernel => {
                        let occ = fold_parts_plan(
                            &self.corpus
                                .occupied_buckets_per_part(&self.scheme),
                            plan,
                        );
                        let partitions = (0..parts)
                            .map(|j| Payload::synthetic(occ[j] * 8))
                            .collect();
                        MapOutput { partitions, records: tokens }
                    }
                    CombinerMode::None => {
                        let ov = self.raw_record_overhead(cfg);
                        let frac = fold_parts_plan(
                            &self
                                .corpus
                                .partition_record_fractions(&self.scheme, ov),
                            plan,
                        );
                        let total = tokens as f64
                            * self.corpus.mean_record_bytes(ov);
                        let partitions = (0..parts)
                            .map(|j| {
                                Payload::synthetic(
                                    (total * frac[j]).round() as u64
                                )
                            })
                            .collect();
                        MapOutput { partitions, records: tokens }
                    }
                }
            }
        }
    }

    fn reduce_partition(
        &self,
        part: usize,
        parts: usize,
        inputs: &[Payload],
        cfg: &SystemConfig,
        _rt: &mut RtEngine,
    ) -> ReduceOutput {
        if inputs.iter().all(|p| p.is_real()) {
            match cfg.combiner {
                CombinerMode::Kernel => {
                    // Merge (bucket, count) aggregates element-wise,
                    // chunk-aware (shared with grep).
                    let (out, records) =
                        crate::workloads::reduce_aggregates(inputs);
                    ReduceOutput { output: Payload::real(out), records }
                }
                CombinerMode::None => {
                    // Count raw records per word with borrowed-slice
                    // keying (shared with grep).
                    let pad = (self.raw_record_overhead(cfg) as usize)
                        .saturating_sub(2);
                    let (out, records) =
                        crate::workloads::reduce_raw_word_counts(
                            inputs, pad,
                        );
                    ReduceOutput { output: Payload::real(out), records }
                }
            }
        } else {
            // Synthetic: fold scheme partitions onto the reducer count
            // through the same plan the map side routed with (plans are
            // scale-free, so the rebuild here is exact).
            let plan = PartitionPlan::build(&cfg.partition, self, 0, parts, 0);
            let records = fold_parts_plan(
                &self.corpus.vocab_per_part(&self.scheme),
                &plan,
            )[part];
            let bytes = match cfg.combiner {
                CombinerMode::Kernel => {
                    fold_parts_plan(
                        &self.corpus.occupied_buckets_per_part(&self.scheme),
                        &plan,
                    )[part] * 12
                }
                CombinerMode::None => {
                    fold_parts_plan(
                        &self.corpus.output_bytes_per_part(&self.scheme, 8),
                        &plan,
                    )[part]
                }
            };
            ReduceOutput { output: Payload::synthetic(bytes), records }
        }
    }

    /// Keys routed to reducers are scheme-partition indices.
    fn key_domain(&self) -> u64 {
        self.scheme.parts as u64
    }

    /// Per-container compute model: the paper's Hadoop-on-OpenWhisk
    /// runtime is a JVM streaming stack at ≈35 MB/s per slot (classic
    /// Hadoop wordcount figures; EXPERIMENTS.md §Calibration). Our
    /// Rust+PJRT data plane measures >100 MB/s — reported separately in
    /// §Perf — but job-time modeling uses the paper-era rate so the
    /// figures compare like for like.
    fn map_rate(&self) -> f64 {
        35e6
    }

    /// Reduce merges pre-serialized records — memcpy-class work, so the
    /// phase is storage-I/O-bound (the paper's premise): ≈400 MB/s.
    fn reduce_rate(&self) -> f64 {
        400e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::SystemConfig;

    fn setup() -> (RtEngine, WordCount) {
        let rt = RtEngine::load(None).unwrap();
        let wc = WordCount::new(2000, 1.07, &rt);
        (rt, wc)
    }

    #[test]
    fn kernel_combine_counts_all_tokens() {
        let (mut rt, wc) = setup();
        let mut rng = Rng::new(3);
        let text = wc.corpus.generate(100_000, &mut rng);
        let tokens = wc.tokenize(&text).count() as u64;
        let cfg = SystemConfig::marvel_igfs();
        let mo = wc.map_split(&Payload::real(text), &PartitionPlan::hash(32), &cfg,
                              &mut rt, &mut rng);
        assert_eq!(mo.records, tokens);
        // Total counted mass = tokens.
        let total: u64 = mo
            .partitions
            .iter()
            .map(|p| {
                p.bytes()
                    .unwrap()
                    .chunks_exact(8)
                    .map(|r| {
                        u32::from_le_bytes(r[4..8].try_into().unwrap()) as u64
                    })
                    .sum::<u64>()
            })
            .sum();
        assert_eq!(total, tokens);
    }

    #[test]
    fn combiner_shrinks_intermediate() {
        let (mut rt, wc) = setup();
        let mut rng = Rng::new(5);
        let text = wc.corpus.generate(200_000, &mut rng);
        let plan = PartitionPlan::hash(32);
        let k = wc.map_split(&Payload::real(text.clone()), &plan,
                             &SystemConfig::marvel_igfs(), &mut rt, &mut rng);
        let raw = wc.map_split(&Payload::real(text), &plan,
                               &SystemConfig::corral_lambda(), &mut rt,
                               &mut rng);
        assert!(k.total_bytes() * 4 < raw.total_bytes(),
                "kernel {} vs raw {}", k.total_bytes(), raw.total_bytes());
        // Raw JSON intermediate expands ≈ 4–6× over the input text
        // (Table 1's WordCount expansion).
        let exp = raw.total_bytes() as f64 / 200_000.0;
        assert!(exp > 3.0 && exp < 7.0, "expansion {exp}");
    }

    #[test]
    fn reduce_totals_match_map_totals() {
        let (mut rt, wc) = setup();
        let mut rng = Rng::new(7);
        let cfg = SystemConfig::marvel_igfs();
        let text = wc.corpus.generate(50_000, &mut rng);
        let tokens = wc.tokenize(&text).count() as u64;
        let mo = wc.map_split(&Payload::real(text), &PartitionPlan::hash(32), &cfg,
                              &mut rt, &mut rng);
        let mut grand = 0u64;
        for (j, p) in mo.partitions.iter().enumerate() {
            let ro = wc.reduce_partition(j, 32, &[p.clone()], &cfg, &mut rt);
            grand += ro
                .output
                .bytes()
                .unwrap()
                .chunks_exact(12)
                .map(|r| {
                    u64::from_le_bytes(r[4..12].try_into().unwrap())
                })
                .sum::<u64>();
        }
        assert_eq!(grand, tokens);
    }

    #[test]
    fn synthetic_matches_real_sizes_approximately() {
        let (mut rt, wc) = setup();
        let mut rng = Rng::new(11);
        let cfg = SystemConfig::corral_lambda();
        let bytes = 400_000u64;
        let real_text = wc.corpus.generate(bytes, &mut rng);
        let plan = PartitionPlan::hash(32);
        let real = wc.map_split(&Payload::real(real_text), &plan, &cfg,
                                &mut rt, &mut rng);
        let synth = wc.map_split(&Payload::synthetic(bytes), &plan, &cfg,
                                 &mut rt, &mut rng);
        let (r, s) = (real.total_bytes() as f64, synth.total_bytes() as f64);
        assert!((r - s).abs() / r < 0.05,
                "real {r} vs synthetic {s} intermediate bytes");
        let rel_rec = (real.records as f64 - synth.records as f64).abs()
            / real.records as f64;
        assert!(rel_rec < 0.05, "records diverge {rel_rec}");
    }
}
