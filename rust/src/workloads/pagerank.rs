//! PageRank — the iterative workload for multi-stage stateful
//! pipelines (multi-round rank propagation, Cloudburst/Faasm-style
//! chained functions over cached state).
//!
//! Record format: 12-byte LE rows `(node: u32, rank: u64)` — exactly
//! the kernel WordCount reducer's output rows, so a wordcount stage
//! seeds the rank vector (cell → count) and every PageRank round
//! chains directly on the previous round's output. Rounds therefore
//! need no adjacency data in flight: a node's out-degree and neighbor
//! ids derive deterministically from `mix64(node)` over the fixed
//! [`NODE_SPACE`] (the combine scheme's parts × buckets flat cell
//! space), the classic synthetic-graph trick.
//!
//! Ranks are integer fixed-point and every round conserves total mass
//! exactly: a node sends `floor(floor(r·85/100)/deg)` to each of its
//! `deg` neighbors and keeps the remainder, so `Σ ranks` is invariant
//! across rounds — pinned by the unit tests below and exercised
//! end-to-end by `rust/tests/pipeline_stateful.rs`.

use std::collections::BTreeMap;

use crate::mapreduce::{
    MapOutput, PartitionPlan, ReduceOutput, SystemConfig, Workload,
};
use crate::runtime::RtEngine;
use crate::storage::Payload;
use crate::util::hash::mix64;
use crate::util::rng::Rng;

/// Node id space: the combine scheme's parts × buckets flat cell space
/// (32 × 1024), so wordcount cells are valid graph nodes.
pub const NODE_SPACE: u64 = 32 * 1024;

/// Bytes per `(node: u32, rank: u64)` row.
pub const ROW: usize = 12;

const DEG_SALT: u64 = 0xA5A5_5A5A_C0FF_EE00;
const NBR_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

#[derive(Clone, Debug, Default)]
/// Iterative PageRank over a synthetic-deterministic adjacency,
/// format-compatible with kernel wordcount rows (12-byte records).
pub struct PageRank;

impl PageRank {
    pub fn new() -> PageRank {
        PageRank
    }

    /// Deterministic synthetic out-degree of `node`: 1..=4.
    pub fn degree(node: u32) -> u64 {
        1 + (mix64(node as u64 ^ DEG_SALT) & 3)
    }

    /// Deterministic i-th out-neighbor of `node` (i < degree).
    pub fn neighbor(node: u32, i: u64) -> u32 {
        let h = mix64(((node as u64) << 3) ^ (i + 1).wrapping_mul(NBR_SALT));
        (h % NODE_SPACE) as u32
    }

    /// Reducer partition owning `node`'s contributions (the routed key
    /// is `mix64(node)`; a hash plan reproduces the historical
    /// `mix64(node) % parts`).
    fn partition(node: u32, plan: &PartitionPlan) -> usize {
        plan.route(mix64(node as u64))
    }

    fn push_row(buf: &mut Vec<u8>, node: u32, val: u64) {
        buf.extend_from_slice(&node.to_le_bytes());
        buf.extend_from_slice(&val.to_le_bytes());
    }
}

impl Workload for PageRank {
    fn name(&self) -> &str {
        "pagerank"
    }

    /// Standalone seeding: whole 12-byte rank rows, zero-padded tail
    /// (the parser ignores a trailing run shorter than one row).
    fn generate_input(&self, bytes: u64, materialize: bool, rng: &mut Rng)
        -> Payload
    {
        if !materialize {
            return Payload::synthetic(bytes);
        }
        let rows = (bytes as usize) / ROW;
        let mut out = Vec::with_capacity(bytes as usize);
        for _ in 0..rows {
            let node = (rng.next_u64() % NODE_SPACE) as u32;
            let rank = 1 + rng.next_u64() % 1000;
            Self::push_row(&mut out, node, rank);
        }
        out.resize(bytes as usize, 0);
        Payload::real(out)
    }

    fn map_split(
        &self,
        split: &Payload,
        plan: &PartitionPlan,
        _cfg: &SystemConfig,
        _rt: &mut RtEngine,
        _rng: &mut Rng,
    ) -> MapOutput {
        let parts = plan.parts();
        match split.contiguous() {
            Some(rows) => {
                let rows: &[u8] = &rows;
                let mut parts_bytes: Vec<Vec<u8>> =
                    vec![Vec::new(); parts];
                let mut records = 0u64;
                for row in rows.chunks_exact(ROW) {
                    let node =
                        u32::from_le_bytes(row[0..4].try_into().unwrap());
                    let rank =
                        u64::from_le_bytes(row[4..12].try_into().unwrap());
                    if rank == 0 {
                        continue;
                    }
                    let deg = Self::degree(node);
                    // Integer damping: send floor(r·85/100)/deg per
                    // neighbor, keep the remainder → mass conserved
                    // exactly (kept + contrib·deg == rank).
                    let contrib =
                        ((rank as u128 * 85 / 100) as u64) / deg;
                    let kept = rank - contrib * deg;
                    if kept > 0 {
                        let j = Self::partition(node, plan);
                        Self::push_row(&mut parts_bytes[j], node, kept);
                        records += 1;
                    }
                    if contrib > 0 {
                        for i in 0..deg {
                            let nb = Self::neighbor(node, i);
                            let j = Self::partition(nb, plan);
                            Self::push_row(&mut parts_bytes[j], nb, contrib);
                            records += 1;
                        }
                    }
                }
                MapOutput {
                    partitions: parts_bytes
                        .into_iter()
                        .map(Payload::real)
                        .collect(),
                    records,
                }
            }
            None => {
                // Synthetic: each input row fans out to ≤ deg+1 rows;
                // exact-expectation accounting with E[deg] = 2.5.
                let rows = split.len() / ROW as u64;
                let out_rows = rows * 7 / 2;
                let per = out_rows / parts as u64;
                let rem = (out_rows % parts as u64) as usize;
                let partitions = (0..parts)
                    .map(|j| {
                        let r = per + u64::from(j < rem);
                        Payload::synthetic(r * ROW as u64)
                    })
                    .collect();
                MapOutput { partitions, records: out_rows }
            }
        }
    }

    fn reduce_partition(
        &self,
        _part: usize,
        parts: usize,
        inputs: &[Payload],
        _cfg: &SystemConfig,
        _rt: &mut RtEngine,
    ) -> ReduceOutput {
        if inputs.iter().all(|p| p.is_real()) {
            // Merge-sum contributions per node, chunk-aware, output
            // sorted rows — the same 12-byte format the next round's
            // map parses.
            let mut merged = BTreeMap::<u32, u64>::new();
            for p in inputs {
                let mut cur = p.cursor();
                while cur.remaining() >= ROW {
                    let node = cur.read_u32_le().unwrap();
                    let val = cur.read_u64_le().unwrap();
                    *merged.entry(node).or_default() += val;
                }
            }
            let mut out = Vec::with_capacity(merged.len() * ROW);
            let mut records = 0u64;
            for (node, val) in &merged {
                if *val == 0 {
                    continue;
                }
                Self::push_row(&mut out, *node, *val);
                records += 1;
            }
            ReduceOutput { output: Payload::real(out), records }
        } else {
            // Synthetic: distinct nodes bounded by the partition's
            // share of the id space and by the rows that arrived.
            let rows: u64 =
                inputs.iter().map(|p| p.len() / ROW as u64).sum();
            let cap = NODE_SPACE / parts.max(1) as u64 + 1;
            let distinct = rows.min(cap);
            ReduceOutput {
                output: Payload::synthetic(distinct * ROW as u64),
                records: distinct,
            }
        }
    }

    /// Rank propagation is parse + hash + emit — memory-bound
    /// streaming, modeled well above the JVM wordcount rate.
    fn map_rate(&self) -> f64 {
        150e6
    }

    /// Reduce is a merge of pre-sorted aggregate rows.
    fn reduce_rate(&self) -> f64 {
        400e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::SystemConfig;

    fn rows_of(p: &Payload) -> Vec<(u32, u64)> {
        let b = p.gather().unwrap();
        b.chunks_exact(ROW)
            .map(|r| {
                (u32::from_le_bytes(r[0..4].try_into().unwrap()),
                 u64::from_le_bytes(r[4..12].try_into().unwrap()))
            })
            .collect()
    }

    fn seed_rows(n: usize) -> (Payload, u64) {
        let mut buf = Vec::new();
        let mut mass = 0u64;
        for i in 0..n {
            let node = ((i as u64 * 37) % NODE_SPACE) as u32;
            let rank = 10 + (i as u64 % 90);
            mass += rank;
            PageRank::push_row(&mut buf, node, rank);
        }
        (Payload::real(buf), mass)
    }

    #[test]
    fn adjacency_is_deterministic_and_in_range() {
        for node in [0u32, 1, 4095, 32767] {
            let deg = PageRank::degree(node);
            assert!((1..=4).contains(&deg), "deg {deg}");
            assert_eq!(deg, PageRank::degree(node));
            for i in 0..deg {
                let nb = PageRank::neighbor(node, i);
                assert!((nb as u64) < NODE_SPACE);
                assert_eq!(nb, PageRank::neighbor(node, i));
            }
        }
    }

    #[test]
    fn map_conserves_total_mass() {
        let mut rt = RtEngine::load(None).unwrap();
        let pr = PageRank::new();
        let (input, mass) = seed_rows(500);
        let cfg = SystemConfig::marvel_igfs();
        let mo = pr.map_split(&input, &PartitionPlan::hash(8), &cfg, &mut rt,
                              &mut Rng::new(1));
        let out_mass: u64 = mo
            .partitions
            .iter()
            .flat_map(|p| rows_of(p))
            .map(|(_, v)| v)
            .sum();
        assert_eq!(out_mass, mass, "damping must conserve rank mass");
    }

    #[test]
    fn rounds_chain_on_reduce_output_format() {
        // map → reduce → map again: the reduce output must parse as a
        // valid next-round input and keep conserving mass.
        let mut rt = RtEngine::load(None).unwrap();
        let pr = PageRank::new();
        let (input, mass) = seed_rows(300);
        let cfg = SystemConfig::marvel_igfs();
        let parts = 4;
        let plan = PartitionPlan::hash(parts);
        let mo = pr.map_split(&input, &plan, &cfg, &mut rt,
                              &mut Rng::new(2));
        let mut round1 = Vec::new();
        for j in 0..parts {
            let ro = pr.reduce_partition(
                j, parts, &[mo.partitions[j].clone()], &cfg, &mut rt);
            // Sorted, deduplicated rows.
            let rows = rows_of(&ro.output);
            assert!(rows.windows(2).all(|w| w[0].0 < w[1].0));
            round1.push(ro.output);
        }
        let r1_mass: u64 = round1
            .iter()
            .flat_map(|p| rows_of(p))
            .map(|(_, v)| v)
            .sum();
        assert_eq!(r1_mass, mass);
        let next = Payload::concat(&round1);
        let mo2 = pr.map_split(&next, &plan, &cfg, &mut rt,
                               &mut Rng::new(3));
        let m2: u64 = mo2
            .partitions
            .iter()
            .flat_map(|p| rows_of(p))
            .map(|(_, v)| v)
            .sum();
        assert_eq!(m2, mass);
    }

    #[test]
    fn generate_input_exact_bytes_and_parseable() {
        let pr = PageRank::new();
        let mut rng = Rng::new(7);
        for bytes in [0u64, 5, 1200, 1207] {
            let p = pr.generate_input(bytes, true, &mut rng);
            assert_eq!(p.len(), bytes);
        }
        assert_eq!(pr.generate_input(999, false, &mut rng).len(), 999);
    }

    #[test]
    fn synthetic_accounting_deterministic() {
        let mut rt = RtEngine::load(None).unwrap();
        let pr = PageRank::new();
        let cfg = SystemConfig::marvel_igfs();
        let plan = PartitionPlan::hash(8);
        let a = pr.map_split(&Payload::synthetic(120_000), &plan, &cfg,
                             &mut rt, &mut Rng::new(1));
        let b = pr.map_split(&Payload::synthetic(120_000), &plan, &cfg,
                             &mut rt, &mut Rng::new(2));
        assert_eq!(a.total_bytes(), b.total_bytes());
        assert_eq!(a.records, b.records);
        let ro = pr.reduce_partition(0, 8, &a.partitions, &cfg, &mut rt);
        assert!(!ro.output.is_empty());
        assert!(!ro.output.is_real());
    }
}
