//! Grep — the paper's second benchmark (Figure 5). Mappers match every
//! word against a pattern; reducers count the matching words. The
//! kernel path runs the `grep_combine` artifact (match + partitioned
//! histogram fused in one PJRT execution).

use crate::mapreduce::{
    CombinerMode, MapOutput, PartitionPlan, ReduceOutput, SystemConfig,
    Workload,
};
use crate::runtime::{oracle, CombineScheme, RtEngine};
use crate::storage::Payload;
use crate::util::rng::Rng;

use super::corpus::Corpus;

/// Distributed grep: emit lines whose first token starts with a
/// prefix (Figure 5) — low selectivity, shuffle-light.
pub struct Grep {
    pub corpus: Corpus,
    scheme: CombineScheme,
    /// Byte prefix the pattern matches (e.g. b"ma").
    pub prefix: Vec<u8>,
    word_width: usize,
    /// Σ p_w over matching vocab words (analytic match rate).
    match_prob: f64,
    /// Per-partition matching vocab (synthetic reduce sizing).
    matching_per_part: Vec<u64>,
    matching_occupied_per_part: Vec<u64>,
}

impl Grep {
    pub fn new(vocab: usize, zipf_s: f64, prefix: &[u8], rt: &RtEngine)
        -> Grep
    {
        let corpus = Corpus::new(vocab, zipf_s);
        let scheme = rt.scheme();
        let mut match_prob = 0.0;
        let mut matching_per_part = vec![0u64; scheme.parts];
        let mut seen = vec![false; scheme.parts * scheme.buckets];
        let mut matching_occupied_per_part = vec![0u64; scheme.parts];
        for ((w, h), p) in
            corpus.vocab.iter().zip(&corpus.hashes).zip(&corpus.probs)
        {
            if w.starts_with(prefix) {
                match_prob += p;
                matching_per_part[scheme.part(*h)] += 1;
                let flat = scheme.flat(*h);
                if !seen[flat] {
                    seen[flat] = true;
                    matching_occupied_per_part[scheme.part(*h)] += 1;
                }
            }
        }
        Grep {
            corpus,
            scheme,
            prefix: prefix.to_vec(),
            word_width: rt.manifest.word_width,
            match_prob,
            matching_per_part,
            matching_occupied_per_part,
        }
    }

    pub fn match_prob(&self) -> f64 {
        self.match_prob
    }

    /// The (W,) i32 pattern literal: prefix bytes then WILD_REST.
    pub fn pattern(&self) -> Vec<i32> {
        let w = self.word_width;
        let mut p = vec![oracle::WILD_REST; w];
        for (i, b) in self.prefix.iter().take(w).enumerate() {
            p[i] = *b as i32;
        }
        p
    }

    fn pad_tokens(&self, words: &[&[u8]]) -> (Vec<i32>, Vec<i32>) {
        let w = self.word_width;
        let mut toks = vec![0i32; words.len() * w];
        let mut hashes = Vec::with_capacity(words.len());
        for (i, word) in words.iter().enumerate() {
            for (k, b) in word.iter().take(w).enumerate() {
                toks[i * w + k] = *b as i32;
            }
            hashes.push(crate::util::hash::token_hash(word));
        }
        (toks, hashes)
    }

    /// Kernel grep over a real chunk: (R*B match counts, total matches).
    pub fn combine_text(&self, text: &[u8], rt: &mut RtEngine)
        -> (Vec<f32>, u64, u64)
    {
        let words: Vec<&[u8]> = text
            .split(|b| *b == b' ')
            .filter(|w| !w.is_empty())
            .collect();
        let n = rt.batch_size();
        let w = self.word_width;
        let pattern = self.pattern();
        let mut acc = vec![0f32; self.scheme.parts * self.scheme.buckets];
        let mut total = 0f64;
        for chunk in words.chunks(n) {
            let (mut toks, mut hashes) = self.pad_tokens(chunk);
            toks.resize(n * w, 0);
            hashes.resize(n, 0);
            let mut mask = vec![0f32; n];
            for m in mask.iter_mut().take(chunk.len()) {
                *m = 1.0;
            }
            let (counts, t) = rt
                .grep_batch(&toks, &hashes, &mask, &pattern)
                .expect("grep batch failed");
            for (a, c) in acc.iter_mut().zip(&counts) {
                *a += c;
            }
            total += t as f64;
        }
        (acc, total as u64, words.len() as u64)
    }
}

impl Workload for Grep {
    fn name(&self) -> &str {
        "grep"
    }

    fn generate_input(&self, bytes: u64, materialize: bool, rng: &mut Rng)
        -> Payload
    {
        if materialize {
            Payload::real(self.corpus.generate(bytes, rng))
        } else {
            Payload::synthetic(bytes)
        }
    }

    fn map_split(
        &self,
        split: &Payload,
        plan: &PartitionPlan,
        cfg: &SystemConfig,
        rt: &mut RtEngine,
        _rng: &mut Rng,
    ) -> MapOutput {
        let parts = plan.parts();
        match split.contiguous() {
            Some(text) => match cfg.combiner {
                CombinerMode::Kernel => {
                    let (counts, _, tokens) = self.combine_text(&text, rt);
                    let b = self.scheme.buckets;
                    // Scheme partitions fold onto reducers through the
                    // plan's route (hash plan = legacy `p % parts`),
                    // ascending p either way.
                    let partitions = (0..parts)
                        .map(|j| {
                            let mut out = Vec::new();
                            for p in (0..self.scheme.parts)
                                .filter(|p| plan.route(*p as u64) == j)
                            {
                                for (bucket, c) in counts[p * b..(p + 1) * b]
                                    .iter()
                                    .enumerate()
                                {
                                    if *c > 0.0 {
                                        let flat = (p * b + bucket) as u32;
                                        out.extend_from_slice(
                                            &flat.to_le_bytes(),
                                        );
                                        out.extend_from_slice(
                                            &(*c as u32).to_le_bytes(),
                                        );
                                    }
                                }
                            }
                            Payload::real(out)
                        })
                        .collect();
                    MapOutput { partitions, records: tokens }
                }
                CombinerMode::None => {
                    // Emit each *matching* word as a raw record (pad
                    // clamped: overhead < 2 must not underflow).
                    let pad = (cfg.ser.record_overhead() as usize)
                        .saturating_sub(2);
                    let mut parts_bytes: Vec<Vec<u8>> = vec![Vec::new(); parts];
                    let mut tokens = 0u64;
                    for w in
                        text.split(|b| *b == b' ').filter(|w| !w.is_empty())
                    {
                        tokens += 1;
                        if !w.starts_with(&self.prefix[..]) {
                            continue;
                        }
                        let h = crate::util::hash::token_hash(w);
                        let j = plan.route(self.scheme.part(h) as u64);
                        let buf = &mut parts_bytes[j];
                        buf.extend_from_slice(&(w.len() as u16).to_le_bytes());
                        buf.extend_from_slice(w);
                        buf.resize(buf.len() + pad, b'x');
                    }
                    MapOutput {
                        partitions: parts_bytes
                            .into_iter()
                            .map(Payload::real)
                            .collect(),
                        records: tokens,
                    }
                }
            },
            None => {
                let tokens = self.corpus.expected_tokens(split.len());
                match cfg.combiner {
                    CombinerMode::Kernel => {
                        let occ =
                            crate::workloads::wordcount::fold_parts_plan(
                                &self.matching_occupied_per_part,
                                plan,
                            );
                        MapOutput {
                            partitions: (0..parts)
                                .map(|j| Payload::synthetic(occ[j] * 8))
                                .collect(),
                            records: tokens,
                        }
                    }
                    CombinerMode::None => {
                        let ov = cfg.ser.record_overhead();
                        // Matching tokens only, spread by record mass of
                        // the matching vocabulary.
                        let mut mass = vec![0.0f64; self.scheme.parts];
                        let mut total_mass = 0.0;
                        for ((w, h), p) in self
                            .corpus
                            .vocab
                            .iter()
                            .zip(&self.corpus.hashes)
                            .zip(&self.corpus.probs)
                        {
                            if w.starts_with(&self.prefix[..]) {
                                let m = (w.len() as u64 + ov) as f64 * p;
                                mass[self.scheme.part(*h)] += m;
                                total_mass += m;
                            }
                        }
                        let mass =
                            crate::workloads::wordcount::fold_parts_plan(
                                &mass, plan,
                            );
                        let partitions = (0..parts)
                            .map(|j| {
                                Payload::synthetic(
                                    (tokens as f64 * total_mass
                                        * (mass[j] / total_mass.max(1e-30)))
                                        .round()
                                        as u64,
                                )
                            })
                            .collect();
                        MapOutput { partitions, records: tokens }
                    }
                }
            }
        }
    }

    fn reduce_partition(
        &self,
        part: usize,
        parts: usize,
        inputs: &[Payload],
        cfg: &SystemConfig,
        _rt: &mut RtEngine,
    ) -> ReduceOutput {
        if inputs.iter().all(|p| p.is_real()) {
            match cfg.combiner {
                CombinerMode::Kernel => {
                    let (out, records) =
                        crate::workloads::reduce_aggregates(inputs);
                    ReduceOutput { output: Payload::real(out), records }
                }
                CombinerMode::None => {
                    // Borrowed-slice keying, chunk-aware (shared with
                    // wordcount).
                    let pad = (cfg.ser.record_overhead() as usize)
                        .saturating_sub(2);
                    let (out, records) =
                        crate::workloads::reduce_raw_word_counts(
                            inputs, pad,
                        );
                    ReduceOutput { output: Payload::real(out), records }
                }
            }
        } else {
            // Rebuild the (scale-free) plan the map side routed with so
            // the synthetic fold lands on the same reducers.
            let plan = PartitionPlan::build(&cfg.partition, self, 0, parts, 0);
            let records = crate::workloads::wordcount::fold_parts_plan(
                &self.matching_per_part, &plan,
            )[part];
            let bytes = match cfg.combiner {
                CombinerMode::Kernel => {
                    crate::workloads::wordcount::fold_parts_plan(
                        &self.matching_occupied_per_part, &plan,
                    )[part] * 12
                }
                CombinerMode::None => records * 14,
            };
            ReduceOutput { output: Payload::synthetic(bytes), records }
        }
    }

    /// Keys routed to reducers are scheme-partition indices.
    fn key_domain(&self) -> u64 {
        self.scheme.parts as u64
    }

    fn map_rate(&self) -> f64 {
        35e6
    }

    fn reduce_rate(&self) -> f64 {
        400e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::SystemConfig;

    fn setup() -> (RtEngine, Grep) {
        let rt = RtEngine::load(None).unwrap();
        // Prefix drawn from the vocabulary so the pattern is live.
        let prefix = crate::workloads::Corpus::new(2000, 1.07)
            .prefix_of_rank(3, 2);
        let g = Grep::new(2000, 1.07, &prefix, &rt);
        (rt, g)
    }

    #[test]
    fn kernel_matches_equal_scalar_scan() {
        let (mut rt, g) = setup();
        let mut rng = Rng::new(3);
        let text = g.corpus.generate(80_000, &mut rng);
        let expected = text
            .split(|b| *b == b' ')
            .filter(|w| !w.is_empty() && w.starts_with(&g.prefix[..]))
            .count() as u64;
        let (_, total, _) = g.combine_text(&text, &mut rt);
        assert_eq!(total, expected);
    }

    #[test]
    fn match_rate_tracks_analytic_probability() {
        let (mut rt, g) = setup();
        let mut rng = Rng::new(5);
        let text = g.corpus.generate(400_000, &mut rng);
        let (_, total, tokens) = g.combine_text(&text, &mut rt);
        let rate = total as f64 / tokens as f64;
        let p = g.match_prob();
        assert!(p > 0.0, "degenerate pattern");
        assert!((rate - p).abs() < 0.02, "rate {rate} vs p {p}");
    }

    #[test]
    fn raw_intermediate_only_matches() {
        let (mut rt, g) = setup();
        let mut rng = Rng::new(7);
        let text = g.corpus.generate(100_000, &mut rng);
        let cfg = SystemConfig::corral_lambda();
        let mo = g.map_split(&Payload::real(text), &PartitionPlan::hash(32),
                             &cfg, &mut rt, &mut rng);
        // Grep intermediate must be far smaller than wordcount's
        // all-tokens intermediate.
        assert!(mo.total_bytes() < 100_000 * 3,
                "grep intermediate too large: {}", mo.total_bytes());
    }

    #[test]
    fn synthetic_real_consistency() {
        let (mut rt, g) = setup();
        let mut rng = Rng::new(11);
        let cfg = SystemConfig::marvel_igfs();
        let bytes = 400_000u64;
        let plan = PartitionPlan::hash(32);
        let real = g.map_split(
            &Payload::real(g.corpus.generate(bytes, &mut rng)),
            &plan, &cfg, &mut rt, &mut rng,
        );
        let synth = g.map_split(&Payload::synthetic(bytes), &plan, &cfg,
                                &mut rt, &mut rng);
        let (r, s) = (real.total_bytes() as f64, synth.total_bytes() as f64);
        // Kernel aggregates: synthetic assumes full matching-vocab
        // coverage; real sees most of it at this size.
        assert!(s >= r && (s - r) / s < 0.35, "real {r} synth {s}");
    }
}
